"""Dependency-free fallback linter (scripts/lint.sh uses it when ruff is
not installed, e.g. in the hermetic dev container).

Approximates the highest-signal subset of the committed ruff config
(pyproject.toml): F401 unused imports, E711/E712 comparisons to
None/True/False, E722 bare except, plus a full syntax pass via ast.parse.
It also carries the highest-signal subset of sproutlint's SPL003
(DESIGN.md §11): bare `hash()` (PYTHONHASHSEED-dependent) and for-loop /
comprehension iteration over unsorted sets — so the hermetic container's
gate covers the nondeterminism rule even where the full analyzer's jax
import is unavailable. It intentionally under-approximates ruff — CI
runs the real thing — but keeps the lint gate meaningful where pip
installs are unavailable. `# noqa` on the offending line suppresses a
finding, as in ruff.

F401 matches ruff's semantics for `__all__`: names re-exported through a
literal `__all__` count as used; other imports in the same module are
still flagged (only `__init__.py` gets the blanket per-file ignore).
"""

from __future__ import annotations

import ast
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
SKIP_DIRS = {".git", "__pycache__", ".github"}


def _py_files() -> list:
    out = []
    for p in sorted(ROOT.rglob("*.py")):
        if not any(part in SKIP_DIRS for part in p.parts):
            out.append(p)
    return out


class _Visitor(ast.NodeVisitor):
    def __init__(self, is_init: bool) -> None:
        self.is_init = is_init
        self.imported = {}  # name -> (lineno, display)
        self.used = set()
        self.has_all = False
        self.all_names = set()  # literal `__all__` entries = re-exports
        self.errors = []

    def visit_Import(self, node: ast.Import) -> None:
        for a in node.names:
            name = (a.asname or a.name).split(".")[0]
            self.imported[name] = (node.lineno, a.asname or a.name)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module == "__future__":  # exempt, as in ruff/pyflakes
            return
        for a in node.names:
            if a.name == "*":
                continue
            name = a.asname or a.name
            self.imported[name] = (node.lineno, name)

    def visit_Name(self, node: ast.Name) -> None:
        if isinstance(node.ctx, ast.Load):
            self.used.add(node.id)
        if node.id == "__all__":
            self.has_all = True
        self.generic_visit(node)

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            if isinstance(t, ast.Name) and t.id == "__all__":
                self.has_all = True
                if isinstance(node.value, (ast.List, ast.Tuple, ast.Set)):
                    for elt in node.value.elts:
                        if isinstance(elt, ast.Constant) and isinstance(
                            elt.value, str
                        ):
                            self.all_names.add(elt.value)
        self.generic_visit(node)

    def visit_Compare(self, node: ast.Compare) -> None:
        for op, comp in zip(node.ops, node.comparators):
            if not isinstance(op, (ast.Eq, ast.NotEq)):
                continue
            if isinstance(comp, ast.Constant) and comp.value is None:
                self.errors.append((node.lineno, "E711 comparison to None (use is / is not)"))
            elif isinstance(comp, ast.Constant) and isinstance(comp.value, bool):
                self.errors.append((node.lineno, "E712 comparison to True/False"))
        self.generic_visit(node)

    def visit_ExceptHandler(self, node: ast.ExceptHandler) -> None:
        if node.type is None:
            self.errors.append((node.lineno, "E722 bare `except:`"))
        self.generic_visit(node)


def _is_set_expr(node, setvars) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if (
        isinstance(node, ast.Call)
        and isinstance(node.func, ast.Name)
        and node.func.id in ("set", "frozenset")
    ):
        return True
    return isinstance(node, ast.Name) and node.id in setvars


def _spl003_subset(tree) -> list:
    """sproutlint SPL003, reduced: bare hash() and for/comprehension
    iteration over unsorted sets (set-typed names are inferred file-wide
    from `x = {...}` / `x = set(...)` assignments)."""
    setvars = set()
    for node in ast.walk(tree):
        if (
            isinstance(node, ast.Assign)
            and len(node.targets) == 1
            and isinstance(node.targets[0], ast.Name)
            and _is_set_expr(node.value, ())
        ):
            setvars.add(node.targets[0].id)
    errors = []
    msg_iter = "SPL003 iteration over an unsorted set (wrap in sorted())"
    for node in ast.walk(tree):
        if (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Name)
            and node.func.id == "hash"
        ):
            errors.append(
                (
                    node.lineno,
                    "SPL003 bare hash() is PYTHONHASHSEED-dependent "
                    "(use zlib.crc32 / hashlib)",
                )
            )
        elif isinstance(node, ast.For) and _is_set_expr(node.iter, setvars):
            errors.append((node.lineno, msg_iter))
        elif isinstance(
            node, (ast.ListComp, ast.SetComp, ast.DictComp, ast.GeneratorExp)
        ):
            for gen in node.generators:
                if _is_set_expr(gen.iter, setvars):
                    errors.append((node.lineno, msg_iter))
    return errors


def lint_file(path: Path) -> list:
    src = path.read_text()
    try:
        tree = ast.parse(src, filename=str(path))
    except SyntaxError as err:
        return [(err.lineno or 0, f"E999 syntax error: {err.msg}")]
    lines = src.splitlines()

    def suppressed(lineno: int) -> bool:
        return 0 < lineno <= len(lines) and "# noqa" in lines[lineno - 1]

    # names referenced only from docstrings / string annotations still
    # count as uses (e.g. sphinx-style cross references)
    text_uses = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            cleaned = node.value
            for ch in "[].,":
                cleaned = cleaned.replace(ch, " ")
            text_uses.update(cleaned.split())
    v = _Visitor(is_init=path.name == "__init__.py")
    v.visit(tree)
    errors = [e for e in v.errors if not suppressed(e[0])]
    errors += [e for e in _spl003_subset(tree) if not suppressed(e[0])]
    # ruff semantics: __init__.py has a blanket per-file F401 ignore; a
    # dynamic (non-literal) __all__ we cannot read also skips the check;
    # a literal __all__ marks exactly its names as re-export uses
    if not (v.is_init or (v.has_all and not v.all_names)):
        for name, (lineno, display) in sorted(v.imported.items()):
            unused = (
                name not in v.used
                and name not in text_uses
                and name not in v.all_names
            )
            if unused and not suppressed(lineno):
                errors.append((lineno, f"F401 `{display}` imported but unused"))
    return errors


def main() -> int:
    failed = 0
    for path in _py_files():
        for lineno, msg in lint_file(path):
            print(f"{path.relative_to(ROOT)}:{lineno}: {msg}")
            failed += 1
    if failed:
        print(f"AST_LINT: {failed} finding(s)", file=sys.stderr)
        return 1
    print("AST_LINT_OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
