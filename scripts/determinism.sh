#!/usr/bin/env bash
# CI determinism job, runnable locally (DESIGN.md §9).
#
# The carbon traces are the root of every "deterministic per (region,
# season)" claim downstream (pinned gateway numbers, regression baselines).
# PR 2 fixed a salted-hash seeding bug that made them PYTHONHASHSEED-
# dependent; this script keeps that fix honest by
#   1. running the pinned-value + cross-hash-seed regression tests under
#      two different PYTHONHASHSEED values, and
#   2. dumping every (region, season) trace to hex under both seeds and
#      byte-diffing the dumps.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

SELECT="test_trace_pinned_values or test_trace_identical_across_hash_seeds or test_trace_deterministic"
SEEDS=(0 12345)

for seed in "${SEEDS[@]}"; do
  echo "== pinned-trace regression tests under PYTHONHASHSEED=${seed} =="
  PYTHONHASHSEED="${seed}" python -m pytest -q tests/test_carbon_workload.py \
      -k "${SELECT}"
done

tmp="$(mktemp -d)"
trap 'rm -rf "${tmp}"' EXIT
for seed in "${SEEDS[@]}"; do
  PYTHONHASHSEED="${seed}" python - "${tmp}/trace_${seed}.hex" <<'EOF'
import sys

from repro.core.carbon import REGIONS, SEASONS, carbon_intensity_trace

lines = [f"{r}-{s} {carbon_intensity_trace(r, s).tobytes().hex()}"
         for r in REGIONS for s in SEASONS]
open(sys.argv[1], "w").write("\n".join(lines) + "\n")
EOF
done

echo "== byte-level diff of pinned traces across hash seeds =="
diff "${tmp}/trace_${SEEDS[0]}.hex" "${tmp}/trace_${SEEDS[1]}.hex"
echo "DETERMINISM_OK"
