"""Doc-reference lint: keep the prose layer from rotting (CI lint job,
DESIGN.md §9).

Three dependency-free checks, each a hard failure:

1. Required docs exist — `README.md` and `DESIGN.md` at the repo root.
2. Section references resolve — every `DESIGN.md §N` mention in the
   code tree (src/, tests/, benchmarks/, scripts/ — .py and .sh files)
   and in the root markdown docs must match a real `## §N` header in
   DESIGN.md. Docstrings cite design sections all over the repo; a
   renumbered or deleted section must not leave dangling pointers.
3. Relative markdown links exist — `[text](path)` links in README.md,
   ROADMAP.md, DESIGN.md, and benchmarks/README.md that are neither
   absolute URLs nor pure fragments must point at a file or directory
   that exists (fragments after `#` are stripped before the check).

Usage:
    python scripts/docs_check.py [--root DIR]

Exit 0 with `DOCS_CHECK_OK` on success; exit 1 listing every dangling
reference otherwise.
"""

from __future__ import annotations

import argparse
import re
import sys
from pathlib import Path

REQUIRED_DOCS = ("README.md", "DESIGN.md")
CODE_DIRS = ("src", "tests", "benchmarks", "scripts")
LINKED_DOCS = ("README.md", "ROADMAP.md", "DESIGN.md", "benchmarks/README.md")

# `DESIGN.md §3`, `DESIGN.md §4b`, and the `§§3` plural form all count.
SECTION_REF = re.compile(r"DESIGN\.md\s+§+(\d+[a-z]?)")
SECTION_HEADER = re.compile(r"^##\s+§(\d+[a-z]?)\b", re.MULTILINE)
# [text](target) — excludes images' size suffixes and nested brackets we
# don't use; good enough for the hand-written markdown in this repo.
MD_LINK = re.compile(r"\[[^\]]+\]\(([^)\s]+)\)")


def _design_sections(root: Path) -> set:
    design = root / "DESIGN.md"
    if not design.is_file():
        return set()
    return set(SECTION_HEADER.findall(design.read_text(encoding="utf-8")))


def _iter_code_files(root: Path):
    for d in CODE_DIRS:
        base = root / d
        if not base.is_dir():
            continue
        for ext in ("*.py", "*.sh"):
            yield from sorted(base.rglob(ext))


def check_required(root: Path) -> list:
    return [f"required doc missing: {name}"
            for name in REQUIRED_DOCS if not (root / name).is_file()]


def check_section_refs(root: Path) -> list:
    sections = _design_sections(root)
    errs = []
    targets = list(_iter_code_files(root))
    targets += [root / name for name in LINKED_DOCS
                if (root / name).is_file() and name != "DESIGN.md"]
    for path in targets:
        text = path.read_text(encoding="utf-8", errors="replace")
        for lineno, line in enumerate(text.splitlines(), start=1):
            for sec in SECTION_REF.findall(line):
                if sec not in sections:
                    rel = path.relative_to(root)
                    errs.append(
                        f"{rel}:{lineno}: dangling reference DESIGN.md "
                        f"§{sec} (no '## §{sec}' header)")
    return errs


def check_links(root: Path) -> list:
    errs = []
    for name in LINKED_DOCS:
        doc = root / name
        if not doc.is_file():
            continue
        text = doc.read_text(encoding="utf-8")
        for lineno, line in enumerate(text.splitlines(), start=1):
            for target in MD_LINK.findall(line):
                if target.startswith(("http://", "https://", "mailto:", "#")):
                    continue
                rel = target.split("#", 1)[0]
                if not rel:
                    continue
                if not (doc.parent / rel).exists():
                    errs.append(f"{name}:{lineno}: dead link -> {target}")
    return errs


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", type=Path,
                    default=Path(__file__).resolve().parents[1],
                    help="repo root to check (default: this repo)")
    args = ap.parse_args()
    root = args.root.resolve()
    errs = check_required(root)
    errs += check_section_refs(root)
    errs += check_links(root)
    if errs:
        print("DOCS CHECK FAILED:", file=sys.stderr)
        for e in errs:
            print(f"  - {e}", file=sys.stderr)
        return 1
    n = len(_design_sections(root))
    print(f"DOCS_CHECK_OK ({n} DESIGN.md sections, all references resolve)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
