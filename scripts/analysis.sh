#!/usr/bin/env bash
# CI static-analysis job, runnable locally (DESIGN.md §11).
#
# Layer 1 — sproutlint: AST rules SPL001–SPL004 over src/, benchmarks/,
# scripts/ against the committed ANALYSIS_baseline.json. A new finding
# fails; a STALE baseline entry (finding fixed but suppression left
# behind) also fails, mirroring the tier-1 xpassed-xfail rule.
#
# Layer 2 — jaxpr audit: traces every compiled entry point of a tiny
# engine for each serving variant (dense/paged x fp32/int8) and checks
# f64-freedom, real donation aliasing, drop-OOB scatters, and the
# committed entry_point_inventory.json. Needs jax; Layer 1 does not.
#
# Regenerating the committed artifacts after a reviewed change:
#   PYTHONPATH=src python -m repro.analysis lint  --write-baseline
#   PYTHONPATH=src python -m repro.analysis audit --write-inventory
set -uo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# Layer 2's tp>1 variants build real sharded engines (DESIGN.md §14):
# force 8 host CPU devices so the tensor-parallel entry points compile
export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"

echo "== layer 1: sproutlint (AST, baseline: ANALYSIS_baseline.json) =="
python -m repro.analysis lint
rc_lint=$?

echo "== layer 2: jaxpr audit (entry_point_inventory.json) =="
python -m repro.analysis audit
rc_audit=$?

exit $(( rc_lint || rc_audit ))
