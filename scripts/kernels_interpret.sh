#!/usr/bin/env bash
# CI kernels-interpret job, runnable locally (DESIGN.md §9).
#
# Runs the Pallas-marked suites with SPROUT_KERNEL_IMPL=pallas_interpret,
# which redirects every "auto" kernel dispatch (kernels/ops.resolve_impl)
# through the REAL Pallas kernels in interpret mode. On CPU the default
# auto path resolves to the XLA reference, so without this job the
# kernels' interpret-mode parity — the closest a CPU runner gets to the
# TPU lowering — is only exercised by the few tests that pass an explicit
# impl. An explicit impl= argument still wins inside the tests.
set -uo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export SPROUT_KERNEL_IMPL=pallas_interpret

echo "== pallas suites under SPROUT_KERNEL_IMPL=pallas_interpret =="
python -m pytest -x -q -m pallas "$@"
