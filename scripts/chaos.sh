#!/usr/bin/env bash
# CI chaos job, runnable locally (DESIGN.md §12).
#
# Runs the paired chaos scenario — a fault-free control run and a run with
# every injection point scripted to fire — and holds the line on the
# recovery invariants: zero stranded requests, bit-identical retried greedy
# outputs, bounded retry counts, and a conserved carbon ledger. The whole
# scenario is seed-deterministic, so it is executed under two different
# PYTHONHASHSEED values and the canonical-JSON digests of the paired
# reports are string-diffed: a chaos run that cannot be replayed byte-for-
# byte cannot anchor a regression test.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

SEEDS=(0 12345)
tmp="$(mktemp -d)"
trap 'rm -rf "${tmp}"' EXIT

for seed in "${SEEDS[@]}"; do
  echo "== paired chaos scenario under PYTHONHASHSEED=${seed} =="
  PYTHONHASHSEED="${seed}" python -m repro.serving.chaos \
      | tee "${tmp}/chaos_${seed}.json"
  python - "${tmp}/chaos_${seed}.json" "${tmp}/digest_${seed}" <<'EOF'
import json, sys
rep = json.load(open(sys.argv[1]))
assert rep["ok"], f"chaos checks failed: {rep['checks']}"
open(sys.argv[2], "w").write(rep["digest"] + "\n")
EOF
done

echo "== chaos digest diff across hash seeds =="
diff "${tmp}/digest_${SEEDS[0]}" "${tmp}/digest_${SEEDS[1]}"
echo "CHAOS_OK"
