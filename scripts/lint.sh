#!/usr/bin/env bash
# CI lint job, runnable locally (DESIGN.md §9).
#
# With ruff installed (CI): `ruff check` over the whole repo against the
# committed pyproject.toml config, plus `ruff format --check` over
# scripts/ (the formatter is adopted file-set-by-file-set; scripts/ is
# the formatted set so far).
#
# Without ruff (the hermetic dev container has no pip access): fall back
# to scripts/ast_lint.py, a dependency-free approximation of the same
# rule set (F401/E711/E712/E722 + a full syntax pass), so the gate still
# means something locally.
set -uo pipefail
cd "$(dirname "$0")/.."

if command -v ruff >/dev/null 2>&1 || python -m ruff --version >/dev/null 2>&1; then
  RUFF="ruff"
  command -v ruff >/dev/null 2>&1 || RUFF="python -m ruff"
  echo "== ruff check (config: pyproject.toml) =="
  ${RUFF} check .
  rc_check=$?
  echo "== ruff format --check scripts/ =="
  ${RUFF} format --check scripts/
  rc_fmt=$?
  exit $(( rc_check || rc_fmt ))
fi

echo "== ruff unavailable: dependency-free fallback (scripts/ast_lint.py) =="
python scripts/ast_lint.py
