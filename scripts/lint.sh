#!/usr/bin/env bash
# CI lint job, runnable locally (DESIGN.md §9).
#
# With ruff installed (CI): `ruff check` over the whole repo against the
# committed pyproject.toml config, plus `ruff format --check` over
# scripts/ (the formatter is adopted file-set-by-file-set; scripts/ is
# the formatted set so far).
#
# Without ruff (the hermetic dev container has no pip access): fall back
# to scripts/ast_lint.py, a dependency-free approximation of the same
# rule set (F401/E711/E712/E722 + the SPL003 subset + a full syntax
# pass), so the gate still means something locally.
#
# Either way, sproutlint (the jax-free AST layer of repro.analysis,
# DESIGN.md §11) runs after the style linter, then the doc-reference
# check (scripts/docs_check.py: DESIGN.md §N citations resolve, no dead
# relative links in the root docs), so local `bash scripts/lint.sh`
# matches what CI's lint + static-analysis jobs check.
set -uo pipefail
cd "$(dirname "$0")/.."

run_sproutlint() {
  echo "== sproutlint (SPL001-SPL004, baseline: ANALYSIS_baseline.json) =="
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m repro.analysis lint
}

run_docs_check() {
  echo "== docs check (DESIGN.md section refs + markdown links) =="
  python scripts/docs_check.py
}

if command -v ruff >/dev/null 2>&1 || python -m ruff --version >/dev/null 2>&1; then
  RUFF="ruff"
  command -v ruff >/dev/null 2>&1 || RUFF="python -m ruff"
  echo "== ruff check (config: pyproject.toml) =="
  ${RUFF} check .
  rc_check=$?
  echo "== ruff format --check scripts/ =="
  ${RUFF} format --check scripts/
  rc_fmt=$?
  run_sproutlint
  rc_spl=$?
  run_docs_check
  rc_docs=$?
  exit $(( rc_check || rc_fmt || rc_spl || rc_docs ))
fi

echo "== ruff unavailable: dependency-free fallback (scripts/ast_lint.py) =="
python scripts/ast_lint.py
rc_ast=$?
run_sproutlint
rc_spl=$?
run_docs_check
rc_docs=$?
exit $(( rc_ast || rc_spl || rc_docs ))
