#!/usr/bin/env bash
# CI multidevice job, runnable locally (DESIGN.md §9, §14).
#
# Forces 8 host CPU devices and runs the suites that need real
# multi-device placement: the tensor-parallel serving equivalence tests
# (tp=1 vs tp>1 token identity, greedy and seeded-sampled, dense and
# paged — tests/test_tp_serving.py) and the sharding-rule suites that
# construct production meshes (tests/test_sharding_roofline.py). On the
# tier-1 single-device run these TP tests skip; here they must EXECUTE —
# the guard below fails the job if the skip condition ever fires, so a
# broken XLA_FLAGS wiring can never turn the job silently green.
#
# A MULTIDEVICE_trace.json evidence artifact (tp1-vs-tp2 token streams)
# is written for CI upload; it is diagnostic output, not a committed file.
set -uo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"

echo "== multidevice: tp serving equivalence + sharding suites (8 host devices) =="
python -m pytest -q -rs tests/test_tp_serving.py tests/test_sharding_roofline.py
rc_tests=$?

echo "== guard: the tp suite must have RUN (not skipped for device count) =="
python - <<'EOF'
import os
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax
n = jax.device_count()
assert n >= 8, f"expected 8 forced host devices, found {n} (XLA_FLAGS lost?)"
print(f"MULTIDEVICE_DEVICES_OK ({n} devices)")
EOF
rc_guard=$?

echo "== evidence: tp=1 vs tp=2 greedy + sampled token identity trace =="
python - <<'EOF'
import json
import os
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax

from repro.configs import reduced
from repro.models import model as MD
from repro.serving.engine import InferenceEngine
from repro.serving.sampler import SamplingParams as SP

cfg = reduced("granite_3_2b").replace(vocab_size=512)
params = MD.init_model(cfg, jax.random.PRNGKey(0))
prompts = ["hello sharded world", "carbon aware decode"]

def run(tp, paged, sampled):
    eng = InferenceEngine(cfg, params, n_slots=4, max_len=64, eos_id=-1,
                          seed=7, decode_block=8, paged=paged,
                          page_size=16, tp_degree=tp)
    sp = SP(temperature=0.9, top_k=40, top_p=0.95) if sampled else None
    for p in prompts:
        eng.submit(eng.tok.encode(p), max_new_tokens=10, sampling=sp)
    eng.run_to_completion()
    return {str(f.rid): list(map(int, f.token_ids)) for f in eng.finished}

trace = {"devices": jax.device_count(), "cases": []}
ok = True
for paged in (False, True):
    for sampled in (False, True):
        t1, t2 = run(1, paged, sampled), run(2, paged, sampled)
        ident = t1 == t2
        ok = ok and ident
        trace["cases"].append({
            "paged": paged, "sampled": sampled, "token_identical": ident,
            "tp1_tokens": t1, "tp2_tokens": t2})
trace["all_token_identical"] = ok
with open("MULTIDEVICE_trace.json", "w") as f:
    json.dump(trace, f, indent=2)
print(f"MULTIDEVICE_TRACE_OK all_token_identical={ok}")
raise SystemExit(0 if ok else 1)
EOF
rc_trace=$?

exit $(( rc_tests || rc_guard || rc_trace ))
