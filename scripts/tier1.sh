#!/usr/bin/env bash
# Tier-1 verification (ROADMAP.md) plus the Pallas kernel split.
#
# The main sweep runs every test except the Pallas-marked kernel suites;
# the second invocation runs ONLY those, so kernel regressions are
# reported separately from engine/control-plane regressions and the
# kernel suites skip cleanly (pytest.importorskip) on jax builds without
# jax.experimental.pallas. On CPU the kernels execute in interpret mode.
#
# xfail-inventory drift check: the DESIGN.md §9 table annotates the
# pre-existing jax-version gaps as xfail(strict=False). If any of them
# starts PASSING (the capability arrived — e.g. a jax upgrade), pytest
# reports it as xpassed and still exits 0; this script turns that into a
# failure so the stale annotation gets removed instead of rotting. New
# unannotated failures already fail the suite through the exit code.
set -uo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

xpass_check() {
  # pytest exits 0 on xpassed tests (strict=False); parse the summary
  local log="$1"
  local n
  n=$(grep -Eo '[0-9]+ xpassed' "$log" | tail -1 | grep -Eo '[0-9]+' || true)
  if [[ -n "${n:-}" && "$n" -gt 0 ]]; then
    echo "XFAIL DRIFT: ${n} xfail-annotated test(s) now PASS." >&2
    echo "The capability arrived and the DESIGN.md §9 inventory is" >&2
    echo "stale: remove the xfail annotation(s) and update the table." >&2
    grep -E '^XPASS' "$log" >&2 || true
    return 1
  fi
  return 0
}

tmplog="$(mktemp)"
trap 'rm -f "${tmplog}"' EXIT

echo "== tier-1: full suite (minus pallas kernel marks) =="
python -m pytest -x -q -rX -m "not pallas" "$@" | tee "${tmplog}"
rc_main=${PIPESTATUS[0]}
xpass_check "${tmplog}" || rc_main=1

echo "== tier-1: pallas kernel suites (interpret mode on CPU) =="
python -m pytest -x -q -rX -m pallas "$@" | tee "${tmplog}"
rc_pallas=${PIPESTATUS[0]}
xpass_check "${tmplog}" || rc_pallas=1

exit $(( rc_main || rc_pallas ))
