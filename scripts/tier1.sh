#!/usr/bin/env bash
# Tier-1 verification (ROADMAP.md) plus the Pallas kernel split.
#
# The main sweep runs every test except the Pallas-marked kernel suites;
# the second invocation runs ONLY those, so kernel regressions are
# reported separately from engine/control-plane regressions and the
# kernel suites skip cleanly (pytest.importorskip) on jax builds without
# jax.experimental.pallas. On CPU the kernels execute in interpret mode.
set -uo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: full suite (minus pallas kernel marks) =="
python -m pytest -x -q -m "not pallas" "$@"
rc_main=$?

echo "== tier-1: pallas kernel suites (interpret mode on CPU) =="
python -m pytest -x -q -m pallas "$@"
rc_pallas=$?

exit $(( rc_main || rc_pallas ))
