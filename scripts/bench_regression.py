"""Bench regression gate: compare a fresh `serving_bench.py --smoke` run
against the committed baseline (CI job `bench-regression`, DESIGN.md §9).

Two checks, in order:

1. HARD schema match — the baseline and the fresh run must have the same
   bench cases with the same key sets. A renamed case or a dropped metric
   is drift that must be acknowledged by refreshing the baseline in the
   same PR (run with --update), never silently absorbed.
2. Tolerance bands on throughput/carbon metrics — generous (default
   +/-30%) because smoke sizes are tiny and runners vary; the band
   catches order-of-magnitude rot (a 10x decode regression, a carbon
   accounting change) while wall-clock `us_per_call` noise is ignored.

Usage:
    python scripts/bench_regression.py [CURRENT] [BASELINE]
    python scripts/bench_regression.py --update     # refresh the baseline
"""

from __future__ import annotations

import argparse
import json
import shutil
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

# Metrics under tolerance bands: decode throughput and carbon accounting.
# us_per_call (pure wall clock) is schema-checked but never banded, and
# neither are MOST derived ratios (savings_pct and friends): banding both
# of a ratio's inputs already bounds it, while near-zero percentages at
# smoke sizes would make a relative band meaninglessly tight. The
# paged-vs-dense throughput ratio is the deliberate exception — its two
# inputs live in different rows and each carries a +/-tol band, so the
# ratio itself could drift ~2*tol unnoticed; banding it directly holds
# the paged-overhead claim (DESIGN.md §3) that the rows exist to make.
BANDED_SUFFIXES = ("tok_per_s", "tok_per_sync", "_g_per_req")
BANDED_KEYS = ("tok_per_s_vs_dense",)


def _banded(key: str) -> bool:
    return key in BANDED_KEYS or any(
        key.endswith(sfx) for sfx in BANDED_SUFFIXES)


def _schema_diff(base: dict, cur: dict) -> list:
    errs = []
    missing = sorted(set(base["rows"]) - set(cur["rows"]))
    extra = sorted(set(cur["rows"]) - set(base["rows"]))
    if missing:
        errs.append(f"bench cases missing from the fresh run: {missing}")
    if extra:
        errs.append(f"new bench cases not in the baseline: {extra}")
    for name in sorted(set(base["rows"]) & set(cur["rows"])):
        bkeys, ckeys = set(base["rows"][name]), set(cur["rows"][name])
        if bkeys != ckeys:
            gone = sorted(bkeys - ckeys)
            new = sorted(ckeys - bkeys)
            errs.append(f"{name}: key drift (missing={gone}, new={new})")
    return errs


def _band_diff(base: dict, cur: dict, tol: float) -> list:
    errs = []
    for name in sorted(set(base["rows"]) & set(cur["rows"])):
        brow, crow = base["rows"][name], cur["rows"][name]
        for key in sorted(set(brow) & set(crow)):
            if not _banded(key):
                continue
            b, c = brow[key], crow[key]
            if not isinstance(b, (int, float)) or isinstance(b, bool):
                continue
            lo = min(b * (1 - tol), b * (1 + tol))
            hi = max(b * (1 - tol), b * (1 + tol))
            if not (lo <= c <= hi):
                band = f"[{lo:.6g}, {hi:.6g}]"
                errs.append(f"{name}.{key}: {c} outside the {band} band (baseline {b})")
    return errs


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", nargs="?", default="BENCH_serving_smoke.json")
    ap.add_argument("baseline", nargs="?", default="BENCH_serving_smoke_baseline.json")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.30,
        help="relative band for throughput/carbon metrics (default 0.30)",
    )
    ap.add_argument(
        "--update",
        action="store_true",
        help="copy CURRENT over BASELINE instead of comparing (acknowledged drift)",
    )
    args = ap.parse_args()
    cur_path = ROOT / args.current
    base_path = ROOT / args.baseline
    if args.update:
        shutil.copyfile(cur_path, base_path)
        print(f"baseline refreshed: {base_path.name} <- {cur_path.name}")
        return 0
    cur = json.loads(cur_path.read_text())
    base = json.loads(base_path.read_text())
    errs = _schema_diff(base, cur)
    if not errs:  # bands only mean anything once the schemas agree
        errs = _band_diff(base, cur, args.tolerance)
    if errs:
        print("BENCH REGRESSION:", file=sys.stderr)
        for e in errs:
            print(f"  - {e}", file=sys.stderr)
        print("key meanings: benchmarks/README.md (the bench row dictionary)", file=sys.stderr)
        hint = "intentional? refresh with: python scripts/bench_regression.py --update"
        print(hint, file=sys.stderr)
        return 1
    n = len(set(base["rows"]) & set(cur["rows"]))
    print(f"BENCH_REGRESSION_OK ({n} cases within +/-{args.tolerance:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
