"""Roofline analysis from the compiled dry-run artifact.

Three terms per (arch x shape x mesh), all in seconds-per-step per device:

  compute    = HLO_FLOPs_per_device   / peak_FLOPs        (197 TF/s bf16)
  memory     = HLO_bytes_per_device   / HBM_bw            (819 GB/s)
  collective = wire_bytes_per_device  / ICI_link_bw       (50 GB/s/link)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()`` (the post-SPMD
per-device module). collective wire bytes are NOT in cost_analysis — we
parse the optimized HLO text and sum per-op wire traffic using ring-
collective cost models over the parsed replica-group size g:

  all-reduce        2 (g-1)/g x bytes     (ring reduce-scatter + all-gather)
  all-gather          (g-1)/g x bytes(out)
  reduce-scatter      (g-1)/g x bytes(in)
  all-to-all          (g-1)/g x bytes / g  ... approximated (g-1)/g x bytes(out)
  collective-permute  bytes(out)

MODEL_FLOPS = 6ND (train) / 2ND (inference), N = active params — the
useful-compute yardstick; MODEL/HLO ratio exposes remat + padding +
dispatch waste.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

import numpy as np

# TPU v5e hardware constants (assignment-specified)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_GROUPS_ARR_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    wire_bytes: float            # per device, cost-model adjusted
    raw_bytes: float             # sum of operand sizes, unadjusted
    by_op: Dict[str, float]


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: Dict[str, int] = {}
    by_op: Dict[str, float] = {}
    wire = 0.0
    raw = 0.0
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2)
        if op.endswith("-done"):
            continue  # counted at -start
        nbytes = _shape_bytes(shape_str)
        # group size from the instruction's full line
        line_end = hlo_text.find("\n", m.end())
        line = hlo_text[m.start(): line_end if line_end > 0 else None]
        g = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            g = max(1, gm.group(1).count(",") + 1)
        else:
            gm2 = _GROUPS_ARR_RE.search(line)
            if gm2:
                g = max(1, int(gm2.group(2)))
        if g <= 1:
            continue
        frac = (g - 1) / g
        if op == "all-reduce":
            w = 2.0 * frac * nbytes
        elif op in ("all-gather", "reduce-scatter", "all-to-all"):
            w = frac * nbytes
        else:  # collective-permute
            w = float(nbytes)
        counts[op] = counts.get(op, 0) + 1
        by_op[op] = by_op.get(op, 0.0) + w
        wire += w
        raw += nbytes
    return CollectiveStats(counts, wire, raw, by_op)


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_dev: float
    bytes_per_dev: float
    wire_bytes_per_dev: float
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_total: float
    collectives: Dict[str, int]
    peak_memory_bytes: Optional[float] = None
    useful_bytes_total: float = 0.0   # params + caches + token I/O (decode)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs x chips): remat/padding/dispatch waste."""
        hlo_total = self.flops_per_dev * self.chips
        return self.model_flops_total / hlo_total if hlo_total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant roofline the step's USEFUL work runs at:
        compute-dominated -> useful-FLOPs time / bound time (MFU bound);
        memory-dominated  -> useful-bytes time / bound time (params+KV once).
        1.0 = the step moves/computes nothing beyond the model's intrinsic
        work at the dominant resource's peak."""
        if self.bound_time_s <= 0:
            return 0.0
        t_useful_c = self.model_flops_total / (self.chips * PEAK_FLOPS)
        t_useful_m = (self.useful_bytes_total / (self.chips * HBM_BW)
                      if self.useful_bytes_total else 0.0)
        return min(1.0, max(t_useful_c, t_useful_m) / self.bound_time_s)

    def row(self) -> str:
        return (f"{self.arch:22s} {self.shape:12s} {self.mesh:9s} "
                f"C={self.compute_s:9.3e} M={self.memory_s:9.3e} "
                f"X={self.collective_s:9.3e} dom={self.dominant:10s} "
                f"useful={self.useful_ratio:6.1%} "
                f"roofline={self.roofline_fraction:6.1%}")


def model_param_counts(cfg) -> Tuple[float, float]:
    """(total params, active params per token) — analytic, no allocation."""
    import jax
    from repro.models import model as MD
    shapes = jax.eval_shape(lambda: MD.init_model(cfg, jax.random.PRNGKey(0)))
    total = sum(float(np.prod(l.shape))
                for l in jax.tree_util.tree_leaves(shapes))
    active = total
    if cfg.n_experts > 0:
        # routed experts: only top_k of n_experts active per token
        per_expert = (2 * cfg.d_model * cfg.moe_d_ff + cfg.moe_d_ff * cfg.d_model)
        n_moe_layers = cfg.n_layers - cfg.n_dense_layers
        routed_total = float(n_moe_layers) * cfg.n_experts * per_expert
        routed_active = float(n_moe_layers) * cfg.top_k * per_expert
        active = total - routed_total + routed_active
    return total, active


def model_flops(cfg, shape_cell, padded_cfg=None) -> float:
    """6·N_active·D train / 2·N_active·D inference (D = tokens this step).
    Uses the UNPADDED config — padding waste must show up in the ratio.
    encdec/vlm: D counts the tokens the cell actually feeds (whisper's
    decoder is capped at 4096; ViT patches replace that many text tokens),
    plus encoder-frame tokens through the encoder's parameter share."""
    _, active = model_param_counts(cfg)
    toks_per_row = shape_cell.seq_len
    extra = 0.0
    if cfg.family == "encdec":
        toks_per_row = min(shape_cell.seq_len, 4096)
        # encoder processes enc_seq frames through ~half the stack
        extra = cfg.enc_seq * 0.5 * active
    if shape_cell.kind == "train":
        return (6.0 * active * toks_per_row + 6.0 * extra) * shape_cell.global_batch
    if shape_cell.kind == "prefill":
        return (2.0 * active * toks_per_row + 2.0 * extra) * shape_cell.global_batch
    return 2.0 * active * shape_cell.global_batch   # decode: one token/row


def build_report(arch: str, shape_cell, mesh_name: str, chips: int,
                 cost: Dict, hlo_text: str, mf: float,
                 peak_mem: Optional[float] = None,
                 useful_bytes: float = 0.0,
                 wire_bytes: Optional[float] = None,
                 coll_counts: Optional[Dict[str, int]] = None) -> RooflineReport:
    if wire_bytes is None:
        coll = parse_collectives(hlo_text)
        wire_bytes = coll.wire_bytes
        coll_counts = coll.counts
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    return RooflineReport(
        arch=arch, shape=shape_cell.name, mesh=mesh_name, chips=chips,
        flops_per_dev=flops, bytes_per_dev=byts,
        wire_bytes_per_dev=wire_bytes,
        compute_s=flops / PEAK_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=wire_bytes / ICI_BW,
        model_flops_total=mf,
        collectives=coll_counts or {},
        peak_memory_bytes=peak_mem,
        useful_bytes_total=useful_bytes)
