"""Serving launcher: the SPROUT carbon-aware service as a CLI.

    PYTHONPATH=src python -m repro.launch.serve --arch llama2_13b \
        --region CA --replicas 2 --requests 24

Runs the real continuous-batching engine on the reduced config (CPU
container) with the full SPROUT control plane: hourly LP re-planning from
the regional carbon-intensity trace, directive rendering into system
prompts, level-cost profiling, and preemption-safe scheduling.

``--gateway`` switches to the closed-loop SproutGateway: one scheduler
pool per ``--regions`` entry, the LP re-planned per pool from its live
intensity, engine telemetry fed back into the level profiles, and requests
routed to the greenest pool under a load cap.

``--tenants`` layers service classes on top (premium/standard/batch, one
LP per (pool, tenant) with per-class quality floors); ``--slo`` arms
their TTFT/TPOT latency targets so admission routes on predicted
completion time jointly with greenness; ``--drain-at H`` empties the
``--drain-region`` pool ahead of maintenance at hour H (DESIGN.md §10).

``--chaos`` arms the default fault-injection script (DESIGN.md §12) — one
fault of every class against the first pool — and prints the recovery
counters; ``--grid-provider electricitymaps --grid-token ...`` swaps the
bundled traces for the live grid-signal client (tokenless runs fall back
to the traces, so the flag is CI-safe).
"""
from __future__ import annotations

import argparse
import dataclasses
import math

import jax
import numpy as np

from repro.configs import reduced
from repro.core import (A100_40GB, DEFAULT_TENANTS, LLAMA2_13B,
                        CarbonIntensityProvider, DirectiveSet, EnergyModel,
                        GridSignalClient, QualityEvaluator, Workload,
                        solve_directive_lp)
from repro.core.carbon import WatchdogProvider
from repro.core.policies import LevelProfiles, SproutPolicy
from repro.models import model as MD
from repro.serving import (CarbonAwareScheduler, FaultInjector, FaultPlan,
                           FaultSpec, InferenceEngine, MigrationPlanner,
                           ServeRequest, SproutGateway, no_faults,
                           serve_request_from)

# request mix across service classes for --tenants runs (premium is the
# minority class with the hard floor; batch soaks up the brief levels)
TENANT_CYCLE = ("premium", "standard", "standard", "batch")


def tenant_specs(slo: bool) -> tuple:
    """The default service classes; without --slo their latency targets
    are disarmed (quality floors only, no deadlines)."""
    if slo:
        return DEFAULT_TENANTS
    return tuple(dataclasses.replace(t, ttft_s=math.inf, tpot_s=math.inf)
                 for t in DEFAULT_TENANTS)


def chaos_plan(regions) -> FaultPlan:
    """The CLI's default chaos script: one fault of every class a plain
    --gateway run can reach, aimed at the first pool so the others keep
    absorbing its recovered work."""
    r0 = regions[0]
    return FaultPlan([
        FaultSpec("carbon.nan", r0, occurrences=(0,)),
        FaultSpec("carbon.stale", r0, occurrences=(1,)),
        FaultSpec("carbon.exception", r0, occurrences=(2,)),
        FaultSpec("lp.fail", r0, occurrences=(0,)),
        FaultSpec("decode.nonfinite", "*", occurrences=(0,)),
        FaultSpec("replica.crash", f"{r0}/0", occurrences=(2,)),
        FaultSpec("migrate.dst_vanish", "*", occurrences=(0,)),
    ])


def grid_provider(region: str, args) -> CarbonIntensityProvider:
    """Trace-backed by default; --grid-provider switches to the live
    Electricity Maps / WattTime client (tokenless = immediate trace
    fallback, so the flag is safe to try offline)."""
    if args.grid_provider == "trace":
        return CarbonIntensityProvider(region, "jun")
    return GridSignalClient(region, "jun", provider=args.grid_provider,
                            token=args.grid_token)


def run_gateway(args, cfg, params) -> None:
    """Closed-loop mode: LP -> scheduler pools -> engine telemetry -> LP."""
    regions = [r.strip() for r in args.regions.split(",") if r.strip()]
    workload = Workload(seed=0)
    evaluator = QualityEvaluator(sample_size=200)
    injector = (FaultInjector(chaos_plan(regions), seed=args.chaos_seed)
                if args.chaos else no_faults())
    # the watchdog wraps every feed (live or trace): staleness aging,
    # last-good fallback, and the chaos injection points for --chaos
    providers = [WatchdogProvider(grid_provider(r, args),
                                  fault_injector=injector)
                 for r in regions]
    k_min = min(p.k_min for p in providers)
    k_max = max(p.k_max for p in providers)
    pools = []
    for j, prov in enumerate(providers):
        engines = [
            # eos_id=-1: the tiny random model has no meaningful EOS, so
            # decoding is budget-bound and measured token counts carry the
            # per-level brevity structure
            InferenceEngine(cfg, params, n_slots=args.slots, max_len=96,
                            seed=100 * j + i, decode_block=args.decode_block,
                            eos_id=-1, **engine_kv_kwargs(args))
            for i in range(args.replicas)]
        pools.append((prov, CarbonAwareScheduler(
            engines, fault_injector=injector)))
    tenants = tenant_specs(args.slo) if args.tenants else None
    # tenant mode solves its own per-(pool, tenant) LPs with per-class xi
    # values — a single-mix SproutPolicy (and --xi) only applies without
    # --tenants, so don't build one that would be silently ignored
    policy = None if tenants else SproutPolicy(
        k0_min=k_min, k0_max=k_max, xi=args.xi,
        k1=A100_40GB.embodied_gco2 / A100_40GB.lifetime_s)
    # the accounting profile mirrors the engine's KV dtype, so the int8
    # flag halves modeled decode KV bytes end to end (roofline -> level
    # profiles -> LP -> Eq. 1 carbon)
    profile = LLAMA2_13B.with_int8_kv() if args.kv_int8 else LLAMA2_13B
    migration = MigrationPlanner() if args.migrate else None
    gw = SproutGateway(pools, policy=policy, tenants=tenants,
                       energy=EnergyModel(A100_40GB),
                       model_profile=profile, load_cap=args.load_cap,
                       forecast_horizon=args.forecast_horizon,
                       migration=migration, fault_injector=injector)

    for hour in range(args.hours):
        pool_sample = [workload.sample_request(hour + i * 0.01)
                       for i in range(300)]
        gw.set_quality(evaluator.evaluate(pool_sample).q)
        reqs = [serve_request_from(workload.sample_request(hour + i * 0.01),
                                   token_scale=320.0 / args.max_new,
                                   max_new=args.max_new,
                                   tenant=(TENANT_CYCLE[i % len(TENANT_CYCLE)]
                                           if tenants else ""))
                for i in range(args.requests)]
        # >= (not ==): --drain-at takes a float hour, and the loop steps
        # in whole hours — drain fires at the first hour past the mark.
        # The drain runs through run_hour's on_inflight hook, i.e. with
        # the hour's work IN FLIGHT — each hour is served to idle, so
        # draining between hours would always find an empty backlog and
        # demonstrate nothing but the admission skip.
        on_inflight = None
        if args.drain_at >= 0 and hour >= args.drain_at \
                and not gw.draining:

            def on_inflight(g, hour=hour):
                # default target: the pool holding the most in-flight
                # work — the interesting maintenance case; --drain-region
                # pins a specific one
                region = args.drain_region or max(
                    g.pools, key=lambda p: p.load()).key
                moved = g.drain_pool(region, deadline=float(hour))
                print(f"  [hour {hour}] draining {region} ahead of "
                      f"maintenance; moved {moved} backlogged requests")
        s = gw.run_hour(float(hour), reqs, on_inflight=on_inflight)
        ks = " ".join(f"{k}={v:4.0f}" for k, v in s["k0"].items())
        xs = " ".join(f"{k}:{np.round(v, 2)}" for k, v in s["x"].items())
        rt = " ".join(f"{k}={v}" for k, v in s["routes"].items())
        kv = " ".join(
            f"{k}={v.get('kv_bytes_in_use', 0) / 1024:.0f}KiB"
            f"@{v.get('occupancy', 1.0):.0%}"
            for k, v in s["kv"].items())
        mig = f"  migrated={s['migrated']}" if migration or s["draining"] \
            else ""
        slo = ""
        if s["slo"]:
            slo = "  slo[" + " ".join(
                f"{k}={v:.0%}" for k, v in sorted(s["slo"].items())) + "]"
        print(f"hour {hour}: CI[{ks}]  served={s['served']:3d}  "
              f"carbon={s['carbon_g']:.4f}g  routes[{rt}]  x[{xs}]  "
              f"kv[{kv}]{mig}{slo}", flush=True)
    st = gw.stats
    print(f"total: {st.carbon_g:.4f} gCO2 across {st.requests} requests "
          f"({1000 * st.carbon_per_request:.3f} mg/req, "
          f"{st.rejected} rejected, {st.migrated} migrated)")
    print(f"level mix: {np.round(st.level_counts / max(st.requests, 1), 3)}")
    if args.chaos:
        inj = " ".join(f"{e.point}@{e.target}" for e in injector.events)
        wd = sum(sum(p.provider.faults.values()) for p in gw.pools
                 if hasattr(p.provider, "faults"))
        print(f"chaos: injected[{inj}]  recovered_faults={st.faults}  "
              f"watchdog_faults={wd}  plan_holds={st.plan_holds}  "
              f"shed={st.shed}  wasted={st.wasted_g:.4f}g")
    if tenants:
        att = " ".join(f"{name}={st.slo_attainment(name):.0%}"
                       f"({st.tenant_requests.get(name, 0)})"
                       for name in ("premium", "standard", "batch"))
        print(f"slo attainment: {att}")
    print(f"profiled e (kWh/level): {np.round(gw.profiles.e, 9)}")


def engine_kv_kwargs(args) -> dict:
    """KV-layout engine kwargs shared by both serving modes."""
    kw = {"kv_int8": args.kv_int8,
          "prefill_chunk": args.prefill_chunk,
          "tp_degree": args.tp_degree}
    if args.paged:
        kw.update(paged=True, page_size=args.page_size,
                  n_pages=args.pages if args.pages > 0 else None,
                  prefix_cache=args.prefix_cache)
    return kw


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2_13b")
    ap.add_argument("--region", default="CA")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--hours", type=int, default=4)
    ap.add_argument("--requests", type=int, default=6,
                    help="requests per simulated hour")
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--decode-block", type=int, default=8,
                    help="tokens decoded per fused device dispatch")
    ap.add_argument("--xi", type=float, default=0.1,
                    help="Eq. 3 quality relaxation for the single-mix LP; "
                         "inert under --tenants (each class carries its "
                         "own xi)")
    ap.add_argument("--gateway", action="store_true",
                    help="closed-loop SproutGateway over regional pools")
    ap.add_argument("--regions", default="CA,TX",
                    help="comma-separated regions for --gateway pools")
    ap.add_argument("--load-cap", type=int, default=8,
                    help="per-pool in-flight cap for green routing")
    ap.add_argument("--migrate", action="store_true",
                    help="cross-region MigrationPlanner: move queued/"
                         "preempted work to greener pools at re-plan ticks "
                         "(--gateway only)")
    ap.add_argument("--forecast-horizon", type=float, default=0.0,
                    help="hours of intensity forecast the per-pool LP "
                         "re-plan (and migration) solves against; 0 = "
                         "instantaneous (--gateway only)")
    ap.add_argument("--tenants", action="store_true",
                    help="premium/standard/batch service classes: one LP "
                         "per (pool, tenant) with per-class quality "
                         "floors (--gateway only)")
    ap.add_argument("--slo", action="store_true",
                    help="arm the tenant classes' TTFT/TPOT latency "
                         "targets: requests carry deadlines and admission "
                         "routes on predicted completion time jointly "
                         "with greenness (implies --tenants)")
    ap.add_argument("--drain-at", type=float, default=-1.0,
                    help="simulated hour at which to drain a pool ahead "
                         "of maintenance (-1 = never; --gateway only)")
    ap.add_argument("--drain-region", default="",
                    help="region to drain at --drain-at (default: the "
                         "pool holding the most in-flight work at the "
                         "drain moment)")
    ap.add_argument("--paged", action="store_true",
                    help="block-table paged KV cache + paged decode kernel")
    ap.add_argument("--page-size", type=int, default=32,
                    help="tokens per KV page (128-256 on TPU; small pages "
                         "suit the reduced CPU config)")
    ap.add_argument("--pages", type=int, default=0,
                    help="page budget per engine (0 = dense-equivalent "
                         "n_slots * max_len worth of pages)")
    ap.add_argument("--kv-int8", action="store_true",
                    help="int8 KV cache (halves decode HBM traffic; "
                         "accounting profile follows)")
    ap.add_argument("--tp-degree", type=int, default=1,
                    help="tensor-parallel sharding per engine: params and "
                         "KV heads split over a (1, T) device mesh "
                         "(DESIGN.md §14). Needs >= T jax devices; on CPU "
                         "set XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=8 before launch")
    ap.add_argument("--chaos", action="store_true",
                    help="arm the default fault-injection script (one "
                         "fault of every class aimed at the first pool) "
                         "and report recovery counters (--gateway only)")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="FaultInjector seed for --chaos")
    ap.add_argument("--grid-provider", default="trace",
                    choices=("trace", "electricitymaps", "watttime"),
                    help="carbon-signal source: bundled synthetic traces "
                         "(default) or the live grid APIs via "
                         "GridSignalClient (needs --grid-token; tokenless "
                         "falls straight back to the traces)")
    ap.add_argument("--grid-token", default="",
                    help="API token for --grid-provider (never bundled; "
                         "empty = CI-safe trace fallback)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="continuous batching: admit arrivals against live "
                         "decode lanes as prefill chunks of this many "
                         "tokens interleaved into the decode scan "
                         "(0 = slot-epoch whole-prompt prefill)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix prefix cache over the paged KV store "
                         "(requires --paged): shared full prompt pages are "
                         "content-hashed, refcounted, and adopted by later "
                         "requests with copy-on-write on divergence — "
                         "cached prompt spans skip prefill entirely and "
                         "Eq. 1 accounting credits the skipped tokens")
    args = ap.parse_args()
    if args.slo:
        args.tenants = True

    cfg = reduced(args.arch).replace(vocab_size=512)
    params = MD.init_model(cfg, jax.random.PRNGKey(0))
    if args.gateway:
        run_gateway(args, cfg, params)
        return
    grid = CarbonIntensityProvider(args.region, "jun")
    energy = EnergyModel(A100_40GB)
    profile = LLAMA2_13B.with_int8_kv() if args.kv_int8 else LLAMA2_13B
    directives = DirectiveSet()
    profiles = LevelProfiles.fresh()
    evaluator = QualityEvaluator(sample_size=200)
    workload = Workload(seed=0)
    rng = np.random.default_rng(0)
    q = np.ones(3) / 3
    plan = {"x": np.ones(3) / 3}

    sched = CarbonAwareScheduler(
        [InferenceEngine(cfg, params, n_slots=args.slots, max_len=96, seed=i,
                         decode_block=args.decode_block,
                         **engine_kv_kwargs(args))
         for i in range(args.replicas)],
        directives,
        level_fn=lambda: int(rng.choice(3, p=plan["x"])))

    total_g = served = 0
    for hour in range(args.hours):
        k0 = grid.intensity(hour)
        if profiles.counts.min() >= 2:
            sol = solve_directive_lp(
                profiles.e, profiles.p, q, k0=k0,
                k1=A100_40GB.embodied_gco2 / A100_40GB.lifetime_s,
                k0_min=grid.k_min, k0_max=grid.k_max, xi=args.xi)
            plan["x"] = sol.x
        pool = [workload.sample_request(hour + i * 0.01) for i in range(300)]
        q = evaluator.evaluate(pool).q
        for i in range(args.requests):
            sched.submit(ServeRequest(0, f"request {hour}:{i} — explain "
                                      "briefly.", max_new_tokens=args.max_new))
        for f in sched.run():
            kwh = energy.request_energy_kwh(profile, f.prompt_tokens,
                                            f.gen_tokens)
            total_g += k0 * kwh * 1.2
            profiles.update(f.directive_level, kwh, f.latency_s)
            served += 1
        mixes = np.round(plan["x"], 2)
        print(f"hour {hour}: CI={k0:5.0f} gCO2/kWh  served={served:3d}  "
              f"x={mixes}", flush=True)
        sched.finished = []
    for req, reason in sched.rejected:
        print(f"rejected rid={req.rid}: {reason}", flush=True)
    print(f"total (13B-scale estimate): {total_g:.3f} gCO2 "
          f"across {served} requests")


if __name__ == "__main__":
    main()
