"""Serving launcher: the SPROUT carbon-aware service as a CLI.

    PYTHONPATH=src python -m repro.launch.serve --arch llama2_13b \
        --region CA --replicas 2 --requests 24

Runs the real continuous-batching engine on the reduced config (CPU
container) with the full SPROUT control plane: hourly LP re-planning from
the regional carbon-intensity trace, directive rendering into system
prompts, level-cost profiling, and preemption-safe scheduling.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import reduced
from repro.core import (A100_40GB, LLAMA2_13B, CarbonIntensityProvider,
                        DirectiveSet, EnergyModel, QualityEvaluator,
                        Workload, solve_directive_lp)
from repro.core.policies import LevelProfiles
from repro.models import model as MD
from repro.serving import (CarbonAwareScheduler, InferenceEngine,
                           ServeRequest)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2_13b")
    ap.add_argument("--region", default="CA")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--hours", type=int, default=4)
    ap.add_argument("--requests", type=int, default=6,
                    help="requests per simulated hour")
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--decode-block", type=int, default=8,
                    help="tokens decoded per fused device dispatch")
    ap.add_argument("--xi", type=float, default=0.1)
    args = ap.parse_args()

    cfg = reduced(args.arch).replace(vocab_size=512)
    params = MD.init_model(cfg, jax.random.PRNGKey(0))
    grid = CarbonIntensityProvider(args.region, "jun")
    energy = EnergyModel(A100_40GB)
    directives = DirectiveSet()
    profiles = LevelProfiles.fresh()
    evaluator = QualityEvaluator(sample_size=200)
    workload = Workload(seed=0)
    rng = np.random.default_rng(0)
    q = np.ones(3) / 3
    plan = {"x": np.ones(3) / 3}

    sched = CarbonAwareScheduler(
        [InferenceEngine(cfg, params, n_slots=args.slots, max_len=96, seed=i,
                         decode_block=args.decode_block)
         for i in range(args.replicas)],
        directives,
        level_fn=lambda: int(rng.choice(3, p=plan["x"])))

    total_g = served = 0
    for hour in range(args.hours):
        k0 = grid.intensity(hour)
        if profiles.counts.min() >= 2:
            sol = solve_directive_lp(
                profiles.e, profiles.p, q, k0=k0,
                k1=A100_40GB.embodied_gco2 / A100_40GB.lifetime_s,
                k0_min=grid.k_min, k0_max=grid.k_max, xi=args.xi)
            plan["x"] = sol.x
        pool = [workload.sample_request(hour + i * 0.01) for i in range(300)]
        q = evaluator.evaluate(pool).q
        for i in range(args.requests):
            sched.submit(ServeRequest(0, f"request {hour}:{i} — explain "
                                      "briefly.", max_new_tokens=args.max_new))
        for f in sched.run():
            kwh = energy.request_energy_kwh(LLAMA2_13B, f.prompt_tokens,
                                            f.gen_tokens)
            total_g += k0 * kwh * 1.2
            profiles.update(f.directive_level, kwh, f.latency_s)
            served += 1
        mixes = np.round(plan["x"], 2)
        print(f"hour {hour}: CI={k0:5.0f} gCO2/kWh  served={served:3d}  "
              f"x={mixes}", flush=True)
        sched.finished = []
    for req, reason in sched.rejected:
        print(f"rejected rid={req.rid}: {reason}", flush=True)
    print(f"total (13B-scale estimate): {total_g:.3f} gCO2 "
          f"across {served} requests")


if __name__ == "__main__":
    main()
