"""Launch layer: production mesh, per-arch sharding rules, multi-pod
dry-run driver, roofline analyzer, and train/serve entry points."""
