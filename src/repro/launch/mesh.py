"""Production mesh builders.

A FUNCTION, not a module-level constant — importing this module must never
touch jax device state (the dry-run pins the device count before any jax
call; tests and benches must keep seeing 1 CPU device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model).

    The dry-run pins ``--xla_force_host_platform_device_count=512``; the
    single-pod mesh uses the first 256 of those placeholder devices."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])


def batch_axes(multi_pod: bool = False):
    """Mesh axes the global batch shards over (DP spans pods)."""
    return ("pod", "data") if multi_pod else ("data",)


def fsdp_axes(multi_pod: bool = False):
    """Mesh axes parameter 'dense' dims shard over (ZeRO-style)."""
    return ("pod", "data") if multi_pod else ("data",)


def ep_axes(multi_pod: bool = False):
    """Mesh axes MoE experts shard over (expert parallelism)."""
    return ("pod", "data") if multi_pod else ("data",)
