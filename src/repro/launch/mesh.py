"""Production mesh builders + jax-version compatibility shims.

A FUNCTION, not a module-level constant — importing this module must never
touch jax device state (the dry-run pins the device count before any jax
call; tests and benches must keep seeing 1 CPU device).

The compat layer papers over the two API moves between jax 0.4.x and
jax >= 0.5 that the sharding/training suites (and the serving engine's
tensor-parallel path, DESIGN.md §14) depend on:

* ``AbstractMesh`` — 0.4.x takes ``((name, size), ...)`` shape tuples,
  >= 0.5 takes ``(axis_sizes, axis_names)``. ``abstract_mesh`` accepts the
  new-style arguments on both.
* ``shard_map`` — >= 0.5 exports it at top level with ``check_vma``;
  0.4.x only has ``jax.experimental.shard_map.shard_map`` with
  ``check_rep``. ``shard_map_compat`` maps one onto the other.
"""
from __future__ import annotations

import jax
from jax.sharding import AbstractMesh


def abstract_mesh(axis_sizes, axis_names) -> AbstractMesh:
    """``AbstractMesh(axis_sizes, axis_names)`` on every supported jax.

    Tries the jax >= 0.5 signature first; on 0.4.x (TypeError: sizes are
    not iterable pairs) falls back to the old ``((name, size), ...)``
    shape-tuple form. Either way the returned mesh answers
    ``mesh.shape[name]`` / ``mesh.axis_names`` identically and is a valid
    ``NamedSharding`` mesh argument."""
    axis_sizes = tuple(int(s) for s in axis_sizes)
    axis_names = tuple(axis_names)
    try:
        return AbstractMesh(axis_sizes, axis_names)
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))


def shard_map_supported() -> bool:
    """True when the installed jax exports top-level ``jax.shard_map``
    (the >= 0.5 API). The serving engine uses this to pick its TP
    mechanism: shard_map where available, jit-with-NamedSharding
    constraints otherwise (DESIGN.md §14)."""
    return hasattr(jax, "shard_map")


def shard_map_compat(f, *, mesh, in_specs, out_specs, check_vma=False):
    """``jax.shard_map`` with the >= 0.5 keyword surface on every jax.

    On 0.4.x this forwards to ``jax.experimental.shard_map.shard_map``,
    translating ``check_vma`` to its older ``check_rep`` spelling."""
    if shard_map_supported():
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)


def axis_size(axis_name):
    """``jax.lax.axis_size`` on every supported jax. 0.4.x lacks the
    function; the classic psum-of-one idiom computes the same trace-time
    constant inside any mapped context (shard_map/pmap)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def make_tp_mesh(tp_degree: int):
    """Serving mesh for tensor-parallel decode: ``(1, tp)`` over axes
    ``("data", "model")`` on the first ``tp_degree`` local devices.

    The degenerate data axis keeps the axis names identical to the
    production mesh, so the same ``launch/sharding.py`` rules derive the
    specs (CPU CI forces the device pool with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``)."""
    assert tp_degree >= 1
    if jax.device_count() < tp_degree:
        raise ValueError(
            f"tp_degree={tp_degree} needs {tp_degree} devices but jax sees "
            f"{jax.device_count()}; on CPU set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={tp_degree} (or more) "
            f"before importing jax")
    return jax.make_mesh((1, tp_degree), ("data", "model"),
                         devices=jax.devices()[:tp_degree])


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model).

    The dry-run pins ``--xla_force_host_platform_device_count=512``; the
    single-pod mesh uses the first 256 of those placeholder devices."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])


def batch_axes(multi_pod: bool = False):
    """Mesh axes the global batch shards over (DP spans pods)."""
    return ("pod", "data") if multi_pod else ("data",)


def fsdp_axes(multi_pod: bool = False):
    """Mesh axes parameter 'dense' dims shard over (ZeRO-style)."""
    return ("pod", "data") if multi_pod else ("data",)


def ep_axes(multi_pod: bool = False):
    """Mesh axes MoE experts shard over (expert parallelism)."""
    return ("pod", "data") if multi_pod else ("data",)
