"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch granite_3_2b \
        --steps 100 --batch 16 --seq 64 [--reduced] [--ckpt DIR]

On this CPU container ``--reduced`` (default) trains the smoke-scale config;
on a real TPU slice the same driver runs the full config under
``make_production_mesh()`` with the launch/sharding.py rules — the mesh path
is exactly what launch/dryrun.py compiles, so what the dry-run proves is
what this runs.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.training import (AdamWConfig, SyntheticLM, checkpoint,
                            make_train_step, train_state_init, wsd_schedule)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_3_2b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--full", action="store_true",
                    help="full production config (TPU slice required)")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor"])
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else reduced(args.arch)
    if args.full:
        from repro.launch.mesh import make_production_mesh  # noqa: F401
        raise SystemExit("--full requires a TPU slice; this container is "
                         "CPU-only. Use launch/dryrun.py to verify the "
                         "production lowering instead.")

    st = train_state_init(cfg, jax.random.PRNGKey(0))
    data = SyntheticLM(cfg.vocab_size, seed=1)
    step_fn = jax.jit(make_train_step(
        cfg, AdamWConfig(lr=args.lr), microbatches=args.microbatches,
        schedule=wsd_schedule(args.steps, warmup=max(1, args.steps // 20)),
        optimizer=args.optimizer))

    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v)
                 for k, v in data.batch(i, args.batch, args.seq).items()}
        st.params, st.opt, m = step_fn(st.params, st.opt, batch)
        if i % 10 == 0:
            print(f"step {i:5d} loss={float(m['loss']):.4f} "
                  f"gnorm={float(m['grad_norm']):.3f} "
                  f"({(time.time() - t0) / (i + 1):.2f}s/step)", flush=True)
        if args.ckpt and (i + 1) % args.ckpt_every == 0:
            checkpoint.save({"params": st.params, "opt": st.opt}, args.ckpt,
                            step=i + 1)
            print(f"  checkpoint @ step {i + 1} -> {args.ckpt}", flush=True)
    print(f"done: final loss {float(m['loss']):.4f}")


if __name__ == "__main__":
    main()
