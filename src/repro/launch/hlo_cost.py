"""Trip-count-aware cost analysis over compiled HLO text.

Why: ``compiled.cost_analysis()`` visits a ``while`` body ONCE — a 61-layer
``lax.scan`` or a 2080-step flash-attention sweep undercounts FLOPs, bytes
and collectives by the trip count. This analyzer parses the post-SPMD HLO
module, builds the computation call graph, multiplies every computation's
costs by its aggregate execution multiplicity (ENTRY=1; while bodies x trip
count parsed from the loop condition's induction bound; nesting composes),
and accounts:

  * FLOPs: dot = 2 x out_elems x contracted extent (batch dims excluded);
    elementwise = out_elems; reduce = operand elems.
  * HBM bytes: per instruction in non-fusion computations, output write +
    operand reads (fusion internals are VMEM-local: only their FLOPs count;
    the fusion call site accounts the memory). dynamic-slice/-update-slice
    count slice-sized traffic, not whole-buffer (in-place semantics).
  * Collective wire bytes: ring-model costs x replica-group fraction
    (see roofline.py), x multiplicity.

Costs are per device (the module is the per-device SPMD program).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16, "s4": 1, "u4": 1}

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_SHAPE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OPCODE = re.compile(r"^((?:\([^)]*\)|[a-z][a-z0-9]*\[[0-9,]*\][^\s]*)\s+)?"
                     r"([a-z][\w\-]*)\(")
_OPERANDS = re.compile(r"%([\w.\-]+)")
_CALL_ATTR = re.compile(r"(?:calls|body|condition|to_apply"
                        r"|true_computation|false_computation)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_ARR_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONST_INT = re.compile(r"constant\((\d+)\)")

_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "custom-call", "rng-bit-generator", "iota",
             "copy-start", "copy-done", "partition-id", "replica-id",
             "opt-barrier"}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    elems = 0
    nbytes = 0
    for dt, dims in _SHAPE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    out_shape: str
    line: str
    operands: List[str]
    callees: List[str]


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    defs: Dict[str, str]     # value name -> shape string
    is_entry: bool


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(raw)
            if m:
                cur = Computation(m.group(2), [], {}, bool(m.group(1)))
                # parameters are declared in following lines as instrs
            continue
        if raw.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(raw)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        om = _OPCODE.match(rhs)
        opcode = om.group(2) if om else rhs.split("(")[0].strip().split()[-1]
        out_shape = rhs.split(opcode)[0] if opcode in rhs else rhs
        body = rhs[rhs.find("("):]
        # operand names: inside the first paren group only (avoid attrs)
        depth = 0
        end = 0
        for i, ch in enumerate(body):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        opnds = _OPERANDS.findall(body[:end + 1])
        callees = [cm.group(1) for cm in _CALL_ATTR.finditer(rhs)]
        bm = _BRANCHES.search(rhs)
        if bm:
            callees.extend(p.strip().lstrip("%") for p in bm.group(1).split(","))
        cur.defs[name] = out_shape
        cur.instrs.append(Instr(name, opcode, out_shape, raw, opnds, callees))
    return comps


def _trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    """Max integer constant in the loop condition = induction bound."""
    best = 1
    seen = set()
    stack = [cond_name]
    while stack:
        cn = stack.pop()
        if cn in seen or cn not in comps:
            continue
        seen.add(cn)
        for ins in comps[cn].instrs:
            for c in _CONST_INT.findall(ins.line):
                best = max(best, int(c))
            stack.extend(ins.callees)
    return best


def _multiplicities(comps: Dict[str, Computation]) -> Dict[str, float]:
    entry = next((c.name for c in comps.values() if c.is_entry), None)
    mult: Dict[str, float] = {name: 0.0 for name in comps}
    if entry is None:
        return mult
    # fusion-called computations are VMEM-local; track separately
    order: List[str] = []
    seen = set()

    def topo(name):
        if name in seen or name not in comps:
            return
        seen.add(name)
        for ins in comps[name].instrs:
            for c in ins.callees:
                topo(c)
        order.append(name)

    topo(entry)
    mult[entry] = 1.0
    for name in reversed(order):
        m = mult.get(name, 0.0)
        if m == 0.0 or name not in comps:
            continue
        for ins in comps[name].instrs:
            if not ins.callees:
                continue
            if ins.opcode == "while":
                trips = _trip_count(comps, ins.callees[-1] if len(ins.callees) > 1
                                    else ins.callees[0])
                # attributes order: condition=, body= — resolve by name role
                cond = body = None
                cm = re.search(r"condition=%?([\w.\-]+)", ins.line)
                bm = re.search(r"body=%?([\w.\-]+)", ins.line)
                if cm:
                    cond = cm.group(1)
                if bm:
                    body = bm.group(1)
                trips = _trip_count(comps, cond) if cond else trips
                if body in mult:
                    mult[body] += m * trips
                if cond in mult:
                    mult[cond] += m * (trips + 1)
            else:
                for c in ins.callees:
                    if c in mult:
                        mult[c] += m
    return mult


def _fusion_internal(comps: Dict[str, Computation]) -> Dict[str, bool]:
    internal = {name: False for name in comps}
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.opcode in ("fusion", "reduce", "sort", "scatter", "map",
                              "reduce-window", "select-and-scatter"):
                for c in ins.callees:
                    if c in internal:
                        internal[c] = True
    # propagate: anything called from an internal computation is internal
    changed = True
    while changed:
        changed = False
        for comp in comps.values():
            if not internal[comp.name]:
                continue
            for ins in comp.instrs:
                for c in ins.callees:
                    if c in internal and not internal[c]:
                        internal[c] = True
                        changed = True
    return internal


_PASSTHRU = ("convert", "bitcast", "copy", "reshape", "transpose")


def _fusion_traffic(comp: Computation) -> Tuple[int, int]:
    """(read_bytes, write_bytes) a fusion call actually causes.

    XLA fuses dynamic-slice reads and dynamic-update-slice writes into the
    fusion with in-place aliasing, so:
      * a parameter consumed only via dynamic-slice reads slice-sized bytes;
      * a parameter that only flows (through converts/bitcasts — CPU-lowering
        artifacts that don't exist on the bf16-native TPU target) into the
        TARGET slot (operand 0) of a dynamic-update-slice is updated in
        place: it contributes no read traffic;
      * a root that is (a convert chain over) a dynamic-update-slice writes
        update-sized bytes, not the whole buffer.
    Naive operand+output counting inflates these cases 10-100x.
    """
    producers = {i.name: i for i in comp.instrs}
    params = {i.name: i.out_shape for i in comp.instrs if i.opcode == "parameter"}

    def is_inplace_target(pname: str) -> bool:
        """Does pname flow only through pass-thru ops into DUS operand 0?"""
        frontier = [pname]
        for _ in range(12):
            nxt = []
            for nm in frontier:
                uses = [i for i in comp.instrs if nm in i.operands]
                if not uses:
                    return False
                for u in uses:
                    if u.opcode in ("dynamic-update-slice", "scatter"):
                        if u.operands and u.operands[0] == nm:
                            continue  # in-place target slot: fine
                        return False
                    elif u.opcode in _PASSTHRU:
                        nxt.append(u.name)
                    else:
                        return False
            if not nxt:
                return True
            frontier = nxt
        return False

    reads = 0
    for pname, pshape in params.items():
        uses = [i for i in comp.instrs if pname in i.operands]
        _, full = _shape_elems_bytes(pshape)
        if uses and all(u.opcode == "dynamic-slice" for u in uses):
            reads += sum(_shape_elems_bytes(u.out_shape)[1] for u in uses)
        elif uses and is_inplace_target(pname):
            reads += 0
        else:
            reads += full

    def resolve(name: str, depth: int = 0):
        p = producers.get(name)
        while p is not None and p.opcode in _PASSTHRU and p.operands and depth < 12:
            p = producers.get(p.operands[0])
            depth += 1
        return p

    def write_of(name: str) -> int:
        p = resolve(name)
        if p is not None and p.opcode == "dynamic-update-slice" \
                and len(p.operands) > 1:
            ub = _shape_elems_bytes(comp.defs.get(p.operands[1], ""))[1]
            if ub:
                return ub
        if p is not None and p.opcode == "scatter" and len(p.operands) > 2:
            ub = _shape_elems_bytes(comp.defs.get(p.operands[2], ""))[1]
            if ub:
                return 3 * ub   # read slots + read updates + write slots
        return _shape_elems_bytes(comp.defs.get(name, ""))[1]

    root = comp.instrs[-1] if comp.instrs else None
    writes = 0
    if root is not None:
        if root.opcode == "tuple":
            for o in root.operands:
                writes += write_of(o)
        else:
            writes += write_of(root.name)
    return reads, writes


@dataclasses.dataclass
class HloCost:
    flops: float              # MXU flops (dot/convolution only — MFU basis)
    vector_flops: float       # elementwise/reduce VPU work (parallel unit)
    bytes: float
    wire_bytes: float
    collective_counts: Dict[str, int]
    collective_bytes_by_op: Dict[str, float]
    loop_info: Dict[str, float]


def analyze(text: str) -> HloCost:
    comps = parse_module(text)
    mult = _multiplicities(comps)
    internal = _fusion_internal(comps)

    flops = 0.0
    vflops = 0.0
    mem = 0.0
    wire = 0.0
    ccounts: Dict[str, int] = {}
    cbytes: Dict[str, float] = {}

    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m <= 0:
            continue
        is_int = internal[comp.name]
        for ins in comp.instrs:
            out_elems, out_bytes = _shape_elems_bytes(ins.out_shape)
            op = ins.opcode
            if op in _SKIP_OPS:
                continue
            coll = next((c for c in _COLLECTIVES if op.startswith(c)), None)
            # ---- FLOPs ------------------------------------------------
            if op in ("dot", "dot-general"):
                k = 1
                cd = _LHS_CDIMS.search(ins.line)
                if cd and ins.operands:
                    lhs_shape = comp.defs.get(ins.operands[0], "")
                    dims = []
                    sm = _SHAPE.search(lhs_shape)
                    if sm:
                        dims = [int(d) for d in sm.group(2).split(",") if d]
                    for di in cd.group(1).split(","):
                        if di and dims and int(di) < len(dims):
                            k *= dims[int(di)]
                flops += m * 2.0 * out_elems * k
            elif op == "reduce":
                in_elems = 0
                for o in ins.operands[:1]:
                    e, _ = _shape_elems_bytes(comp.defs.get(o, ""))
                    in_elems += e
                vflops += m * max(in_elems, out_elems)
            elif op not in ("while", "conditional", "call", "fusion"):
                vflops += m * out_elems
            # ---- bytes ------------------------------------------------
            if not is_int:
                if op in ("while", "conditional", "call"):
                    pass  # bodies account themselves
                elif op == "fusion" and ins.callees and ins.callees[0] in comps:
                    r, w = _fusion_traffic(comps[ins.callees[0]])
                    mem += m * (r + w)
                elif op == "dynamic-slice":
                    mem += m * 2.0 * out_bytes
                elif op == "dynamic-update-slice":
                    upd = (comp.defs.get(ins.operands[1], "")
                           if len(ins.operands) > 1 else "")
                    _, ub = _shape_elems_bytes(upd)
                    mem += m * 2.0 * (ub or out_bytes)
                elif op in ("gather",):
                    mem += m * 2.0 * out_bytes
                elif op in ("scatter",):
                    upd = (comp.defs.get(ins.operands[2], "")
                           if len(ins.operands) > 2 else "")
                    _, ub = _shape_elems_bytes(upd)
                    mem += m * 3.0 * (ub or out_bytes)
                elif op == "copy":
                    mem += m * 2.0 * out_bytes
                else:
                    rd = 0
                    for o in ins.operands:
                        _, b = _shape_elems_bytes(comp.defs.get(o, ""))
                        rd += b
                    mem += m * (out_bytes + rd)
            # ---- collectives -------------------------------------------
            if coll and not op.endswith("-done"):
                g = 1
                gm = _GROUPS_RE.search(ins.line)
                if gm:
                    g = max(1, gm.group(1).count(",") + 1)
                else:
                    gm2 = _GROUPS_ARR_RE.search(ins.line)
                    if gm2:
                        g = max(1, int(gm2.group(2)))
                if g <= 1:
                    continue
                frac = (g - 1) / g
                if coll == "all-reduce":
                    w = 2.0 * frac * out_bytes
                elif coll == "collective-permute":
                    w = float(out_bytes)
                elif coll == "reduce-scatter":
                    rd = sum(_shape_elems_bytes(comp.defs.get(o, ""))[1]
                             for o in ins.operands)
                    w = frac * max(rd, out_bytes)
                else:
                    w = frac * out_bytes
                wire += m * w
                ccounts[coll] = ccounts.get(coll, 0) + int(m)
                cbytes[coll] = cbytes.get(coll, 0.0) + m * w

    loop_info = {name: mv for name, mv in mult.items() if mv > 1.0}
    return HloCost(flops, vflops, mem, wire, ccounts, cbytes, loop_info)
