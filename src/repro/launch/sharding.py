"""Per-architecture sharding rules for the production mesh.

One scheme serves every mode (DESIGN.md §5):

* **TP** on ``model``: attention heads / MLP hidden / vocab.
* **FSDP** on ``data`` (+``pod`` when multi-pod): the non-TP dim of every
  >=2-D parameter is sharded ZeRO-style. For training this shards optimizer
  state; for decode XLA's SPMD partitioner keeps weights stationary and
  moves the (tiny) activations instead — weight-stationary decode, no
  per-layer weight all-gather (verified in the dry-run HLO).
* **EP** on ``data`` (+``pod``): MoE expert dim (deepseek 256e, kimi 384e —
  both divide every EP extent), expert matrices further TP-sharded on
  ``model``. Token routing crosses the EP axis as an all-to-all inserted by
  SPMD at the ``moe_expert_buf`` constraint.
* **Batch** on (``pod``, ``data``); unshardable batch (long_500k B=1) stays
  replicated and the roofline notes the idle axis.
* **SP**: recurrent state (SSM h, mLSTM S/n, conv buffers) shards its
  feature dim on ``model`` so the 500k-token cells hold O(1)-per-token state
  across TP shards.

Specs are derived by walking the param/cache trees by path — model code
never imports mesh machinery (see models/shard_hints.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import model as MD
from repro.models.common import ModelConfig

PyTree = Any


def axes_of(mesh: Mesh):
    multi = "pod" in mesh.axis_names
    F = ("pod", "data") if multi else ("data",)   # fsdp / batch / ep axes
    return F, "model", multi


def _path_keys(path) -> Tuple[str, ...]:
    out = []
    for p in path:
        k = getattr(p, "key", None)
        if k is None:
            k = getattr(p, "idx", None)
        out.append(str(k))
    return tuple(out)


def _divides(n: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return True
    if isinstance(axis, tuple):
        size = 1
        for a in axis:
            size *= mesh.shape[a]
    else:
        size = mesh.shape[axis]
    return n % size == 0


def _guard(spec: Tuple, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop any spec entry that does not divide its dim (graceful fallback)."""
    fixed = []
    for dim, ax in zip(shape, spec):
        fixed.append(ax if _divides(dim, mesh, ax) else None)
    return P(*fixed)


# ======================================================================
# parameters
# ======================================================================

def _base_param_spec(keys: Tuple[str, ...], bshape, F, M):
    """Spec for the UNSTACKED base shape; caller prepends the layer dim."""
    name = keys[-1]
    parent = keys[-2] if len(keys) >= 2 else ""
    nd = len(bshape)
    if nd <= 1:
        return (None,) * nd                      # norms, biases: replicate
    if name == "tok":
        return (M, F)
    if name == "unembed":
        return (F, M)
    if name == "pos":
        return (None, None)
    if parent == "moe" and name in ("w_gate", "w_up") and nd == 3:
        return (F, None, M)                      # (E, d, f): EP x TP
    if parent == "moe" and name == "w_down" and nd == 3:
        return (F, M, None)                      # (E, f, d)
    if name == "router":
        return (None, None)                      # (d, E): small, replicated
    if name in ("wq", "wk", "wv", "up", "in_proj", "W", "ff_up", "ff_gate",
                "w_up", "w_gate", "proj"):
        return (F, M)                            # (d_in, X)
    if name in ("wo", "down", "out_proj", "ff_down", "w_down"):
        return (M, F)                            # (X, d_out)
    if name in ("wq_a", "wkv_a"):
        return (F, None)                         # (d, rank)
    if name in ("wq_b", "wkv_b"):
        return (None, M)                         # (rank, heads*dim)
    if name in ("conv_w", "dt_proj"):
        return (None, M)
    if name in ("x_proj", "A_log", "w_i", "w_f"):
        return (M, None)
    if name == "R":
        return (None, None, M)                   # sLSTM (H, dh, 4dh)
    return (None,) * nd                          # safe default: replicate


def param_shardings(cfg: ModelConfig, mesh: Mesh, params_shape=None) -> PyTree:
    """NamedSharding tree matching init_model(cfg)'s structure."""
    F, M, _ = axes_of(mesh)
    if params_shape is None:
        params_shape = jax.eval_shape(
            lambda: MD.init_model(cfg, jax.random.PRNGKey(0)))

    def spec_of(path, leaf):
        keys = _path_keys(path)
        stacked = ("segs" in keys or "layers" in keys) and "mtp" not in keys
        shape = leaf.shape
        bshape = shape[1:] if stacked else shape
        base = _base_param_spec(keys, bshape, F, M)
        spec = ((None,) + base) if stacked else base
        return NamedSharding(mesh, _guard(spec, shape, mesh))

    return jax.tree_util.tree_map_with_path(spec_of, params_shape)


def opt_shardings(cfg: ModelConfig, mesh: Mesh, opt_shape) -> PyTree:
    """Optimizer-state shardings: m/v mirror params; adafactor vr/vc drop the
    reduced dim; scalars replicate."""
    F, M, _ = axes_of(mesh)
    pshapes = jax.eval_shape(lambda: MD.init_model(cfg, jax.random.PRNGKey(0)))

    def spec_of(path, leaf):
        keys = _path_keys(path)
        if keys and keys[-1] in ("step",):
            return NamedSharding(mesh, P())
        # strip the optimizer wrapper keys (m/v/fac/vr/vc/v) to find the
        # corresponding parameter path
        core = [k for k in keys if k not in ("m", "v", "fac", "vr", "vc")]
        stacked = ("segs" in core or "layers" in core) and "mtp" not in core
        shape = leaf.shape
        # param base spec
        name_keys = tuple(core)
        bshape_full = shape[1:] if stacked else shape
        base = _base_param_spec(name_keys, bshape_full, F, M)
        tag = keys[-1] if keys[-1] in ("vr", "vc", "v") and "fac" in keys else None
        if tag in ("vr", "vc"):
            # factored states: vr = param shape minus last dim; vc = minus 2nd
            # last. Recompute from the param's spec by dropping entries.
            try:
                pleaf = pshapes
                for k in core[:-1]:
                    pleaf = pleaf[int(k)] if k.isdigit() else pleaf[k]
                pleaf = pleaf[core[-1]] if not core[-1].isdigit() else pleaf[int(core[-1])]
                pspec = _base_param_spec(name_keys, pleaf.shape[1:] if stacked
                                         else pleaf.shape, F, M)
                pspec = ((None,) + pspec) if stacked else pspec
                spec = pspec[:-1] if tag == "vr" else pspec[:-2] + pspec[-1:]
                return NamedSharding(mesh, _guard(spec, shape, mesh))
            except Exception:
                return NamedSharding(mesh, P())
        spec = ((None,) + base) if stacked else base
        if len(spec) != len(shape):
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, _guard(spec, shape, mesh))

    return jax.tree_util.tree_map_with_path(spec_of, opt_shape)


# ======================================================================
# inputs / caches / activations
# ======================================================================

def batch_spec(mesh: Mesh, global_batch: int) -> Optional[Tuple]:
    F, M, _ = axes_of(mesh)
    size = 1
    for a in F:
        size *= mesh.shape[a]
    if global_batch % size == 0:
        return F
    if global_batch % mesh.shape[F[-1]] == 0:
        return (F[-1],)
    return None


def input_shardings(cfg: ModelConfig, mesh: Mesh, batch_shape: PyTree) -> PyTree:
    """Shardings for a batch pytree of ShapeDtypeStructs (dim 0 = batch)."""
    def spec_of(leaf):
        b = batch_spec(mesh, leaf.shape[0])
        return NamedSharding(mesh, P(b, *(None,) * (len(leaf.shape) - 1)))
    return jax.tree.map(spec_of, batch_shape)


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_shape: PyTree) -> PyTree:
    """Decode-cache shardings. Leaves are stacked (L, B, ...)."""
    F, M, _ = axes_of(mesh)

    def spec_of(path, leaf):
        keys = _path_keys(path)
        name = keys[-1]
        shape = leaf.shape
        b = batch_spec(mesh, shape[1])
        if name in ("k", "v", "xk", "xv"):        # (L,B,S,KV,dh)
            spec = (None, b, None, M, None)
        elif name in ("k_scale", "v_scale"):      # (L,B,S,KV)
            spec = (None, b, None, M)
        elif name == "kpos":                      # (L,B,S)
            spec = (None, b, None)
        elif name in ("ckv", "kr"):               # (L,B,S,rank) — MLA latent
            spec = (None, b, None, None)
        elif name == "conv":                      # (L,B,k-1,di)
            spec = (None, b, None, M)
        elif name == "h" and len(shape) == 4:     # ssm h (L,B,di,st)
            spec = (None, b, M, None)
        elif name == "S" and len(shape) == 5:     # mlstm (L,B,H,dk,dk)
            spec = (None, b, None, M, None)
        elif name == "n" and len(shape) == 4:     # mlstm n (L,B,H,dk)
            spec = (None, b, None, M)
        elif len(shape) == 3:                     # slstm h/c/n (L,B,d)
            spec = (None, b, M)
        else:
            spec = (None,) * len(shape)
        return NamedSharding(mesh, _guard(spec, shape, mesh))

    return jax.tree_util.tree_map_with_path(spec_of, cache_shape)


def paged_cache_shardings(cfg: ModelConfig, mesh: Mesh,
                          cache_shape: PyTree) -> PyTree:
    """Paged-KV store shardings (the serving engine's block-table layout,
    DESIGN.md §14). Leaves are stacked ``(L, n_pages, page_size, ...)``:
    pages are partitioned over the KV-head axis on ``model`` — every chip
    holds ALL pages for ITS heads, so the host-side block table (tiny,
    SMEM-prefetch sized) stays replicated and page ids mean the same
    thing on every shard. ``_guard`` falls back to replication when the
    KV-head count does not divide the TP extent (e.g. 2 KV heads on a
    tp=4 mesh)."""
    F, M, _ = axes_of(mesh)

    def spec_of(path, leaf):
        name = _path_keys(path)[-1]
        shape = leaf.shape
        if name in ("k", "v"):                    # (L, P, ps, KV, dh)
            spec = (None, None, None, M, None)
        elif name in ("k_scale", "v_scale"):      # (L, P, ps, KV)
            spec = (None, None, None, M)
        else:
            spec = (None,) * len(shape)
        return NamedSharding(mesh, _guard(spec, shape, mesh))

    return jax.tree_util.tree_map_with_path(spec_of, cache_shape)


# ======================================================================
# serving (tensor-parallel decode)
# ======================================================================

@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """Resolved sharding plan for one tensor-parallel serving engine
    (DESIGN.md §14): the mesh, the NamedSharding trees its params and KV
    store were placed with, and the replicated sharding for per-dispatch
    host state (positions/live masks/block tables). Entry-point names
    carry ``suffix`` so the jaxpr-audit inventory is mesh-keyed."""
    mesh: Mesh
    tp_degree: int
    params: PyTree
    cache: PyTree
    replicated: NamedSharding

    @property
    def suffix(self) -> str:
        return f"_tp{self.tp_degree}" if self.tp_degree > 1 else ""


def _shapes_of(tree: PyTree) -> PyTree:
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def serving_shard_spec(cfg: ModelConfig, mesh: Mesh, params: PyTree,
                       cache: PyTree, *, paged: bool) -> ShardSpec:
    """Build the engine's ShardSpec from concrete params and a freshly
    initialized KV store: TP param specs via the production rules, the
    cache via the dense decode rules or the paged page-store rules."""
    cache_fn = paged_cache_shardings if paged else cache_shardings
    return ShardSpec(
        mesh=mesh,
        tp_degree=mesh.shape["model"],
        params=param_shardings(cfg, mesh, _shapes_of(params)),
        cache=cache_fn(cfg, mesh, _shapes_of(cache)),
        replicated=NamedSharding(mesh, P()))


def activation_rules(cfg: ModelConfig, mesh: Mesh, global_batch: int):
    """shard_hints rules: name -> NamedSharding (None = leave to SPMD)."""
    F, M, _ = axes_of(mesh)
    b = batch_spec(mesh, global_batch)

    def rules(name: str, shape):
        ndim = len(shape)
        if name in ("act_embed", "act_resid") and ndim == 3:
            return NamedSharding(mesh, _guard((b, None, None), shape, mesh))
        if name == "act_logits" and ndim == 3:
            return NamedSharding(mesh, _guard((b, None, M), shape, mesh))
        if name in ("moe_expert_buf", "moe_expert_hidden") and ndim == 3:
            # (E, C, d|f): EP on E, capacity rows TP-sharded on model so the
            # per-chip buffer is E/ep x C/tp x d — the grouped GEMM stays
            # fully local (see DESIGN.md §5 EP).
            return NamedSharding(mesh, _guard((F, M, None), shape, mesh))
        if name == "moe_row_buf" and ndim == 4:
            # (B, E, C, d): E over EP axes; SPMD inserts the dispatch/return
            # all-to-all at the (B-sharded -> E-sharded) boundary.
            return NamedSharding(mesh, _guard((None, F, None, None), shape, mesh))
        if name == "moe_row_hidden" and ndim == 4:
            # (B, E, C, f): f TP-sharded — the within-expert Megatron split;
            # GEMM2's f-contraction psums over model.
            return NamedSharding(mesh, _guard((None, F, None, M), shape, mesh))
        if name == "moe_row_out" and ndim == 4:
            # (B, E, C, d): back to (B-shard, d-shard) — the return
            # all-to-all; the per-row combine is then a local batched gather.
            return NamedSharding(mesh, _guard((b, None, None, M), shape, mesh))
        if name == "moe_row_payload" and ndim == 3:
            # (B, S|S*k, d): dispatch payloads (B-shard, d-shard) — index ops
            # are elementwise in d, so scatter/gather AND their backward stay
            # collective-free.
            return NamedSharding(mesh, _guard((b, None, M), shape, mesh))
        return None

    return rules
