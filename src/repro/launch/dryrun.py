import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input shape x mesh) cell: lower the real
train/prefill/serve step against ShapeDtypeStruct inputs with the
production shardings, ``.compile()`` it, and record memory analysis,
cost analysis, and the collective schedule for §Dry-run / §Roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite_3_2b \
        --shape train_4k --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single

Each cell writes experiments/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED, get_config
from repro.launch import hlo_cost as HC
from repro.launch import roofline as RL
from repro.launch import sharding as SH
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import (SHAPES, SHAPE_BY_NAME, input_specs,
                                 skip_reason)
from repro.models import model as MD
from repro.models.shard_hints import sharding_rules
from repro.training.optimizer import AdamWConfig, adafactor_init, adamw_init
from repro.training.train import make_train_step

# AdamW fp32 m/v for >=100B params exceeds per-chip HBM even fully sharded;
# these train with factored second moments (DESIGN.md §5).
ADAFACTOR_ARCHS = {"deepseek_v3_671b", "kimi_k2_1t_a32b",
                   "command_r_plus_104b"}

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _mem_dict(compiled) -> Dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # backend without memory analysis
        return {"error": str(e)}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = int(v)
    if not out:
        out["repr"] = str(ma)
    args = out.get("argument_size_in_bytes", 0)
    alias = out.get("alias_size_in_bytes", 0)
    out["peak_bytes_est"] = (args - alias + out.get("output_size_in_bytes", 0)
                             + out.get("temp_size_in_bytes", 0))
    return out


def _cost_dict(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:
        return {"error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float))}


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               save_hlo: bool = False,
               overrides: Dict[str, Any] = None) -> Dict[str, Any]:
    t0 = time.time()
    shape_cell = SHAPE_BY_NAME[shape_name]
    reason = skip_reason(arch, shape_cell)
    mesh_name = "multi" if multi_pod else "single"
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name}
    if reason:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    tp = mesh.shape["model"]
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
        rec["overrides"] = {k: str(v) for k, v in overrides.items()}
    cfg = cfg.padded_for_tp(tp)
    specs = input_specs(cfg, shape_cell)
    rules = SH.activation_rules(cfg, mesh, shape_cell.global_batch)

    param_shape = jax.eval_shape(lambda: MD.init_model(cfg, jax.random.PRNGKey(0)))
    pshard = SH.param_shardings(cfg, mesh, param_shape)
    bspec = SH.batch_spec(mesh, shape_cell.global_batch)

    with mesh, sharding_rules(rules):
        if shape_cell.kind == "train":
            opt_kind = ("adafactor" if arch in ADAFACTOR_ARCHS else "adamw")
            init_opt = adafactor_init if opt_kind == "adafactor" else adamw_init
            opt_shape = jax.eval_shape(init_opt, param_shape)
            oshard = SH.opt_shardings(cfg, mesh, opt_shape)
            bshard = SH.input_shardings(cfg, mesh, specs)
            # per-device batch memory knob: 8 grad-accumulation microbatches
            # at the production batch (activations scale 1/8, wire bytes same)
            mb = 8 if shape_cell.global_batch >= 256 else 1
            step = make_train_step(cfg, AdamWConfig(), optimizer=opt_kind,
                                   microbatches=mb)
            jf = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                         out_shardings=(pshard, oshard, None),
                         donate_argnums=(0, 1))
            lowered = jf.lower(param_shape, opt_shape, specs)
        elif shape_cell.kind == "prefill":
            S = shape_cell.seq_len
            bshard = SH.input_shardings(cfg, mesh, specs)

            def pf(params, batch):
                logits, cache, _ = MD.prefill(
                    cfg, params, batch["tokens"], max_len=S,
                    lengths=batch.get("lengths"),
                    frames=batch.get("frames"), patches=batch.get("patches"))
                return logits, cache

            jf = jax.jit(pf, in_shardings=(pshard, bshard))
            lowered = jf.lower(param_shape, specs)
        else:  # decode
            cache_shape = specs["cache"]
            cshard = SH.cache_shardings(cfg, mesh, cache_shape)
            tsh = NamedSharding(mesh, P(bspec, None))
            psh = NamedSharding(mesh, P(bspec))

            def df(params, tokens, positions, cache):
                return MD.decode_step(cfg, params, tokens, positions, cache)

            jf = jax.jit(df, in_shardings=(pshard, tsh, psh, cshard),
                         out_shardings=(None, cshard), donate_argnums=(3,))
            lowered = jf.lower(param_shape, specs["tokens"],
                               specs["positions"], cache_shape)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = _mem_dict(compiled)
    cost = _cost_dict(compiled)
    hlo = compiled.as_text()
    # trip-count-aware costs (cost_analysis counts while bodies ONCE —
    # see launch/hlo_cost.py); raw cost_analysis kept alongside in the JSON
    hc = HC.analyze(hlo)
    cost = dict(cost, raw_flops=cost.get("flops", 0.0),
                raw_bytes=cost.get("bytes accessed", 0.0))
    cost["flops"] = hc.flops
    cost["vector flops"] = hc.vector_flops
    cost["bytes accessed"] = hc.bytes
    mf = RL.model_flops(get_config(arch), shape_cell)
    tree_bytes = lambda t: sum(
        float(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
        for l in jax.tree_util.tree_leaves(t))
    useful = 0.0
    if shape_cell.kind == "decode":
        # decode's intrinsic traffic: read active params + the KV/state once
        _, act = RL.model_param_counts(get_config(arch))
        useful = act * jnp.dtype(cfg.dtype).itemsize + tree_bytes(specs["cache"])
    report = RL.build_report(arch, shape_cell, mesh_name, chips, cost, hlo,
                             mf, mem.get("peak_bytes_est"), useful,
                             wire_bytes=hc.wire_bytes,
                             coll_counts=hc.collective_counts)
    rec.update({
        "status": "ok", "chips": chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory_analysis": mem, "cost_analysis": cost,
        "collectives": report.collectives,
        "wire_bytes_per_dev": report.wire_bytes_per_dev,
        "roofline": {
            "flops_per_dev": report.flops_per_dev,
            "bytes_per_dev": report.bytes_per_dev,
            "compute_s": report.compute_s,
            "memory_s": report.memory_s,
            "collective_s": report.collective_s,
            "dominant": report.dominant,
            "model_flops": mf,
            "useful_ratio": report.useful_ratio,
            "roofline_fraction": report.roofline_fraction,
        },
    })
    if save_hlo:
        rec["hlo_path"] = os.path.join(OUT_DIR, f"{arch}__{shape_name}__{mesh_name}.hlo")
        with open(rec["hlo_path"], "w") as f:
            f.write(hlo)
    print(report.row(), flush=True)
    print("  memory:", {k: v for k, v in mem.items() if k != "repr"}, flush=True)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=[s.name for s in SHAPES] + [None])
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    os.makedirs(OUT_DIR, exist_ok=True)
    archs = ASSIGNED if (args.all or not args.arch) else [args.arch]
    shapes = [s.name for s in SHAPES] if (args.all or not args.shape) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "multi" if mp else "single"
                out = os.path.join(OUT_DIR, f"{arch}__{shape}__{mesh_name}.json")
                try:
                    rec = lower_cell(arch, shape, mp, save_hlo=args.save_hlo)
                except Exception as e:
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "error", "error": f"{type(e).__name__}: {e}"}
                    failures += 1
                with open(out, "w") as f:
                    json.dump(rec, f, indent=1)
                if rec.get("status") == "skipped":
                    print(f"SKIP {arch} {shape} {mesh_name}: {rec['reason']}",
                          flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
