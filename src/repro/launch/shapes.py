"""The assigned input-shape cells and per-(arch x shape) input specs.

Every spec is a ShapeDtypeStruct pytree — weak-type-correct, shardable, no
device allocation — exactly what ``jax.jit(...).lower()`` wants.

  train_4k      seq 4096,   global_batch 256   -> train_step
  prefill_32k   seq 32768,  global_batch 32    -> prefill
  decode_32k    seq 32768 KV, global_batch 128 -> serve_step (1 new token)
  long_500k     seq 524288 KV, global_batch 1  -> serve_step; sub-quadratic
                archs only (hymba sliding-window+SSM, xlstm recurrent) —
                pure full-attention archs skip with a note (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import model as MD
from repro.models.common import ModelConfig

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", "train", 4096, 256),
    ShapeCell("prefill_32k", "prefill", 32768, 32),
    ShapeCell("decode_32k", "decode", 32768, 128),
    ShapeCell("long_500k", "decode", 524288, 1),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}

# archs able to run 524288-token decode sub-quadratically
LONG_CONTEXT_OK = {"hymba_1_5b", "xlstm_1_3b"}


def skip_reason(arch: str, shape: ShapeCell) -> Optional[str]:
    if shape.name == "long_500k" and arch not in LONG_CONTEXT_OK:
        return ("pure full-attention architecture: 524288-token KV is "
                "quadratic/undeployable; skipped per assignment "
                "(DESIGN.md §6)")
    return None


def train_specs(cfg: ModelConfig, shape: ShapeCell) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": SDS((B, S), jnp.int32),
             "labels": SDS((B, S), jnp.int32)}
    if cfg.family == "encdec":
        batch["tokens"] = SDS((B, min(S, 4096)), jnp.int32)
        batch["labels"] = SDS((B, min(S, 4096)), jnp.int32)
        batch["frames"] = SDS((B, cfg.enc_seq, cfg.d_model), cfg.compute_dtype)
    if cfg.family == "vlm":
        batch["tokens"] = SDS((B, S - cfg.n_patches), jnp.int32)
        batch["labels"] = SDS((B, S - cfg.n_patches), jnp.int32)
        batch["patches"] = SDS((B, cfg.n_patches, cfg.d_model), cfg.compute_dtype)
    return batch


def prefill_specs(cfg: ModelConfig, shape: ShapeCell) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    out: Dict[str, Any] = {}
    if cfg.family == "encdec":
        out["tokens"] = SDS((B, min(S, 4096)), jnp.int32)
        out["frames"] = SDS((B, cfg.enc_seq, cfg.d_model), cfg.compute_dtype)
    elif cfg.family == "vlm":
        out["tokens"] = SDS((B, S - cfg.n_patches), jnp.int32)
        out["patches"] = SDS((B, cfg.n_patches, cfg.d_model), cfg.compute_dtype)
    else:
        out["tokens"] = SDS((B, S), jnp.int32)
    out["lengths"] = SDS((B,), jnp.int32)
    return out


def decode_specs(cfg: ModelConfig, shape: ShapeCell) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    cache_shape = jax.eval_shape(lambda: MD.init_cache(cfg, B, S))
    return {"tokens": SDS((B, 1), jnp.int32),
            "positions": SDS((B,), jnp.int32),
            "cache": cache_shape}


def input_specs(cfg: ModelConfig, shape: ShapeCell) -> Dict[str, Any]:
    if shape.kind == "train":
        return train_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_specs(cfg, shape)
    return decode_specs(cfg, shape)
