"""Distributed-optimization tricks for cross-pod training at scale.

These compose as ``grad_transform`` hooks inside the (shard_mapped) train
step — each is a pure function of gradients + mesh axis names:

* ``bucketed_psum``       — flatten grads into ~bucket_bytes buckets; one
  collective per bucket instead of per tensor. Buckets are issued in layer
  order so on hardware each all-reduce overlaps the next bucket's backward
  compute (XLA latency-hiding scheduler handles the interleave; bucket size
  is the overlap granularity knob).
* ``compressed_psum``     — int8-on-the-wire cross-pod all-reduce:
  reduce-scatter int8 chunks (all_to_all) -> local fp32 sum -> requantize ->
  all_gather int8. Wire bytes drop 4x vs fp32; per-chunk fp32 scales ride
  along (amortized, <1%). This is what shrinks the collective roofline term
  on the slow cross-pod (DCI) axis.
* ``periodic_sync``       — local-SGD style: sync every k steps (lax.cond),
  trading staleness for a k-fold cut in cross-pod traffic.

Composition used by the launcher: fast in-pod axes always run fp32
``bucketed_psum``; the slow cross-pod axis runs compressed and/or periodic.
"""
from __future__ import annotations

from typing import Any, List, Optional

import jax
import jax.numpy as jnp

from repro.launch.mesh import axis_size
import numpy as np

PyTree = Any


# ----------------------------------------------------------------------
# bucketing
# ----------------------------------------------------------------------

def _bucket_layout(tree: PyTree, bucket_bytes: int):
    leaves, tdef = jax.tree.flatten(tree)
    sizes = [int(np.prod(l.shape)) for l in leaves]
    buckets: List[List[int]] = [[]]
    acc = 0
    for i, s in enumerate(sizes):
        if acc + s * 4 > bucket_bytes and buckets[-1]:
            buckets.append([])
            acc = 0
        buckets[-1].append(i)
        acc += s * 4
    return leaves, tdef, sizes, buckets


def bucketed_psum(tree: PyTree, axis_name: str,
                  bucket_bytes: int = 4 << 20) -> PyTree:
    """One psum per ~bucket_bytes of gradients (issued in layer order)."""
    leaves, tdef, sizes, buckets = _bucket_layout(tree, bucket_bytes)
    out: List[Optional[jnp.ndarray]] = [None] * len(leaves)
    for idxs in buckets:
        flat = jnp.concatenate([leaves[i].reshape(-1).astype(jnp.float32)
                                for i in idxs])
        red = jax.lax.psum(flat, axis_name)
        off = 0
        for i in idxs:
            out[i] = red[off: off + sizes[i]].reshape(leaves[i].shape
                                                      ).astype(leaves[i].dtype)
            off += sizes[i]
    return jax.tree.unflatten(tdef, out)


# ----------------------------------------------------------------------
# int8-on-the-wire all-reduce
# ----------------------------------------------------------------------

def _quantize_chunks(x: jnp.ndarray, n: int):
    """x: (L,) fp32 -> int8 (n, L/n) + per-chunk scales (n,)."""
    xc = x.reshape(n, -1)
    amax = jnp.max(jnp.abs(xc), axis=1)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xc / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(tree: PyTree, axis_name: str,
                    bucket_bytes: int = 4 << 20) -> PyTree:
    """All-reduce with int8 wire format (reduce-scatter + all-gather).

    Each device quantizes its bucket into N chunks (N = axis size), sends
    chunk j to device j (all_to_all, int8), locally dequantizes + sums its
    owned chunk in fp32, requantizes, and all-gathers the int8 result.
    """
    n = axis_size(axis_name)
    leaves, tdef, sizes, buckets = _bucket_layout(tree, bucket_bytes)
    out: List[Optional[jnp.ndarray]] = [None] * len(leaves)
    for idxs in buckets:
        flat = jnp.concatenate([leaves[i].reshape(-1).astype(jnp.float32)
                                for i in idxs])
        L = flat.shape[0]
        pad = (-L) % n
        if pad:
            flat = jnp.pad(flat, (0, pad))
        q, scale = _quantize_chunks(flat, n)              # (n, C) int8
        # reduce-scatter: device j receives everyone's chunk j
        qt = jax.lax.all_to_all(q[:, None], axis_name, 0, 1,
                                tiled=False)              # (1, n, C)
        st = jax.lax.all_gather(scale, axis_name)          # (n, n)
        mine = jnp.sum(qt[0].astype(jnp.float32)
                       * st[:, jax.lax.axis_index(axis_name)][:, None], axis=0)
        # requantize my reduced chunk, all-gather int8 + scales
        amax = jnp.maximum(jnp.max(jnp.abs(mine)), 1e-12)
        s2 = amax / 127.0
        q2 = jnp.clip(jnp.round(mine / s2), -127, 127).astype(jnp.int8)
        qg = jax.lax.all_gather(q2, axis_name)             # (n, C) int8 wire
        sg = jax.lax.all_gather(s2, axis_name)             # (n,)
        red = (qg.astype(jnp.float32) * sg[:, None]).reshape(-1)[:L]
        off = 0
        for i in idxs:
            out[i] = red[off: off + sizes[i]].reshape(leaves[i].shape
                                                      ).astype(leaves[i].dtype)
            off += sizes[i]
    return jax.tree.unflatten(tdef, out)


# ----------------------------------------------------------------------
# periodic (local-SGD) sync
# ----------------------------------------------------------------------

def periodic_sync(tree: PyTree, axis_name: str, step, every: int,
                  sync_fn=None) -> PyTree:
    """Cross-axis sync only when step % every == 0; otherwise local grads.
    (Bounded-staleness local SGD; cross-pod traffic / every.)"""
    sync = sync_fn or (lambda t: bucketed_psum(t, axis_name))
    do = (step % every) == 0

    def mean_branch(t):
        n = axis_size(axis_name)
        return jax.tree.map(lambda x: x / n, sync(t))

    return jax.lax.cond(do, mean_branch, lambda t: t, tree)


def pmean(tree: PyTree, axis_name: str) -> PyTree:
    n = axis_size(axis_name)
    return jax.tree.map(lambda x: x / n, bucketed_psum(tree, axis_name))
