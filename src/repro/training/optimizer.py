"""AdamW (self-contained, optax-free) + LR schedules (cosine, WSD).

WSD (warmup–stable–decay) is the MiniCPM schedule: linear warmup, long
constant plateau, then a short exponential-ish decay tail — wired in because
minicpm-2b is one of the assigned architectures.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params: PyTree) -> PyTree:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: PyTree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads: PyTree, state: PyTree,
                 params: PyTree, lr_scale=1.0):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * clip
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m2 / (1 - cfg.b1 ** step)
        vhat = v2 / (1 - cfg.b2 ** step)
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - cfg.lr * lr_scale * delta
        return p2.astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm}


# ----------------------------------------------------------------------
# Adafactor (factored second moment, no momentum) — for 100B–1T-param
# architectures where AdamW's fp32 m/v (8 bytes/param) exceeds per-chip HBM
# even fully sharded. State is O(rows+cols) per matrix: ~1000x smaller.
# ----------------------------------------------------------------------

def adafactor_init(params: PyTree) -> PyTree:
    def leaf_state(p):
        if p.ndim >= 2:
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"fac": jax.tree.map(leaf_state, params,
                                is_leaf=lambda x: hasattr(x, "ndim")),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(cfg: AdamWConfig, grads: PyTree, state: PyTree,
                     params: PyTree, lr_scale=1.0):
    step = state["step"] + 1
    b2 = 1.0 - jnp.asarray(step, jnp.float32) ** -0.8   # schedule from paper
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    def upd(g, st, p):
        g = g.astype(jnp.float32) * clip
        g2 = g * g + 1e-30
        if p.ndim >= 2:
            vr = b2 * st["vr"] + (1 - b2) * jnp.mean(g2, axis=-1)
            vc = b2 * st["vc"] + (1 - b2) * jnp.mean(g2, axis=-2)
            denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), 1e-30)
            v = vr[..., None] * vc[..., None, :] / denom[..., None]
            new_st = {"vr": vr, "vc": vc}
        else:
            v = b2 * st["v"] + (1 - b2) * g2
            new_st = {"v": v}
        update = g / jnp.sqrt(v + cfg.eps)
        # update clipping (RMS <= 1) stabilizes factored estimates
        rms = jnp.sqrt(jnp.mean(jnp.square(update)) + 1e-30)
        update = update / jnp.maximum(1.0, rms)
        p2 = (p.astype(jnp.float32)
              - cfg.lr * lr_scale * (update + cfg.weight_decay * p.astype(jnp.float32)))
        return p2.astype(p.dtype), new_st

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_s = tdef.flatten_up_to(state["fac"])
    out = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_s = tdef.unflatten([o[1] for o in out])
    return new_p, {"fac": new_s, "step": step}, {"grad_norm": gnorm}


# ----------------------------------------------------------------------
# schedules: step -> lr multiplier in [0, 1]
# ----------------------------------------------------------------------

def cosine_schedule(total_steps: int, warmup: int = 0,
                    min_frac: float = 0.1) -> Callable:
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / max(total_steps - warmup, 1), 0, 1)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return warm * cos
    return f


def wsd_schedule(total_steps: int, warmup: int = 0,
                 decay_frac: float = 0.1, min_frac: float = 0.1) -> Callable:
    """MiniCPM warmup-stable-decay: plateau at 1.0, decay in the last
    ``decay_frac`` of training."""
    decay_start = int(total_steps * (1 - decay_frac))

    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        in_decay = step > decay_start
        prog = jnp.clip((step - decay_start) / max(total_steps - decay_start, 1), 0, 1)
        decay = min_frac ** prog     # exponential tail (MiniCPM-style)
        return warm * jnp.where(in_decay, decay, 1.0)
    return f
