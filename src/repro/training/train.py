"""Train step: loss -> grads -> AdamW, with gradient-accumulation
microbatching (sequential ``lax.scan`` over microbatches so peak activation
memory is 1/k of the global batch) and pluggable distributed grad sync
(see training/distributed.py). The model applies per-layer remat itself
(cfg.remat)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import model as MD
from repro.models.common import ModelConfig
from repro.training.optimizer import (AdamWConfig, adafactor_update,
                                      adamw_init, adamw_update)

PyTree = Any


@dataclasses.dataclass
class TrainState:
    params: PyTree
    opt: PyTree
    step: int = 0


def train_state_init(cfg: ModelConfig, key) -> TrainState:
    params = MD.init_model(cfg, key)
    return TrainState(params, adamw_init(params), 0)


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig = AdamWConfig(),
                    *, microbatches: int = 1,
                    schedule: Optional[Callable] = None,
                    grad_transform: Optional[Callable] = None,
                    optimizer: str = "adamw") -> Callable:
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``grad_transform(grads) -> grads`` is the hook where the launcher
    installs cross-pod gradient sync (bucketed / compressed / periodic).
    ``optimizer``: "adamw" | "adafactor" (factored states for 100B+ archs).
    """
    update_fn = adamw_update if optimizer == "adamw" else adafactor_update

    def loss_of(params, mb):
        return MD.loss_fn(cfg, params, mb)

    def step(params, opt_state, batch):
        if microbatches > 1:
            def split(x):
                B = x.shape[0]
                assert B % microbatches == 0
                return x.reshape(microbatches, B // microbatches, *x.shape[1:])
            mbs = jax.tree.map(split, batch)

            def accum(carry, mb):
                g_acc, l_acc = carry
                (loss, metrics), g = jax.value_and_grad(loss_of, has_aux=True)(params, mb)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + loss), metrics

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), metrics = jax.lax.scan(accum, (g0, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(
                params, batch)
        if grad_transform is not None:
            grads = grad_transform(grads)
        lr_scale = schedule(opt_state["step"]) if schedule is not None else 1.0
        params, opt_state, opt_metrics = update_fn(
            opt_cfg, grads, opt_state, params, lr_scale)
        metrics = dict(metrics, **opt_metrics, loss=loss)
        return params, opt_state, metrics

    return step
