"""Sharded, elastic checkpointing.

Layout on disk:
    <dir>/manifest.json        tree structure, shapes, dtypes, shard map
    <dir>/shard_<k>.npz        leaf chunks owned by (simulated) host k

Leaves are chunked along axis 0 across ``n_shards`` writers (each host
writes only its own shard — no gather through one host). ``restore`` reads
whatever shard count exists and re-assembles, then ``device_put``s against
*any* target sharding — so a checkpoint written on a 512-chip mesh restores
onto 256 or 1024 chips unchanged (elastic scale up/down). Atomicity: writes
go to <dir>.tmp then rename, so a preempted save never corrupts the last
good checkpoint (fault tolerance / restart path).
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
_SEP = "/"


def _flatten_with_paths(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def save(tree: PyTree, directory: str, *, step: int = 0,
         n_shards: int = 4, extra: Optional[Dict] = None) -> None:
    tmp = directory + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten_with_paths(tree)
    manifest = {"step": step, "n_shards": n_shards, "extra": extra or {},
                "leaves": {}}
    shard_data: Dict[int, Dict[str, np.ndarray]] = {k: {} for k in range(n_shards)}
    for key, arr in flat.items():
        manifest["leaves"][key] = {"shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
        if arr.ndim == 0 or arr.shape[0] < n_shards:
            shard_data[0][key] = arr            # tiny leaf: single shard
            manifest["leaves"][key]["shards"] = [0]
        else:
            chunks = np.array_split(arr, n_shards, axis=0)
            manifest["leaves"][key]["shards"] = list(range(n_shards))
            for k, ch in enumerate(chunks):
                shard_data[k][key] = ch
    for k, data in shard_data.items():
        np.savez(os.path.join(tmp, f"shard_{k}.npz"),
                 **{key.replace("/", "!"): v for key, v in data.items()})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(directory):
        shutil.rmtree(directory)
    os.rename(tmp, directory)                   # atomic publish


def restore(directory: str, target_tree: PyTree,
            shardings: Optional[PyTree] = None) -> PyTree:
    """Rebuild a pytree like ``target_tree`` (structure donor). If
    ``shardings`` (same structure, NamedSharding leaves) is given, leaves are
    device_put against it — this is the elastic re-mesh path."""
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    shards = {}
    for k in range(manifest["n_shards"]):
        p = os.path.join(directory, f"shard_{k}.npz")
        if os.path.exists(p):
            shards[k] = np.load(p)
    flat_target, tdef = jax.tree_util.tree_flatten_with_path(target_tree)
    flat_shardings = (jax.tree_util.tree_leaves(shardings)
                      if shardings is not None else [None] * len(flat_target))
    leaves = []
    for (path, leaf), shd in zip(flat_target, flat_shardings):
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        meta = manifest["leaves"][key]
        fkey = key.replace("/", "!")
        parts = [shards[k][fkey] for k in meta["shards"] if fkey in shards[k].files]
        arr = parts[0] if len(parts) == 1 else np.concatenate(parts, axis=0)
        arr = arr.reshape(meta["shape"]).astype(meta["dtype"])
        if shd is not None:
            leaves.append(jax.device_put(arr, shd))
        else:
            leaves.append(jnp.asarray(arr))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target_tree), leaves)
    return tree


def latest_step(directory: str) -> int:
    with open(os.path.join(directory, "manifest.json")) as f:
        return json.load(f)["step"]
