"""Synthetic LM data pipeline.

A seeded order-2 Markov token source with genuine structure (so training
loss actually falls below unigram entropy) plus deterministic batch
sharding. ``SyntheticLM`` is the offline stand-in for a tokenized corpus
reader; the interface (``batch(step) -> {tokens, labels}``) matches what a
real loader would expose.
"""
from __future__ import annotations

import zlib
from typing import Dict, Iterator

import numpy as np


class SyntheticLM:
    def __init__(self, vocab_size: int, seed: int = 0, branch: int = 4):
        self.vocab = vocab_size
        rng = np.random.default_rng(seed)
        # sparse order-2 transitions: each (a, b) context allows `branch`
        # successors with dirichlet weights -> learnable structure
        self.next_tok = rng.integers(0, vocab_size,
                                     size=(vocab_size, branch)).astype(np.int64)
        w = rng.dirichlet(np.ones(branch) * 0.5, size=vocab_size)
        self.next_p = w.astype(np.float64)

    def sample(self, rng: np.random.Generator, batch: int, seq: int) -> np.ndarray:
        toks = np.empty((batch, seq + 1), np.int64)
        cur = rng.integers(0, self.vocab, size=batch)
        toks[:, 0] = cur
        for t in range(1, seq + 1):
            rows = self.next_tok[cur]                      # (B, branch)
            pick = np.array([rng.choice(r.shape[0], p=p)
                             for r, p in zip(rows, self.next_p[cur])])
            cur = rows[np.arange(batch), pick]
            toks[:, t] = cur
        return toks

    def batch(self, step: int, batch: int, seq: int) -> Dict[str, np.ndarray]:
        # crc32, not hash(): batch contents must not vary with PYTHONHASHSEED
        rng = np.random.default_rng(zlib.crc32(f"batch:{step}".encode()))
        toks = self.sample(rng, batch, seq)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


def batches(vocab_size: int, batch: int, seq: int, n_steps: int,
            seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    src = SyntheticLM(vocab_size, seed)
    for step in range(n_steps):
        yield src.batch(step, batch, seq)
