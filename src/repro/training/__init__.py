"""Training substrate: AdamW + schedules, microbatched train step,
synthetic data pipeline, sharded/elastic checkpointing, and the
distributed-optimization tricks (bucketed+compressed+periodic grad sync).
"""
from repro.training.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                      cosine_schedule, wsd_schedule)
from repro.training.train import make_train_step, TrainState, train_state_init
from repro.training.data import SyntheticLM, batches
from repro.training import checkpoint
from repro.training import distributed

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
           "wsd_schedule", "make_train_step", "TrainState",
           "train_state_init", "SyntheticLM", "batches", "checkpoint",
           "distributed"]
