"""GPipe-style pipeline parallelism over a mesh axis (off by default).

Each device on the ``stage`` axis owns one contiguous stage's parameters;
microbatches flow stage-to-stage via ``lax.ppermute`` inside ``shard_map``.
The schedule is the classic GPipe ramp: M microbatches over S stages take
M + S - 1 ticks with (S-1)/(M+S-1) bubble overhead — choose M >= 4S to keep
the bubble under 20%. Designed for the ``pod`` axis of the production mesh
(cross-pod DCI hops carry exactly one microbatch activation per tick, the
cheapest possible inter-pod pattern for deep models).

``pipeline_apply`` is mesh-agnostic: it runs inside any shard_map whose
``axis_name`` enumerates stages; see tests/test_pipeline.py for the wiring.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.launch.mesh import axis_size

PyTree = Any


def pipeline_apply(fn: Callable, stage_params: PyTree, microbatches,
                   axis_name: str = "stage"):
    """Run ``y_mb = fn(stage_params, x_mb)`` through S pipeline stages.

    ``fn``: one stage's computation (shape-preserving on the activation).
    ``stage_params``: THIS device's stage parameters (shard_map slices the
    stage axis before calling us).
    ``microbatches``: (M, ...) activations, replicated across stages.
    Returns (M, ...) outputs (replicated across stages after the final
    collect).
    """
    S = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    M = microbatches.shape[0]
    T = M + S - 1
    perm = [(i, (i + 1) % S) for i in range(S)]

    buf = jnp.zeros_like(microbatches[0])
    outs = jnp.zeros_like(microbatches)

    def tick(carry, t):
        buf, outs = carry
        # stage 0 ingests microbatch t while t < M; later stages consume
        # what the previous stage handed over on the last tick.
        inject = microbatches[jnp.clip(t, 0, M - 1)]
        x = jnp.where(idx == 0, inject, buf)
        y = fn(stage_params, x)
        handoff = jax.lax.ppermute(y, axis_name, perm)
        om = t - (S - 1)
        write = jnp.logical_and(idx == S - 1, om >= 0)
        outs = outs.at[jnp.clip(om, 0, M - 1)].set(
            jnp.where(write, y, outs[jnp.clip(om, 0, M - 1)]))
        return (handoff, outs), None

    (_, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(T))
    # results live on the last stage; replicate to every stage
    outs = jax.lax.psum(jnp.where(idx == S - 1, outs, jnp.zeros_like(outs)),
                        axis_name)
    return outs


def bubble_fraction(n_microbatches: int, n_stages: int) -> float:
    """GPipe idle fraction — the napkin number behind 'M >= 4S'."""
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
