"""llama2-13b — the paper's inference model (SPROUT serves this).
40L d_model=5120 40H (MHA) d_ff=13824 vocab=32000. [arXiv:2307.09288]"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama2-13b", family="dense",
        n_layers=40, d_model=5120, n_heads=40, n_kv_heads=40,
        d_ff=13824, vocab_size=32000,
        act="silu", norm="rmsnorm", pos="rope",
        dtype="bfloat16", remat="full", attn_impl="blocked",
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160,
        vocab_size=256, dtype="float32", remat="none", attn_impl="xla")
