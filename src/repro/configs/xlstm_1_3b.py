"""xlstm-1.3b [ssm] — 48L d_model=2048 4H d_ff=0 vocab=50304 —
sLSTM + mLSTM blocks (7:1 ratio). [arXiv:2405.04517]"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b", family="ssm",
        n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=50304,
        block_pattern=("m",) * 7 + ("s",), proj_factor=2.0, chunk_size=64,
        act="gelu", norm="layernorm", pos="none",
        tie_embeddings=True, dtype="bfloat16", remat="full",
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=4, d_model=64, n_heads=2, n_kv_heads=2,
        block_pattern=("m", "m", "m", "s"), chunk_size=8,
        vocab_size=256, dtype="float32", remat="none")
