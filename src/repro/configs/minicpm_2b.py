"""minicpm-2b [dense] — 40L d_model=2304 36H (GQA kv=36) d_ff=5760
vocab=122753. WSD schedule (arch=llama-like). [arXiv:2404.06395]"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm-2b", family="dense",
        n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36,
        d_ff=5760, vocab_size=122753,
        act="silu", norm="rmsnorm", pos="rope", rope_theta=10000.0,
        tie_embeddings=True, dtype="bfloat16", remat="full",
        attn_impl="blocked",
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=72, n_heads=6, n_kv_heads=6, d_ff=144,
        vocab_size=256, dtype="float32", remat="none", attn_impl="xla")
