"""llama2-7b — the paper's MODEL_OPT small variant.
32L d_model=4096 32H (MHA) d_ff=11008 vocab=32000. [arXiv:2307.09288]"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama2-7b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
        d_ff=11008, vocab_size=32000,
        act="silu", norm="rmsnorm", pos="rope",
        dtype="bfloat16", remat="full", attn_impl="blocked",
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256, dtype="float32", remat="none", attn_impl="xla")
