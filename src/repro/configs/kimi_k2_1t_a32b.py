"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) d_ff=2048 (moe)
vocab=163840, MoE 384e top-8 — trillion-param MoE. [arXiv:2501.kimi2]

Kimi K2 keeps the DeepSeek-V3 backbone shape but with 384 experts, 64
attention heads and 1 dense layer. The assignment table lists GQA kv=8.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe",
        n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
        d_ff=18432, vocab_size=163840,
        n_experts=384, n_shared_experts=1, top_k=8, moe_d_ff=2048,
        n_dense_layers=1, capacity_factor=1.25,
        act="silu", norm="rmsnorm", pos="rope",
        dtype="bfloat16", remat="full", attn_impl="blocked",
        moe_impl="rowwise",
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        n_experts=8, top_k=2, moe_d_ff=32, n_dense_layers=1,
        vocab_size=256, capacity_factor=4.0,
        dtype="float32", remat="none", attn_impl="xla")
