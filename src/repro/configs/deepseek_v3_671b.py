"""deepseek-v3-671b [moe] — 61L d_model=7168 128H d_ff=2048 (moe)
vocab=129280, MoE 256e top-8 — MLA, 1 shared + 256 routed top-8, MTP.
[arXiv:2412.19437]

MLA dims from the paper: q_lora 1536, kv_lora 512, qk_nope 128, qk_rope 64,
v_head 128. First 3 layers dense FFN (d_ff=18432), then MoE with
moe_d_ff=2048 per expert.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", family="moe",
        n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
        d_ff=18432, vocab_size=129280,
        attn_type="mla", q_lora_rank=1536, kv_lora_rank=512,
        qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
        n_experts=256, n_shared_experts=1, top_k=8, moe_d_ff=2048,
        n_dense_layers=3, capacity_factor=1.25, mtp_depth=1,
        act="silu", norm="rmsnorm", pos="rope",
        dtype="bfloat16", remat="full", attn_impl="blocked",
        moe_impl="rowwise",
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        q_lora_rank=24, kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
        v_head_dim=16, n_experts=8, top_k=2, moe_d_ff=32,
        n_dense_layers=1, vocab_size=256, mtp_depth=1, capacity_factor=4.0,
        dtype="float32", remat="none", attn_impl="xla")
