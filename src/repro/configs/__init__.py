"""Architecture registry: ``get_config(arch_id)`` / ``reduced(arch_id)``.

One module per assigned architecture (exact numbers from the assignment
table) plus the paper's own Llama2 7B/13B inference models. ``reduced()``
returns a same-family config small enough for a CPU smoke test.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.common import ModelConfig

ARCH_IDS: List[str] = [
    "granite_3_2b",
    "minicpm_2b",
    "command_r_plus_104b",
    "starcoder2_15b",
    "hymba_1_5b",
    "deepseek_v3_671b",
    "kimi_k2_1t_a32b",
    "xlstm_1_3b",
    "whisper_base",
    "internvl2_26b",
    "llama2_13b",
    "llama2_7b",
]

ASSIGNED: List[str] = ARCH_IDS[:10]

_ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}


def canonical(arch: str) -> str:
    a = arch.replace("-", "_")
    if a not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return a


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.config()


def reduced(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.reduced()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
