"""starcoder2-15b [dense] — 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152. GQA, RoPE, sliding-window 4096, learned bias. [arXiv:2402.19173]"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b", family="dense",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4,
        d_ff=24576, vocab_size=49152,
        act="gelu", norm="layernorm", use_bias=True, pos="rope",
        rope_theta=100_000.0, sliding_window=4096,
        dtype="bfloat16", remat="full", attn_impl="blocked",
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, sliding_window=16, dtype="float32", remat="none",
        attn_impl="xla")
