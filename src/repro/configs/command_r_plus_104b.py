"""command-r-plus-104b [dense] — 64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000. GQA, no-bias. [hf:CohereForAI/c4ai-command-r-v01]"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="command-r-plus-104b", family="dense",
        n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8,
        d_ff=33792, vocab_size=256000,
        act="silu", norm="layernorm", use_bias=False, pos="rope",
        rope_theta=75_000_000.0, tie_embeddings=True,
        dtype="bfloat16", remat="selective", attn_impl="blocked",
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, d_ff=192,
        vocab_size=256, dtype="float32", remat="none", attn_impl="xla")
