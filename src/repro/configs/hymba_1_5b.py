"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attn+mamba heads. [arXiv:2411.13676]

Hymba runs attention and Mamba heads *in parallel* within each block; most
layers use sliding-window attention, with full (global) attention on the
first, a middle, and the last layer. ``global_layer_every=15`` reproduces
full attention at layers {0, 15, 30, 31} of 32.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b", family="hybrid",
        n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
        d_ff=5504, vocab_size=32001,
        act="silu", norm="rmsnorm", pos="rope",
        sliding_window=1024, global_layer_every=15,
        ssm_state=16, ssm_conv=4, d_inner=3200,
        tie_embeddings=True, dtype="bfloat16", remat="full",
        attn_impl="blocked",
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, sliding_window=16, global_layer_every=3,
        ssm_state=4, d_inner=128, dtype="float32", remat="none",
        attn_impl="xla")
