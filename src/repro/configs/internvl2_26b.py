"""internvl2-26b [vlm] — 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553 — InternViT + InternLM2 backbone. [arXiv:2404.16821]

The ViT frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings (B, n_patches=256, d_model) already projected
into the LM space; the backbone is the InternLM2-20B-style GQA decoder.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b", family="vlm",
        n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=16384, vocab_size=92553, n_patches=256,
        act="silu", norm="rmsnorm", pos="rope", rope_theta=1_000_000.0,
        dtype="bfloat16", remat="full", attn_impl="blocked",
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, n_patches=8, dtype="float32", remat="none",
        attn_impl="xla")
