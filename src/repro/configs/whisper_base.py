"""whisper-base [audio] — 6L d_model=512 8H d_ff=2048 vocab=51865 —
enc-dec, conv frontend (stub). [arXiv:2212.04356]

The modality frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, enc_seq=1500, d_model) standing in
for the log-mel + conv1d stack. 6 encoder + 6 decoder layers, learned
decoder positions, sinusoidal encoder positions, GELU, pre-norm LayerNorm.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base", family="encdec",
        n_layers=6, n_enc_layers=6, enc_seq=1500,
        d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
        vocab_size=51865,
        act="gelu", norm="layernorm", use_bias=True, pos="learned",
        tie_embeddings=True, dtype="bfloat16", remat="none",
        attn_impl="blocked",
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, n_enc_layers=2, enc_seq=32, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab_size=256, dtype="float32",
        attn_impl="xla")
