"""Generation directives (paper Definition 1, §III-E).

A *generation directive* is a per-request instruction level ``L0..L(n-1)``;
each level maps to a predefined system-prompt text that steers the model
toward shorter generations. SPROUT implements levels as system prompts
prepended to the user prompt (Fig. 7): when the request already carries a
system prompt, the directive text precedes it.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Directive:
    level: int
    name: str
    text: str  # empty for L0 (no directive)


DEFAULT_DIRECTIVES: Tuple[Directive, ...] = (
    Directive(0, "L0", ""),
    Directive(1, "L1", "Provide a brief response to the following."),
    Directive(2, "L2",
              "Provide a very brief response to the following, in as few "
              "words as possible."),
)


class DirectiveSet:
    """The service provider's configured directive levels."""

    def __init__(self, directives: Sequence[Directive] = DEFAULT_DIRECTIVES):
        assert directives[0].level == 0 and directives[0].text == "", \
            "level 0 must be the no-directive baseline"
        self.directives = tuple(directives)

    def __len__(self) -> int:
        return len(self.directives)

    def __getitem__(self, level: int) -> Directive:
        return self.directives[level]

    def apply(self, user_prompt: str, level: int,
              system_prompt: Optional[str] = None) -> str:
        """Render the final prompt text (ChatML-style) for a directive level.

        The directive is injected as (the leading part of) the system prompt;
        an existing system prompt is preserved after it (Fig. 7).
        """
        d = self.directives[level]
        sys_parts = [s for s in (d.text, system_prompt) if s]
        out = []
        if sys_parts:
            out.append(f"<|system|>{' '.join(sys_parts)}<|end|>")
        out.append(f"<|user|>{user_prompt}<|end|>")
        out.append("<|assistant|>")
        return "".join(out)

    def extra_prompt_tokens(self, level: int, tokenizer=None) -> int:
        """Approximate token overhead of the directive text (stored in the KV
        cache during prefill — Takeaway 2's 'minimal additional emissions')."""
        text = self.directives[level].text
        if not text:
            return 0
        if tokenizer is not None:
            return len(tokenizer.encode(text))
        return max(1, len(text) // 4)
