"""Carbon accounting (Eq. 1) and regional carbon-intensity traces (Table II).

C_req = CI · E_req + (CO2_embed / T_life) · T_req        (Eq. 1)

Traces: the paper uses hourly Electricity Maps data for five grid regions in
Feb/Jun/Oct 2023. Offline here, we synthesize hourly traces with the same
resolution, deterministic per (region, season), calibrated to each region's
published annual min/max and qualitative shape: solar duck curve (CA, SA),
wind-driven volatility (GB, NL), fossil baseline (TX). The provider
interface (``intensity(t)``) matches a live Electricity Maps client.
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Dict

import numpy as np


@dataclasses.dataclass(frozen=True)
class Region:
    key: str
    name: str
    operator: str
    ci_min: float      # annual min, gCO2/kWh (Table II)
    ci_max: float      # annual max
    solar_share: float  # depth of the midday solar dip, 0..1
    wind_vol: float     # wind-driven hour-to-hour volatility, 0..1
    base_level: float   # fossil baseline position within [min, max], 0..1


REGIONS: Dict[str, Region] = {
    "TX": Region("TX", "Texas (US)", "ERCOT", 124, 494, 0.25, 0.30, 0.55),
    "CA": Region("CA", "California (US)", "CISO", 55, 331, 0.65, 0.20, 0.45),
    "SA": Region("SA", "South Australia", "AEMO", 10, 526, 0.70, 0.45, 0.40),
    "NL": Region("NL", "Netherlands", "TenneT", 23, 463, 0.30, 0.50, 0.50),
    "GB": Region("GB", "Great Britain", "ESO", 24, 282, 0.20, 0.55, 0.45),
}

SEASONS = ("feb", "jun", "oct")
_SEASON_IDX = {s: i for i, s in enumerate(SEASONS)}
# seasonal modifiers: (baseline shift, solar-dip multiplier)
_SEASON_MOD = {"feb": (+0.12, 0.55), "jun": (-0.08, 1.30), "oct": (0.0, 1.0)}

HOURS_PER_MONTH = 24 * 28
PUE = 1.2  # paper §II-B


def carbon_intensity_trace(region: str, season: str = "jun",
                           hours: int = HOURS_PER_MONTH) -> np.ndarray:
    """Hourly gCO2/kWh trace, deterministic per (region, season)."""
    r = REGIONS[region]
    shift, dipmul = _SEASON_MOD[season]
    # stable digest, NOT Python's salted str hash: hash((str, str)) varies
    # with PYTHONHASHSEED, which silently changed the "deterministic" traces
    # across interpreter invocations and machines
    rng = np.random.default_rng(zlib.crc32(f"{r.key}-{season}".encode()))
    t = np.arange(hours, dtype=np.float64)
    span = r.ci_max - r.ci_min
    base = r.ci_min + (r.base_level + shift) * span

    # diurnal: demand peak in the evening, solar dip at midday
    hour_of_day = t % 24.0
    evening = 0.18 * span * np.cos((hour_of_day - 19.0) / 24.0 * 2 * math.pi)
    solar = -r.solar_share * dipmul * 0.38 * span * np.exp(
        -0.5 * ((hour_of_day - 13.0) / 3.0) ** 2)
    # multi-day weather systems drive wind output (smooth random walk)
    steps = rng.standard_normal(hours)
    weather = np.convolve(steps, np.ones(36) / 36.0, mode="same")
    weather = r.wind_vol * 0.9 * span * weather / max(1e-9, np.abs(weather).max())
    noise = 0.03 * span * rng.standard_normal(hours)

    ci = base + evening + solar + weather + noise
    return np.clip(ci, r.ci_min, r.ci_max)


class CarbonIntensityProvider:
    """Hourly carbon-intensity lookups (stand-in for Electricity Maps API).

    Two methods shape the live-client interface:

    * ``intensity(t)`` — the current signal (Electricity Maps "latest").
    * ``forecast(t, horizon_hours)`` — hourly gCO2/kWh for the next
      ``horizon_hours`` starting at the hour containing ``t`` (Electricity
      Maps "forecast" endpoint). The trace-backed stand-in has perfect
      foresight — it reads the synthetic trace ahead — which is the right
      oracle for testing forecast-aware re-planning; a live client returns
      the grid operator's published forecast through the same signature.
    """

    def __init__(self, region: str, season: str = "jun",
                 hours: int = HOURS_PER_MONTH):
        self.region = REGIONS[region]
        self.trace = carbon_intensity_trace(region, season, hours)

    def intensity(self, t_hours: float) -> float:
        return float(self.trace[int(t_hours) % len(self.trace)])

    def forecast(self, t_hours: float, horizon_hours: float) -> np.ndarray:
        """Hourly intensities for hours [t, t + horizon). Always returns at
        least one entry (the current hour), so ``forecast(t, 0)[0]`` ==
        ``intensity(t)`` and a degenerate horizon degrades gracefully to
        instantaneous planning."""
        n = max(1, int(math.ceil(horizon_hours)))
        idx = (int(t_hours) + np.arange(n)) % len(self.trace)
        return np.asarray(self.trace, dtype=float)[idx]

    @property
    def k_min(self) -> float:
        return self.region.ci_min

    @property
    def k_max(self) -> float:
        return self.region.ci_max


class WatchdogProvider(CarbonIntensityProvider):
    """Validating wrapper around any carbon-intensity provider.

    Production grid feeds misbehave in three ways the planner must survive
    (DESIGN.md §12): the transport fails (timeout / 5xx), the payload is
    garbage (non-finite), or the feed silently re-serves an old sample.
    The watchdog validates every fetch, keeps the last good sample, and
    answers from it when the feed is sick — flipping ``degraded`` only
    once the last good sample is older than ``max_stale_h`` simulated
    hours, so a single blip never pushes the LP into degraded planning.
    With no good sample at all it falls back to the region climatology
    (trace mean): finite, conservative, and honest about being degraded.

    ``fault_injector`` (duck-typed; ``repro.serving.faults.FaultInjector``
    — not imported here to keep core/ serving-independent) scripts the
    three failure modes at named points ``carbon.exception``,
    ``carbon.nan``, ``carbon.stale`` with the provider's region key as
    target. Injected garbage flows through the SAME validation gate as
    genuine garbage: a NaN payload is rejected by the isfinite check, not
    short-circuited by the injector.
    """

    def __init__(self, inner: CarbonIntensityProvider, *,
                 max_stale_h: float = 3.0, fault_injector=None):
        # deliberately no super().__init__ — everything proxies ``inner``
        self.inner = inner
        self.max_stale_h = max_stale_h
        self.injector = fault_injector
        self.degraded = False
        self.faults = {"stale": 0, "nan": 0, "exception": 0}
        self._last_good = None      # (t_hours, gCO2/kWh) of last valid fetch

    # ----- proxied identity ------------------------------------------
    @property
    def region(self) -> Region:
        return self.inner.region

    @property
    def trace(self) -> np.ndarray:
        return self.inner.trace

    @property
    def k_min(self) -> float:
        return self.inner.k_min

    @property
    def k_max(self) -> float:
        return self.inner.k_max

    # ----- validated fetch -------------------------------------------
    def _fire(self, point: str) -> bool:
        return self.injector is not None and \
            self.injector.fire(point, self.region.key)

    def _fetch(self, t_hours: float):
        """One validated fetch. Returns a fresh finite sample, or None
        (transport failure / garbage payload / stale re-serve)."""
        inj_nan = self._fire("carbon.nan")
        inj_stale = self._fire("carbon.stale")
        try:
            if self._fire("carbon.exception"):
                raise ConnectionError("injected: carbon feed down")
            v = float(self.inner.intensity(t_hours))
        except Exception:
            self.faults["exception"] += 1
            return None
        if inj_nan:
            v = float("nan")         # garbage payload, pre-validation
        if not math.isfinite(v):     # the genuine validation gate
            self.faults["nan"] += 1
            return None
        if inj_stale:
            # the feed answered, but with a sample it already served: no
            # fresh information — the last-good age keeps growing
            self.faults["stale"] += 1
            return None
        return v

    def _fallback(self, t_hours: float) -> float:
        """Last-good sample (aging toward ``degraded``), else climatology."""
        if self._last_good is not None:
            self.degraded = (t_hours - self._last_good[0]) > self.max_stale_h
            return self._last_good[1]
        self.degraded = True
        return float(np.mean(np.asarray(self.inner.trace, dtype=float)))

    def intensity(self, t_hours: float) -> float:
        v = self._fetch(t_hours)
        if v is not None:
            self._last_good = (float(t_hours), v)
            self.degraded = False
            return v
        return self._fallback(t_hours)

    def forecast(self, t_hours: float, horizon_hours: float) -> np.ndarray:
        n = max(1, int(math.ceil(horizon_hours)))
        inj_nan = self._fire("carbon.nan")
        inj_stale = self._fire("carbon.stale")
        try:
            if self._fire("carbon.exception"):
                raise ConnectionError("injected: carbon feed down")
            f = np.asarray(self.inner.forecast(t_hours, horizon_hours),
                           dtype=float)
            if inj_nan and f.size:
                f = f.copy()
                f[0] = float("nan")
            if f.size == n and np.isfinite(f).all():
                if inj_stale:
                    self.faults["stale"] += 1
                else:
                    return f
            else:
                self.faults["nan"] += 1
        except Exception:
            self.faults["exception"] += 1
        # persistence forecast: hold the fallback level flat across the
        # horizon — the planner keeps planning, just without foresight
        return np.full(n, self._fallback(t_hours), dtype=float)


def request_carbon(ci_g_per_kwh: float, energy_kwh: float, time_s: float,
                   embodied_gco2: float, lifetime_s: float,
                   pue: float = PUE) -> float:
    """Eq. 1 with datacenter PUE applied to operational energy."""
    operational = ci_g_per_kwh * energy_kwh * pue
    embodied = (embodied_gco2 / lifetime_s) * time_s
    return operational + embodied
