"""Workload synthesis: six task families (Table I) with per-(task, directive)
response-length and quality behavior, plus a diurnal request-rate trace
shaped like the Alibaba PAI workload the paper samples from.

Per-request latent model (drives every evaluation figure):
  * task t ~ mixture(t_hour)         (mixture drifts over time, Fig. 12/13)
  * prompt_tokens ~ LogNormal(task)
  * per-level gen_tokens[l] ~ LogNormal(task, level)
  * per-level quality score s[l] = base_quality[t][l] + N(0, sigma_t)
      the auto-eval judge prefers argmax_l s[l] (with 3% judge error — the
      paper reports 97% judge agreement), head-to-head comparisons use
      sign(s[a] - s[b]).

Directive sensitivity follows the paper's findings (Fig. 4): conciseness
*hurts* multi-step reasoning (GSM8K, Alpaca) but *helps* tasks whose answer
is directly inferable (TriviaQA, MMLU, NQ).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class TaskProfile:
    name: str
    prompt_mean: float
    prompt_std: float
    gen_mean: Sequence[float]      # per directive level
    gen_std: Sequence[float]
    base_quality: Sequence[float]  # per directive level
    quality_noise: float = 0.18


TASKS: Dict[str, TaskProfile] = {
    # reasoning / open-ended: conciseness hurts — the judge wants the steps
    "alpaca":    TaskProfile("alpaca", 90, 50, (320, 150, 70), (140, 70, 35),
                             (1.00, 0.78, 0.50), 0.15),
    "gsm8k":     TaskProfile("gsm8k", 120, 40, (260, 140, 60), (90, 60, 30),
                             (1.00, 0.80, 0.52), 0.15),
    # direct-answer tasks: brief responses are both correct and preferred
    # (paper Fig. 3: "L1 ensures both brevity and correctness" on MMLU)
    "mmlu":      TaskProfile("mmlu", 160, 60, (190, 40, 12), (80, 25, 6),
                             (0.84, 1.00, 0.92), 0.15),
    "naturalqa": TaskProfile("naturalqa", 40, 15, (120, 45, 14), (60, 25, 8),
                             (0.82, 1.00, 0.94), 0.15),
    "scienceqa": TaskProfile("scienceqa", 140, 50, (200, 70, 22), (85, 35, 12),
                             (0.90, 1.00, 0.80), 0.15),
    "triviaqa":  TaskProfile("triviaqa", 60, 25, (90, 30, 10), (45, 18, 6),
                             (0.78, 1.00, 0.97), 0.15),
}

TASK_NAMES = tuple(TASKS)
N_LEVELS = 3


@dataclasses.dataclass
class Request:
    rid: int
    task: str
    prompt_tokens: int
    gen_tokens: np.ndarray        # per level
    quality: np.ndarray           # latent per-level quality score
    preferred: int                # argmax quality (true preference)

    def judge_pick(self, rng: np.random.Generator,
                   levels: Optional[Sequence[int]] = None,
                   error: float = 0.03) -> int:
        """Auto-eval LLM's pick among ``levels`` (default: all)."""
        levels = list(levels if levels is not None else range(len(self.quality)))
        best = levels[int(np.argmax(self.quality[levels]))]
        if rng.random() < error:
            others = [l for l in levels if l != best]
            return int(rng.choice(others)) if others else best
        return int(best)

    def judge_prefers(self, rng: np.random.Generator, a: int, b: int,
                      error: float = 0.03) -> bool:
        """Head-to-head: does the judge prefer level ``a`` over ``b``?"""
        if a == b:
            return bool(rng.random() < 0.5)
        pick = self.judge_pick(rng, (a, b), error)
        return pick == a


def _lognormal(rng, mean, std, lo=1.0):
    var = math.log(1.0 + (std / max(mean, 1e-9)) ** 2)
    mu = math.log(max(mean, 1e-9)) - var / 2
    return max(lo, float(rng.lognormal(mu, math.sqrt(var))))


class Workload:
    """Deterministic-seeded request stream with a drifting task mixture."""

    def __init__(self, seed: int = 0,
                 mixture_schedule: Optional[Sequence[Dict[str, float]]] = None,
                 rps_peak: float = 30.0):
        self.rng = np.random.default_rng(seed)
        self._rid = 0
        self.mixture_schedule = mixture_schedule
        self.rps_peak = rps_peak

    def mixture(self, t_hours: float) -> Dict[str, float]:
        if self.mixture_schedule:
            idx = int(t_hours) % len(self.mixture_schedule)
            return self.mixture_schedule[idx]
        # slow diurnal drift between reasoning-heavy and lookup-heavy mixes
        w = 0.5 + 0.35 * math.sin(2 * math.pi * (t_hours / 24.0 - 0.3))
        mix = {"alpaca": 1.0 + w, "gsm8k": 0.8 + 0.6 * w, "mmlu": 1.0,
               "naturalqa": 1.2 - 0.5 * w, "scienceqa": 0.9,
               "triviaqa": 1.4 - 0.8 * w}
        z = sum(mix.values())
        return {k: v / z for k, v in mix.items()}

    def rps(self, t_hours: float) -> float:
        """Diurnal request rate (PAI-trace-like: evening peak, night trough)."""
        hod = t_hours % 24.0
        return self.rps_peak * (0.45 + 0.55 * math.exp(
            -0.5 * ((hod - 20.0) / 4.5) ** 2) + 0.25 * math.exp(
            -0.5 * ((hod - 10.0) / 3.0) ** 2)) / 1.25

    def sample_request(self, t_hours: float) -> Request:
        mix = self.mixture(t_hours)
        names = list(mix)
        task = self.rng.choice(names, p=np.array([mix[n] for n in names]))
        tp = TASKS[task]
        gen = np.array([_lognormal(self.rng, tp.gen_mean[l], tp.gen_std[l])
                        for l in range(N_LEVELS)])
        gen = np.maximum.accumulate(gen[::-1])[::-1]  # L0 >= L1 >= L2
        qual = np.array(tp.base_quality) + self.rng.normal(
            0, tp.quality_noise, N_LEVELS)
        self._rid += 1
        return Request(self._rid, task,
                       int(_lognormal(self.rng, tp.prompt_mean, tp.prompt_std)),
                       gen, qual, int(np.argmax(qual)))

    def requests_for_hour(self, t_hours: float,
                          cap: int = 400) -> List[Request]:
        """A representative sample of the hour's requests (statistically
        sufficient; carbon totals scale by true_count/len)."""
        true_count = int(self.rps(t_hours) * 3600)
        n = min(cap, true_count)
        reqs = [self.sample_request(t_hours) for _ in range(n)]
        for r in reqs:
            r.weight = true_count / max(n, 1)  # type: ignore[attr-defined]
        return reqs
