"""SPROUT controller: end-to-end carbon-aware serving simulation (Fig. 5).

Drives one month of hourly serving for a set of competing schemes over the
SAME request stream (paired evaluation, as the paper does):

  hour t:  k0 = grid carbon intensity (region trace)
           policies re-plan (SPROUT solves the LP; ORACLE plans exactly)
           each request r -> (model, level) -> energy/time via EnergyModel
           -> carbon via Eq. 1; feedback logged to per-level profiles
           invoker watches urgency-adjusted k2' -> offline evaluation
           refreshes SPROUT's q vector (500-sample judge)

Outputs per scheme: carbon totals, per-request carbon normalized to BASE,
head-to-head generation preference vs BASE, directive mix over time, and
evaluator overhead — everything the paper's figures need.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.carbon import (PUE, CarbonIntensityProvider, request_carbon)
from repro.core.directives import DirectiveSet
from repro.core.energy import (A100_40GB, LLAMA2_7B, LLAMA2_13B, EnergyModel,
                               ModelProfile)
from repro.core.invoker import EvaluationInvoker
from repro.core.policies import (BasePolicy, CO2OptPolicy, LevelProfiles,
                                 ModelOptPolicy, OraclePolicy, Policy,
                                 SproutPolicy, SproutStaticPolicy,
                                 SproutTaskPolicy)
from repro.core.quality import QualityEvaluator
from repro.core.workload import N_LEVELS, Request, Workload


@dataclasses.dataclass
class SchemeStats:
    name: str
    carbon_g: float = 0.0
    requests: float = 0.0
    wins_vs_base: float = 0.0       # judge prefers this scheme's response
    comparisons: float = 0.0
    per_request_norm: List[float] = dataclasses.field(default_factory=list)
    level_counts: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(N_LEVELS))
    hourly_carbon: List[float] = dataclasses.field(default_factory=list)
    hourly_mix: List[np.ndarray] = dataclasses.field(default_factory=list)
    eval_overhead_g: float = 0.0
    eval_times: List[float] = dataclasses.field(default_factory=list)

    @property
    def carbon_per_request(self) -> float:
        return self.carbon_g / max(self.requests, 1e-9)

    def normalized_preference(self) -> float:
        """paper metric: P(prefer scheme) / P(prefer BASE) head-to-head."""
        if self.comparisons == 0:
            return 1.0
        p = self.wins_vs_base / self.comparisons
        return p / max(1.0 - p, 1e-9)


class SproutSimulation:
    def __init__(self, region: str = "CA", season: str = "jun",
                 hours: int = 24 * 28, xi: float = 0.1, seed: int = 0,
                 schemes: Optional[Sequence[str]] = None,
                 workload: Optional[Workload] = None,
                 requests_per_hour_cap: int = 250,
                 directives: DirectiveSet = DirectiveSet(),
                 energy: Optional[EnergyModel] = None,
                 with_evaluator: bool = True):
        self.provider = CarbonIntensityProvider(region, season, hours)
        self.hours = hours
        self.xi = xi
        self.rng = np.random.default_rng(seed + 101)
        self.workload = workload or Workload(seed=seed)
        self.cap = requests_per_hour_cap
        self.directives = directives
        self.energy = energy or EnergyModel(A100_40GB)
        self.with_evaluator = with_evaluator
        self.models: Dict[str, ModelProfile] = {"13b": LLAMA2_13B,
                                                "7b": LLAMA2_7B}
        k = self.provider
        self.k1 = A100_40GB.embodied_gco2 / A100_40GB.lifetime_s
        names = list(schemes or ["BASE", "CO2_OPT", "MODEL_OPT",
                                 "SPROUT_STA", "SPROUT", "ORACLE"])
        self.policies: Dict[str, Policy] = {}
        for n in names:
            self.policies[n] = self._make_policy(n, k.k_min, k.k_max)
        self.stats = {n: SchemeStats(n) for n in names}
        self.profiles = LevelProfiles.fresh()
        self.q_est = np.ones(N_LEVELS) / N_LEVELS
        self.task_q: Dict[str, np.ndarray] = {}
        self.invoker = EvaluationInvoker(k_hist_max=k.k_max)
        self.evaluator = QualityEvaluator()
        self._recent: List[Request] = []
        self._static_initialized = False

    # ------------------------------------------------------------------
    def _make_policy(self, name: str, k_min: float, k_max: float) -> Policy:
        if name == "BASE":
            return BasePolicy()
        if name == "CO2_OPT":
            return CO2OptPolicy()
        if name == "MODEL_OPT":
            return ModelOptPolicy(k0_min=k_min, k0_max=k_max, xi=self.xi,
                                  k1=self.k1)
        if name == "SPROUT":
            return SproutPolicy(k0_min=k_min, k0_max=k_max, xi=self.xi,
                                k1=self.k1)
        if name == "SPROUT_TASK":
            return SproutTaskPolicy(k0_min=k_min, k0_max=k_max, xi=self.xi,
                                    k1=self.k1)
        if name == "SPROUT_STA":
            return SproutStaticPolicy(np.array([1.0, 0.0, 0.0]))
        if name == "ORACLE":
            return OraclePolicy(k0_min=k_min, k0_max=k_max, xi=self.xi)
        raise KeyError(name)

    # ------------------------------------------------------------------
    def _request_cost(self, req: Request, model: ModelProfile, level: int):
        """(energy kWh incl. PUE, time s) for serving req at level."""
        extra = self.directives.extra_prompt_tokens(level)
        prompt = req.prompt_tokens + extra
        gen = float(req.gen_tokens[level])
        e = self.energy.request_energy_kwh(model, prompt, gen) * PUE
        t = self.energy.request_time(model, prompt, gen)
        return e, t

    def _model_quality_ctx(self) -> Dict:
        """MODEL_OPT context: per-variant e/p/q measured at L0."""
        e13, t13 = self.profiles.e[0], self.profiles.p[0]
        if e13 == 0:
            return {}
        ratio_e = LLAMA2_7B.n_params / LLAMA2_13B.n_params
        return {"model_e": np.array([e13, e13 * ratio_e]),
                "model_p": np.array([t13, t13 * ratio_e]),
                "model_q": np.array([0.62, 0.38])}  # 13B-vs-7B judge pref

    def _quality_7b(self, req: Request) -> float:
        return req.quality[0] - 0.18 + 0.05 * self.rng.standard_normal()

    # ------------------------------------------------------------------
    def run(self, progress: bool = False) -> Dict[str, SchemeStats]:
        sprout = self.policies.get("SPROUT")
        static = self.policies.get("SPROUT_STA")
        for t in range(self.hours):
            k0 = self.provider.intensity(t)
            reqs = self.workload.requests_for_hour(t, cap=self.cap)
            self._recent = (self._recent + reqs)[-4000:]

            # best static config: pick once from the warmup window
            if static is not None and not self._static_initialized and t == 24:
                avg_k0 = float(np.mean(self.provider.trace))
                pol = SproutStaticPolicy.sweep(
                    self.profiles.e, self.q_est, k0_avg=avg_k0,
                    k0_min=self.provider.k_min, k0_max=self.provider.k_max,
                    xi=self.xi)
                static.x = pol.x
                self._static_initialized = True

            ctx = self._model_quality_ctx()
            if self.task_q:
                counts: Dict[str, float] = {}
                for r in self._recent[-1000:]:
                    counts[r.task] = counts.get(r.task, 0.0) + 1.0
                ctx["task_q"] = self.task_q
                ctx["task_w"] = {t_: counts.get(t_, 0.1) for t_ in self.task_q}
            for name, pol in self.policies.items():
                pol.begin_hour(t, k0, self.profiles, self.q_est, ctx)
            if "ORACLE" in self.policies:
                carbon_rl = np.zeros((len(reqs), N_LEVELS))
                for i, r in enumerate(reqs):
                    for l in range(N_LEVELS):
                        e, tt = self._request_cost(r, self.models["13b"], l)
                        carbon_rl[i, l] = request_carbon(
                            k0, e, tt, A100_40GB.embodied_gco2,
                            A100_40GB.lifetime_s, pue=1.0)
                self.policies["ORACLE"].plan_hour(reqs, carbon_rl, k0)

            base_carbon: Dict[int, float] = {}
            for name, pol in self.policies.items():
                st = self.stats[name]
                hour_c = 0.0
                mix = np.zeros(N_LEVELS)
                for r in reqs:
                    mkey, lvl = pol.assign(r, self.rng)
                    model = self.models[mkey]
                    e, tt = self._request_cost(r, model, lvl)
                    c = request_carbon(k0, e, tt, A100_40GB.embodied_gco2,
                                       A100_40GB.lifetime_s, pue=1.0)
                    w = getattr(r, "weight", 1.0)
                    st.carbon_g += c * w
                    st.requests += w
                    hour_c += c * w
                    mix[lvl] += 1
                    if name == "BASE":
                        base_carbon[r.rid] = c
                    else:
                        st.per_request_norm.append(
                            c / max(base_carbon.get(r.rid, c), 1e-12))
                        # head-to-head judging vs BASE response
                        if mkey == "7b":
                            win = (self._quality_7b(r) > r.quality[0]
                                   if self.rng.random() > 0.03
                                   else self.rng.random() < 0.5)
                        else:
                            win = r.judge_prefers(self.rng, lvl, 0)
                        st.wins_vs_base += float(win) * w
                        st.comparisons += w
                    # online profiling feedback (13B levels only)
                    if mkey == "13b":
                        self.profiles.update(lvl, e, tt)
                st.hourly_carbon.append(hour_c)
                st.hourly_mix.append(mix / max(mix.sum(), 1))

            # opportunistic offline evaluation
            if self.with_evaluator and self.invoker.observe(t, k0):
                rep = self.evaluator.evaluate(self._recent)
                self.q_est = rep.q
                if rep.q_by_task:
                    self.task_q = rep.q_by_task
                overhead = request_carbon(k0, rep.eval_energy_kwh, 0.0,
                                          0.0, 1.0, pue=PUE)
                if "SPROUT" in self.stats:  # STA only needs the initial sweep
                    self.stats["SPROUT"].eval_overhead_g += overhead
                    self.stats["SPROUT"].eval_times.append(t)
            elif not self.with_evaluator:
                pass
            if progress and t % 168 == 0:
                print(f"  hour {t}/{self.hours}")
        return self.stats


def summarize(stats: Dict[str, SchemeStats]) -> Dict[str, Dict[str, float]]:
    base = stats["BASE"].carbon_per_request
    base_total = stats["BASE"].carbon_g
    out = {}
    for name, st in stats.items():
        out[name] = {
            "carbon_per_request_g": st.carbon_per_request,
            "carbon_savings_pct": 100 * (1 - st.carbon_per_request / base),
            "normalized_preference_pct": 100 * min(st.normalized_preference(), 2.0)
            if name != "BASE" else 100.0,
            # evaluator overhead relative to the inference service's
            # unoptimized emissions (the paper's Fig. 14 denominator)
            "eval_overhead_pct": 100 * st.eval_overhead_g / max(base_total, 1e-9),
        }
    return out
