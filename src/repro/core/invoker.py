"""Opportunistic offline-evaluation invoker (paper §III-C, Eq. 8, Fig. 6).

Urgency-adjusted carbon intensity:  k2'(t) = exp(-beta (t - t0)) * k2(t)

An evaluation fires when, causally observing hourly k2' samples:
  (i)   the previous sample was a local minimum of k2' (discrete positive
        second derivative: k'[t-2] > k'[t-1] <= k'[t]);
  (ii)  the grace period since the last evaluation has elapsed;
  (iii) that minimum lies below the threshold (default 50% of the
        historical maximum carbon intensity).

beta = 0.028/hr halves the urgency-adjusted intensity after 24 h (paper),
so under persistently high intensity the decay alone eventually drops k2'
below the threshold — evaluation always happens (Fig. 6b).
"""
from __future__ import annotations

import math
from typing import List


class EvaluationInvoker:
    def __init__(self, *, beta: float = 0.028, grace_hours: float = 12.0,
                 threshold_frac: float = 0.5, k_hist_max: float = 500.0,
                 max_staleness_hours: float = 48.0):
        self.beta = beta
        self.grace = grace_hours
        self.threshold = threshold_frac * k_hist_max
        # hard deadline: a perfectly flat trace has no k2' local minima, but
        # "increasing evaluation urgency ensures offline evaluation always
        # occurs" (Fig. 6b) — enforce it explicitly.
        self.max_staleness = max_staleness_hours
        self.last_eval_t: float = 0.0
        self._hist: List[float] = []   # recent urgency-adjusted samples
        self._hist_t: List[float] = []

    def urgency_adjusted(self, t: float, k2: float) -> float:
        return math.exp(-self.beta * (t - self.last_eval_t)) * k2

    def observe(self, t: float, k2: float) -> bool:
        """Feed one hourly sample; returns True if evaluation should fire."""
        kprime = self.urgency_adjusted(t, k2)
        self._hist.append(kprime)
        self._hist_t.append(t)
        if len(self._hist) > 3:
            self._hist = self._hist[-3:]
            self._hist_t = self._hist_t[-3:]
        if t - self.last_eval_t < self.grace:
            return False
        if t - self.last_eval_t >= self.max_staleness \
                and kprime <= self.threshold:
            self.fire(t)                   # staleness deadline (Fig. 6b)
            return True
        if len(self._hist) < 3:
            return False
        a, b, c = self._hist[-3], self._hist[-2], self._hist[-1]
        if not (a > b <= c):               # (i) local minimum at t-1
            return False
        if b > self.threshold:             # (iii) below threshold
            return False
        self.fire(t)
        return True

    def fire(self, t: float) -> None:
        self.last_eval_t = t
        self._hist.clear()
        self._hist_t.clear()
