"""Competing schemes (paper §IV): BASE, CO2_OPT, MODEL_OPT, SPROUT_STA,
SPROUT, ORACLE. Each policy maps a request to (model_key, directive level);
SPROUT/SPROUT_STA draw the level from a probability vector x.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence, Tuple

import numpy as np

from repro.core.lp import quality_lower_bound, solve_directive_lp
from repro.core.workload import N_LEVELS, Request


@dataclasses.dataclass
class LevelProfiles:
    """Running per-level energy (kWh) / time (s) estimates — the e, p vectors."""
    e: np.ndarray
    p: np.ndarray
    counts: np.ndarray

    @classmethod
    def fresh(cls, n: int = N_LEVELS):
        return cls(np.zeros(n), np.zeros(n), np.zeros(n))

    def update(self, level: int, energy_kwh: float, time_s: float,
               window: float = 500.0):
        c = min(self.counts[level], window)
        self.e[level] = (self.e[level] * c + energy_kwh) / (c + 1)
        self.p[level] = (self.p[level] * c + time_s) / (c + 1)
        self.counts[level] += 1


def _simplex_grid(n: int, levels: int):
    """All integer compositions of ``n`` into ``levels`` parts (the step-1/n
    grid over the probability simplex)."""
    if levels == 1:
        yield (n,)
        return
    for i in range(n + 1):
        for rest in _simplex_grid(n - i, levels - 1):
            yield (i,) + rest


class Policy:
    name = "policy"
    uses_lp = False

    def begin_hour(self, t: float, k0: float, profiles: LevelProfiles,
                   q: np.ndarray, ctx: Dict) -> None:
        pass

    def assign(self, req: Request, rng: np.random.Generator) -> Tuple[str, int]:
        raise NotImplementedError


class BasePolicy(Policy):
    """Vanilla serving: big model, no directive."""
    name = "BASE"

    def assign(self, req, rng):
        return "13b", 0


class CO2OptPolicy(Policy):
    """Always the lowest-carbon directive level, quality-blind."""
    name = "CO2_OPT"

    def __init__(self):
        self.level = N_LEVELS - 1

    def begin_hour(self, t, k0, profiles, q, ctx):
        if profiles.counts.min() > 0:
            self.level = int(np.argmin(profiles.e))

    def assign(self, req, rng):
        return "13b", self.level


class ModelOptPolicy(Policy):
    """Prior-work scheme [10,13,14]: optimize over MODEL VARIANTS (7B vs 13B
    at L0), blind to generation directives. Solves the same LP but with
    model variants as the options."""
    name = "MODEL_OPT"
    uses_lp = True

    def __init__(self, *, k0_min: float, k0_max: float, xi: float = 0.1,
                 k1: float = 1e-3):
        self.k0_min, self.k0_max, self.xi, self.k1 = k0_min, k0_max, xi, k1
        self.x = np.array([1.0, 0.0])  # P(13b), P(7b)

    def begin_hour(self, t, k0, profiles, q, ctx):
        e = ctx.get("model_e")      # per-variant kWh [13b, 7b]
        p = ctx.get("model_p")
        qm = ctx.get("model_q")     # head-to-head preference rates
        if e is None:
            return
        sol = solve_directive_lp(e, p, qm, k0=k0, k1=self.k1,
                                 k0_min=self.k0_min, k0_max=self.k0_max,
                                 xi=self.xi)
        self.x = sol.x

    def assign(self, req, rng):
        pick = rng.choice(2, p=self.x)
        return ("13b", 0) if pick == 0 else ("7b", 0)


class SproutPolicy(Policy):
    """The full system: hourly LP over directive levels with live carbon
    intensity and evaluator feedback (Eq. 2–7)."""
    name = "SPROUT"
    uses_lp = True

    def __init__(self, *, k0_min: float, k0_max: float, xi: float = 0.1,
                 k1: float = 1e-3, explore: float = 0.01,
                 n_levels: int = N_LEVELS):
        self.k0_min, self.k0_max, self.xi, self.k1 = k0_min, k0_max, xi, k1
        self.explore = explore
        self.n_levels = n_levels
        self.x = np.ones(n_levels) / n_levels
        self.last_solution = None

    def begin_hour(self, t, k0, profiles, q, ctx):
        if profiles.counts.min() < 5:   # warmup: uniform to build profiles
            self.x = np.ones(self.n_levels) / self.n_levels
            return
        sol = solve_directive_lp(profiles.e, profiles.p, q, k0=k0,
                                 k1=self.k1, k0_min=self.k0_min,
                                 k0_max=self.k0_max, xi=self.xi)
        self.last_solution = sol
        x = (1 - self.explore) * sol.x + self.explore / self.n_levels
        self.x = x / x.sum()

    def assign(self, req, rng):
        return "13b", int(rng.choice(self.n_levels, p=self.x))


class SproutStaticPolicy(Policy):
    """SPROUT_STA: one month-long static directive mix, chosen by sweeping
    static configurations offline against month-average conditions."""
    name = "SPROUT_STA"

    def __init__(self, x: np.ndarray):
        self.x = np.asarray(x, float)

    @classmethod
    def sweep(cls, e: np.ndarray, q: np.ndarray, *, k0_avg: float,
              k0_min: float, k0_max: float, xi: float = 0.1,
              step: float = 0.05) -> "SproutStaticPolicy":
        """Grid-sweep the simplex for min avg carbon s.t. the month-average
        quality constraint (the paper's 'best static configuration').

        Works for any number of directive levels (the grid enumerates the
        full ``len(e)``-dimensional simplex, not a hardcoded 3-level walk);
        Eq. 3 guarantees q_lb <= q[0], so the pure-L0 point is always
        feasible and seeds the search."""
        e = np.asarray(e, float)
        q = np.asarray(q, float)
        assert len(e) == len(q)
        q_lb = quality_lower_bound(q[0], k0_avg, k0_min, k0_max, xi)
        n = int(round(1 / step))
        best, best_c = np.eye(len(e))[0], float(e[0])
        for comp in _simplex_grid(n, len(e)):
            x = np.asarray(comp, float) / n
            if q @ x >= q_lb - 1e-12:
                c = float(e @ x)
                if c < best_c:
                    best, best_c = x, c
        return cls(best)

    def assign(self, req, rng):
        return "13b", int(rng.choice(len(self.x), p=self.x))


class SproutTaskPolicy(Policy):
    """BEYOND-PAPER extension: task-conditioned LP.

    The request's task family is observable from the prompt (a lightweight
    classifier in production; exact here). Solving the same LP *per task*
    with per-task preference vectors q_t — subject to the same aggregate
    quality floor — recovers most of the per-prompt ORACLE's advantage while
    staying a system-level (low-dimensional) optimization: n_tasks small LPs
    instead of one, still microseconds on the control plane.

    Decomposition: min Σ_t w_t c_tᵀx_t  s.t. Σ_t w_t q_tᵀx_t ≥ q_lb. We
    lagrangian-split by sweeping a shared quality price λ (bisection), which
    is exact for this separable LP.
    """
    name = "SPROUT_TASK"
    uses_lp = True

    def __init__(self, *, k0_min: float, k0_max: float, xi: float = 0.1,
                 k1: float = 1e-3, explore: float = 0.01):
        self.k0_min, self.k0_max, self.xi, self.k1 = k0_min, k0_max, xi, k1
        self.explore = explore
        self.x_by_task: Dict[str, np.ndarray] = {}
        self.x_default = np.ones(N_LEVELS) / N_LEVELS

    def begin_hour(self, t, k0, profiles, q, ctx):
        task_q = ctx.get("task_q")       # {task: q_t}, from the evaluator
        task_w = ctx.get("task_w")       # {task: mixture weight}
        if not task_q or profiles.counts.min() < 5:
            return
        tasks = list(task_q)
        w = np.array([task_w[t_] for t_ in tasks])
        w = w / w.sum()
        qs = np.stack([task_q[t_] for t_ in tasks])     # (T, L)
        c = k0 * profiles.e + self.k1 * profiles.p       # (L,)
        q0 = float(w @ qs[:, 0])
        q_lb = quality_lower_bound(q0, k0, self.k0_min, self.k0_max, self.xi)

        def assign_for(lam):
            # per task: pick level minimizing c - lam * q  (pointwise LP)
            scores = c[None, :] - lam * qs               # (T, L)
            pick = np.argmin(scores, axis=1)
            qual = float(w @ qs[np.arange(len(tasks)), pick])
            return pick, qual

        lo, hi = 0.0, 10.0 * float(np.max(c)) / max(1e-9, np.min(np.ptp(qs, 1)))
        pick, qual = assign_for(lo)
        if qual < q_lb:
            for _ in range(60):
                mid = 0.5 * (lo + hi)
                pick, qual = assign_for(mid)
                if qual < q_lb:
                    lo = mid
                else:
                    hi = mid
            pick, qual = assign_for(hi)
        self.x_by_task = {}
        for i, t_ in enumerate(tasks):
            x = np.full(N_LEVELS, self.explore / N_LEVELS)
            x[pick[i]] += 1 - self.explore
            self.x_by_task[t_] = x / x.sum()

    def assign(self, req, rng):
        x = self.x_by_task.get(req.task, self.x_default)
        return "13b", int(rng.choice(N_LEVELS, p=x))


class OraclePolicy(Policy):
    """Impractical upper bound: exact per-request carbon AND quality
    knowledge, no profiling/sampling error. Greedy per-hour assignment =
    fractional-knapsack optimum of the per-request LP."""
    name = "ORACLE"

    def __init__(self, *, k0_min: float, k0_max: float, xi: float = 0.1):
        self.k0_min, self.k0_max, self.xi = k0_min, k0_max, xi
        self._assignment: Dict[int, int] = {}

    def plan_hour(self, reqs: Sequence[Request], carbon_rl: np.ndarray,
                  k0: float) -> None:
        """carbon_rl: (N, L) exact per-request carbon at each level."""
        N = len(reqs)
        if N == 0:
            self._assignment = {}
            return
        pref = np.array([r.preferred for r in reqs])
        q0 = float(np.mean(pref == 0))
        q_lb = quality_lower_bound(q0, k0, self.k0_min, self.k0_max, self.xi)
        cheapest = np.argmin(carbon_rl, axis=1)
        lvl = cheapest.copy()
        quality = np.mean(lvl == pref)
        if quality < q_lb:
            # upgrade requests to their preferred level, cheapest-first
            cand = np.where(lvl != pref)[0]
            penalty = carbon_rl[cand, pref[cand]] - carbon_rl[cand, lvl[cand]]
            order = cand[np.argsort(penalty)]
            need = int(np.ceil((q_lb - quality) * N))
            for i in order[:need]:
                lvl[i] = pref[i]
        self._assignment = {r.rid: int(l) for r, l in zip(reqs, lvl)}

    def assign(self, req, rng):
        return "13b", self._assignment.get(req.rid, 0)
