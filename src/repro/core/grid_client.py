"""Live grid carbon-signal client (Electricity Maps / WattTime).

The paper's traces are hourly Electricity Maps data (Table II); this
client serves the SAME ``CarbonIntensityProvider`` interface from the
live API so a deployment can point the gateway at the real grid without
touching the planner. Design constraints (DESIGN.md §12):

* **Transport is injectable.** ``transport(url, headers, timeout_s)``
  returns the response body (bytes/str). The default is a lazy
  ``urllib.request`` adapter, but tests pass a stub — CI never touches
  the network, and the retry/fallback logic is unit-testable without it.
* **Bounded retries.** Each fetch attempts the transport up to
  ``1 + max_retries`` times with capped exponential backoff
  (``backoff_base_s * 2^attempt``, capped at ``backoff_cap_s``), sleeping
  through an injectable ``sleep`` so tests run instantly.
* **Automatic trace fallback.** Any terminal failure (retries exhausted,
  malformed payload) answers from the bundled synthetic trace for the
  region — the planner always gets a finite number. Pair with
  ``WatchdogProvider`` to also get staleness aging and degraded-state
  reporting on top.

No API tokens ship with the repo: construct with ``token=""`` and the
client never builds a default transport (it falls back immediately),
which is the CI-safe configuration.
"""
from __future__ import annotations

import json
import math
import time
from typing import Callable, Optional

import numpy as np

from repro.core.carbon import HOURS_PER_MONTH, CarbonIntensityProvider

# repo region keys -> Electricity Maps zone ids (Table II regions)
EMAPS_ZONES = {
    "TX": "US-TEX-ERCO",
    "CA": "US-CAL-CISO",
    "SA": "AU-SA",
    "NL": "NL",
    "GB": "GB",
}

# repo region keys -> WattTime balancing-authority abbrevs
WATTTIME_BA = {
    "TX": "ERCOT",
    "CA": "CAISO_NORTH",
    "SA": "AEMO_SA",
    "NL": "NL",
    "GB": "UK",
}


def _urllib_transport(url: str, headers: dict, timeout_s: float):
    """Default transport: stdlib-only GET (built lazily, never in tests)."""
    import urllib.request
    req = urllib.request.Request(url, headers=headers)
    with urllib.request.urlopen(req, timeout=timeout_s) as resp:
        return resp.read()


class GridSignalClient(CarbonIntensityProvider):
    """``CarbonIntensityProvider`` backed by a live grid-signal API.

    ``intensity(t)``/``forecast(t, h)`` keep the trace-backed signature
    (hours into the run); the live payload supplies the *value* while
    ``t`` keeps indexing the bundled fallback trace, so swapping this in
    for the synthetic provider changes no call sites.
    """

    def __init__(self, region: str, season: str = "jun",
                 hours: int = HOURS_PER_MONTH, *,
                 provider: str = "electricitymaps", token: str = "",
                 timeout_s: float = 5.0, max_retries: int = 3,
                 backoff_base_s: float = 0.5, backoff_cap_s: float = 8.0,
                 transport: Optional[Callable] = None,
                 sleep: Callable[[float], None] = time.sleep):
        super().__init__(region, season, hours)   # bundled-trace fallback
        if provider not in ("electricitymaps", "watttime"):
            raise ValueError(f"unknown grid provider {provider!r}")
        self.provider = provider
        self.token = token
        self.timeout_s = timeout_s
        self.max_retries = max_retries
        self.backoff_base_s = backoff_base_s
        self.backoff_cap_s = backoff_cap_s
        # no token -> never build a network transport: CI-safe by default
        self._transport = transport if transport is not None else (
            _urllib_transport if token else None)
        self._sleep = sleep
        self.fetches = 0          # successful live fetches
        self.fallbacks = 0        # answers served from the bundled trace
        self.retries_used = 0     # transport attempts beyond the first

    # ----- endpoint shapes -------------------------------------------
    def _url(self, kind: str) -> str:
        key = self.region.key
        if self.provider == "electricitymaps":
            zone = EMAPS_ZONES.get(key, key)
            return (f"https://api.electricitymap.org/v3/carbon-intensity/"
                    f"{kind}?zone={zone}")
        ba = WATTTIME_BA.get(key, key)
        sig = "co2_moer" if kind == "latest" else "co2_moer_forecast"
        return (f"https://api.watttime.org/v3/{kind}?region={ba}"
                f"&signal_type={sig}")

    def _headers(self) -> dict:
        if self.provider == "electricitymaps":
            return {"auth-token": self.token}
        return {"Authorization": f"Bearer {self.token}"}

    # ----- bounded-retry fetch ---------------------------------------
    def _get_json(self, kind: str):
        """Fetch + parse one endpoint, or None after bounded retries."""
        if self._transport is None:
            return None
        for attempt in range(1 + self.max_retries):
            if attempt:
                self.retries_used += 1
                self._sleep(min(self.backoff_cap_s,
                                self.backoff_base_s * 2 ** (attempt - 1)))
            try:
                body = self._transport(self._url(kind), self._headers(),
                                       self.timeout_s)
                if isinstance(body, bytes):
                    body = body.decode("utf-8")
                return json.loads(body)
            except Exception:
                continue
        return None

    @staticmethod
    def _parse_latest(payload) -> Optional[float]:
        try:
            v = float(payload.get("carbonIntensity",
                                  payload.get("value", float("nan"))))
        except (AttributeError, TypeError, ValueError):
            return None
        return v if math.isfinite(v) else None

    @staticmethod
    def _parse_forecast(payload) -> Optional[np.ndarray]:
        try:
            rows = payload.get("forecast", payload.get("data", []))
            vals = [float(r.get("carbonIntensity", r.get("value")))
                    for r in rows]
        except (AttributeError, TypeError, ValueError):
            return None
        arr = np.asarray(vals, dtype=float)
        if arr.size == 0 or not np.isfinite(arr).all():
            return None
        return arr

    # ----- provider interface ----------------------------------------
    def intensity(self, t_hours: float) -> float:
        v = self._parse_latest(self._get_json("latest") or {})
        if v is not None:
            self.fetches += 1
            return v
        self.fallbacks += 1
        return super().intensity(t_hours)

    def forecast(self, t_hours: float, horizon_hours: float) -> np.ndarray:
        n = max(1, int(math.ceil(horizon_hours)))
        f = self._parse_forecast(self._get_json("forecast") or {})
        if f is not None:
            self.fetches += 1
            if f.size >= n:
                return f[:n]
            # short horizon from the API: persist its last value
            return np.concatenate([f, np.full(n - f.size, f[-1])])
        self.fallbacks += 1
        return super().forecast(t_hours, horizon_hours)
