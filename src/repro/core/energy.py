"""Analytic per-request energy/time model (hardware-adaptation layer).

The paper measures Llama2 on A100-40GB with nvidia-smi + CarbonTracker; this
container has no GPU, so request energy is derived from a calibrated
roofline model (documented in DESIGN.md §4):

  * decode is memory-bound:  t_token ≈ bytes(params + KV ctx) / HBM_bw
  * prefill is compute-bound: t ≈ 2 · N_active · S_prompt / (MFU · peak)
  * energy = t × (util · P_peak + (1-util) · P_idle) × PUE-at-accounting

The model reproduces the paper's two empirical anchors: (i) carbon/request
is linear in generated tokens (Fig. 2b); (ii) the 13B slope ≈ 1.85× the 7B
slope (Fig. 2a). A real deployment swaps this for telemetry via the same
``EnergyModel`` interface (``measure(request) -> (energy_kwh, seconds)``).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float        # FLOP/s (bf16)
    hbm_bw: float            # bytes/s
    power_peak: float        # W at full utilization
    power_idle: float        # W idle
    embodied_gco2: float     # manufacturing carbon per device, gCO2
    lifetime_s: float = 5 * 365 * 24 * 3600.0  # paper: five-year lifespan
    ici_bw: float = 300e9    # bytes/s per chip over the interconnect


A100_40GB = HardwareSpec(
    name="a100-40gb", peak_flops=312e12, hbm_bw=1.555e12,
    power_peak=250.0, power_idle=50.0, embodied_gco2=150_000.0,
    ici_bw=600e9 / 2)  # NVLink3: 600 GB/s bidirectional, half per direction

# TPU v5e — deployment target (roofline constants from the assignment).
TPU_V5E = HardwareSpec(
    name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9,
    power_peak=220.0, power_idle=60.0, embodied_gco2=120_000.0,
    ici_bw=186e9)  # 4-link ICI, ~186 GB/s aggregate per chip


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    name: str
    n_params: float          # total parameters
    n_active: float = 0.0    # active params per token (MoE); 0 -> n_params
    kv_bytes_per_token: float = 0.0
    param_bytes: float = 0.0  # 0 -> 2 * n_params (bf16)
    kv_quant: str = ""       # "" (16-bit) | "int8" — bookkeeping tag only
    d_model: int = 0         # hidden width (0 -> collective bytes unknown)
    n_layers: int = 0        # transformer depth (0 -> collective unknown)

    @property
    def active(self) -> float:
        return self.n_active or self.n_params

    @property
    def pbytes(self) -> float:
        return self.param_bytes or 2.0 * self.n_params

    def with_int8_kv(self, head_dim: int = 128) -> "ModelProfile":
        """The same model serving an int8-quantized KV cache: each 16-bit
        K/V element becomes 1 byte plus a float32 per-token-per-head scale
        amortized over ``head_dim`` elements (~2x fewer decode KV bytes —
        the serving engine's ``kv_int8`` flag, models/attention.py). The
        resulting profile flows unchanged through ``measure`` into
        LevelProfiles and gateway carbon accounting."""
        elems = self.kv_bytes_per_token / 2.0        # 16-bit baseline
        int8_bytes = elems * 1.0 + (elems / head_dim) * 4.0
        return dataclasses.replace(
            self, name=f"{self.name}-kv8", kv_bytes_per_token=int8_bytes,
            kv_quant="int8")


LLAMA2_13B = ModelProfile("llama2-13b", 13.0e9,
                          kv_bytes_per_token=40 * 40 * 128 * 2 * 2.0,
                          d_model=5120, n_layers=40)
LLAMA2_7B = ModelProfile("llama2-7b", 7.0e9,
                         kv_bytes_per_token=32 * 32 * 128 * 2 * 2.0,
                         d_model=4096, n_layers=32)


class EnergyModel:
    """Per-request (energy, time) under batched continuous serving.

    ``batch`` is the average number of co-scheduled sequences: parameter
    reads amortize across the batch during decode (the dominant effect that
    makes batched serving energy-efficient); KV reads do not.

    ``n_chips`` prices a tensor-parallel fleet (DESIGN.md §14): weights and
    the KV store split evenly over the chips, so per-chip HBM traffic is
    total/n_chips, but every decoded token pays two all-reduces per layer
    over the interconnect; decode t_token is the roofline max of the two.
    ``n_chips=1`` is numerically identical to the single-chip model.
    """

    def __init__(self, hw: HardwareSpec = A100_40GB, *, mfu: float = 0.45,
                 batch: int = 8, decode_overhead: float = 1.25,
                 trust_wall_time: bool = False, n_chips: int = 1):
        assert n_chips >= 1
        self.hw = hw
        self.mfu = mfu
        self.batch = batch
        self.n_chips = n_chips
        self.decode_overhead = decode_overhead  # dequant, sampling, host
        # True when the serving hardware IS the accounting target, so
        # measured decode wall seconds replace the modeled decode duration
        # in measure(); False in this container, where a reduced CPU config
        # stands in for the target device and only token counts transfer
        self.trust_wall_time = trust_wall_time

    def with_chips(self, n_chips: int) -> "EnergyModel":
        """This model repriced for an ``n_chips`` tensor-parallel fleet.
        Returns ``self`` unchanged at the current chip count, so tp=1
        pools pay no object churn and keep bit-identical accounting."""
        if n_chips == self.n_chips:
            return self
        return EnergyModel(self.hw, mfu=self.mfu, batch=self.batch,
                           decode_overhead=self.decode_overhead,
                           trust_wall_time=self.trust_wall_time,
                           n_chips=n_chips)

    # ----- time ------------------------------------------------------
    def prefill_time(self, m: ModelProfile, prompt_tokens: int) -> float:
        flops = 2.0 * m.active * prompt_tokens
        return flops / (self.mfu * self.hw.peak_flops * self.n_chips)

    def collective_bytes_per_token(self, m: ModelProfile) -> float:
        """Interconnect bytes one chip moves per decoded token: two
        all-reduces per layer (post-attention, post-MLP) over a (1,
        d_model) bf16 activation; ring all-reduce moves 2(T-1)/T of the
        payload per chip. Zero when the profile carries no geometry or
        when there is nothing to reduce (one chip)."""
        if self.n_chips == 1 or not (m.d_model and m.n_layers):
            return 0.0
        ring = 2.0 * (self.n_chips - 1) / self.n_chips
        return 2.0 * m.n_layers * ring * m.d_model * 2.0

    def decode_bytes_per_token(self, m: ModelProfile,
                               context_tokens: int) -> float:
        """Modeled HBM bytes streamed per decoded token at a given live
        context — the §4 roofline numerator (param reads amortized over the
        batch; KV reads are per-request and dominate at depth, which is why
        int8 KV halves decode energy)."""
        return m.pbytes / self.batch + m.kv_bytes_per_token * context_tokens

    def decode_kv_bytes_per_token(self, m: ModelProfile,
                                  context_tokens: int) -> float:
        """KV-only share of ``decode_bytes_per_token`` (the term paging and
        int8 quantization act on)."""
        return m.kv_bytes_per_token * context_tokens

    def decode_time(self, m: ModelProfile, gen_tokens: int,
                    context_tokens: int) -> float:
        """Time attributable to ONE request generating ``gen_tokens``."""
        # average context over the generation: context + gen/2. Per chip:
        # HBM traffic splits n_chips ways; the collective term overlaps
        # with it only up to the roofline max (whichever pipe is slower
        # sets the token time).
        hbm_t = (self.decode_bytes_per_token(m, context_tokens + gen_tokens / 2.0)
                 / self.n_chips / self.hw.hbm_bw)
        ici_t = self.collective_bytes_per_token(m) / self.hw.ici_bw
        return gen_tokens * max(hbm_t, ici_t) * self.decode_overhead

    def request_time(self, m: ModelProfile, prompt_tokens: int,
                     gen_tokens: int) -> float:
        return (self.prefill_time(m, prompt_tokens)
                + self.decode_time(m, gen_tokens, prompt_tokens))

    # ----- energy ----------------------------------------------------
    def _power(self, util: float) -> float:
        # every chip in the fleet draws power for the request's duration
        per_chip = util * self.hw.power_peak + (1 - util) * self.hw.power_idle
        return per_chip * self.n_chips

    def request_energy_kwh(self, m: ModelProfile, prompt_tokens: int,
                           gen_tokens: int) -> float:
        tp = self.prefill_time(m, prompt_tokens)
        td = self.decode_time(m, gen_tokens, prompt_tokens)
        joules = tp * self._power(0.85) + td * self._power(0.55)
        return joules / 3.6e6

    def joules_per_token(self, m: ModelProfile, context: int = 512) -> float:
        return self.request_energy_kwh(m, 0, 1) * 3.6e6 + 0 * context

    # ----- telemetry -------------------------------------------------
    def measure(self, m: ModelProfile, prompt_tokens: int, gen_tokens: int,
                decode_s: float = 0.0) -> "tuple[float, float]":
        """Engine telemetry -> (energy_kwh, seconds).

        This is the interface a live deployment implements with power
        telemetry (nvidia-smi / TPU power rails). Here the measured token
        counts drive the calibrated roofline; when ``trust_wall_time`` the
        measured decode-only wall seconds replace the modeled decode
        duration in both the time and energy terms.
        """
        if self.trust_wall_time and decode_s > 0.0:
            tp = self.prefill_time(m, prompt_tokens)
            joules = tp * self._power(0.85) + decode_s * self._power(0.55)
            return joules / 3.6e6, tp + decode_s
        return (self.request_energy_kwh(m, prompt_tokens, gen_tokens),
                self.request_time(m, prompt_tokens, gen_tokens))
