"""Offline generation-quality evaluator (paper §III-A item 4–5, §III-E).

Extends the AlpacaEval-style auto-annotator to N-way choice: sample 500
recent prompts, generate a response at every directive level, shuffle the
candidates to remove position bias, and ask the auto-eval LLM to name the
best one with a minimal-token reply. The preference-rate vector q feeds the
optimizer's quality constraint (Eq. 5).

The judge is any callable ``judge(request, levels, rng) -> level``; the
default simulates a GPT-4-class judge with the paper's measured 97%
agreement. A real API judge drops in unchanged.

Sample size: 500 prompts => max margin of error 4.4% at 95% confidence
(paper §III-D, ref [32]).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.workload import N_LEVELS, Request


@dataclasses.dataclass
class EvaluationReport:
    q: np.ndarray                 # preference rate per level (sums to 1)
    n_samples: int
    judge_queries: int
    judge_tokens_generated: int   # minimal-token replies (cost control)
    eval_energy_kwh: float        # evaluator-side energy (judge LLM)
    regen_energy_kwh: float       # inference-side regeneration energy
    q_by_task: Optional[dict] = None  # per-task preference rates (smoothed)


class QualityEvaluator:
    """N-way AlpacaEval-style evaluator with shuffling + fixed sample size."""

    def __init__(self, n_levels: int = N_LEVELS, sample_size: int = 500,
                 judge: Optional[Callable] = None, judge_error: float = 0.03,
                 seed: int = 17,
                 judge_energy_kwh_per_query: float = 2000.0 / 3.6e6,
                 regen_energy_fn: Optional[Callable] = None):
        """judge_energy default: paper Fig. 14 estimate — 16 A100s at max
        power (250 W) for the 500 ms API time = 2000 J per query."""
        self.n_levels = n_levels
        self.sample_size = sample_size
        self.judge = judge
        self.judge_error = judge_error
        self.rng = np.random.default_rng(seed)
        self.judge_energy = judge_energy_kwh_per_query
        self.regen_energy_fn = regen_energy_fn

    def evaluate(self, pool: Sequence[Request]) -> EvaluationReport:
        if len(pool) == 0:
            q = np.ones(self.n_levels) / self.n_levels
            return EvaluationReport(q, 0, 0, 0, 0.0, 0.0)
        idx = self.rng.choice(len(pool), size=min(self.sample_size, len(pool)),
                              replace=len(pool) < self.sample_size)
        votes = np.zeros(self.n_levels)
        task_votes: dict = {}
        regen_kwh = 0.0
        tokens = 0
        for i in idx:
            r = pool[int(i)]
            order = self.rng.permutation(self.n_levels)  # position-bias shuffle
            if self.judge is not None:
                pick = self.judge(r, list(order), self.rng)
            else:
                pick = r.judge_pick(self.rng, list(order), self.judge_error)
            votes[pick] += 1
            tv = task_votes.setdefault(r.task, np.zeros(self.n_levels))
            tv[pick] += 1
            tokens += 3  # "Output (k)" — minimal-token reply (Fig. 8)
            if self.regen_energy_fn is not None:
                regen_kwh += sum(self.regen_energy_fn(r, l)
                                 for l in range(self.n_levels))
        q = votes / votes.sum()
        # per-task rates, smoothed toward the aggregate (small task samples)
        q_by_task = {t: (v + 5.0 * q) / (v.sum() + 5.0)
                     for t, v in task_votes.items()}
        return EvaluationReport(q, len(idx), len(idx), tokens,
                                float(len(idx)) * self.judge_energy, regen_kwh,
                                q_by_task)
