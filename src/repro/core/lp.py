"""SPROUT's generation-directive optimizer (paper §III-B, Eq. 2–7).

  min_x  f(x) = k0 · eᵀx + k1 · pᵀx                                 (Eq. 2)
  s.t.   qᵀx ≥ q_lb                                                 (Eq. 5)
         0 ≤ x_i ≤ 1                                                (Eq. 6)
         Σ x_i = 1                                                  (Eq. 7)
  q_lb = (1 − (k0 − k0_min)/(k0_max − k0_min) · ξ) · q0             (Eq. 3)

Solved with HiGHS dual simplex (paper ref. [30]) via scipy; a dependency-
free dense two-phase simplex is included as fallback and as a cross-check
oracle in tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

try:
    from scipy.optimize import linprog as _scipy_linprog
    _HAVE_SCIPY = True
except Exception:  # pragma: no cover
    _HAVE_SCIPY = False


@dataclasses.dataclass(frozen=True)
class DirectiveSolution:
    x: np.ndarray              # probability per directive level
    expected_carbon: float     # f(x), gCO2 per request
    expected_quality: float    # qᵀx
    q_lb: float
    feasible: bool
    solver: str


def quality_lower_bound(q0: float, k0: float, k0_min: float, k0_max: float,
                        xi: float) -> float:
    """Eq. 3: quality floor tightens when the grid is green."""
    k0c = min(max(k0, k0_min), k0_max)
    frac = (k0c - k0_min) / max(k0_max - k0_min, 1e-12)
    return (1.0 - frac * xi) * q0


def forecast_weighted_intensity(window, *, decay: float = 0.5) -> float:
    """Collapse an hourly intensity forecast window into the effective k0
    the LP should plan against.

    The Eq. 2 objective is linear in k0, so planning the next H hours
    against weights w is EXACTLY solving the LP at the scalar
    k0_eff = Σ_h w_h · k0_h — no new solver needed, just a weighted
    effective intensity. Weights decay geometrically (w_h ∝ decay^h):
    requests admitted under this plan mostly finish within the current
    hour, but a dirty hour ahead still pulls the mix toward brevity
    pre-emptively (the Fig. 12 adaptivity signal, one hour early).
    ``decay=1`` is a plain window mean; ``decay→0`` recovers the
    instantaneous value.
    """
    window = np.asarray(window, float)
    assert window.size > 0, "forecast window must hold at least one hour"
    if not (0.0 < decay <= 1.0):
        raise ValueError(f"decay must be in (0, 1], got {decay}")
    w = decay ** np.arange(window.size)
    return float(w @ window / w.sum())


def solve_directive_lp(e: Sequence[float], p: Sequence[float],
                       q: Sequence[float], *, k0: float, k1: float,
                       k0_min: float, k0_max: float, xi: float = 0.1,
                       solver: str = "auto") -> DirectiveSolution:
    """Configure directive-level probabilities x (Eq. 4–7)."""
    e = np.asarray(e, float)
    p = np.asarray(p, float)
    q = np.asarray(q, float)
    n = len(e)
    assert len(p) == n and len(q) == n
    c = k0 * e + k1 * p                      # objective coefficients
    q_lb = quality_lower_bound(q[0], k0, k0_min, k0_max, xi)

    if solver in ("auto", "highs") and _HAVE_SCIPY:
        res = _scipy_linprog(
            c,
            A_ub=(-q)[None, :], b_ub=[-q_lb],          # qᵀx ≥ q_lb
            A_eq=np.ones((1, n)), b_eq=[1.0],
            bounds=[(0.0, 1.0)] * n,
            method="highs-ds")                          # dual simplex [30]
        if res.status == 0:
            x = np.clip(res.x, 0.0, 1.0)
            x = x / x.sum()
            return DirectiveSolution(x, float(c @ x), float(q @ x), q_lb,
                                     True, "highs-ds")
        # infeasible: fall through to the fallback path below

    return _solve_fallback(c, q, q_lb)


def _solve_fallback(c: np.ndarray, q: np.ndarray,
                    q_lb: float) -> DirectiveSolution:
    """Dense exact solver for this specific LP structure.

    With one simplex constraint and one quality inequality, a vertex optimum
    mixes at most TWO levels (n-variable LP with 2 active constraints).
    Enumerate single levels and all 2-level mixes that hit qᵀx = q_lb.
    """
    n = len(c)
    best_x, best_f = None, np.inf
    for i in range(n):
        if q[i] >= q_lb - 1e-12 and c[i] < best_f:
            x = np.zeros(n)
            x[i] = 1.0
            best_x, best_f = x, c[i]
    for i in range(n):
        for j in range(n):
            if i == j or q[i] <= q[j]:
                continue
            # mix a (high-quality i) with (1-a) (low j) to hit the floor
            denom = q[i] - q[j]
            a = (q_lb - q[j]) / denom
            if not (0.0 <= a <= 1.0):
                continue
            f = a * c[i] + (1 - a) * c[j]
            if f < best_f - 1e-15:
                x = np.zeros(n)
                x[i], x[j] = a, 1 - a
                best_x, best_f = x, f
    if best_x is None:  # infeasible: best effort = highest-quality level
        x = np.zeros(n)
        x[int(np.argmax(q))] = 1.0
        return DirectiveSolution(x, float(c @ x), float(q @ x), q_lb,
                                 False, "fallback")
    return DirectiveSolution(best_x, float(best_f), float(q @ best_x), q_lb,
                             True, "fallback")
