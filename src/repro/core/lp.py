"""SPROUT's generation-directive optimizer (paper §III-B, Eq. 2–7).

  min_x  f(x) = k0 · eᵀx + k1 · pᵀx                                 (Eq. 2)
  s.t.   qᵀx ≥ q_lb                                                 (Eq. 5)
         0 ≤ x_i ≤ 1                                                (Eq. 6)
         Σ x_i = 1                                                  (Eq. 7)
  q_lb = (1 − (k0 − k0_min)/(k0_max − k0_min) · ξ) · q0             (Eq. 3)

Solved with HiGHS dual simplex (paper ref. [30]) via scipy; a dependency-
free dense two-phase simplex is included as fallback and as a cross-check
oracle in tests.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Mapping, Optional, Sequence, Tuple

import numpy as np

try:
    from scipy.optimize import linprog as _scipy_linprog
    _HAVE_SCIPY = True
except Exception:  # pragma: no cover
    _HAVE_SCIPY = False


@dataclasses.dataclass(frozen=True)
class DirectiveSolution:
    x: np.ndarray              # probability per directive level
    expected_carbon: float     # f(x), gCO2 per request
    expected_quality: float    # qᵀx
    q_lb: float
    feasible: bool
    solver: str


def quality_lower_bound(q0: float, k0: float, k0_min: float, k0_max: float,
                        xi: float) -> float:
    """Eq. 3: quality floor tightens when the grid is green."""
    k0c = min(max(k0, k0_min), k0_max)
    frac = (k0c - k0_min) / max(k0_max - k0_min, 1e-12)
    return (1.0 - frac * xi) * q0


def forecast_weighted_intensity(window, *, decay: float = 0.5) -> float:
    """Collapse an hourly intensity forecast window into the effective k0
    the LP should plan against.

    The Eq. 2 objective is linear in k0, so planning the next H hours
    against weights w is EXACTLY solving the LP at the scalar
    k0_eff = Σ_h w_h · k0_h — no new solver needed, just a weighted
    effective intensity. Weights decay geometrically (w_h ∝ decay^h):
    requests admitted under this plan mostly finish within the current
    hour, but a dirty hour ahead still pulls the mix toward brevity
    pre-emptively (the Fig. 12 adaptivity signal, one hour early).
    ``decay=1`` is a plain window mean; ``decay→0`` recovers the
    instantaneous value.
    """
    window = np.asarray(window, float)
    assert window.size > 0, "forecast window must hold at least one hour"
    if not (0.0 < decay <= 1.0):
        raise ValueError(f"decay must be in (0, 1], got {decay}")
    w = decay ** np.arange(window.size)
    return float(w @ window / w.sum())


def solve_directive_lp(e: Sequence[float], p: Sequence[float],
                       q: Sequence[float], *, k0: float, k1: float,
                       k0_min: float, k0_max: float, xi: float = 0.1,
                       q_lb_floor: float = 0.0,
                       solver: str = "auto") -> DirectiveSolution:
    """Configure directive-level probabilities x (Eq. 4–7).

    ``q_lb_floor`` clamps the Eq. 3 floor from below (absolute units of
    q): a premium tenant's quality guarantee must hold even when the grid
    is at its dirtiest and Eq. 3 would relax the floor all the way to
    ``(1 - xi) * q0``.
    """
    e = np.asarray(e, float)
    p = np.asarray(p, float)
    q = np.asarray(q, float)
    n = len(e)
    assert len(p) == n and len(q) == n
    # validate BEFORE solving (DESIGN.md §12): a NaN carbon price or
    # telemetry vector would not crash the solver — it would return a
    # garbage mix that silently misplans the hour. Fail loudly here so
    # the gateway's plan-hold degraded mode can catch it.
    if not (np.isfinite(e).all() and np.isfinite(p).all()
            and np.isfinite(q).all()):
        raise ValueError("non-finite LP inputs: e/p/q telemetry")
    if not all(math.isfinite(v) for v in (k0, k1, k0_min, k0_max, xi)):
        raise ValueError(
            f"non-finite LP carbon terms: k0={k0} k1={k1} "
            f"k0_min={k0_min} k0_max={k0_max} xi={xi}")
    c = k0 * e + k1 * p                      # objective coefficients
    q_lb = max(quality_lower_bound(q[0], k0, k0_min, k0_max, xi),
               q_lb_floor)

    if solver in ("auto", "highs") and _HAVE_SCIPY:
        res = _scipy_linprog(
            c,
            A_ub=(-q)[None, :], b_ub=[-q_lb],          # qᵀx ≥ q_lb
            A_eq=np.ones((1, n)), b_eq=[1.0],
            bounds=[(0.0, 1.0)] * n,
            method="highs-ds")                          # dual simplex [30]
        if res.status == 0:
            x = np.clip(res.x, 0.0, 1.0)
            x = x / x.sum()
            return DirectiveSolution(x, float(c @ x), float(q @ x), q_lb,
                                     True, "highs-ds")
        # infeasible: fall through to the fallback path below

    return _solve_fallback(c, q, q_lb)


def _solve_fallback(c: np.ndarray, q: np.ndarray,
                    q_lb: float) -> DirectiveSolution:
    """Dense exact solver for this specific LP structure.

    With one simplex constraint and one quality inequality, a vertex optimum
    mixes at most TWO levels (n-variable LP with 2 active constraints).
    Enumerate single levels and all 2-level mixes that hit qᵀx = q_lb.
    """
    n = len(c)
    best_x, best_f = None, np.inf
    for i in range(n):
        if q[i] >= q_lb - 1e-12 and c[i] < best_f:
            x = np.zeros(n)
            x[i] = 1.0
            best_x, best_f = x, c[i]
    for i in range(n):
        for j in range(n):
            if i == j or q[i] <= q[j]:
                continue
            # mix a (high-quality i) with (1-a) (low j) to hit the floor
            denom = q[i] - q[j]
            a = (q_lb - q[j]) / denom
            if not (0.0 <= a <= 1.0):
                continue
            f = a * c[i] + (1 - a) * c[j]
            if f < best_f - 1e-15:
                x = np.zeros(n)
                x[i], x[j] = a, 1 - a
                best_x, best_f = x, f
    if best_x is None:  # infeasible: best effort = highest-quality level
        x = np.zeros(n)
        x[int(np.argmax(q))] = 1.0
        return DirectiveSolution(x, float(c @ x), float(q @ x), q_lb,
                                 False, "fallback")
    return DirectiveSolution(best_x, float(best_f), float(q @ best_x), q_lb,
                             True, "fallback")


# ---------------------------------------------------------------------------
# Per-tenant service classes (gateway-side SLOs)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant service class: its quality floor AND its latency targets.

    The directive optimizer trades quality for carbon; a production fleet
    makes that trade per tenant. Each class carries

    * ``xi`` — its own Eq. 3 relaxation (how far quality may drop as the
      grid greens); a premium class keeps xi small;
    * ``q_floor_frac`` — an ABSOLUTE floor as a fraction of the pure-L0
      preference rate q0: ``q_lb >= q_floor_frac * q0`` no matter how
      dirty the grid is (Eq. 3 alone would keep relaxing);
    * ``ttft_s`` / ``tpot_s`` — latency targets: when a request arrives
      without an explicit deadline, the gateway derives one as
      ``ttft_s + tpot_s * max_new_tokens``;
    * ``priority`` — dispatch order within a pool (lower dispatches
      first), so a premium request never queues behind batch work;
    * ``q_by_task`` — optional per-task preference vectors (the evaluator
      reports them per SPROUT task family); the tenant's LP then solves
      over its task-weighted quality vector instead of the aggregate.
    """
    name: str
    xi: float = 0.1
    q_floor_frac: float = 0.0
    ttft_s: float = math.inf
    tpot_s: float = math.inf
    priority: int = 1
    q_by_task: Optional[Mapping[str, Sequence[float]]] = None

    def deadline_for(self, max_new_tokens: int) -> float:
        """Per-class completion deadline for a request of this budget."""
        if math.isinf(self.ttft_s) and math.isinf(self.tpot_s):
            return math.inf
        ttft = 0.0 if math.isinf(self.ttft_s) else self.ttft_s
        tpot = 0.0 if math.isinf(self.tpot_s) else self.tpot_s
        return ttft + tpot * max_new_tokens

    def effective_q(self, q_default: np.ndarray,
                    task_weights: Optional[Mapping[str, float]] = None
                    ) -> np.ndarray:
        """The quality vector this tenant's LP solves over: the task-
        weighted mix of its per-task q vectors when it has them (weights
        default to uniform over the tenant's known tasks), else the
        fleet-wide aggregate."""
        if not self.q_by_task:
            return np.asarray(q_default, float)
        tasks = list(self.q_by_task)
        if task_weights:
            w = np.array([max(float(task_weights.get(t, 0.0)), 0.0)
                          for t in tasks])
            if w.sum() <= 0:
                w = np.ones(len(tasks))
        else:
            w = np.ones(len(tasks))
        w = w / w.sum()
        qs = np.stack([np.asarray(self.q_by_task[t], float) for t in tasks])
        return w @ qs


# Default service classes. Premium holds ~97% of L0 preference no matter
# the grid and dispatches first; batch has no latency target and lets the
# optimizer chase carbon almost freely.
PREMIUM = TenantSpec("premium", xi=0.03, q_floor_frac=0.97,
                     ttft_s=0.5, tpot_s=0.05, priority=0)
STANDARD = TenantSpec("standard", xi=0.12, q_floor_frac=0.80,
                      ttft_s=2.0, tpot_s=0.25, priority=1)
BATCH = TenantSpec("batch", xi=0.35, q_floor_frac=0.0, priority=2)
DEFAULT_TENANTS: Tuple[TenantSpec, ...] = (PREMIUM, STANDARD, BATCH)


def solve_tenant_lps(e: Sequence[float], p: Sequence[float],
                     tenants: Sequence[TenantSpec], q_default: np.ndarray,
                     *, k0: float, k1: float, k0_min: float, k0_max: float,
                     task_weights: Optional[Mapping[str, float]] = None,
                     solver: str = "auto") -> Dict[str, DirectiveSolution]:
    """One directive LP per tenant class at a shared grid signal.

    Each tenant's solve uses its own xi, its absolute quality floor
    (``q_floor_frac * q_t[0]``), and its task-weighted quality vector.
    The LPs are independent (per-tenant floors, not one aggregate
    constraint), so solving them separately IS the exact optimum — and
    stays microseconds-scale on the control plane.
    """
    out: Dict[str, DirectiveSolution] = {}
    for t in tenants:
        q_t = t.effective_q(q_default, task_weights)
        out[t.name] = solve_directive_lp(
            e, p, q_t, k0=k0, k1=k1, k0_min=k0_min, k0_max=k0_max,
            xi=t.xi, q_lb_floor=t.q_floor_frac * float(q_t[0]),
            solver=solver)
    return out
