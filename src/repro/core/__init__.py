"""SPROUT core: generation directives, carbon-aware LP optimizer,
opportunistic offline quality evaluation, and the serving controller.

Public API:
    DirectiveSet, Directive          — paper Def. 1 / §III-E
    solve_directive_lp               — Eq. 2–7 optimizer
    EvaluationInvoker                — Eq. 8 opportunistic assessment
    QualityEvaluator                 — N-way AlpacaEval-style judge
    SproutSimulation, summarize      — end-to-end evaluation harness
    EnergyModel, CarbonIntensityProvider, request_carbon
"""
from repro.core.carbon import (CarbonIntensityProvider, REGIONS, SEASONS,
                               WatchdogProvider, carbon_intensity_trace,
                               request_carbon, PUE)
from repro.core.grid_client import GridSignalClient
from repro.core.controller import SproutSimulation, SchemeStats, summarize
from repro.core.directives import DEFAULT_DIRECTIVES, Directive, DirectiveSet
from repro.core.energy import (A100_40GB, TPU_V5E, LLAMA2_7B, LLAMA2_13B,
                               EnergyModel, HardwareSpec, ModelProfile)
from repro.core.invoker import EvaluationInvoker
from repro.core.lp import (BATCH, DEFAULT_TENANTS, PREMIUM, STANDARD,
                           DirectiveSolution, TenantSpec,
                           quality_lower_bound, solve_directive_lp,
                           solve_tenant_lps)
from repro.core.quality import EvaluationReport, QualityEvaluator
from repro.core.workload import TASKS, Request, Workload

__all__ = [
    "CarbonIntensityProvider", "WatchdogProvider", "GridSignalClient",
    "REGIONS", "SEASONS", "carbon_intensity_trace",
    "request_carbon", "PUE", "SproutSimulation", "SchemeStats", "summarize",
    "DEFAULT_DIRECTIVES", "Directive", "DirectiveSet", "A100_40GB", "TPU_V5E",
    "LLAMA2_7B", "LLAMA2_13B", "EnergyModel", "HardwareSpec", "ModelProfile",
    "EvaluationInvoker", "DirectiveSolution", "quality_lower_bound",
    "solve_directive_lp", "solve_tenant_lps", "TenantSpec", "PREMIUM",
    "STANDARD", "BATCH", "DEFAULT_TENANTS", "EvaluationReport",
    "QualityEvaluator", "TASKS", "Request", "Workload",
]
