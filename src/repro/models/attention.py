"""Attention: GQA (full / sliding-window) and MLA (DeepSeek-style latent).

Three execution modes share one parameter layout:
  * ``train``   — full sequence, no cache, causal (or bidirectional) mask.
  * ``prefill`` — full sequence, writes the KV cache, returns it.
  * ``decode``  — q_len == 1 against a cache with per-slot positions.

Cache layout (GQA):  {"k": (B, S, n_kv, Dh), "v": ..., "kpos": (B, S) int32}
  ``kpos`` holds the absolute position of each cache row (-2**30 = empty),
  which uniformly supports full caches, ring-buffer sliding windows, and
  continuous batching with ragged per-slot lengths.
Cache layout (MLA):  {"ckv": (B, S, rank), "kr": (B, S, rope), "kpos": ...}
Cache layout (paged GQA): {"k": (P, page, n_kv, Dh), "v": ...} — a block-table
  page store shared by every slot; the per-slot page list and live lengths
  arrive as separate decode-step inputs (``gqa_decode_paged``), and the
  attention read runs through the Pallas paged kernel or its XLA reference
  (kernels/ops.py dispatch).
Int8 KV (beyond-paper optimization): "k"/"v" stored int8 + "k_scale"/"v_scale"
  (B, S, n_kv) float32 per-token-per-head scales (paged: (P, page, n_kv)).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, KeyGen, dense_init
from repro.models.layers import apply_rope, apply_norm
from repro.models.blocked_attn import flash_sdpa

NEG_INF = -1e30
EMPTY_POS = -(1 << 30)


# ======================================================================
# parameter init
# ======================================================================

def init_attention(cfg: ModelConfig, key, cross: bool = False):
    if cfg.attn_type == "mla" and not cross:
        return _init_mla(cfg, key)
    return _init_gqa(cfg, key, cross=cross)


def _init_gqa(cfg: ModelConfig, key, cross: bool = False):
    kg = KeyGen(key)
    dt = cfg.compute_dtype
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": dense_init(kg(), (d, h * dh), dt),
        "wk": dense_init(kg(), (d, kv * dh), dt),
        "wv": dense_init(kg(), (d, kv * dh), dt),
        "wo": dense_init(kg(), (h * dh, d), dt, scale=1.0 / math.sqrt(h * dh)),
    }
    if cfg.use_bias:
        p["bq"] = jnp.zeros((h * dh,), dt)
        p["bk"] = jnp.zeros((kv * dh,), dt)
        p["bv"] = jnp.zeros((kv * dh,), dt)
        p["bo"] = jnp.zeros((d,), dt)
    return p


def _init_mla(cfg: ModelConfig, key):
    kg = KeyGen(key)
    dt = cfg.compute_dtype
    d, h = cfg.d_model, cfg.n_heads
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    p = {}
    if cfg.q_lora_rank > 0:
        p["wq_a"] = dense_init(kg(), (d, cfg.q_lora_rank), dt)
        p["q_norm"] = {"scale": jnp.ones((cfg.q_lora_rank,), jnp.float32)}
        p["wq_b"] = dense_init(kg(), (cfg.q_lora_rank, h * (nope + rope)), dt)
    else:
        p["wq"] = dense_init(kg(), (d, h * (nope + rope)), dt)
    p["wkv_a"] = dense_init(kg(), (d, cfg.kv_lora_rank + rope), dt)
    p["kv_norm"] = {"scale": jnp.ones((cfg.kv_lora_rank,), jnp.float32)}
    p["wkv_b"] = dense_init(kg(), (cfg.kv_lora_rank, h * (nope + vd)), dt)
    p["wo"] = dense_init(kg(), (h * vd, d), dt, scale=1.0 / math.sqrt(h * vd))
    return p


# ======================================================================
# KV quantization helpers (int8 per-token-per-head symmetric)
# ======================================================================

def quantize_kv(x):
    """x: (B, T, n_kv, Dh) -> (int8 values, float32 scales (B, T, n_kv))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


# ======================================================================
# masks
# ======================================================================

def _causal_window_mask(q_pos, k_pos, window: int, causal: bool):
    """q_pos: (..., Tq), k_pos: (..., Tk) -> bool (..., Tq, Tk)."""
    dq = q_pos[..., :, None]
    dk = k_pos[..., None, :]
    m = dk > EMPTY_POS // 2  # valid rows only
    if causal:
        m &= dk <= dq
    if window > 0:
        m &= dk > dq - window
    return m


# ======================================================================
# core attention math (XLA path; fp32 softmax)
# ======================================================================

def _sdpa(q, k, v, mask, softcap: float = 0.0):
    """q: (B,Tq,KV,G,D)  k: (B,Tk,KV,D)  v: (B,Tk,KV,Dv)  mask: (B,Tq,Tk) or (Tq,Tk)."""
    d = q.shape[-1]
    scores = jnp.einsum("btkgd,bskd->bkgts", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(d)
    if softcap > 0:
        scores = softcap * jnp.tanh(scores / softcap)
    if mask.ndim == 2:
        mask = mask[None]
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", probs.astype(v.dtype), v)
    return out


# ======================================================================
# GQA forward
# ======================================================================

def _project_qkv(cfg: ModelConfig, p, x, positions, rope: bool = True):
    B, T = x.shape[:2]
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.use_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, T, h, dh)
    k = k.reshape(B, T, kv, dh)
    v = v.reshape(B, T, kv, dh)
    if rope and cfg.pos == "rope":
        q = apply_rope(cfg, q, positions)
        k = apply_rope(cfg, k, positions)
    return q, k, v


def gqa_full(cfg: ModelConfig, p, x, positions, *, causal: bool = True,
             window: int = 0, kv_override=None):
    """train/prefill attention over the whole sequence (no cache read)."""
    B, T = x.shape[:2]
    h, kv_h, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if kv_override is None:
        q, k, v = _project_qkv(cfg, p, x, positions)
    else:  # cross-attention: kv computed from encoder output
        q = (x @ p["wq"] + (p["bq"] if cfg.use_bias else 0)).reshape(B, T, h, dh)
        k, v = kv_override
    g = h // k.shape[2]
    qg = q.reshape(B, T, k.shape[2], g, dh)
    use_flash = (cfg.attn_impl in ("blocked", "pallas")
                 and cfg.logit_softcap == 0.0 and kv_override is None)
    if use_flash:
        qpos = jnp.broadcast_to(positions, (B, T)) if positions.ndim == 1 else positions
        out = flash_sdpa(qg, k, v, qpos, qpos, causal=causal, window=window)
    else:
        if kv_override is None:
            mask = _causal_window_mask(positions, positions, window, causal)
        else:
            Tk = k.shape[1]
            mask = jnp.ones((B, T, Tk), bool)
        out = _sdpa(qg, k, v, mask, cfg.logit_softcap)
    out = out.reshape(B, T, h * dh)
    y = out @ p["wo"]
    if cfg.use_bias:
        y = y + p["bo"]
    return y, (k, v)


def init_gqa_cache(cfg: ModelConfig, batch: int, max_len: int, window: int = 0):
    S = min(max_len, window) if window > 0 else max_len
    kv, dh = cfg.n_kv_heads, cfg.head_dim
    c = {"kpos": jnp.full((batch, S), EMPTY_POS, jnp.int32)}
    if cfg.kv_cache_dtype == "int8":
        c["k"] = jnp.zeros((batch, S, kv, dh), jnp.int8)
        c["v"] = jnp.zeros((batch, S, kv, dh), jnp.int8)
        c["k_scale"] = jnp.zeros((batch, S, kv), jnp.float32)
        c["v_scale"] = jnp.zeros((batch, S, kv), jnp.float32)
    else:
        c["k"] = jnp.zeros((batch, S, kv, dh), cfg.kv_dtype)
        c["v"] = jnp.zeros((batch, S, kv, dh), cfg.kv_dtype)
    return c


def _cache_write(cache, new_k, new_v, positions, window: int,
                 quantized: bool):
    """Scatter one token per batch row into the cache at ring/linear slots."""
    S = cache["k"].shape[1]
    slot = positions % S if window > 0 else jnp.minimum(positions, S - 1)

    def wr(buf, val):  # buf: (B,S,...), val: (B,...) one token
        return jax.vmap(lambda b, v_, i: b.at[i].set(v_))(buf, val, slot)

    if quantized:
        qk, sk = quantize_kv(new_k)
        qv, sv = quantize_kv(new_v)
        cache = dict(cache,
                     k=wr(cache["k"], qk[:, 0]),
                     v=wr(cache["v"], qv[:, 0]),
                     k_scale=wr(cache["k_scale"], sk[:, 0]),
                     v_scale=wr(cache["v_scale"], sv[:, 0]))
    else:
        cache = dict(cache, k=wr(cache["k"], new_k[:, 0]),
                     v=wr(cache["v"], new_v[:, 0]))
    cache["kpos"] = jax.vmap(lambda b, i, pv: b.at[i].set(pv))(
        cache["kpos"], slot, positions)
    return cache


def gqa_decode(cfg: ModelConfig, p, x, positions, cache, *, window: int = 0,
               kv_override=None):
    """x: (B, 1, d); positions: (B,) absolute position of the new token."""
    B = x.shape[0]
    h, dh = cfg.n_heads, cfg.head_dim
    quantized = cfg.kv_cache_dtype == "int8"
    if kv_override is None:
        q, k_new, v_new = _project_qkv(cfg, p, x, positions[:, None])
        cache = _cache_write(cache, k_new, v_new, positions, window, quantized)
        if quantized:
            k = dequantize_kv(cache["k"], cache["k_scale"], cfg.compute_dtype)
            v = dequantize_kv(cache["v"], cache["v_scale"], cfg.compute_dtype)
        else:
            k = cache["k"].astype(cfg.compute_dtype)
            v = cache["v"].astype(cfg.compute_dtype)
        mask = _causal_window_mask(positions[:, None], cache["kpos"],
                                   window, causal=True)
    else:
        q = (x @ p["wq"] + (p["bq"] if cfg.use_bias else 0)).reshape(B, 1, h, dh)
        if cfg.pos == "rope":
            q = apply_rope(cfg, q, positions[:, None])
        k, v = kv_override
        mask = jnp.ones((B, 1, k.shape[1]), bool)
    kv_h = k.shape[2]
    qg = q.reshape(B, 1, kv_h, h // kv_h, dh)
    out = _sdpa(qg, k, v, mask, cfg.logit_softcap)
    y = out.reshape(B, 1, h * dh) @ p["wo"]
    if cfg.use_bias:
        y = y + p["bo"]
    return y, cache


# ======================================================================
# chunked prefill append (continuous batching; serving hot path)
# ======================================================================

def gqa_chunk_append(cfg: ModelConfig, p, x, positions, valid, lane, cache,
                     *, block_table=None):
    """Append one prompt *chunk* for a single lane and attend causally over
    everything that lane has written so far (earlier chunks included).

    x: (1, C, d) chunk hidden states; positions: (C,) absolute positions;
    valid: (C,) bool (False rows are pad — their writes are dropped);
    lane: scalar int32 — the batch row / block-table row this chunk owns.

    Dense cache (leaves (B, S, ...)): chunk K/V is scattered into the
    lane's linear region with an explicit index scatter (invalid rows are
    redirected to out-of-bounds index S, which JAX drops), then the lane's
    full row is read back with the kpos causal mask. Write-before-attend
    gives cross-chunk causality for free: every row with kpos <= q_pos was
    freshly written by this request (chunks land in order), and rows this
    request has not yet written carry kpos from a previous occupant only
    at positions > q_pos, which the causal mask excludes.

    Paged cache (block_table given; leaves (P, ps, ...)): writes go
    through the lane's block-table row (unmapped / invalid entries are
    redirected to the out-of-bounds page P and dropped), reads gather the
    lane's pages and mask by absolute index <= q_pos — positional
    validity, exactly like decode.
    """
    C = x.shape[1]
    h, dh = cfg.n_heads, cfg.head_dim
    quantized = cfg.kv_cache_dtype == "int8"
    q, k_new, v_new = _project_qkv(cfg, p, x, positions[None, :])
    if quantized:
        qk, sk = quantize_kv(k_new)
        qv, sv = quantize_kv(v_new)

    if block_table is None:
        S = cache["k"].shape[1]
        idx = jnp.where(valid, jnp.minimum(positions, S - 1), S)

        def wr(buf, val):          # buf (B, S, ...), val (1, C, ...)
            return buf.at[lane, idx].set(val[0].astype(buf.dtype))

        if quantized:
            cache = dict(cache, k=wr(cache["k"], qk), v=wr(cache["v"], qv),
                         k_scale=wr(cache["k_scale"], sk),
                         v_scale=wr(cache["v_scale"], sv))
        else:
            cache = dict(cache, k=wr(cache["k"], k_new),
                         v=wr(cache["v"], v_new))
        cache["kpos"] = cache["kpos"].at[lane, idx].set(positions)
        if quantized:
            k = dequantize_kv(cache["k"][lane], cache["k_scale"][lane],
                              cfg.compute_dtype)[None]
            v = dequantize_kv(cache["v"][lane], cache["v_scale"][lane],
                              cfg.compute_dtype)[None]
        else:
            k = cache["k"][lane][None].astype(cfg.compute_dtype)
            v = cache["v"][lane][None].astype(cfg.compute_dtype)
        mask = _causal_window_mask(positions[None, :], cache["kpos"][lane][None],
                                   0, causal=True)
    else:
        P, ps = cache["k"].shape[:2]
        bt = block_table[lane]                       # (max_pages,)
        pidx = jnp.clip(positions // ps, 0, bt.shape[0] - 1)
        entry = bt[pidx]
        page = jnp.where(valid & (entry >= 0), entry, P)
        off = positions % ps

        def wr(buf, val):          # buf (P, ps, ...), val (1, C, ...)
            return buf.at[page, off].set(val[0].astype(buf.dtype))

        if quantized:
            cache = dict(cache, k=wr(cache["k"], qk), v=wr(cache["v"], qv),
                         k_scale=wr(cache["k_scale"], sk),
                         v_scale=wr(cache["v_scale"], sv))
        else:
            cache = dict(cache, k=wr(cache["k"], k_new),
                         v=wr(cache["v"], v_new))
        # gather the lane's pages (clamped; stale/unmapped rows sit at
        # absolute indices > q_pos and are masked positionally)
        safe = jnp.clip(bt, 0, P - 1)
        kp = cache["k"][safe].reshape(-1, *cache["k"].shape[2:])
        vp = cache["v"][safe].reshape(-1, *cache["v"].shape[2:])
        if quantized:
            ksp = cache["k_scale"][safe].reshape(-1, *cache["k_scale"].shape[2:])
            vsp = cache["v_scale"][safe].reshape(-1, *cache["v_scale"].shape[2:])
            k = dequantize_kv(kp, ksp, cfg.compute_dtype)[None]
            v = dequantize_kv(vp, vsp, cfg.compute_dtype)[None]
        else:
            k = kp[None].astype(cfg.compute_dtype)
            v = vp[None].astype(cfg.compute_dtype)
        kidx = jnp.arange(k.shape[1])
        mask = (kidx[None, None, :] <= positions[None, :, None])

    kv_h = k.shape[2]
    qg = q.reshape(1, C, kv_h, h // kv_h, dh)
    out = _sdpa(qg, k, v, mask, cfg.logit_softcap)
    y = out.reshape(1, C, h * dh) @ p["wo"]
    if cfg.use_bias:
        y = y + p["bo"]
    return y, cache


# ======================================================================
# paged GQA decode (block-table cache; serving hot path)
# ======================================================================

def init_paged_gqa_cache(cfg: ModelConfig, n_pages: int, page_size: int):
    """One layer's page store: K/V for every slot live in shared pages."""
    kv, dh = cfg.n_kv_heads, cfg.head_dim
    if cfg.kv_cache_dtype == "int8":
        return {"k": jnp.zeros((n_pages, page_size, kv, dh), jnp.int8),
                "v": jnp.zeros((n_pages, page_size, kv, dh), jnp.int8),
                "k_scale": jnp.zeros((n_pages, page_size, kv), jnp.float32),
                "v_scale": jnp.zeros((n_pages, page_size, kv), jnp.float32)}
    return {"k": jnp.zeros((n_pages, page_size, kv, dh), cfg.kv_dtype),
            "v": jnp.zeros((n_pages, page_size, kv, dh), cfg.kv_dtype)}


def _paged_cache_write(cache, new_k, new_v, positions, block_table, live,
                       quantized: bool):
    """Append one token per row through the block table.

    Rows whose table entry is unmapped (-1) and rows whose lane is dead
    (``live`` False) are redirected to the out-of-bounds page id ``P`` —
    JAX drops out-of-bounds scatter updates, so a dead lane can never
    corrupt a page that was re-allocated to another slot mid-block.
    """
    P, ps = cache["k"].shape[:2]
    entry = jnp.take_along_axis(block_table, (positions // ps)[:, None],
                                axis=1)[:, 0]
    page = jnp.where(entry >= 0, entry, P)
    if live is not None:
        page = jnp.where(live, page, P)
    off = positions % ps

    def wr(buf, val):      # buf (P, ps, ...), val (B, ...) one token per row
        return buf.at[page, off].set(val.astype(buf.dtype))

    if quantized:
        qk, sk = quantize_kv(new_k)
        qv, sv = quantize_kv(new_v)
        return dict(cache,
                    k=wr(cache["k"], qk[:, 0]), v=wr(cache["v"], qv[:, 0]),
                    k_scale=wr(cache["k_scale"], sk[:, 0]),
                    v_scale=wr(cache["v_scale"], sv[:, 0]))
    return dict(cache, k=wr(cache["k"], new_k[:, 0]),
                v=wr(cache["v"], new_v[:, 0]))


def gqa_decode_paged(cfg: ModelConfig, p, x, positions, cache, block_table,
                     *, live=None, impl: str = "auto"):
    """Paged decode step: append the new token's K/V through the block
    table, then attend over the slot's pages.

    x: (B, 1, d); positions: (B,) absolute position of the new token;
    block_table: (B, max_pages) int32 (-1 = unmapped); live: (B,) bool or
    None. ``impl`` picks the attention read: "pallas" /
    "pallas_interpret" force the kernel, "xla" the pure-jnp reference,
    "auto" resolves per backend (kernels/ops.py).
    """
    from repro.kernels import ops

    B = x.shape[0]
    h, dh = cfg.n_heads, cfg.head_dim
    quantized = cfg.kv_cache_dtype == "int8"
    q, k_new, v_new = _project_qkv(cfg, p, x, positions[:, None])
    cache = _paged_cache_write(cache, k_new, v_new, positions, block_table,
                               live, quantized)
    # append-only pages: validity == index < length, causality is implicit
    lengths = positions + 1
    out = ops.paged_attention(
        q[:, 0], cache["k"], cache["v"], block_table, lengths,
        cache.get("k_scale"), cache.get("v_scale"), impl=impl)
    y = out.reshape(B, 1, h * dh) @ p["wo"]
    if cfg.use_bias:
        y = y + p["bo"]
    return y, cache


# ======================================================================
# MLA forward
# ======================================================================

def _mla_q(cfg: ModelConfig, p, x, positions):
    B, T = x.shape[:2]
    h, nope, rope = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    if cfg.q_lora_rank > 0:
        cq = apply_norm(cfg, p["q_norm"], x @ p["wq_a"])
        q = cq @ p["wq_b"]
    else:
        q = x @ p["wq"]
    q = q.reshape(B, T, h, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(cfg, q_rope, positions)
    return q_nope, q_rope


def mla_full(cfg: ModelConfig, p, x, positions, *, causal: bool = True):
    """train/prefill: materialize per-head K/V from the latent."""
    B, T = x.shape[:2]
    h, nope, rope, vd = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    rank = cfg.kv_lora_rank
    q_nope, q_rope = _mla_q(cfg, p, x, positions)

    kv_a = x @ p["wkv_a"]                              # (B,T,rank+rope)
    ckv = apply_norm(cfg, p["kv_norm"], kv_a[..., :rank])
    kr = apply_rope(cfg, kv_a[..., rank:], positions)  # shared across heads
    kv_b = (ckv @ p["wkv_b"]).reshape(B, T, h, nope + vd)
    k_nope, v = kv_b[..., :nope], kv_b[..., nope:]

    if cfg.attn_impl in ("blocked", "pallas"):
        # flash path: per-head K = [k_nope ; kr broadcast], heads as KV, G=1
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr[:, :, None], (B, T, h, rope))], -1)
        q_full = jnp.concatenate([q_nope, q_rope], -1).reshape(
            B, T, h, 1, nope + rope)
        qpos = jnp.broadcast_to(positions, (B, T)) if positions.ndim == 1 else positions
        out = flash_sdpa(q_full, k_full, v, qpos, qpos, causal=causal)
        out = out.reshape(B, T, h * vd)
    else:
        scale = 1.0 / math.sqrt(nope + rope)
        s = (jnp.einsum("bthd,bshd->bhts", q_nope, k_nope)
             + jnp.einsum("bthd,bsd->bhts", q_rope, kr)).astype(jnp.float32) * scale
        mask = _causal_window_mask(positions, positions, 0, causal)
        s = jnp.where(mask[:, None], s, NEG_INF)
        probs = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhts,bshd->bthd", probs.astype(v.dtype), v)
        out = out.reshape(B, T, h * vd)
    y = out @ p["wo"]
    return y, (ckv, kr)


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int):
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), cfg.kv_dtype),
        "kr": jnp.zeros((batch, max_len, cfg.qk_rope_dim), cfg.kv_dtype),
        "kpos": jnp.full((batch, max_len), EMPTY_POS, jnp.int32),
    }


def mla_decode(cfg: ModelConfig, p, x, positions, cache):
    """Absorbed decode: attention runs in the latent space (rank ≪ h·dh).

    This is the TPU-friendly analogue of DeepSeek's weight-absorbed MLA
    inference: K/V are never materialized per-head; the query is mapped into
    the latent via W_kv_b's K-half, context is read in the latent and mapped
    out via the V-half.
    """
    B = x.shape[0]
    h, nope, rope, vd = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    rank = cfg.kv_lora_rank
    q_nope, q_rope = _mla_q(cfg, p, x, positions[:, None])  # (B,1,h,·)

    kv_a = x @ p["wkv_a"]
    ckv_new = apply_norm(cfg, p["kv_norm"], kv_a[..., :rank])
    kr_new = apply_rope(cfg, kv_a[..., rank:], positions[:, None])
    S = cache["ckv"].shape[1]
    slot = jnp.minimum(positions, S - 1)
    wr = lambda b, v_, i: jax.vmap(lambda bb, vv, ii: bb.at[ii].set(vv))(b, v_, i)
    cache = dict(cache,
                 ckv=wr(cache["ckv"], ckv_new[:, 0].astype(cache["ckv"].dtype), slot),
                 kr=wr(cache["kr"], kr_new[:, 0].astype(cache["kr"].dtype), slot),
                 kpos=wr(cache["kpos"], positions, slot))

    wkv_b = p["wkv_b"].reshape(rank, h, nope + vd)
    w_k, w_v = wkv_b[..., :nope], wkv_b[..., nope:]
    # absorb: q_lat (B,h,rank)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_k)
    ckv = cache["ckv"].astype(cfg.compute_dtype)
    kr = cache["kr"].astype(cfg.compute_dtype)
    scale = 1.0 / math.sqrt(nope + rope)
    s = (jnp.einsum("bhr,bsr->bhs", q_lat, ckv)
         + jnp.einsum("bhd,bsd->bhs", q_rope[:, 0], kr)).astype(jnp.float32) * scale
    mask = ((cache["kpos"] <= positions[:, None])
            & (cache["kpos"] > EMPTY_POS // 2))[:, None]   # (B,1,S)
    s = jnp.where(mask, s, NEG_INF)
    probs = jax.nn.softmax(s, axis=-1)
    ctx_lat = jnp.einsum("bhs,bsr->bhr", probs.astype(ckv.dtype), ckv)
    out = jnp.einsum("bhr,rhd->bhd", ctx_lat, w_v)       # (B,h,vd)
    y = out.reshape(B, 1, h * vd) @ p["wo"]
    return y, cache
