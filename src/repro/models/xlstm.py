"""xLSTM blocks: chunked-parallel mLSTM (matrix memory) and sequential sLSTM.

Numerics note (recorded in DESIGN.md): the paper's exponential input gate
with running stabilizer is replaced by sigmoid gating with the xLSTM
normalizer state n (GLA-equivalent chunked form). The compute/memory pattern
— the thing the roofline and dry-run care about — is identical: chunked
linear attention with per-head (dk × dv) matrix state carried across chunks.

Train/prefill: O(T·ck) intra-chunk attention + inter-chunk state recurrence.
Decode: O(1) state update per token — this is why xlstm runs the 500k-token
long-context cell that quadratic-attention archs must skip.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, KeyGen, dense_init


# ======================================================================
# mLSTM
# ======================================================================

def _mlstm_dims(cfg: ModelConfig):
    di = int(cfg.proj_factor * cfg.d_model)
    H = cfg.n_heads
    dk = di // H
    return di, H, dk


def init_mlstm(cfg: ModelConfig, key):
    kg = KeyGen(key)
    dt = cfg.compute_dtype
    d = cfg.d_model
    di, H, dk = _mlstm_dims(cfg)
    k = cfg.ssm_conv or 4
    return {
        "up": dense_init(kg(), (d, 2 * di), dt),
        "conv_w": dense_init(kg(), (k, di), dt, scale=1.0 / math.sqrt(k)),
        "conv_b": jnp.zeros((di,), dt),
        "wq": dense_init(kg(), (di, di), dt),
        "wk": dense_init(kg(), (di, di), dt),
        "wv": dense_init(kg(), (di, di), dt),
        "w_i": dense_init(kg(), (di, H), jnp.float32),
        "b_i": jnp.zeros((H,), jnp.float32),
        "w_f": dense_init(kg(), (di, H), jnp.float32),
        "b_f": jnp.full((H,), 3.0, jnp.float32),  # init long memory
        "out_norm": {"scale": jnp.ones((di,), jnp.float32)},
        "down": dense_init(kg(), (di, d), dt, scale=1.0 / math.sqrt(di)),
    }


def _mlstm_qkvgates(cfg: ModelConfig, p, x, conv_state=None):
    B, T, _ = x.shape
    di, H, dk = _mlstm_dims(cfg)
    u = x @ p["up"]
    xm, z = u[..., :di], u[..., di:]
    kkern = p["conv_w"].shape[0]
    if conv_state is None:
        pad = jnp.zeros((B, kkern - 1, di), xm.dtype)
    else:
        pad = conv_state.astype(xm.dtype)
    xp = jnp.concatenate([pad, xm], axis=1)
    xc = sum(xp[:, i: i + T] * p["conv_w"][i] for i in range(kkern)) + p["conv_b"]
    new_conv = xp[:, -(kkern - 1):]
    xc = jax.nn.silu(xc)
    q = (xc @ p["wq"]).reshape(B, T, H, dk)
    kk = (xc @ p["wk"]).reshape(B, T, H, dk) / math.sqrt(dk)
    v = (xm @ p["wv"]).reshape(B, T, H, dk)
    ig = jax.nn.sigmoid(xm.astype(jnp.float32) @ p["w_i"] + p["b_i"])   # (B,T,H)
    fg = jax.nn.sigmoid(xm.astype(jnp.float32) @ p["w_f"] + p["b_f"])
    return q, kk, v, ig, fg, z, new_conv


def _mlstm_out(cfg: ModelConfig, p, h, z):
    """h: (B,T,H,dk) -> (B,T,d)."""
    B, T = h.shape[:2]
    di, H, dk = _mlstm_dims(cfg)
    hf = h.reshape(B, T, di)
    # per-head rms norm (multi-head layer norm in xLSTM)
    hf32 = hf.astype(jnp.float32).reshape(B, T, H, dk)
    ms = jnp.mean(jnp.square(hf32), axis=-1, keepdims=True)
    hn = (hf32 * jax.lax.rsqrt(ms + 1e-6)).reshape(B, T, di) * p["out_norm"]["scale"]
    y = hn.astype(z.dtype) * jax.nn.silu(z)
    return y @ p["down"]


def mlstm_scan(cfg: ModelConfig, p, x, return_cache: bool = False):
    """Full-sequence chunked mLSTM. x: (B, T, d) -> (B, T, d)."""
    B, T, _ = x.shape
    di, H, dk = _mlstm_dims(cfg)
    ck = min(cfg.chunk_size, T)
    while T % ck:      # largest divisor of T <= chunk_size (exactness first)
        ck -= 1
    nc = T // ck
    q, k, v, ig, fg, z, conv_state = _mlstm_qkvgates(cfg, p, x)

    rs = lambda a: jnp.moveaxis(a.reshape(B, nc, ck, *a.shape[2:]), 1, 0)
    qc, kc, vc, ic, fc = map(rs, (q, k, v, ig, fg))

    def chunk_step(carry, inp):
        S, n = carry                                   # (B,H,dk,dk), (B,H,dk)
        qt, kt, vt, it, ft = inp
        lf = jnp.log(jnp.maximum(ft, 1e-9))            # (B,ck,H)
        cum = jnp.cumsum(lf, axis=1)
        cl = cum[:, -1]                                 # (B,H)
        # intra-chunk decay matrix D[t,s] = exp(cum_t - cum_s) * i_s, s<=t
        diff = cum[:, :, None] - cum[:, None, :]        # (B,ck,ck,H)
        causal = jnp.tril(jnp.ones((ck, ck), bool))
        D = jnp.where(causal[None, :, :, None], jnp.exp(diff) * it[:, None], 0.0)
        qf = qt.astype(jnp.float32)
        kf = kt.astype(jnp.float32)
        vf = vt.astype(jnp.float32)
        scores = jnp.einsum("bthd,bshd->btsh", qf, kf) * D
        intra = jnp.einsum("btsh,bshd->bthd", scores, vf)
        inter = jnp.exp(cum)[..., None] * jnp.einsum("bthd,bhde->bthe", qf, S)
        num = intra + inter
        # normalizer
        n_t = (jnp.exp(cum)[..., None] * n[:, None]
               + jnp.einsum("btsh,bshd->bthd", D, kf))
        denom = jnp.maximum(jnp.abs(jnp.einsum("bthd,bthd->bth", qf, n_t)), 1.0)
        h = (num / denom[..., None]).astype(x.dtype)
        # carry update
        w_in = jnp.exp(cl[:, None] - cum) * it         # (B,ck,H)
        S_new = jnp.exp(cl)[..., None, None] * S + jnp.einsum(
            "bshd,bshe,bsh->bhde", kf, vf, w_in)
        n_new = jnp.exp(cl)[..., None] * n + jnp.einsum("bshd,bsh->bhd", kf, w_in)
        return (S_new, n_new), h

    S0 = jnp.zeros((B, H, dk, dk), jnp.float32)
    n0 = jnp.zeros((B, H, dk), jnp.float32)
    (S_f, n_f), hs = jax.lax.scan(chunk_step, (S0, n0), (qc, kc, vc, ic, fc))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, T, H, dk)
    out = _mlstm_out(cfg, p, h, z)
    if return_cache:
        return out, {"S": S_f, "n": n_f,
                     "conv": conv_state.astype(cfg.compute_dtype)}
    return out


def init_mlstm_cache(cfg: ModelConfig, batch: int):
    di, H, dk = _mlstm_dims(cfg)
    k = cfg.ssm_conv or 4
    return {
        "S": jnp.zeros((batch, H, dk, dk), jnp.float32),
        "n": jnp.zeros((batch, H, dk), jnp.float32),
        "conv": jnp.zeros((batch, k - 1, di), cfg.compute_dtype),
    }


def mlstm_step(cfg: ModelConfig, p, x, cache):
    """x: (B, 1, d)."""
    B = x.shape[0]
    di, H, dk = _mlstm_dims(cfg)
    q, k, v, ig, fg, z, conv = _mlstm_qkvgates(cfg, p, x, cache["conv"])
    qf = q[:, 0].astype(jnp.float32)
    kf = k[:, 0].astype(jnp.float32)
    vf = v[:, 0].astype(jnp.float32)
    i1, f1 = ig[:, 0], fg[:, 0]                        # (B,H)
    S = f1[..., None, None] * cache["S"] + i1[..., None, None] * (
        kf[..., :, None] * vf[..., None, :])
    n = f1[..., None] * cache["n"] + i1[..., None] * kf
    num = jnp.einsum("bhd,bhde->bhe", qf, S)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)), 1.0)
    h = (num / denom[..., None])[:, None].astype(x.dtype)  # (B,1,H,dk)
    y = _mlstm_out(cfg, p, h, z)
    return y, {"S": S, "n": n, "conv": conv}


# ======================================================================
# sLSTM
# ======================================================================

def init_slstm(cfg: ModelConfig, key):
    kg = KeyGen(key)
    dt = cfg.compute_dtype
    d, H = cfg.d_model, cfg.n_heads
    dh = d // H
    ffd = ((int(4 * d / 3) + 63) // 64) * 64
    return {
        "W": dense_init(kg(), (d, 4 * d), dt),
        "R": dense_init(kg(), (H, dh, 4 * dh), dt),
        "b": jnp.zeros((4 * d,), jnp.float32),
        "out_norm": {"scale": jnp.ones((d,), jnp.float32)},
        "ff_up": dense_init(kg(), (d, ffd), dt),
        "ff_gate": dense_init(kg(), (d, ffd), dt),
        "ff_down": dense_init(kg(), (ffd, d), dt, scale=1.0 / math.sqrt(ffd)),
    }


def _slstm_cell(cfg: ModelConfig, p, gx, state):
    """gx: (B, 4d) pre-computed input gates; state: (h, c, n)."""
    B = gx.shape[0]
    d, H = cfg.d_model, cfg.n_heads
    dh = d // H
    h, c, n = state
    gr = jnp.einsum("bhd,hde->bhe", h.reshape(B, H, dh).astype(p["R"].dtype),
                    p["R"]).reshape(B, 4 * d)
    g = (gx + gr).astype(jnp.float32) + p["b"]
    i = jax.nn.sigmoid(g[:, :d])
    f = jax.nn.sigmoid(g[:, d:2 * d])
    zt = jnp.tanh(g[:, 2 * d:3 * d])
    o = jax.nn.sigmoid(g[:, 3 * d:])
    c = f * c + i * zt
    n = f * n + i
    h = o * c / jnp.maximum(n, 1e-6)
    return h, c, n


def slstm_scan(cfg: ModelConfig, p, x, return_cache: bool = False):
    """x: (B, T, d) — sequential over T (true recurrence)."""
    B, T, d = x.shape
    gx = (x @ p["W"])                                  # (B,T,4d)

    def step(state, g):
        h, c, n = _slstm_cell(cfg, p, g, state)
        return (h, c, n), h

    z0 = jnp.zeros((B, d), jnp.float32)
    (h_f, c_f, n_f), hs = jax.lax.scan(step, (z0, z0, z0), jnp.moveaxis(gx, 1, 0))
    h = jnp.moveaxis(hs, 0, 1)                         # (B,T,d)
    hn = h * jax.lax.rsqrt(jnp.mean(h * h, -1, keepdims=True) + 1e-6)
    hn = (hn * p["out_norm"]["scale"]).astype(x.dtype)
    ff = (jax.nn.gelu(hn @ p["ff_gate"]) * (hn @ p["ff_up"])) @ p["ff_down"]
    if return_cache:
        return ff, {"h": h_f, "c": c_f, "n": n_f}
    return ff


def init_slstm_cache(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"h": z, "c": z, "n": z}


def slstm_step(cfg: ModelConfig, p, x, cache):
    gx = (x[:, 0] @ p["W"])
    h, c, n = _slstm_cell(cfg, p, gx, (cache["h"], cache["c"], cache["n"]))
    hn = h * jax.lax.rsqrt(jnp.mean(h * h, -1, keepdims=True) + 1e-6)
    hn = (hn * p["out_norm"]["scale"]).astype(x.dtype)
    ff = (jax.nn.gelu(hn @ p["ff_gate"]) * (hn @ p["ff_up"])) @ p["ff_down"]
    return ff[:, None], {"h": h, "c": c, "n": n}
