"""Mixture-of-Experts layer: top-k router + sort-based capacity dispatch.

Design notes (TPU adaptation):
  * Dispatch is *sort-based* (MegaBlocks/MaxText-style), not GShard one-hot
    einsum, so compiled FLOPs ≈ active FLOPs — the dispatch itself is
    gathers/scatters, which keeps the roofline's compute term honest.
  * The (E, C, d) expert buffer carries a sharding hint ("moe_expert_buf")
    that the launcher maps to the expert-parallel axis; XLA inserts the
    token all-to-all at that boundary.
  * Capacity C = ceil(T·k/E · capacity_factor); overflow tokens are dropped
    (contribute zero) exactly as in capacity-based systems.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, KeyGen, dense_init
from repro.models.layers import activation, init_mlp, apply_mlp
from repro.models.shard_hints import hint


def init_moe(cfg: ModelConfig, key):
    kg = KeyGen(key)
    dt = cfg.compute_dtype
    d, E, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    p = {
        "router": dense_init(kg(), (d, E), jnp.float32),
        "router_bias": jnp.zeros((E,), jnp.float32),  # aux-loss-free balancing bias
        "w_gate": dense_init(kg(), (E, d, f), dt),
        "w_up": dense_init(kg(), (E, d, f), dt),
        "w_down": dense_init(kg(), (E, f, d), dt, scale=1.0 / math.sqrt(f)),
    }
    if cfg.n_shared_experts > 0:
        p["shared"] = init_mlp(cfg, kg(), d_ff=cfg.moe_d_ff * cfg.n_shared_experts)
    return p


def _capacity(cfg: ModelConfig, T: int) -> int:
    E, k = cfg.n_experts, cfg.top_k
    c = int(math.ceil(T * k / E * cfg.capacity_factor))
    return max(8, ((c + 7) // 8) * 8)  # pad to multiple of 8 for nice tiling


def route(cfg: ModelConfig, p, x2d):
    """x2d: (T, d) -> (weights (T,k), idx (T,k), router_probs (T,E))."""
    logits = (x2d.astype(jnp.float32) @ p["router"]) * cfg.router_scale
    probs = jax.nn.sigmoid(logits) if cfg.n_shared_experts else jax.nn.softmax(logits, -1)
    biased = probs + p["router_bias"]           # bias affects selection only
    _, idx = jax.lax.top_k(biased, cfg.top_k)
    w = jnp.take_along_axis(probs, idx, axis=-1)
    w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)
    return w.astype(x2d.dtype), idx, probs


def apply_moe(cfg: ModelConfig, p, x):
    """x: (B, S, d) -> (y, aux_metrics). Dispatch per cfg.moe_impl.

    ``rowwise`` pays per-(row, expert) capacity padding: at decode (S=1,
    k slots/row vs E*C_min buffer slots) that wastes ~E*C/k = 100s-fold
    compute+wire, while the global sort is tiny (B*k elements). Route by
    tokens-per-row: row-local dispatch for train/prefill, global sort for
    decode-sized steps (EXPERIMENTS.md §Perf cell A, iteration 5)."""
    if cfg.moe_impl == "rowwise" and x.shape[1] * cfg.top_k >= 2 * cfg.n_experts:
        return apply_moe_rowwise(cfg, p, x)
    return apply_moe_sorted(cfg, p, x)


def apply_moe_sorted(cfg: ModelConfig, p, x):
    """Global sort-based dispatch (paper-faithful baseline).

    Correct but SPMD-hostile at scale: the argsort runs over ALL B*S*k
    routing slots, which XLA partitions as a distributed bitonic sort —
    O(log^2 n) all-to-all phases over the full routing array. The dry-run
    measured this at thousands of seconds of collective time per step for
    deepseek-v3/kimi (EXPERIMENTS.md §Perf iteration 1); kept as the
    reference implementation and ablation point.
    """
    B, S, d = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.top_k
    C = _capacity(cfg, T)
    x2d = x.reshape(T, d)

    w, idx, probs = route(cfg, p, x2d)

    # ---- sort-based dispatch --------------------------------------
    flat_e = idx.reshape(T * k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank = jnp.arange(T * k, dtype=jnp.int32) - seg_start
    keep = rank < C
    dest = jnp.where(keep, sorted_e * C + rank, E * C)  # E*C = drop slot
    src_tok = order // k

    buf = jnp.zeros((E * C + 1, d), x.dtype).at[dest].set(x2d[src_tok])
    buf = hint(buf[: E * C].reshape(E, C, d), "moe_expert_buf")

    # ---- grouped expert FFN (batched over E) ----------------------
    g = activation(cfg.act, jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = hint(g * u, "moe_expert_hidden")
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    out_buf = hint(out_buf, "moe_expert_buf")

    # ---- combine ---------------------------------------------------
    out_flat = jnp.concatenate(
        [out_buf.reshape(E * C, d), jnp.zeros((1, d), x.dtype)], axis=0)[dest]
    inv = jnp.argsort(order, stable=True)
    per_slot = out_flat[inv].reshape(T, k, d)
    y = jnp.einsum("tkd,tk->td", per_slot, w.astype(per_slot.dtype))

    if cfg.n_shared_experts > 0:
        y = y + apply_mlp(cfg, p["shared"], x2d)

    # ---- aux: load-balance loss + drop fraction --------------------
    me = jnp.mean(jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(1), axis=0)  # tokens/expert
    pe = jnp.mean(probs, axis=0)
    aux_loss = E * jnp.sum(me / k * pe)
    drop_frac = 1.0 - jnp.mean(keep.astype(jnp.float32))
    return y.reshape(B, S, d), {"moe_aux_loss": aux_loss, "moe_drop_frac": drop_frac}


def apply_moe_rowwise(cfg: ModelConfig, p, x):
    """Row-local dispatch (beyond-paper optimization; the default).

    Every sort/rank runs *within one batch row* (a batched argsort over the
    row's S*k routing slots), so dispatch itself needs NO collective — the
    batch dim is data-sharded and the sort is embarrassingly parallel. The
    only cross-device movement left is the intended pair of token
    all-to-alls, inserted by SPMD at the (B-sharded -> E-sharded) buffer
    resharding around the grouped GEMM. Capacity is per (row, expert) —
    standard per-device-capacity MoE semantics.

    Buffer: (B, E, C_row, d); C_row = ceil(S*k/E * capacity_factor).
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = _capacity(cfg, S)
    x2d = x.reshape(B * S, d)
    w, idx, probs = route(cfg, p, x2d)

    idx_r = idx.reshape(B, S * k)                 # (B, S*k) expert per slot
    order = jnp.argsort(idx_r, axis=1, stable=True)
    sorted_e = jnp.take_along_axis(idx_r, order, axis=1)
    seg_start = jax.vmap(lambda se: jnp.searchsorted(se, se, side="left"))(sorted_e)
    rank = jnp.arange(S * k, dtype=jnp.int32)[None, :] - seg_start
    keep = rank < C
    dest = jnp.where(keep, sorted_e * C + rank, E * C)     # per-row drop slot
    src_tok = order // k                           # token index within row

    # GATHER-ONLY construction: every indexed op keeps IDENTICAL sharding on
    # its source and result ((B-shard, d-shard) payloads — index ops are
    # elementwise in d), and resharding to/from expert ownership happens at
    # DENSE tensor boundaries only, where SPMD emits a clean all-to-all. A
    # scatter into an E-sharded buffer instead degrades to partial-scatter +
    # full-buffer all-reduce (EXPERIMENTS.md §Perf iteration 3/4).
    # buf[b, e, c] = x of the token with rank c for expert e  (via the sort:
    # tokens of expert e occupy sorted positions [start_e, start_e+count_e)).
    x_rows = hint(x2d.reshape(B, S, d), "moe_row_payload")
    xs = jnp.take_along_axis(x_rows, (src_tok % S)[..., None], axis=1)
    xs = hint(xs, "moe_row_payload")               # (B, S*k, d) sorted payload
    starts = jax.vmap(lambda se: jnp.searchsorted(
        se, jnp.arange(E, dtype=se.dtype), side="left"))(sorted_e)  # (B, E)
    counts = jax.vmap(lambda se: jnp.searchsorted(
        se, jnp.arange(E, dtype=se.dtype), side="right"))(sorted_e) - starts
    slot = starts[:, :, None] + jnp.arange(C, dtype=jnp.int32)[None, None, :]
    valid = jnp.arange(C, dtype=jnp.int32)[None, None, :] < \
        jnp.minimum(counts, C)[:, :, None]         # (B, E, C)
    slot = jnp.clip(slot, 0, S * k - 1).reshape(B, E * C)
    buf = jnp.take_along_axis(xs, slot[..., None], axis=1)
    buf = buf * valid.reshape(B, E * C, 1).astype(buf.dtype)
    buf = hint(buf.reshape(B, E, C, d), "moe_row_buf")

    # ---- grouped expert FFN, batched over rows --------------------
    g = activation(cfg.act, jnp.einsum("becd,edf->becf", buf, p["w_gate"],
                                       preferred_element_type=jnp.float32
                                       ).astype(buf.dtype))
    u = jnp.einsum("becd,edf->becf", buf, p["w_up"],
                   preferred_element_type=jnp.float32).astype(buf.dtype)
    h = hint(g * u, "moe_row_hidden")
    out_buf = jnp.einsum("becf,efd->becd", h, p["w_down"],
                         preferred_element_type=jnp.float32).astype(buf.dtype)
    # return all-to-all: back to (B-shard, d-shard) so the per-row combine
    # gathers are local (E-sharded + global indices would all-gather)
    out_buf = hint(out_buf, "moe_row_out")

    # ---- combine back per row -------------------------------------
    flat = jnp.concatenate([out_buf.reshape(B, E * C, d),
                            jnp.zeros((B, 1, d), x.dtype)], axis=1)
    out_slots = jnp.take_along_axis(flat, dest[..., None], axis=1)  # (B,S*k,d)
    out_slots = hint(out_slots, "moe_row_payload")
    inv = jnp.argsort(order, axis=1, stable=True)
    per_slot = jnp.take_along_axis(out_slots, inv[..., None], axis=1)
    per_slot = per_slot.reshape(B, S, k, d)
    y = jnp.einsum("bskd,bsk->bsd", per_slot,
                   w.reshape(B, S, k).astype(per_slot.dtype))

    if cfg.n_shared_experts > 0:
        y = y + apply_mlp(cfg, p["shared"], x)

    me = jnp.mean(jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(1), axis=0)
    pe = jnp.mean(probs, axis=0)
    aux_loss = E * jnp.sum(me / k * pe)
    drop_frac = 1.0 - jnp.mean(keep.astype(jnp.float32))
    return y, {"moe_aux_loss": aux_loss, "moe_drop_frac": drop_frac}
