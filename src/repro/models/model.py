"""Unified model composition: init / train / prefill / decode for every family.

A model is a stack of *segments* (contiguous runs of identical block kinds,
``cfg.segments()``); each segment's layer parameters are stacked on axis 0
and executed with ``lax.scan`` so a 61-layer model compiles like a 1-layer
model. Three modes share one parameter layout:

  * ``train``   — full sequence -> logits (B, S, V), aux metrics
  * ``prefill`` — full sequence -> logits + decode caches
  * ``decode``  — one token per row against per-segment caches

Families:
  dense   : [norm->attn] + [norm->mlp]                     (granite, minicpm,
            command-r-plus, starcoder2, internvl2 backbone, llama2)
  moe     : attention (GQA or MLA) + MoE FFN (+ shared)    (deepseek-v3, kimi)
  hybrid  : parallel GQA-attention and Mamba-SSM heads     (hymba)
  ssm     : mLSTM / sLSTM blocks per ``block_pattern``     (xlstm)
  encdec  : bidirectional encoder + cross-attending decoder (whisper; the
            audio conv frontend is a STUB — inputs are frame embeddings)
  vlm     : dense decoder over [patch embeds ; token embeds] (internvl2; the
            ViT frontend is a STUB — inputs are patch embeddings)
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.ad_checkpoint
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import moe as M
from repro.models import ssm as S
from repro.models import xlstm as X
from repro.models.common import ModelConfig, KeyGen
from repro.models.layers import (add_positional, apply_mlp, apply_norm,
                                 embed_tokens, init_embeddings, init_mlp,
                                 init_norm, sinusoidal_pos, unembed)
from repro.models.shard_hints import hint

PyTree = Any


# ======================================================================
# per-kind layer init
# ======================================================================

def init_layer(cfg: ModelConfig, kind: str, key) -> PyTree:
    kg = KeyGen(key)
    if kind == "dense":
        return {"ln1": init_norm(cfg, kg()), "attn": A.init_attention(cfg, kg()),
                "ln2": init_norm(cfg, kg()), "mlp": init_mlp(cfg, kg())}
    if kind == "moe":
        return {"ln1": init_norm(cfg, kg()), "attn": A.init_attention(cfg, kg()),
                "ln2": init_norm(cfg, kg()), "moe": M.init_moe(cfg, kg())}
    if kind in ("hyb_local", "hyb_full"):
        return {"ln1": init_norm(cfg, kg()), "attn": A.init_attention(cfg, kg()),
                "ssm": S.init_ssm(cfg, kg()),
                "no_a": init_norm(cfg, kg()), "no_s": init_norm(cfg, kg()),
                "ln2": init_norm(cfg, kg()), "mlp": init_mlp(cfg, kg())}
    if kind == "mlstm":
        return {"ln1": init_norm(cfg, kg()), "cell": X.init_mlstm(cfg, kg())}
    if kind == "slstm":
        return {"ln1": init_norm(cfg, kg()), "cell": X.init_slstm(cfg, kg())}
    if kind == "enc":
        return {"ln1": init_norm(cfg, kg()), "attn": A.init_attention(cfg, kg()),
                "ln2": init_norm(cfg, kg()), "mlp": init_mlp(cfg, kg())}
    if kind == "xdec":
        return {"ln1": init_norm(cfg, kg()), "attn": A.init_attention(cfg, kg()),
                "lnx": init_norm(cfg, kg()),
                "xattn": A.init_attention(cfg, kg(), cross=True),
                "ln2": init_norm(cfg, kg()), "mlp": init_mlp(cfg, kg())}
    raise ValueError(f"unknown layer kind {kind!r}")


def _seg_kinds(cfg: ModelConfig) -> Tuple[Tuple[str, int], ...]:
    if cfg.family == "encdec":
        return (("xdec", cfg.n_layers),)
    return cfg.segments()


def init_model(cfg: ModelConfig, key) -> PyTree:
    kg = KeyGen(key)
    params: Dict[str, Any] = {"embed": init_embeddings(cfg, kg())}
    if cfg.family == "encdec":
        ekeys = jax.random.split(kg(), cfg.n_enc_layers)
        params["enc"] = {
            "layers": jax.vmap(lambda k: init_layer(cfg, "enc", k))(ekeys),
            "norm": init_norm(cfg, kg()),
        }
    segs = []
    for kind, n in _seg_kinds(cfg):
        keys = jax.random.split(kg(), n)
        segs.append(jax.vmap(lambda k, kind=kind: init_layer(cfg, kind, k))(keys))
    params["segs"] = segs
    params["norm"] = init_norm(cfg, kg())
    if cfg.mtp_depth > 0:
        eye = jnp.eye(cfg.d_model, dtype=cfg.compute_dtype)
        params["mtp"] = {
            "nh": init_norm(cfg, kg()), "ne": init_norm(cfg, kg()),
            "proj": jnp.concatenate([eye, eye * 0], axis=0),
            "block": init_layer(cfg, "dense" if cfg.n_experts == 0 else "moe", kg()),
            "norm": init_norm(cfg, kg()),
        }
    return params


# ======================================================================
# caches
# ======================================================================

def _window_for(cfg: ModelConfig, kind: str) -> int:
    if kind == "hyb_local":
        return cfg.sliding_window
    if kind == "dense" and cfg.sliding_window > 0:
        return cfg.sliding_window
    return 0


def _cache_len(cfg: ModelConfig, kind: str, max_len: int) -> int:
    w = _window_for(cfg, kind)
    return min(max_len, w) if w > 0 else max_len


def init_layer_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int):
    if kind in ("dense", "moe"):
        if cfg.attn_type == "mla":
            return A.init_mla_cache(cfg, batch, max_len)
        return A.init_gqa_cache(cfg, batch, _cache_len(cfg, kind, max_len),
                                window=_window_for(cfg, kind))
    if kind in ("hyb_local", "hyb_full"):
        w = _window_for(cfg, kind)
        return {"attn": A.init_gqa_cache(cfg, batch, _cache_len(cfg, kind, max_len), window=w),
                "ssm": S.init_ssm_cache(cfg, batch)}
    if kind == "mlstm":
        return X.init_mlstm_cache(cfg, batch)
    if kind == "slstm":
        return X.init_slstm_cache(cfg, batch)
    if kind == "xdec":
        enc_s = cfg.enc_seq or 1
        kv, dh = cfg.n_kv_heads, cfg.head_dim
        return {"self": A.init_gqa_cache(cfg, batch, max_len),
                "xk": jnp.zeros((batch, enc_s, kv, dh), cfg.compute_dtype),
                "xv": jnp.zeros((batch, enc_s, kv, dh), cfg.compute_dtype)}
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Per-segment stacked caches (axis 0 = layer within segment)."""
    caches = []
    for kind, n in _seg_kinds(cfg):
        one = lambda _, kind=kind: init_layer_cache(cfg, kind, batch, max_len)
        caches.append(jax.vmap(one)(jnp.arange(n)))
    return caches


def paged_supported(cfg: ModelConfig) -> bool:
    """Paged decode serves full-attention GQA stacks (dense / moe kinds);
    windowed, recurrent, latent (MLA) and cross-attending segments keep
    the dense per-slot cache path."""
    return (cfg.attn_type == "gqa"
            and all(kind in ("dense", "moe") and _window_for(cfg, kind) == 0
                    for kind, _ in _seg_kinds(cfg)))


def chunked_prefill_supported(cfg: ModelConfig) -> bool:
    """Chunked prefill (continuous batching) serves the same stacks as
    paged decode: full-attention GQA, dense / moe kinds. Windowed,
    recurrent, latent (MLA) and cross-attending segments keep whole-prompt
    prefill — their caches are not append-addressable per chunk."""
    return paged_supported(cfg)


def init_paged_cache(cfg: ModelConfig, n_pages: int, page_size: int):
    """Per-segment stacked page stores (axis 0 = layer within segment).

    Every layer owns its own pages; the block table is SHARED across
    layers (one page id maps the same slot/offset range in each layer's
    store), so the per-slot table stays small enough for the Pallas
    kernel's SMEM scalar prefetch.
    """
    assert paged_supported(cfg), \
        f"paged decode unsupported for {cfg.name} ({cfg.family}/{cfg.attn_type})"
    caches = []
    for _, n in _seg_kinds(cfg):
        one = lambda _: A.init_paged_gqa_cache(cfg, n_pages, page_size)
        caches.append(jax.vmap(one)(jnp.arange(n)))
    return caches


def _fill_gqa_cache(cfg: ModelConfig, cache, k, v, kpos, window: int = 0):
    """Write T contiguous tokens (positions 0..T-1) into a fresh cache."""
    T = k.shape[1]
    S = cache["k"].shape[1]
    if T > S:  # window cache shorter than the prompt: keep the last S tokens
        assert window > 0, \
            f"prompt ({T}) exceeds full-attention cache capacity ({S})"
        k, v, kpos = k[:, -S:], v[:, -S:], kpos[:, -S:]
    if cfg.kv_cache_dtype == "int8":
        qk, sk = A.quantize_kv(k)
        qv, sv = A.quantize_kv(v)
        cache = dict(cache,
                     k=jax.lax.dynamic_update_slice_in_dim(cache["k"], qk, 0, 1),
                     v=jax.lax.dynamic_update_slice_in_dim(cache["v"], qv, 0, 1),
                     k_scale=jax.lax.dynamic_update_slice_in_dim(cache["k_scale"], sk, 0, 1),
                     v_scale=jax.lax.dynamic_update_slice_in_dim(cache["v_scale"], sv, 0, 1))
    else:
        kd = k.astype(cache["k"].dtype)
        vd = v.astype(cache["v"].dtype)
        cache = dict(cache,
                     k=jax.lax.dynamic_update_slice_in_dim(cache["k"], kd, 0, 1),
                     v=jax.lax.dynamic_update_slice_in_dim(cache["v"], vd, 0, 1))
    cache["kpos"] = jax.lax.dynamic_update_slice_in_dim(cache["kpos"], kpos, 0, 1)
    return cache


# ======================================================================
# per-kind block application
# ======================================================================

def block_apply(cfg: ModelConfig, kind: str, p, x, positions, mode: str,
                cache, *, max_len: int = 0, lengths=None, enc_out=None,
                block_table=None, live=None, paged_impl: str = "auto"):
    """Returns (x_out, cache_out, aux).

    ``positions``: (B,S) for train/prefill, (B,) for decode.
    ``lengths``: (B,) valid lengths for ragged prefill.
    ``max_len``: decode-cache capacity to allocate at prefill.
    ``enc_out``: (B, enc_seq, d) encoder output for xdec train/prefill.
    ``block_table``: (B, max_pages) int32 — switches decode attention onto
    the paged path (``cache`` is then a page store, not a per-slot cache);
    ``live``/``paged_impl`` predicate dead-lane page writes and pick the
    paged attention implementation.
    """
    aux: Dict[str, Any] = {}
    B = x.shape[0]
    window = _window_for(cfg, kind)

    def kpos_of(T):
        pos = positions if positions.ndim == 2 else jnp.broadcast_to(positions, (B, T))
        if lengths is not None:
            pos = jnp.where(jnp.arange(T)[None, :] < lengths[:, None], pos, A.EMPTY_POS)
        return pos

    # ---------------- recurrent kinds ---------------------------------
    if kind in ("mlstm", "slstm"):
        cell = X.mlstm_step if kind == "mlstm" else X.slstm_step
        scan = X.mlstm_scan if kind == "mlstm" else X.slstm_scan
        h = apply_norm(cfg, p["ln1"], x)
        if mode == "decode":
            y, c2 = cell(cfg, p["cell"], h, cache)
        elif mode == "prefill":
            y, c2 = scan(cfg, p["cell"], h, return_cache=True)
        else:
            y, c2 = scan(cfg, p["cell"], h), None
        return x + y, c2, aux

    # ---------------- attention-style kinds ---------------------------
    is_mla = cfg.attn_type == "mla" and kind in ("dense", "moe")
    h = apply_norm(cfg, p["ln1"], x)
    causal = kind != "enc"
    new_attn_cache = None
    if mode == "decode":
        if block_table is not None:
            a, new_attn_cache = A.gqa_decode_paged(
                cfg, p["attn"], h, positions, cache, block_table,
                live=live, impl=paged_impl)
        elif is_mla:
            a, new_attn_cache = A.mla_decode(cfg, p["attn"], h, positions, cache
                                             if kind in ("dense", "moe") else cache["attn"])
        else:
            c_in = (cache["self"] if kind == "xdec"
                    else cache["attn"] if kind.startswith("hyb") else cache)
            a, new_attn_cache = A.gqa_decode(cfg, p["attn"], h, positions,
                                             c_in, window=window)
    else:
        if is_mla:
            a, (ckv, kr) = A.mla_full(cfg, p["attn"], h, positions, causal=causal)
            if mode == "prefill":
                T = h.shape[1]
                c0 = A.init_mla_cache(cfg, B, max_len)
                kp = kpos_of(T)
                c0 = dict(c0,
                          ckv=jax.lax.dynamic_update_slice_in_dim(
                              c0["ckv"], ckv.astype(c0["ckv"].dtype), 0, 1),
                          kr=jax.lax.dynamic_update_slice_in_dim(
                              c0["kr"], kr.astype(c0["kr"].dtype), 0, 1),
                          kpos=jax.lax.dynamic_update_slice_in_dim(c0["kpos"], kp, 0, 1))
                new_attn_cache = c0
        else:
            a, (k, v) = A.gqa_full(cfg, p["attn"], h, positions,
                                   causal=causal, window=window)
            if mode == "prefill":
                T = h.shape[1]
                c0 = A.init_gqa_cache(cfg, B, _cache_len(cfg, kind, max_len),
                                      window=window)
                new_attn_cache = _fill_gqa_cache(cfg, c0, k, v, kpos_of(T),
                                                 window=window)

    # hybrid: parallel SSM branch, outputs fused via per-branch norms
    if kind.startswith("hyb"):
        if mode == "decode":
            s_out, new_ssm = S.ssm_step(cfg, p["ssm"], h, cache["ssm"])
        elif mode == "prefill":
            s_out, new_ssm = S.ssm_scan(cfg, p["ssm"], h, return_cache=True)
        else:
            s_out, new_ssm = S.ssm_scan(cfg, p["ssm"], h), None
        fused = 0.5 * (apply_norm(cfg, p["no_a"], a) + apply_norm(cfg, p["no_s"], s_out))
        fused = jax.ad_checkpoint.checkpoint_name(fused, "attn_out")
        x = x + fused
        new_cache = ({"attn": new_attn_cache, "ssm": new_ssm}
                     if mode != "train" else None)
    else:
        a = jax.ad_checkpoint.checkpoint_name(a, "attn_out")
        x = x + a
        new_cache = new_attn_cache

    # cross-attention (whisper decoder)
    if kind == "xdec":
        hx = apply_norm(cfg, p["lnx"], x)
        if mode == "decode":
            xa, _ = A.gqa_decode(cfg, p["xattn"], hx, positions, None,
                                 kv_override=(cache["xk"].astype(cfg.compute_dtype),
                                              cache["xv"].astype(cfg.compute_dtype)))
            new_cache = {"self": new_attn_cache, "xk": cache["xk"], "xv": cache["xv"]}
        else:
            kvh, dh = cfg.n_kv_heads, cfg.head_dim
            xk = (enc_out @ p["xattn"]["wk"]).reshape(B, -1, kvh, dh)
            xv = (enc_out @ p["xattn"]["wv"]).reshape(B, -1, kvh, dh)
            if cfg.use_bias:
                xk = xk + p["xattn"]["bk"].reshape(kvh, dh)
                xv = xv + p["xattn"]["bv"].reshape(kvh, dh)
            xa, _ = A.gqa_full(cfg, p["xattn"], hx, positions,
                               kv_override=(xk, xv))
            if mode == "prefill":
                new_cache = {"self": new_attn_cache,
                             "xk": xk.astype(cfg.compute_dtype),
                             "xv": xv.astype(cfg.compute_dtype)}
        x = x + xa

    # FFN
    h2 = apply_norm(cfg, p["ln2"], x)
    if kind == "moe":
        y, moe_aux = M.apply_moe(cfg, p["moe"], h2)
        aux.update(moe_aux)
    else:
        y = apply_mlp(cfg, p["mlp"], h2)
    y = jax.ad_checkpoint.checkpoint_name(y, "ffn_out")
    x = x + y
    return x, new_cache, aux


def block_apply_chunk(cfg: ModelConfig, kind: str, p, x, positions, valid,
                      lane, cache, *, block_table=None):
    """One block over a single lane's prompt chunk (1, C, d). Attention
    appends the chunk's K/V to the lane's cache and attends causally over
    everything written so far; the FFN is position-wise as usual. Only
    dense / moe kinds reach here (``chunked_prefill_supported``)."""
    h = apply_norm(cfg, p["ln1"], x)
    a, cache = A.gqa_chunk_append(cfg, p["attn"], h, positions, valid, lane,
                                  cache, block_table=block_table)
    x = x + a
    h2 = apply_norm(cfg, p["ln2"], x)
    if kind == "moe":
        y, _ = M.apply_moe(cfg, p["moe"], h2)
    else:
        y = apply_mlp(cfg, p["mlp"], h2)
    return x + y, cache


def prefill_chunk_step(cfg: ModelConfig, params, tokens, pos0, clen, lane,
                       cache, *, block_table=None):
    """Run ONE prompt chunk for one lane through the whole stack.

    tokens: (C,) int32 chunk token ids (pad beyond ``clen``); pos0: scalar
    absolute position of tokens[0]; clen: scalar valid length (0 = no-op
    step: every write is dropped); lane: scalar cache row / block-table
    row. ``cache`` is the engine's per-segment stacked cache (dense rows
    or paged stores). Returns (last_logits (V,), cache) — the logits at
    position pos0 + clen - 1, meaningful only on a request's final chunk,
    so the serving layer can sample the first token inside the same traced
    program.
    """
    C = tokens.shape[0]
    positions = pos0 + jnp.arange(C, dtype=jnp.int32)
    valid = jnp.arange(C) < clen
    x = embed_tokens(cfg, params["embed"], tokens[None])
    x = add_positional(cfg, params["embed"], x, positions[None])

    new_caches = []
    for i, (kind, n) in enumerate(_seg_kinds(cfg)):
        def body(x, per_layer, kind=kind):
            p, c = per_layer
            x2, c2 = block_apply_chunk(cfg, kind, p, x, positions, valid,
                                       lane, c, block_table=block_table)
            return x2, c2

        x, c2 = jax.lax.scan(body, x, (params["segs"][i], cache[i]))
        new_caches.append(c2)

    x = apply_norm(cfg, params["norm"], x)
    last = jnp.clip(clen - 1, 0, C - 1)
    logits = unembed(cfg, params["embed"], x[:, last][:, None])[0, 0]
    return logits, new_caches


# ======================================================================
# segment scan
# ======================================================================

def _seg_apply(cfg: ModelConfig, kind: str, stacked_p, x, positions, mode: str,
               stacked_cache, max_len: int, lengths=None, enc_out=None,
               block_table=None, live=None, paged_impl: str = "auto"):
    """Scan one segment. Returns (x, new_stacked_cache, stacked_aux).

    ``block_table``/``live`` are shared across the segment's layers (scan
    constants): each layer's page store is its own scanned cache slice, but
    one page id addresses the same slot range in every layer.
    """

    def body(x, per_layer):
        if mode == "decode":
            p, c = per_layer
        else:
            p, c = per_layer, None
        x2, c2, aux = block_apply(cfg, kind, p, x, positions, mode, c,
                                  max_len=max_len, lengths=lengths,
                                  enc_out=enc_out, block_table=block_table,
                                  live=live, paged_impl=paged_impl)
        return x2, (c2, aux)

    if cfg.remat != "none" and mode == "train":
        if cfg.remat == "selective":
            # save ONLY the named per-layer outputs — the post-TP-all-reduce
            # tensors — so backward recompute re-runs neither the collectives
            # nor the big matmuls, at 2 extra (B,S,d) saves per layer
            policy = jax.checkpoint_policies.save_only_these_names(
                "attn_out", "ffn_out")
        else:
            policy = None
        body = jax.checkpoint(body, prevent_cse=False, policy=policy)

    if not cfg.scan_layers:
        n = jax.tree_util.tree_leaves(stacked_p)[0].shape[0]
        caches, auxs = [], []
        for i in range(n):
            p_i = jax.tree.map(lambda a: a[i], stacked_p)
            c_i = (jax.tree.map(lambda a: a[i], stacked_cache)
                   if mode == "decode" else None)
            x, (c2, aux) = body(x, (p_i, c_i) if mode == "decode" else p_i)
            caches.append(c2)
            auxs.append(aux)
        stack = lambda *xs: jnp.stack(xs)
        caches = jax.tree.map(stack, *caches) if caches[0] is not None else None
        auxs = jax.tree.map(stack, *auxs) if auxs and auxs[0] else {}
        return x, (caches if mode != "train" else None), auxs

    if mode == "decode":
        x, (caches, auxs) = jax.lax.scan(body, x, (stacked_p, stacked_cache))
        return x, caches, auxs
    x, (caches, auxs) = jax.lax.scan(body, x, stacked_p)
    return x, (caches if mode == "prefill" else None), auxs


# ======================================================================
# encoder (whisper)
# ======================================================================

def encode(cfg: ModelConfig, params, frames):
    """frames: (B, enc_seq, d_model) stub frontend embeddings."""
    B, Se, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(Se), (B, Se))
    x = frames + sinusoidal_pos(pos, cfg.d_model).astype(frames.dtype)

    def body(x, p):
        x2, _, _ = block_apply(cfg, "enc", p, x, pos, "train", None)
        return x2, None

    x, _ = jax.lax.scan(body, x, params["enc"]["layers"])
    return apply_norm(cfg, params["enc"]["norm"], x)


# ======================================================================
# top-level forward
# ======================================================================

def _mean_aux(auxs_list):
    out: Dict[str, Any] = {}
    for auxs in auxs_list:
        for k, v in auxs.items():
            out.setdefault(k, []).append(jnp.mean(v))
    return {k: jnp.mean(jnp.stack(v)) for k, v in out.items()}


def forward(cfg: ModelConfig, params, tokens, *, mode: str = "train",
            positions=None, lengths=None, cache=None, max_len: int = 0,
            frames=None, patches=None, return_hidden: bool = False,
            block_table=None, live=None, paged_impl: str = "auto"):
    """``tokens``: (B,S) int32 (decode: (B,1));
    ``positions``: decode (B,), else (B,S) or None (=arange).
    ``max_len``: cache capacity for prefill.
    ``block_table`` (decode only): (B, max_pages) int32 routes attention
    through the paged path — ``cache`` must then be ``init_paged_cache``
    output; ``live`` (B,) bool predicates dead-lane page writes. Returns:
      train  -> (logits, aux)
      prefill-> (logits, caches, aux)
      decode -> (logits (B,V), caches)
    """
    B, T = tokens.shape
    x = embed_tokens(cfg, params["embed"], tokens)
    x = hint(x, "act_embed")

    if cfg.family == "vlm" and patches is not None and mode != "decode":
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
        T = x.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    if mode != "decode":
        x = add_positional(cfg, params["embed"], x, positions)
    else:
        x = add_positional(cfg, params["embed"], x, positions[:, None])[:, 0][:, None] \
            if cfg.pos == "learned" else x

    enc_out = None
    if cfg.family == "encdec" and mode != "decode":
        enc_out = encode(cfg, params, frames)

    seg_defs = _seg_kinds(cfg)
    new_caches = []
    auxs_list = []
    for i, (kind, n) in enumerate(seg_defs):
        seg_p = params["segs"][i]
        seg_c = cache[i] if cache is not None else None
        x, c2, auxs = _seg_apply(cfg, kind, seg_p, x, positions, mode,
                                 seg_c, max_len, lengths=lengths,
                                 enc_out=enc_out, block_table=block_table,
                                 live=live, paged_impl=paged_impl)
        new_caches.append(c2)
        auxs_list.append(auxs)
        x = hint(x, "act_resid")

    x = apply_norm(cfg, params["norm"], x)
    hidden = x
    logits = unembed(cfg, params["embed"], x)
    logits = hint(logits, "act_logits")
    aux = _mean_aux(auxs_list)
    if return_hidden:
        aux["hidden"] = hidden

    if mode == "train":
        return logits, aux
    if mode == "prefill":
        return logits, new_caches, aux
    return logits[:, 0], new_caches


# ======================================================================
# losses / steps
# ======================================================================

def cross_entropy(logits, labels, ignore_label: int = -100):
    """logits (B,S,V) any dtype; labels (B,S) int. Mean over valid tokens."""
    mask = labels != ignore_label
    labels_safe = jnp.where(mask, labels, 0)
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels_safe[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)


def _mtp_loss(cfg: ModelConfig, params, hidden, tokens, labels):
    """DeepSeek-V3 multi-token prediction, depth 1: predict t+2 from
    Block(W [norm(h_t); norm(Emb(token_{t+1}))])."""
    p = params["mtp"]
    B, T = tokens.shape
    nxt = jnp.concatenate([tokens[:, 1:], jnp.zeros((B, 1), tokens.dtype)], 1)
    e = embed_tokens(cfg, params["embed"], nxt)
    h = jnp.concatenate([apply_norm(cfg, p["nh"], hidden),
                         apply_norm(cfg, p["ne"], e)], -1) @ p["proj"]
    pos = jnp.broadcast_to(jnp.arange(T), (B, T))
    kind = "dense" if cfg.n_experts == 0 else "moe"
    h, _, _ = block_apply(cfg, kind, p["block"], h, pos, "train", None)
    h = apply_norm(cfg, p["norm"], h)
    logits = unembed(cfg, params["embed"], h)
    lab2 = jnp.concatenate([labels[:, 1:],
                            jnp.full((B, 1), -100, labels.dtype)], 1)
    return cross_entropy(logits, lab2)


def loss_fn(cfg: ModelConfig, params, batch, *, aux_weight: float = 1e-2,
            mtp_weight: float = 0.3):
    """batch: {tokens, labels[, frames, patches]}. Returns (loss, metrics)."""
    patches = batch.get("patches")
    frames = batch.get("frames")
    need_hidden = cfg.mtp_depth > 0
    logits, aux = forward(cfg, params, batch["tokens"], mode="train",
                          frames=frames, patches=patches,
                          return_hidden=need_hidden)
    labels = batch["labels"]
    if cfg.family == "vlm" and patches is not None:
        npat = patches.shape[1]
        pad = jnp.full(labels.shape[:1] + (npat,), -100, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    loss = cross_entropy(logits, labels)
    metrics = {"ce": loss}
    if "moe_aux_loss" in aux:
        loss = loss + aux_weight * aux["moe_aux_loss"]
        metrics["moe_aux_loss"] = aux["moe_aux_loss"]
        metrics["moe_drop_frac"] = aux["moe_drop_frac"]
    if cfg.mtp_depth > 0:
        ml = _mtp_loss(cfg, params, aux["hidden"], batch["tokens"], batch["labels"])
        loss = loss + mtp_weight * ml
        metrics["mtp_ce"] = ml
    metrics["loss"] = loss
    return loss, metrics


def prefill(cfg: ModelConfig, params, tokens, *, max_len: int, lengths=None,
            frames=None, patches=None):
    return forward(cfg, params, tokens, mode="prefill", max_len=max_len,
                   lengths=lengths, frames=frames, patches=patches)


def decode_step(cfg: ModelConfig, params, tokens, positions, cache):
    """tokens: (B,1); positions: (B,). Returns (logits (B,V), cache)."""
    return forward(cfg, params, tokens, mode="decode", positions=positions,
                   cache=cache)


def decode_sample_step(cfg: ModelConfig, params, tokens, positions, cache,
                       key, sampling, sample_fn, *, block_table=None,
                       live=None, paged_impl: str = "auto", fold_ids=None,
                       with_ok: bool = False):
    """One decode step with sampling fused into the same traced program.

    ``sampling`` is a tuple of stacked per-row arrays
    ``(temperature (B,) f32, top_k (B,) i32, top_p (B,) f32)`` and
    ``sample_fn(logits, key, *sampling) -> (B,) int32`` performs the draw
    (the serving layer passes ``sampler.sample_logits_batched``; injected
    as a callable so models/ stays import-independent of serving/).
    With ``block_table`` the step reads/writes the paged KV store instead
    of per-slot linear regions (``live`` gates dead-lane page writes).
    ``fold_ids`` (B,) int32 overrides the sampler's per-row PRNG fold so a
    batch-bucketed caller can fold by slot id instead of lane position.
    Returns (next_tokens (B,) int32, cache) — logits never leave the
    program, so a jitted caller pays no host transfer per token.

    ``with_ok=True`` additionally returns a per-row finiteness verdict
    ``ok (B,) bool = isfinite(logits).all(-1)`` so the serving engine can
    detect a poisoned lane (NaN/Inf logits from corrupted KV or a kernel
    fault) *inside* the fused program — the verdict rides the caller's
    existing per-block fetch, adding no host sync of its own.
    """
    logits, cache = forward(cfg, params, tokens, mode="decode",
                            positions=positions, cache=cache,
                            block_table=block_table, live=live,
                            paged_impl=paged_impl)
    toks = sample_fn(logits, key, *sampling, fold_ids=fold_ids)
    if with_ok:
        return toks, cache, jnp.isfinite(logits).all(axis=-1)
    return toks, cache
