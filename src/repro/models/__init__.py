"""Model zoo: configs, layers, attention (GQA/MLA), MoE, SSM, xLSTM, and
the unified composable model (models/model.py)."""
