"""Shared layers: norms, activations, MLPs, embeddings, RoPE."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, KeyGen, dense_init


# ----------------------------------------------------------------------
# norms
# ----------------------------------------------------------------------

def init_norm(cfg: ModelConfig, key, d=None):
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(cfg: ModelConfig, p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(x.dtype)


# ----------------------------------------------------------------------
# activations
# ----------------------------------------------------------------------

def activation(name: str, x):
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(f"unknown activation {name!r}")


# ----------------------------------------------------------------------
# MLP (gated for silu-family, plain for gelu enc-dec)
# ----------------------------------------------------------------------

def init_mlp(cfg: ModelConfig, key, d_ff=None, d_model=None):
    kg = KeyGen(key)
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    dt = cfg.compute_dtype
    p = {
        "w_up": dense_init(kg(), (d, f), dt),
        "w_down": dense_init(kg(), (f, d), dt, scale=1.0 / math.sqrt(f)),
    }
    if cfg.act in ("silu",):  # gated (SwiGLU-style)
        p["w_gate"] = dense_init(kg(), (d, f), dt)
    if cfg.use_bias:
        p["b_up"] = jnp.zeros((f,), dt)
        p["b_down"] = jnp.zeros((d,), dt)
    return p


def apply_mlp(cfg: ModelConfig, p, x):
    up = x @ p["w_up"]
    if cfg.use_bias:
        up = up + p["b_up"]
    if "w_gate" in p:
        h = activation(cfg.act, x @ p["w_gate"]) * up
    else:
        h = activation(cfg.act, up)
    y = h @ p["w_down"]
    if cfg.use_bias:
        y = y + p["b_down"]
    return y


# ----------------------------------------------------------------------
# embeddings / unembedding
# ----------------------------------------------------------------------

def init_embeddings(cfg: ModelConfig, key):
    kg = KeyGen(key)
    dt = cfg.compute_dtype
    p = {"tok": dense_init(kg(), (cfg.vocab_size, cfg.d_model), dt,
                           scale=1.0 / math.sqrt(cfg.d_model))}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(kg(), (cfg.d_model, cfg.vocab_size), dt)
    if cfg.pos == "learned":
        p["pos"] = dense_init(kg(), (cfg.max_position_learned(), cfg.d_model), dt, scale=0.02)
    return p


def _max_pos_learned(cfg: ModelConfig) -> int:
    # learned positions only used by whisper-style decoders; keep modest
    return min(cfg.max_position, 4096)


ModelConfig.max_position_learned = _max_pos_learned


def embed_tokens(cfg: ModelConfig, p, tokens):
    return jnp.take(p["tok"], tokens, axis=0)


def unembed(cfg: ModelConfig, p, x):
    if cfg.tie_embeddings:
        logits = x @ p["tok"].T
    else:
        logits = x @ p["unembed"]
    if cfg.logit_softcap > 0:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


def add_positional(cfg: ModelConfig, p, x, positions):
    if cfg.pos == "learned":
        return x + jnp.take(p["pos"], positions, axis=0)
    if cfg.pos == "sinusoidal":
        return x + sinusoidal_pos(positions, x.shape[-1]).astype(x.dtype)
    return x


def sinusoidal_pos(positions, d):
    half = d // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ----------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------

def rope_freqs(cfg: ModelConfig, dim: int):
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    return inv  # (dim/2,)


def apply_rope(cfg: ModelConfig, x, positions, dim=None):
    """x: (..., S, H, D) or (..., S, D); positions: (..., S)."""
    d = dim or x.shape[-1]
    inv = rope_freqs(cfg, d)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, d/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    if x.ndim == positions.ndim + 2:  # head axis present
        sin, cos = sin[..., None, :], cos[..., None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2: d]
    xr1 = x1 * cos - x2 * sin
    xr2 = x2 * cos + x1 * sin
    out = jnp.concatenate([xr1, xr2], axis=-1)
    if d < x.shape[-1]:
        out = jnp.concatenate([out, x[..., d:]], axis=-1)
    return out.astype(x.dtype)
