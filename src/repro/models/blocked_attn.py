"""FlashAttention-2 in pure XLA: triangle-pair scan + custom-VJP backward.

Why this exists: the naive SDPA materializes (B, H, Tq, Tk) scores — at
prefill_32k that is 100s of GB per device. This module computes identical
math with:

  * **online softmax** over (q-block, k-block) pairs so the live working set
    is O(block_q x block_k) per head — the XLA analogue of streaming K/V
    HBM->VMEM in the Pallas kernel;
  * **true causal/window block skipping**: the scan iterates a *precomputed
    flattened list of live block pairs* (lower triangle for causal, band for
    sliding window), so compiled HLO FLOPs are ~T^2/2 (causal) or ~T*W
    (window), not T^2 — the dry-run cost_analysis reflects the real work;
  * **flash backward** (custom_vjp): forward saves only (out, lse); backward
    re-walks the same pair list recomputing scores per block, so training
    memory is O(T) not O(T^2).

Numerics: fp32 running max/sum/accumulator (same as FlashAttention-2);
output cast back to the input dtype. Softcap is supported forward-only via
the non-custom path (no assigned architecture uses softcap).

Layouts: q (B, Tq, KV, G, D); k (B, Tk, KV, D); v (B, Tk, KV, Dv);
q_pos/k_pos (B, T) absolute positions, negative = padding/empty row.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30
LSE_EMPTY = 1e30  # lse sentinel for fully-masked rows -> p == 0 in bwd


# ----------------------------------------------------------------------
# block-pair schedule (static python -> the scan length IS the flop count)
# ----------------------------------------------------------------------

def _pair_schedule(nq: int, nk: int, causal: bool, window: int, bq: int, bk: int):
    pairs = []
    if causal:
        assert nq * bq == nk * bk or nq == nk, "causal assumes square layout"
        wblk = -(-window // bk) + 1 if window > 0 else 0
        for qi in range(nq):
            lo = max(0, qi - wblk) if window > 0 else 0
            for ki in range(lo, qi + 1):
                pairs.append((qi, ki))
    else:
        for qi in range(nq):
            for ki in range(nk):
                pairs.append((qi, ki))
    # static python ints at trace time, not a device sync
    qis = np.array([p[0] for p in pairs], np.int32)  # noqa: SPL001
    kis = np.array([p[1] for p in pairs], np.int32)  # noqa: SPL001
    n = len(pairs)
    first = np.zeros(n, bool)
    first[0] = True
    first[1:] = qis[1:] != qis[:-1]
    return qis, kis, first


def _block_mask(qp, kp, causal, window):
    """qp: (B,bq) kp: (B,bk) -> (B,bq,bk) bool."""
    m = (kp[:, None, :] >= 0) & (qp[:, :, None] >= 0)
    if causal:
        m &= kp[:, None, :] <= qp[:, :, None]
    if window > 0:
        m &= kp[:, None, :] > qp[:, :, None] - window
    return m


def _pad_t(x, t_pad, axis, fill=0):
    pad = t_pad - x.shape[axis]
    if pad == 0:
        return x
    cfgs = [(0, 0)] * x.ndim
    cfgs[axis] = (0, pad)
    return jnp.pad(x, cfgs, constant_values=fill)


# ----------------------------------------------------------------------
# forward
# ----------------------------------------------------------------------

def _flash_fwd_impl(q, k, v, q_pos, k_pos, causal, window, bq, bk):
    B, Tq, KV, G, D = q.shape
    Tk, Dv = k.shape[1], v.shape[-1]
    nq, nk = -(-Tq // bq), -(-Tk // bk)
    Tqp, Tkp = nq * bq, nk * bk
    qf = _pad_t(q, Tqp, 1).astype(jnp.float32)
    kf = _pad_t(k, Tkp, 1).astype(jnp.float32)
    vf = _pad_t(v, Tkp, 1).astype(jnp.float32)
    qp = _pad_t(q_pos, Tqp, 1, fill=-1)
    kp = _pad_t(k_pos, Tkp, 1, fill=-1)
    scale = 1.0 / math.sqrt(D)

    qis, kis, first = _pair_schedule(nq, nk, causal, window, bq, bk)

    out0 = jnp.zeros((B, Tqp, KV, G, Dv), jnp.float32)
    lse0 = jnp.full((B, KV, G, Tqp), LSE_EMPTY, jnp.float32)
    m0 = jnp.full((B, KV, G, bq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, bq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, bq, Dv), jnp.float32)

    def step(carry, inp):
        m, l, acc, out, lse = carry
        qi, ki, fst = inp
        m = jnp.where(fst, m0, m)
        l = jnp.where(fst, l0, l)
        acc = jnp.where(fst, a0, acc)
        qblk = jax.lax.dynamic_slice_in_dim(qf, qi * bq, bq, 1)
        kblk = jax.lax.dynamic_slice_in_dim(kf, ki * bk, bk, 1)
        vblk = jax.lax.dynamic_slice_in_dim(vf, ki * bk, bk, 1)
        qpb = jax.lax.dynamic_slice_in_dim(qp, qi * bq, bq, 1)
        kpb = jax.lax.dynamic_slice_in_dim(kp, ki * bk, bk, 1)
        s = jnp.einsum("btkgd,bskd->bkgts", qblk, kblk) * scale
        msk = _block_mask(qpb, kpb, causal, window)
        s = jnp.where(msk[:, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.maximum(m_new, NEG_INF / 2)  # avoid -inf - -inf
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(msk[:, None, None], p, 0.0)
        corr = jnp.exp(jnp.maximum(m, NEG_INF / 2) - m_safe)
        corr = jnp.where(m > NEG_INF / 2, corr, 0.0)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bkgts,bskd->bkgtd", p, vblk)
        # finalize current row state into the output buffers every step —
        # later steps of the same row overwrite with the completed value.
        ob = (acc / jnp.maximum(l, 1e-30)[..., None])
        out = jax.lax.dynamic_update_slice_in_dim(
            out, jnp.moveaxis(ob, 3, 1), qi * bq, 1)
        lb = jnp.where(l > 0, m_safe + jnp.log(jnp.maximum(l, 1e-30)), LSE_EMPTY)
        lse = jax.lax.dynamic_update_slice_in_dim(lse, lb, qi * bq, 3)
        return (m_new, l, acc, out, lse), None

    xs = (jnp.asarray(qis), jnp.asarray(kis), jnp.asarray(first))
    (_, _, _, out, lse), _ = jax.lax.scan(step, (m0, l0, a0, out0, lse0), xs)
    return out[:, :Tq].astype(q.dtype), lse[..., :Tq]


# ----------------------------------------------------------------------
# backward (flash recompute)
# ----------------------------------------------------------------------

def _flash_bwd_impl(q, k, v, q_pos, k_pos, out, lse, do,
                    causal, window, bq, bk):
    B, Tq, KV, G, D = q.shape
    Tk, Dv = k.shape[1], v.shape[-1]
    nq, nk = -(-Tq // bq), -(-Tk // bk)
    Tqp, Tkp = nq * bq, nk * bk
    qf = _pad_t(q, Tqp, 1).astype(jnp.float32)
    kf = _pad_t(k, Tkp, 1).astype(jnp.float32)
    vf = _pad_t(v, Tkp, 1).astype(jnp.float32)
    qp = _pad_t(q_pos, Tqp, 1, fill=-1)
    kp = _pad_t(k_pos, Tkp, 1, fill=-1)
    dof = _pad_t(do, Tqp, 1).astype(jnp.float32)
    lsef = _pad_t(lse, Tqp, 3, fill=LSE_EMPTY)
    scale = 1.0 / math.sqrt(D)

    # delta[b,kv,g,t] = sum_e do * out
    delta = jnp.einsum("btkge,btkge->bkgt",
                       dof, _pad_t(out, Tqp, 1).astype(jnp.float32))

    qis, kis, _ = _pair_schedule(nq, nk, causal, window, bq, bk)

    dq0 = jnp.zeros((B, Tqp, KV, G, D), jnp.float32)
    dk0 = jnp.zeros((B, Tkp, KV, D), jnp.float32)
    dv0 = jnp.zeros((B, Tkp, KV, Dv), jnp.float32)

    def step(carry, inp):
        dq, dk, dv = carry
        qi, ki = inp
        qblk = jax.lax.dynamic_slice_in_dim(qf, qi * bq, bq, 1)
        kblk = jax.lax.dynamic_slice_in_dim(kf, ki * bk, bk, 1)
        vblk = jax.lax.dynamic_slice_in_dim(vf, ki * bk, bk, 1)
        qpb = jax.lax.dynamic_slice_in_dim(qp, qi * bq, bq, 1)
        kpb = jax.lax.dynamic_slice_in_dim(kp, ki * bk, bk, 1)
        doblk = jax.lax.dynamic_slice_in_dim(dof, qi * bq, bq, 1)
        lseblk = jax.lax.dynamic_slice_in_dim(lsef, qi * bq, bq, 3)
        dlblk = jax.lax.dynamic_slice_in_dim(delta, qi * bq, bq, 3)

        s = jnp.einsum("btkgd,bskd->bkgts", qblk, kblk) * scale
        msk = _block_mask(qpb, kpb, causal, window)
        s = jnp.where(msk[:, None, None], s, NEG_INF)
        p = jnp.exp(s - lseblk[..., None])
        p = jnp.where(msk[:, None, None], p, 0.0)
        dp = jnp.einsum("btkge,bske->bkgts", doblk, vblk)
        ds = p * (dp - dlblk[..., None]) * scale

        dq_blk = jnp.einsum("bkgts,bskd->btkgd", ds, kblk)
        dk_blk = jnp.einsum("bkgts,btkgd->bskd", ds, qblk)
        dv_blk = jnp.einsum("bkgts,btkge->bske", p, doblk)

        rmw = jax.lax.dynamic_slice_in_dim(dq, qi * bq, bq, 1) + dq_blk
        dq = jax.lax.dynamic_update_slice_in_dim(dq, rmw, qi * bq, 1)
        rmw = jax.lax.dynamic_slice_in_dim(dk, ki * bk, bk, 1) + dk_blk
        dk = jax.lax.dynamic_update_slice_in_dim(dk, rmw, ki * bk, 1)
        rmw = jax.lax.dynamic_slice_in_dim(dv, ki * bk, bk, 1) + dv_blk
        dv = jax.lax.dynamic_update_slice_in_dim(dv, rmw, ki * bk, 1)
        return (dq, dk, dv), None

    xs = (jnp.asarray(qis), jnp.asarray(kis))
    (dq, dk, dv), _ = jax.lax.scan(step, (dq0, dk0, dv0), xs)
    return (dq[:, :Tq].astype(q.dtype), dk[:, :Tk].astype(k.dtype),
            dv[:, :Tk].astype(v.dtype))


# ----------------------------------------------------------------------
# custom-vjp wrapper
# ----------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _flash(q, k, v, q_pos, k_pos, causal, window, bq, bk):
    out, _ = _flash_fwd_impl(q, k, v, q_pos, k_pos, causal, window, bq, bk)
    return out


def _flash_fwd(q, k, v, q_pos, k_pos, causal, window, bq, bk):
    out, lse = _flash_fwd_impl(q, k, v, q_pos, k_pos, causal, window, bq, bk)
    return out, (q, k, v, q_pos, k_pos, out, lse)


def _flash_bwd(causal, window, bq, bk, res, do):
    q, k, v, q_pos, k_pos, out, lse = res
    dq, dk, dv = _flash_bwd_impl(q, k, v, q_pos, k_pos, out, lse, do,
                                 causal, window, bq, bk)
    return dq, dk, dv, None, None


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_sdpa(q, k, v, q_pos, k_pos, *, causal=True, window=0,
               softcap=0.0, block_q=512, block_k=512):
    """Blocked attention; see module docstring. Returns (B,Tq,KV,G,Dv)."""
    assert softcap == 0.0, "softcap routes through the naive path"
    Tq, Tk = q.shape[1], k.shape[1]
    bq = min(block_q, Tq)
    bk = min(block_k, Tk)
    if causal and Tq != Tk:
        raise ValueError("causal flash assumes Tq == Tk (use decode path)")
    return _flash(q, k, v, q_pos, k_pos, causal, window, bq, bk)
