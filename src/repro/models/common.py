"""Model configuration and parameter-init utilities.

Every architecture in the zoo is described by a single frozen ``ModelConfig``.
Forward functions are pure (cfg, params, inputs) -> outputs so they can be
jit/pjit'd, scanned over layers, and lowered with ShapeDtypeStruct params for
the multi-pod dry-run (``jax.eval_shape`` over ``init``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Unified architecture description (one per assigned architecture)."""

    name: str = "model"
    family: str = "dense"  # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    d_head: int = 0          # 0 -> d_model // n_heads
    d_ff: int = 256
    vocab_size: int = 256

    # --- norm / activation / embeddings ---
    act: str = "silu"            # silu | gelu | relu2
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    use_bias: bool = False
    tie_embeddings: bool = False
    pos: str = "rope"            # rope | learned | sinusoidal | none
    rope_theta: float = 10000.0
    max_position: int = 1 << 20
    logit_softcap: float = 0.0

    # --- attention ---
    attn_type: str = "gqa"       # gqa | mla
    sliding_window: int = 0      # 0 = full attention
    global_layer_every: int = 0  # >0: every k-th layer is full-attn (hybrid)

    # --- MLA (deepseek-style) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_dense_layers: int = 0      # leading dense-FFN layers (deepseek: 3, kimi: 1)
    capacity_factor: float = 1.25
    router_scale: float = 1.0

    # --- SSM (mamba) / hybrid ---
    ssm_state: int = 0
    ssm_conv: int = 4
    d_inner: int = 0             # 0 -> 2 * d_model
    ssm_dt_rank: int = 0         # 0 -> ceil(d_model / 16)

    # --- xLSTM ---
    block_pattern: Tuple[str, ...] = ()  # e.g. ("m",)*7 + ("s",) repeated
    proj_factor: float = 2.0
    chunk_size: int = 64

    # --- encoder-decoder (whisper) ---
    n_enc_layers: int = 0
    enc_seq: int = 0             # encoder context (stub frontend frames)
    enc_d_model: int = 0         # 0 -> d_model

    # --- VLM ---
    n_patches: int = 0           # stub ViT patch-embedding count

    # --- multi-token prediction (deepseek-v3) ---
    mtp_depth: int = 0

    # --- numerics / runtime ---
    dtype: str = "float32"       # compute/param dtype ("bfloat16" on TPU)
    kv_cache_dtype: str = ""     # "" -> dtype; "int8" enables quantized KV
    remat: str = "none"          # none | full | selective
    attn_impl: str = "xla"       # xla | pallas
    moe_impl: str = "sorted"     # sorted (capacity, sort-based dispatch)
    scan_layers: bool = True

    # ------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        if self.attn_type == "mla":
            return self.qk_nope_dim + self.qk_rope_dim
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def v_dim(self) -> int:
        if self.attn_type == "mla":
            return self.v_head_dim
        return self.head_dim

    @property
    def q_per_kv(self) -> int:
        return max(1, self.n_heads // max(1, self.n_kv_heads))

    @property
    def inner_dim(self) -> int:
        return self.d_inner or 2 * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or max(1, math.ceil(self.d_model / 16))

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def kv_dtype(self):
        return jnp.dtype(self.kv_cache_dtype or self.dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def padded_for_tp(self, tp: int) -> "ModelConfig":
        """Pad head counts / hidden dims so every TP-sharded axis divides.

        The padding overhead is real compute and is reported honestly in the
        roofline table (it shows up in MODEL_FLOPS / HLO_FLOPS).
        """
        kw = {}
        if self.attn_type != "mla":
            nh = _round_up(self.n_heads, tp)
            nkv = self.n_kv_heads
            if nkv < tp:
                nkv = tp  # replicate KV heads up to TP degree (standard GQA TP)
            else:
                nkv = _round_up(nkv, tp)
            if nh != self.n_heads or nkv != self.n_kv_heads:
                dh = self.head_dim
                kw.update(n_heads=nh, n_kv_heads=nkv, d_head=dh)
        else:
            kw.update(n_heads=_round_up(self.n_heads, tp))
        if self.d_ff:
            kw["d_ff"] = _round_up(self.d_ff, tp * 2)
        if self.moe_d_ff:
            kw["moe_d_ff"] = _round_up(self.moe_d_ff, tp)
        kw["vocab_size"] = _round_up(self.vocab_size, tp * 8)
        if self.inner_dim % tp:
            kw["d_inner"] = _round_up(self.inner_dim, tp)
        return self.replace(**kw)

    # --- layer segmentation: contiguous runs of identical block types ----
    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind; scanning happens within equal-kind runs."""
        kinds = []
        for i in range(self.n_layers):
            if self.family == "moe":
                kinds.append("dense" if i < self.n_dense_layers else "moe")
            elif self.family == "hybrid":
                g = self.global_layer_every
                full = g > 0 and (i % g == 0 or i == self.n_layers - 1)
                kinds.append("hyb_full" if full else "hyb_local")
            elif self.family == "ssm":
                pat = self.block_pattern or ("m",)
                kinds.append({"m": "mlstm", "s": "slstm"}[pat[i % len(pat)]])
            else:
                kinds.append("dense")
        return tuple(kinds)

    def segments(self) -> Tuple[Tuple[str, int], ...]:
        kinds = self.layer_kinds()
        segs = []
        for k in kinds:
            if segs and segs[-1][0] == k:
                segs[-1][1] += 1
            else:
                segs.append([k, 1])
        return tuple((k, n) for k, n in segs)


# ----------------------------------------------------------------------
# init helpers
# ----------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init."""
    fan_in = shape[0] if len(shape) >= 2 else max(1, shape[-1])
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def zeros_init(key, shape, dtype, scale=None):
    del key, scale
    return jnp.zeros(shape, dtype)


def ones_init(key, shape, dtype, scale=None):
    del key, scale
    return jnp.ones(shape, dtype)


class KeyGen:
    """Deterministic key stream via fold_in (cheap for huge param trees)."""

    def __init__(self, key):
        self._key = key
        self._i = 0

    def __call__(self):
        self._i += 1
        return jax.random.fold_in(self._key, self._i)


def stack_init(init_fn, n: int, key):
    """Initialize ``n`` stacked copies of a layer's params (for lax.scan)."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def param_count(tree: PyTree) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))


def param_bytes(tree: PyTree) -> int:
    return sum(int(x.size) * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))
