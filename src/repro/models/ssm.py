"""Mamba-style selective SSM block (used by the hymba hybrid).

Training/prefill uses a *chunked* exact scan: sequential ``lax.scan`` over
chunks carrying the (d_inner, state) hidden, with a parallel
``associative_scan`` inside each chunk — bounding the materialized state to
chunk_len × d_inner × state (the full-sequence associative scan would
materialize T× that and blow HBM at 4k×batch).

Decode is the O(1) single-step recurrence with a conv ring buffer.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, KeyGen, dense_init


def init_ssm(cfg: ModelConfig, key):
    kg = KeyGen(key)
    dt = cfg.compute_dtype
    d, di, st, k = cfg.d_model, cfg.inner_dim, cfg.ssm_state, cfg.ssm_conv
    dtr = cfg.dt_rank
    p = {
        "in_proj": dense_init(kg(), (d, 2 * di), dt),
        "conv_w": dense_init(kg(), (k, di), dt, scale=1.0 / math.sqrt(k)),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": dense_init(kg(), (di, dtr + 2 * st), dt),
        "dt_proj": dense_init(kg(), (dtr, di), dt),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, st + 1, dtype=jnp.float32), (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(kg(), (di, d), dt, scale=1.0 / math.sqrt(di)),
    }
    return p


def _causal_conv(cfg: ModelConfig, p, x, conv_state=None):
    """Depthwise causal conv1d. x: (B, T, di). conv_state: (B, k-1, di)."""
    k = cfg.ssm_conv
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)          # (B, T+k-1, di)
    out = sum(xp[:, i: i + x.shape[1]] * p["conv_w"][i] for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else pad
    return out + p["conv_b"], new_state


def _ssm_params(cfg: ModelConfig, p, xc):
    """xc: (..., di) -> dt (..., di), B (..., st), C (..., st)."""
    st, dtr = cfg.ssm_state, cfg.dt_rank
    db = xc @ p["x_proj"]
    dt_r, Bm, Cm = db[..., :dtr], db[..., dtr:dtr + st], db[..., dtr + st:]
    dt = jax.nn.softplus((dt_r @ p["dt_proj"]).astype(jnp.float32) + p["dt_bias"])
    return dt, Bm.astype(jnp.float32), Cm.astype(jnp.float32)


def ssm_scan(cfg: ModelConfig, p, x, chunk: int = 256, return_cache: bool = False):
    """Full-sequence selective scan. x: (B, T, d) -> (B, T, d)."""
    B, T, _ = x.shape
    di, st = cfg.inner_dim, cfg.ssm_state
    u = x @ p["in_proj"]
    xi, z = u[..., :di], u[..., di:]
    xc, conv_state = _causal_conv(cfg, p, xi)
    xc = jax.nn.silu(xc)

    dt, Bm, Cm = _ssm_params(cfg, p, xc)
    A = -jnp.exp(p["A_log"])                                  # (di, st)
    dA = jnp.exp(dt[..., None] * A)                           # (B,T,di,st)
    dBx = (dt * xc.astype(jnp.float32))[..., None] * Bm[..., None, :]

    ck = min(chunk, T)
    while T % ck:      # largest divisor of T <= chunk (exactness first)
        ck -= 1
    nc = T // ck
    dA_c = dA.reshape(B, nc, ck, di, st)
    dBx_c = dBx.reshape(B, nc, ck, di, st)
    Cm_c = Cm.reshape(B, nc, ck, st)

    def chunk_step(h, inputs):
        da, dbx, c = inputs                                   # (B,ck,di,st),( ,st)
        def op(a, b):
            return a[0] * b[0], b[0] * a[1] + b[1]
        cumA, inner = jax.lax.associative_scan(op, (da, dbx), axis=1)
        hs = cumA * h[:, None] + inner                        # (B,ck,di,st)
        y = jnp.einsum("bcds,bcs->bcd", hs, c)
        return hs[:, -1], y

    dA_t = jnp.moveaxis(dA_c, 1, 0)
    dBx_t = jnp.moveaxis(dBx_c, 1, 0)
    Cm_t = jnp.moveaxis(Cm_c, 1, 0)
    h0 = jnp.zeros((B, di, st), jnp.float32)
    h_final, ys = jax.lax.scan(chunk_step, h0, (dA_t, dBx_t, Cm_t))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T, di)

    y = y + p["D"] * xc.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    if return_cache:
        return out, {"conv": conv_state.astype(cfg.compute_dtype), "h": h_final}
    return out


def init_ssm_cache(cfg: ModelConfig, batch: int):
    di, st, k = cfg.inner_dim, cfg.ssm_state, cfg.ssm_conv
    return {
        "conv": jnp.zeros((batch, k - 1, di), cfg.compute_dtype),
        "h": jnp.zeros((batch, di, st), jnp.float32),
    }


def ssm_step(cfg: ModelConfig, p, x, cache):
    """Single decode step. x: (B, 1, d)."""
    di = cfg.inner_dim
    u = x @ p["in_proj"]
    xi, z = u[..., :di], u[..., di:]
    xc, conv_state = _causal_conv(cfg, p, xi, cache["conv"])
    xc = jax.nn.silu(xc)                                      # (B,1,di)

    dt, Bm, Cm = _ssm_params(cfg, p, xc[:, 0])
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[..., None] * A)                           # (B,di,st)
    dBx = (dt * xc[:, 0].astype(jnp.float32))[..., None] * Bm[..., None, :]
    h = dA * cache["h"] + dBx
    y = jnp.einsum("bds,bs->bd", h, Cm) + p["D"] * xc[:, 0].astype(jnp.float32)
    y = (y[:, None].astype(x.dtype)) * jax.nn.silu(z)
    return y @ p["out_proj"], {"conv": conv_state, "h": h}
