"""Decoupled sharding hints.

Model code calls ``hint(x, "name")``; by default this is the identity. The
launcher installs a rules table (name -> PartitionSpec) and hints become
``jax.lax.with_sharding_constraint`` so XLA's SPMD partitioner places the
MoE all-to-alls / activation shardings we want, without the model importing
any mesh machinery.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Callable, Optional

import jax

_state = threading.local()


def _rules() -> Optional[Callable]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def sharding_rules(fn: Callable):
    """fn(name: str, ndim: int) -> Optional[NamedSharding/PartitionSpec]."""
    prev = _rules()
    _state.rules = fn
    try:
        yield
    finally:
        _state.rules = prev


def hint(x, name: str):
    fn = _rules()
    if fn is None:
        return x
    spec = fn(name, getattr(x, "shape", ()))
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)
