"""sproutlint driver: walk the repo, run SPL001–SPL004, apply noqa /
allowlist budgets / the committed baseline, and report.

Layering: this module (and everything it imports) must not import jax —
it is the Layer-1 entry that `scripts/lint.sh` runs even in hermetic
containers without a JAX install. Layer 2 (jaxpr_audit) is imported
lazily by ``__main__`` only for the ``audit`` subcommand.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis import config
from repro.analysis.callgraph import CallGraph
from repro.analysis.findings import (BASELINE_DEFAULT, Finding, Key,
                                     apply_baseline, load_baseline,
                                     save_baseline)
from repro.analysis.rules import (parse_module, spl001, spl002, spl003,
                                  spl004)

_NOQA_RE = re.compile(r"#\s*noqa(?::\s*(?P<codes>[A-Z0-9, ]+))?", re.I)

Allowlist = Dict[Tuple[str, str, str], int]


def _noqa_codes(line: str) -> Optional[Set[str]]:
    """None = no noqa on this line; empty set = bare ``# noqa`` (all rules);
    else the specific rule codes."""
    m = _NOQA_RE.search(line)
    if m is None:
        return None
    codes = m.group("codes")
    if not codes:
        return set()
    return {c.strip().upper() for c in codes.split(",") if c.strip()}


def _apply_noqa(findings: List[Finding], lines: List[str]) -> List[Finding]:
    kept = []
    for f in findings:
        line = lines[f.line - 1] if 0 < f.line <= len(lines) else ""
        codes = _noqa_codes(line)
        if codes is None or (codes and f.rule not in codes):
            kept.append(f)
    return kept


def _apply_allowlist(findings: List[Finding], allowlist: Allowlist,
                     ) -> Tuple[List[Finding], List[Finding]]:
    """Consume per-(path, scope, rule) budgets in line order; findings past
    the budget are kept (and annotated so the overflow is obvious)."""
    budget = dict(allowlist)
    kept: List[Finding] = []
    allowed: List[Finding] = []
    for f in sorted(findings, key=lambda f: (f.path, f.line)):
        key = (f.path, f.scope, f.rule)
        if budget.get(key, 0) > 0:
            budget[key] -= 1
            allowed.append(f)
        elif key in allowlist:
            kept.append(dataclasses.replace(
                f, message=f.message + (
                    f" [exceeds allowlist budget of {allowlist[key]}]")))
        else:
            kept.append(f)
    return kept, allowed


def lint_module(path: str, source: str, hot_scopes: Set[str],
                deterministic: bool = True,
                allowlist: Optional[Allowlist] = None,
                ) -> Tuple[List[Finding], List[Finding]]:
    """Run all rules on one module. Returns ``(kept, allowed)`` after noqa
    and allowlist filtering. ``hot_scopes={"*"}`` marks every scope hot
    (used by fixture tests)."""
    ctx = parse_module(path, source)
    findings = (spl001(ctx, hot_scopes) + spl002(ctx)
                + spl003(ctx, deterministic) + spl004(ctx))
    findings = _apply_noqa(findings, ctx.lines)
    return _apply_allowlist(findings, allowlist or {})


def _repo_files(root: Path) -> List[Path]:
    out: List[Path] = []
    for d in config.SCAN_DIRS:
        base = root / d
        if base.is_dir():
            out.extend(sorted(base.rglob("*.py")))
    return out


def _hot_scopes_by_path(root: Path, files: Iterable[Path],
                        trees: Dict[str, ast.Module]) -> Dict[str, Set[str]]:
    graph = CallGraph()
    for rel, tree in trees.items():
        graph.add_module(rel, tree)
    hot: Dict[str, Set[str]] = {}
    for path, qualname in graph.reachable(config.HOT_PATH_ROOTS):
        hot.setdefault(path, set()).add(qualname)
    return hot


@dataclasses.dataclass
class LintResult:
    new: List[Finding]
    baselined: List[Finding]
    allowed: List[Finding]
    stale: List[Key]
    hot_scopes: Dict[str, Set[str]]

    @property
    def rc(self) -> int:
        return 1 if (self.new or self.stale) else 0

    def render(self, verbose: bool = False) -> str:
        out: List[str] = []
        for f in self.new:
            out.append(f.render())
        for key in self.stale:
            rule, path, scope, snippet = key
            out.append(f"{path}: STALE baseline entry {rule} [{scope}] — "
                       f"finding no longer fires; remove it\n    {snippet}")
        if verbose:
            for f in self.allowed:
                out.append(f"allowed: {f.render()}")
            for f in self.baselined:
                out.append(f"baselined: {f.render()}")
        out.append(f"sproutlint: {len(self.new)} new, "
                   f"{len(self.baselined)} baselined, "
                   f"{len(self.allowed)} allowlisted, "
                   f"{len(self.stale)} stale baseline entries")
        return "\n".join(out)


def run_lint(root: Path, baseline_path: Optional[Path] = None,
             write_baseline: bool = False) -> LintResult:
    baseline_path = baseline_path or root / BASELINE_DEFAULT
    files = _repo_files(root)
    sources: Dict[str, str] = {}
    trees: Dict[str, ast.Module] = {}
    for p in files:
        rel = p.relative_to(root).as_posix()
        text = p.read_text()
        try:
            trees[rel] = ast.parse(text, filename=rel)
        except SyntaxError:
            continue   # not this gate's job; ruff/ast_lint own syntax
        sources[rel] = text
    hot = _hot_scopes_by_path(root, files, trees)

    findings: List[Finding] = []
    allowed: List[Finding] = []
    for rel, text in sources.items():
        deterministic = any(rel.startswith(prefix)
                            for prefix in config.DETERMINISTIC_PATHS)
        module_allow = {(p, s, r): n for (p, s, r), n
                        in config.ALLOWLIST.items() if p == rel}
        kept, ok = lint_module(rel, text, hot.get(rel, set()),
                               deterministic, module_allow)
        findings.extend(kept)
        allowed.extend(ok)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))

    if write_baseline:
        save_baseline(baseline_path, findings)
        return LintResult([], findings, allowed, [], hot)

    new, baselined, stale = apply_baseline(
        findings, load_baseline(baseline_path))
    return LintResult(new, baselined, allowed, stale, hot)
