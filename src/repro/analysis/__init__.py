"""Static-analysis subsystem (DESIGN.md §11).

Layer 1 — ``sproutlint``: AST rules SPL001–SPL004 over src/, benchmarks/,
scripts/; no jax dependency, safe to import in hermetic containers.
Layer 2 — ``jaxpr_audit``: traces every compiled entry point and checks
semantic properties (f64-free, donation aliased, drop-OOB scatters,
inventory match); import it lazily, it needs jax.

Also home to the shared entry-point-table hygiene helpers used by the
serving benchmarks: a measured window must not compile new programs, or
the tok/s figure silently includes tracing time.
"""
from __future__ import annotations

import contextlib
from typing import Iterator, Tuple

from repro.analysis.findings import (BASELINE_DEFAULT, Finding,
                                     apply_baseline, load_baseline,
                                     save_baseline)
from repro.analysis.sproutlint import LintResult, lint_module, run_lint

__all__ = [
    "BASELINE_DEFAULT", "Finding", "apply_baseline", "load_baseline",
    "save_baseline", "LintResult", "lint_module", "run_lint",
    "entry_point_snapshot", "frozen_entry_points",
]


def entry_point_snapshot(engine) -> Tuple[str, ...]:
    """Sorted, immutable view of the engine's compiled entry-point names."""
    return tuple(sorted(engine.entry_points))


@contextlib.contextmanager
def frozen_entry_points(engine, label: str = "measured window",
                        ) -> Iterator[Tuple[str, ...]]:
    """Assert the entry-point table is identical on exit — i.e. the body
    compiled nothing new and retired nothing. Wrap every measured bench
    window in this."""
    before = entry_point_snapshot(engine)
    yield before
    after = entry_point_snapshot(engine)
    if after != before:
        added = sorted(set(after) - set(before))
        removed = sorted(set(before) - set(after))
        raise AssertionError(
            f"entry-point table changed during {label}: "
            f"added={added} removed={removed} — compile everything "
            "before the measured window starts")
