"""Best-effort intra-repo call graph for hot-path reachability (SPL001).

Python ASTs carry no types, so edges are matched by *terminal name*: a
call ``self.pages.ensure_capacity(...)`` is an edge to every known
definition named ``ensure_capacity``. That over-approximates — a generic
name can pull unrelated definitions into the hot set — which is the right
failure mode for a lint gate: extra coverage surfaces as an explicit
finding to allowlist or ``# noqa``, never as a silently unchecked sync.

Scopes are top-level functions and class methods; nested ``def``s belong
to their enclosing scope (their bodies are scanned with it, their calls
count as the parent's calls).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, Iterator, List, Set, Tuple

Scope = Tuple[str, str]   # (repo-relative path, qualname)


def iter_scopes(tree: ast.Module) -> Iterator[Tuple[str, ast.AST]]:
    """Yield ``(qualname, node)`` for every top-level scope in a module:
    functions, class methods, and finally ``("<module>", tree)`` for
    statements outside any def (rules must skip nodes owned by an inner
    scope when walking the module node)."""
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node.name, node
        elif isinstance(node, ast.ClassDef):
            for item in node.body:
                if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    yield f"{node.name}.{item.name}", item
    yield "<module>", tree


def _terminal_name(func: ast.AST) -> str:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return ""


def _called_names(node: ast.AST) -> Set[str]:
    out: Set[str] = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            name = _terminal_name(n.func)
            if name:
                out.add(name)
    return out


class CallGraph:
    def __init__(self) -> None:
        self.defs: Dict[Scope, ast.AST] = {}
        self.by_name: Dict[str, List[Scope]] = {}
        self.calls: Dict[Scope, Set[str]] = {}

    def add_module(self, path: str, tree: ast.Module) -> None:
        for qualname, node in iter_scopes(tree):
            if qualname == "<module>":
                continue
            scope = (path, qualname)
            self.defs[scope] = node
            self.by_name.setdefault(qualname.rsplit(".", 1)[-1],
                                    []).append(scope)
            self.calls[scope] = _called_names(node)

    def reachable(self, roots: Iterable[str]) -> Set[Scope]:
        """Transitive closure from ``"path::qualname"`` root specs over
        terminal-name-matched edges."""
        frontier: List[Scope] = []
        for spec in roots:
            path, qualname = spec.split("::")
            scope = (path, qualname)
            if scope in self.defs:
                frontier.append(scope)
        seen: Set[Scope] = set(frontier)
        while frontier:
            scope = frontier.pop()
            for name in self.calls.get(scope, ()):
                for callee in self.by_name.get(name, ()):
                    if callee not in seen:
                        seen.add(callee)
                        frontier.append(callee)
        return seen
