"""Finding record + baseline file IO for the sproutlint layer.

A finding is identified by ``(rule, path, scope, snippet)`` — the stripped
source line rather than a line number, so a baseline entry survives
unrelated edits above it but dies with the line it describes. Baselines
are committed JSON (``ANALYSIS_baseline.json`` at the repo root): findings
present in the baseline do not fail the lint, and — mirroring the tier-1
xpassed-xfail rule — a baseline entry whose finding no longer fires FAILS
the lint as *stale* until it is removed (the defect was fixed; the
suppression must not outlive it). ``--write-baseline`` regenerates the
file from the current findings.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, List, Tuple

BASELINE_DEFAULT = "ANALYSIS_baseline.json"

Key = Tuple[str, str, str, str]


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str        # "SPL001".."SPL004"
    path: str        # repo-relative posix path
    scope: str       # "Class.method", "func", or "<module>"
    line: int        # 1-indexed; informational only (not part of the key)
    snippet: str     # stripped source line
    message: str

    @property
    def key(self) -> Key:
        return (self.rule, self.path, self.scope, self.snippet)

    def render(self) -> str:
        return (f"{self.path}:{self.line}: {self.rule} [{self.scope}] "
                f"{self.message}\n    {self.snippet}")


def save_baseline(path: Path, findings: List[Finding]) -> None:
    entries = [{"rule": f.rule, "path": f.path, "scope": f.scope,
                "snippet": f.snippet} for f in findings]
    entries.sort(key=lambda e: (e["path"], e["rule"], e["scope"], e["snippet"]))
    path.write_text(json.dumps({"findings": entries}, indent=2) + "\n")


def load_baseline(path: Path) -> List[Key]:
    """Baseline keys as a list (a multiset: the same line firing twice needs
    two entries)."""
    if not path.exists():
        return []
    data = json.loads(path.read_text())
    return [(e["rule"], e["path"], e["scope"], e["snippet"])
            for e in data.get("findings", [])]


def apply_baseline(findings: List[Finding], baseline: List[Key],
                   ) -> Tuple[List[Finding], List[Finding], List[Key]]:
    """Split ``findings`` against ``baseline``.

    Returns ``(new, baselined, stale)``: findings not covered by the
    baseline, findings the baseline absorbs, and baseline keys that no
    longer match any finding (stale entries — these FAIL the lint)."""
    budget: Dict[Key, int] = {}
    for k in baseline:
        budget[k] = budget.get(k, 0) + 1
    new: List[Finding] = []
    baselined: List[Finding] = []
    for f in findings:
        if budget.get(f.key, 0) > 0:
            budget[f.key] -= 1
            baselined.append(f)
        else:
            new.append(f)
    stale: List[Key] = []
    for k, n in budget.items():
        stale.extend([k] * n)
    return new, baselined, stale
