"""CLI: ``python -m repro.analysis {lint,audit,all}``.

``lint`` runs the jax-free AST layer; ``audit`` traces the engine's
compiled entry points (imports jax lazily, so ``lint`` keeps working in
containers without it); ``all`` runs both and fails if either fails.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path


def _cmd_lint(args: argparse.Namespace) -> int:
    from repro.analysis.sproutlint import run_lint
    result = run_lint(Path(args.root), Path(args.baseline) if args.baseline
                      else None, write_baseline=args.write_baseline)
    print(result.render(verbose=args.verbose))
    if args.write_baseline:
        print(f"baseline written ({len(result.baselined)} findings)")
        return 0
    return result.rc


def _cmd_audit(args: argparse.Namespace) -> int:
    from repro.analysis.jaxpr_audit import run_audit
    report = run_audit(Path(args.root),
                       write_inventory=args.write_inventory)
    print(report.render(verbose=args.verbose))
    return report.rc


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="sproutlint (AST) + jaxpr audit for the serving engine")
    parser.add_argument("command", choices=("lint", "audit", "all"))
    parser.add_argument("--root", default=".",
                        help="repo root (default: cwd)")
    parser.add_argument("--baseline", default=None,
                        help="baseline JSON path "
                             "(default: <root>/ANALYSIS_baseline.json)")
    parser.add_argument("--write-baseline", action="store_true",
                        help="regenerate the baseline from current findings")
    parser.add_argument("--write-inventory", action="store_true",
                        help="regenerate the committed entry-point inventory")
    parser.add_argument("--verbose", "-v", action="store_true")
    args = parser.parse_args(argv)

    if args.command == "lint":
        return _cmd_lint(args)
    if args.command == "audit":
        return _cmd_audit(args)
    rc = _cmd_lint(args)
    rc_audit = _cmd_audit(args)
    return rc or rc_audit


if __name__ == "__main__":
    sys.exit(main())
