"""sproutlint rule implementations: SPL001–SPL004 (DESIGN.md §11).

Every rule is a pure function ``(ModuleContext, ...) -> [Finding]`` over
one parsed module. Rules are deliberately *syntactic* best-effort: they
cannot see types or dataflow, so each one documents exactly what it
matches; what slips through the AST net is Layer 2's job (jaxpr_audit
checks the compiled programs themselves). ``# noqa: SPLxxx`` and the
config allowlist are applied by the driver, not here.

SPL001 host-sync-in-hot-path
    In functions reachable from the decode dispatch (callgraph.py from
    ``config.HOT_PATH_ROOTS``): ``jax.device_get``, ``.item()``,
    ``.block_until_ready()``, ``np.asarray``/``np.array`` (device→host
    copy), and ``float()``/``int()`` wrapping a ``jnp.``/``jax.`` call
    (implicit sync). ``np.asarray(jax.device_get(x))`` counts once.

SPL002 donation-after-use
    A value passed at a ``donate_argnums`` position of a jitted callable
    defined in the same module is loaded again afterwards without being
    rebound. Donated buffers are deleted by the call; a later read either
    crashes or — worse, on backends that silently copy — hides the aliasing
    the perf model assumes.

SPL003 nondeterminism
    Bare ``hash()`` (PYTHONHASHSEED-dependent for str/bytes — the PR 2
    trace-seeding bug class); iteration over unsorted ``set`` values
    (for/comprehension/consuming calls like ``list``/``np.fromiter``,
    exempt when directly wrapped in ``sorted``/``np.sort``/``np.unique``);
    ``time.time()`` and stdlib ``random.*`` inside the configured
    deterministic paths.

SPL004 recompile hazard
    ``jax.jit(f)(...)`` invoked inline (retraces every call);
    ``jax.jit`` called inside a loop (a fresh compiled callable per
    iteration); an entry-point-table key built from an f-string whose
    format field is a *call* (e.g. ``f"bs{len(rows)}"`` — unbucketed
    values mint unbounded program variants).
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.callgraph import iter_scopes
from repro.analysis.findings import Finding

_SORT_WRAPPERS = {"sorted", "sort", "unique"}
_SET_CONSUMERS_NAME = {"list", "tuple", "enumerate", "iter"}
_SET_CONSUMERS_ATTR = {"fromiter", "join"}


@dataclasses.dataclass
class ModuleContext:
    path: str
    tree: ast.Module
    lines: List[str]
    parents: Dict[ast.AST, ast.AST] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                self.parents[child] = node

    def finding(self, rule: str, node: ast.AST, scope: str,
                message: str) -> Finding:
        line = getattr(node, "lineno", 0)
        snippet = (self.lines[line - 1].strip()
                   if 0 < line <= len(self.lines) else "")
        return Finding(rule, self.path, scope, line, snippet, message)


def parse_module(path: str, source: str) -> ModuleContext:
    return ModuleContext(path, ast.parse(source, filename=path),
                         source.splitlines())


# ---------------------------------------------------------------- helpers
def _terminal(func: ast.AST) -> str:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return ""


def _root_name(node: ast.AST) -> str:
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    return node.id if isinstance(node, ast.Name) else ""


def _owned_by_module(tree: ast.Module) -> Iterator[ast.AST]:
    """Module-scope nodes: everything except function bodies (class-level
    statements stay with the module)."""
    stack = list(ast.iter_child_nodes(tree))
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


def _scope_nodes(qualname: str, node: ast.AST,
                 tree: ast.Module) -> Iterator[ast.AST]:
    if qualname == "<module>":
        yield from _owned_by_module(tree)
    else:
        yield from ast.walk(node)


# ---------------------------------------------------------------- SPL001
def spl001(ctx: ModuleContext, hot_scopes: Set[str]) -> List[Finding]:
    out: List[Finding] = []
    for qualname, scope_node in iter_scopes(ctx.tree):
        if not ("*" in hot_scopes or qualname in hot_scopes):
            continue
        for node in _scope_nodes(qualname, scope_node, ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = _terminal(node.func)
            if name == "device_get":
                out.append(ctx.finding(
                    "SPL001", node, qualname,
                    "host sync (jax.device_get) on the decode hot path"))
            elif name == "block_until_ready":
                out.append(ctx.finding(
                    "SPL001", node, qualname,
                    "host sync (.block_until_ready()) on the decode hot "
                    "path"))
            elif (name == "item" and not node.args and not node.keywords
                  and isinstance(node.func, ast.Attribute)):
                out.append(ctx.finding(
                    "SPL001", node, qualname,
                    "host sync (.item()) on the decode hot path"))
            elif (name in ("asarray", "array")
                  and _root_name(node.func) in ("np", "numpy")):
                arg_is_sync = (node.args
                               and isinstance(node.args[0], ast.Call)
                               and _terminal(node.args[0].func)
                               == "device_get")
                if not arg_is_sync:   # device_get arg: counted once, above
                    out.append(ctx.finding(
                        "SPL001", node, qualname,
                        f"np.{name}() copies device values to host on the "
                        "decode hot path"))
            elif name in ("float", "int") and isinstance(node.func, ast.Name):
                if any(isinstance(n, ast.Call)
                       and _root_name(n.func) in ("jnp", "jax")
                       for a in node.args for n in ast.walk(a)):
                    out.append(ctx.finding(
                        "SPL001", node, qualname,
                        f"{name}() over a jax expression forces a host sync "
                        "on the decode hot path"))
    return out


# ---------------------------------------------------------------- SPL002
def _donors(tree: ast.Module) -> Dict[str, Tuple[int, ...]]:
    """Terminal name -> donated positions, for every ``X = jax.jit(f,
    donate_argnums=...)`` in the module (Name, ``self.attr`` and other
    attribute targets; subscript targets are untrackable by name)."""
    donors: Dict[str, Tuple[int, ...]] = {}
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1):
            continue
        value = node.value
        if not (isinstance(value, ast.Call)
                and _terminal(value.func) == "jit"):
            continue
        positions: Optional[Tuple[int, ...]] = None
        for kw in value.keywords:
            if kw.arg == "donate_argnums":
                try:
                    lit = ast.literal_eval(kw.value)
                except ValueError:
                    break
                positions = (tuple(lit) if isinstance(lit, (tuple, list))
                             else (int(lit),))
                break
        if positions is None:
            continue
        target = node.targets[0]
        name = (target.id if isinstance(target, ast.Name)
                else target.attr if isinstance(target, ast.Attribute)
                else "")
        if name:
            donors[name] = positions
    return donors


def _expr_loads(stmt: ast.AST) -> List[Tuple[str, ast.AST]]:
    out = []
    for node in ast.walk(stmt):
        if (isinstance(node, (ast.Name, ast.Attribute))
                and isinstance(getattr(node, "ctx", None), ast.Load)):
            out.append((ast.unparse(node), node))
    return out


def _stmt_stores(stmt: ast.AST) -> List[str]:
    out = []
    for node in ast.walk(stmt):
        if (isinstance(node, (ast.Name, ast.Attribute))
                and isinstance(getattr(node, "ctx", None),
                               (ast.Store, ast.Del))):
            out.append(ast.unparse(node))
    return out


def _scan_spl002(ctx: ModuleContext, scope: str, stmts: List[ast.stmt],
                 donors: Dict[str, Tuple[int, ...]],
                 donated: Dict[str, Tuple[int, str]],
                 out: List[Finding]) -> None:
    for stmt in stmts:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue          # nested scopes run under their own pass
        # 1. loads of already-donated values
        for expr, node in _expr_loads(stmt):
            if expr in donated:
                dline, donor = donated[expr]
                out.append(ctx.finding(
                    "SPL002", node, scope,
                    f"`{expr}` was donated to `{donor}` "
                    f"(donate_argnums) at line {dline} and is read here — "
                    "the buffer no longer exists"))
        # 2. new donations made by this statement
        for node in ast.walk(stmt):
            if isinstance(node, ast.Call):
                positions = donors.get(_terminal(node.func))
                if not positions:
                    continue
                for pos in positions:
                    if pos < len(node.args) and isinstance(
                            node.args[pos], (ast.Name, ast.Attribute)):
                        donated[ast.unparse(node.args[pos])] = (
                            node.lineno, _terminal(node.func))
        # 3. rebinds clear the mark (e.g. ``self.cache = jit_fn(self.cache)``)
        for expr in _stmt_stores(stmt):
            donated.pop(expr, None)
        # recurse into compound statements, sequentially (over-approximate
        # across exclusive branches — acceptable for a lint)
        for field in ("body", "orelse", "finalbody"):
            inner = getattr(stmt, field, None)
            if inner:
                _scan_spl002(ctx, scope, inner, donors, donated, out)
        for handler in getattr(stmt, "handlers", ()):
            _scan_spl002(ctx, scope, handler.body, donors, donated, out)


def spl002(ctx: ModuleContext) -> List[Finding]:
    donors = _donors(ctx.tree)
    if not donors:
        return []
    out: List[Finding] = []
    for qualname, scope_node in iter_scopes(ctx.tree):
        if qualname == "<module>":
            body = [s for s in ctx.tree.body
                    if not isinstance(s, (ast.FunctionDef,
                                          ast.AsyncFunctionDef,
                                          ast.ClassDef))]
        else:
            body = scope_node.body
        _scan_spl002(ctx, qualname, body, donors, {}, out)
    return out


# ---------------------------------------------------------------- SPL003
def _set_vars(scope_node: ast.AST) -> Set[str]:
    names: Set[str] = set()
    for node in ast.walk(scope_node):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            value = node.value
            if isinstance(value, (ast.Set, ast.SetComp)) or (
                    isinstance(value, ast.Call)
                    and isinstance(value.func, ast.Name)
                    and value.func.id in ("set", "frozenset")):
                names.add(node.targets[0].id)
    return names


def _is_set_expr(node: ast.AST, setvars: Set[str]) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id in ("set", "frozenset")):
        return True
    return isinstance(node, ast.Name) and node.id in setvars


def _sort_wrapped(ctx: ModuleContext, node: ast.AST) -> bool:
    parent = ctx.parents.get(node)
    while parent is not None and not isinstance(parent, ast.stmt):
        if isinstance(parent, ast.Call) \
                and _terminal(parent.func) in _SORT_WRAPPERS:
            return True
        parent = ctx.parents.get(parent)
    return False


def _random_imports(tree: ast.Module) -> Tuple[bool, Set[str]]:
    """(module imports stdlib ``random``, names imported from it)."""
    bare = False
    names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name == "random":
                    bare = True
        elif isinstance(node, ast.ImportFrom) and node.module == "random":
            names.update(alias.asname or alias.name for alias in node.names)
    return bare, names


def spl003(ctx: ModuleContext, deterministic: bool) -> List[Finding]:
    out: List[Finding] = []
    has_random, random_names = _random_imports(ctx.tree)
    for qualname, scope_node in iter_scopes(ctx.tree):
        setvars = (_set_vars(scope_node) if qualname != "<module>"
                   else set())
        for node in _scope_nodes(qualname, scope_node, ctx.tree):
            if isinstance(node, ast.For) \
                    and _is_set_expr(node.iter, setvars):
                out.append(ctx.finding(
                    "SPL003", node, qualname,
                    "iteration over an unsorted set — order feeds "
                    "downstream state; wrap in sorted()"))
            elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                   ast.GeneratorExp)):
                for gen in node.generators:
                    if _is_set_expr(gen.iter, setvars):
                        out.append(ctx.finding(
                            "SPL003", node, qualname,
                            "comprehension over an unsorted set — wrap in "
                            "sorted()"))
            elif isinstance(node, ast.Call):
                name = _terminal(node.func)
                if name == "hash" and isinstance(node.func, ast.Name):
                    out.append(ctx.finding(
                        "SPL003", node, qualname,
                        "bare hash() is PYTHONHASHSEED-dependent for "
                        "str/bytes — use zlib.crc32 or hashlib"))
                elif ((name in _SET_CONSUMERS_NAME
                       and isinstance(node.func, ast.Name))
                      or (name in _SET_CONSUMERS_ATTR
                          and isinstance(node.func, ast.Attribute))):
                    if (node.args
                            and _is_set_expr(node.args[0], setvars)
                            and not _sort_wrapped(ctx, node)):
                        out.append(ctx.finding(
                            "SPL003", node, qualname,
                            f"{name}() materializes an unsorted set — "
                            "order feeds downstream state; wrap in "
                            "sorted()/np.sort()"))
                elif (deterministic and name == "time"
                      and _root_name(node.func) == "time"):
                    out.append(ctx.finding(
                        "SPL003", node, qualname,
                        "time.time() in a deterministic path (telemetry "
                        "should use time.monotonic / perf_counter; plans "
                        "should take t as input)"))
                elif deterministic and (
                        (_root_name(node.func) == "random" and has_random
                         and isinstance(node.func, ast.Attribute))
                        or (isinstance(node.func, ast.Name)
                            and node.func.id in random_names)):
                    out.append(ctx.finding(
                        "SPL003", node, qualname,
                        "stdlib random in a deterministic path — use a "
                        "seeded np.random.Generator or jax.random"))
    return out


# ---------------------------------------------------------------- SPL004
def _in_loop(ctx: ModuleContext, node: ast.AST) -> bool:
    parent = ctx.parents.get(node)
    while parent is not None and not isinstance(
            parent, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Module)):
        if isinstance(parent, (ast.For, ast.While)):
            return True
        parent = ctx.parents.get(parent)
    return False


def _fstring_call_field(node: ast.AST) -> bool:
    return isinstance(node, ast.JoinedStr) and any(
        isinstance(v, ast.FormattedValue) and isinstance(v.value, ast.Call)
        for v in node.values)


def spl004(ctx: ModuleContext) -> List[Finding]:
    out: List[Finding] = []
    for qualname, scope_node in iter_scopes(ctx.tree):
        for node in _scope_nodes(qualname, scope_node, ctx.tree):
            if isinstance(node, ast.Call):
                if (isinstance(node.func, ast.Call)
                        and _terminal(node.func.func) == "jit"):
                    out.append(ctx.finding(
                        "SPL004", node, qualname,
                        "jax.jit(f)(...) invoked inline retraces on every "
                        "call — bind the jitted callable once"))
                elif _terminal(node.func) == "jit" and _in_loop(ctx, node):
                    out.append(ctx.finding(
                        "SPL004", node, qualname,
                        "jax.jit inside a loop mints a fresh compiled "
                        "callable per iteration — hoist it or key it in "
                        "an entry-point table"))
                elif (_terminal(node.func) == "setdefault"
                      and isinstance(node.func, ast.Attribute)
                      and "entry_point" in ast.unparse(node.func.value)
                      and node.args
                      and _fstring_call_field(node.args[0])):
                    out.append(ctx.finding(
                        "SPL004", node, qualname,
                        "entry-point name minted from an f-string with a "
                        "call field — bucket the value into a bounded "
                        "variable first"))
            elif (isinstance(node, ast.Subscript)
                  and "entry_point" in ast.unparse(node.value)
                  and _fstring_call_field(node.slice)):
                out.append(ctx.finding(
                    "SPL004", node, qualname,
                    "entry-point table keyed by an f-string with a call "
                    "field — unbounded variant minting; bucket the value "
                    "first"))
    return out
