"""Repo-specific configuration for the sproutlint AST layer (DESIGN.md §11).

Everything the rules need to know about THIS codebase lives here, so the
rule implementations in ``rules.py`` stay mechanical:

* ``SCAN_DIRS`` — file sets the lint walks (tests/ is deliberately out:
  fixture snippets there *violate* the rules on purpose).
* ``HOT_PATH_ROOTS`` — the decode-dispatch entry points; every function
  reachable from them through the (name-matched, over-approximate) call
  graph is "hot" for SPL001.
* ``ALLOWLIST`` — ``(path, scope, rule) -> max_count`` budgets for
  *sanctioned* findings. Unlike ``# noqa`` (which silences one line
  unconditionally), an allowlist budget machine-enforces a count: the
  engine's decode block is allowed exactly ONE host sync, so a second
  ``device_get`` in ``InferenceEngine.step`` fires even though the first
  is sanctioned. Budgets must stay in lock-step with the
  ``sproutlint: allow(...)`` anchor comments at the sanctioned sites.
* ``DETERMINISTIC_PATHS`` — module prefixes whose behavior feeds traces,
  PRNG streams or plan state; SPL003's wall-clock/stdlib-random checks
  apply only there (launch/ tooling may legitimately read time.time()).
"""
from __future__ import annotations

SCAN_DIRS = ("src", "benchmarks", "scripts")

# Decode-dispatch roots for SPL001 reachability. Format: "path::scope".
HOT_PATH_ROOTS = (
    "src/repro/serving/engine.py::InferenceEngine.step",
)

# (repo-relative path, scope, rule) -> max sanctioned findings.
ALLOWLIST = {
    # The single host<->device sync per fused decode block: the emitted
    # token matrix + validity + live masks, fetched once after the scan.
    ("src/repro/serving/engine.py", "InferenceEngine.step", "SPL001"): 1,
    # Batched whole-prompt prefill draws every admitted request's first
    # token in one fetch — one sanctioned sync per prefill group.
    ("src/repro/serving/engine.py", "InferenceEngine._prefill_group",
     "SPL001"): 1,
}

DETERMINISTIC_PATHS = (
    "src/repro/core",
    "src/repro/serving",
    "src/repro/models",
    "src/repro/kernels",
    "src/repro/training",
)
