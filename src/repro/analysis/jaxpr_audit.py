"""Layer 2: jaxpr audit of the engine's compiled entry points.

The AST layer can only see source text; this layer checks the *compiled
programs*. It drives a tiny engine through a deterministic scenario for
every serving variant (dense/paged x fp32/int8, plus tp=2 tensor-
parallel builds of two of them), recording each entry point's argument
specs on first dispatch, then re-traces every recorded program and
asserts:

* **f64-free** — no float64 abstract value anywhere in any (sub)jaxpr.
  An accidental promotion doubles decode HBM traffic and corrupts the
  Eq. 1 energy attribution without changing any output.
* **donation aliased** — programs that donate (fused decode/mixed donate
  the cache, the insert programs donate the batch cache) must show
  ``tf.aliasing_output`` in their lowered text: donation that silently
  degrades to a copy doubles peak cache HBM. Prefill donates nothing and
  must show no aliasing.
* **drop-OOB scatters** — every scatter in every program keeps JAX's
  drop-out-of-bounds semantics. Dead lanes and pad rows are *scattered
  out of bounds on purpose* (slot id ``n_slots``, page id ``n_pages``);
  a ``PROMISE_IN_BOUNDS``/``CLIP`` "optimization" would corrupt live
  rows instead of dropping dead ones.
* **inventory** — the audited entry-point name set matches the committed
  ``entry_point_inventory.json``. Drift means a new uncompiled variant
  appeared or one died silently; regenerate with ``--write-inventory``
  and review the diff (same spirit as the xfail-inventory rule).

The scenario uses ``eos_id=-1`` so every finish is budget- or cap-driven:
entry-point names depend only on host-side scheduling, never on sampled
token values, keeping the inventory identical across jax versions and
platforms.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Callable, Dict, List, Optional, Tuple

import jax

INVENTORY_DEFAULT = Path(__file__).with_name("entry_point_inventory.json")

# (variant, paged, int8, tp_degree). The tp>1 variants audit the sharded
# entry points (decode_*_tp2 and friends, DESIGN.md §14); they need >= 2
# jax devices, which scripts/analysis.sh provides by forcing 8 host CPU
# devices via XLA_FLAGS before python starts. A bare `repro.analysis
# audit` on a single-device interpreter fails fast in make_tp_mesh with
# the same incantation in the error message.
VARIANTS = (
    ("dense_fp32", False, False, 1),
    ("dense_int8", False, True, 1),
    ("paged_fp32", True, False, 1),
    ("paged_int8", True, True, 1),
    ("dense_fp32_tp2", False, False, 2),
    ("paged_int8_tp2", True, True, 2),
)


# ------------------------------------------------------------- recording
def _spec(leaf):
    if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
        # preserve mesh placement on tp-sharded leaves: the donation check
        # lowers from these specs, and an unsharded re-trace of a sharded
        # program would audit a different module than the one serving runs
        sharding = getattr(leaf, "sharding", None)
        if isinstance(sharding, jax.sharding.NamedSharding):
            return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                        sharding=sharding)
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype)
    return leaf


class Recorder:
    """Capture ``(fn, arg specs)`` per entry-point name on first dispatch.

    Specs are taken BEFORE the call runs: donated buffers are deleted by
    the dispatch, so the concrete args must be reduced to
    ``ShapeDtypeStruct`` while they still exist.
    """

    def __init__(self) -> None:
        self.programs: Dict[str, Tuple[Callable, tuple]] = {}

    def wrap(self, name: str, fn: Callable) -> Callable:
        if getattr(fn, "_sproutlint_recorded", False):
            return fn

        def wrapper(*args):
            if name not in self.programs:
                self.programs[name] = (fn, jax.tree.map(_spec, args))
            return fn(*args)

        wrapper._sproutlint_recorded = True
        return wrapper


class RecordingTable(dict):
    """entry_points stand-in: wraps every registered callable so the first
    dispatch through the table records its specs."""

    def __init__(self, recorder: Recorder) -> None:
        super().__init__()
        self._recorder = recorder

    def __setitem__(self, key, fn):
        super().__setitem__(key, self._recorder.wrap(key, fn))

    def setdefault(self, key, fn=None):
        if key not in self:
            self[key] = fn
        return self[key]


def instrument(engine) -> Recorder:
    """Swap the engine's entry-point table (and the named insert programs)
    for recording wrappers. Call before the first dispatch."""
    rec = Recorder()
    table = RecordingTable(rec)
    table.update({k: v for k, v in engine.entry_points.items()})
    engine.entry_points = table
    engine._insert_jit = rec.wrap("insert", engine._insert_jit)
    if getattr(engine, "paged", False):
        engine._paged_insert_jit = rec.wrap("paged_insert",
                                            engine._paged_insert_jit)
    return rec


# ---------------------------------------------------------------- checks
def _walk_jaxprs(jaxpr):
    """Yield a jaxpr and every sub-jaxpr reachable through eqn params
    (pjit/scan/cond/while bodies)."""
    yield jaxpr
    for eqn in jaxpr.eqns:
        for value in eqn.params.values():
            stack = [value]
            while stack:
                v = stack.pop()
                if isinstance(v, (tuple, list)):
                    stack.extend(v)
                elif hasattr(v, "jaxpr"):        # ClosedJaxpr
                    yield from _walk_jaxprs(v.jaxpr)
                elif hasattr(v, "eqns"):         # raw Jaxpr
                    yield from _walk_jaxprs(v)


def check_f64(fn: Callable, specs: tuple) -> List[str]:
    """Return a description per float64 aval found in the traced program."""
    closed = jax.make_jaxpr(fn)(*specs)
    issues: List[str] = []
    for jaxpr in _walk_jaxprs(closed.jaxpr):
        for eqn in jaxpr.eqns:
            for var in list(eqn.invars) + list(eqn.outvars):
                dtype = getattr(getattr(var, "aval", None), "dtype", None)
                if dtype is not None and str(dtype) == "float64":
                    issues.append(f"float64 aval in `{eqn.primitive.name}` "
                                  f"({var.aval})")
                    break   # one report per eqn is enough
    return issues


def check_donation(fn: Callable, specs: tuple,
                   expect_donation: bool) -> List[str]:
    """Donation must survive to the lowered module as buffer aliasing.

    Single-device lowering marks the resolved alias pair directly
    (``tf.aliasing_output``); mesh-sharded lowering instead marks the
    donated input ``jax.buffer_donor`` and leaves the pairing to XLA.
    Either marker proves the donation reached the compiler rather than
    silently degrading to a copy."""
    text = fn.lower(*specs).as_text()
    aliased = ("tf.aliasing_output" in text) or ("jax.buffer_donor" in text)
    if expect_donation and not aliased:
        return ["donate_argnums declared but no aliased buffer in the "
                "lowered module — donation degraded to a copy"]
    if not expect_donation and aliased:
        return ["unexpected buffer aliasing in a program that must not "
                "donate (its inputs are read again by the host)"]
    return []


def check_scatter_oob(fn: Callable, specs: tuple) -> List[str]:
    """Every scatter keeps drop-OOB semantics (FILL_OR_DROP / default)."""
    from jax.lax import GatherScatterMode
    closed = jax.make_jaxpr(fn)(*specs)
    issues: List[str] = []
    for jaxpr in _walk_jaxprs(closed.jaxpr):
        for eqn in jaxpr.eqns:
            if not eqn.primitive.name.startswith("scatter"):
                continue
            mode = eqn.params.get("mode")
            if mode in (GatherScatterMode.PROMISE_IN_BOUNDS,
                        GatherScatterMode.CLIP):
                issues.append(
                    f"`{eqn.primitive.name}` uses {mode} — dead-lane / "
                    "pad-row writes rely on out-of-bounds updates being "
                    "DROPPED")
    return issues


def expects_donation(name: str) -> bool:
    return (name.startswith("decode_") or name.startswith("mixed_")
            or name in ("insert", "paged_insert"))


# -------------------------------------------------------------- scenario
def _build_engine(paged: bool, int8: bool, tp: int = 1):
    from repro.configs import reduced
    from repro.models import model as MD
    from repro.serving.engine import InferenceEngine

    cfg = reduced("granite_3_2b").replace(vocab_size=512)
    params = MD.init_model(cfg, jax.random.PRNGKey(0))
    return InferenceEngine(cfg, params, n_slots=4, max_len=64, eos_id=-1,
                           decode_block=8, paged=paged, kv_int8=int8,
                           page_size=16, prefill_chunk=4, tp_degree=tp)


def _drive(engine) -> None:
    """Deterministic scenario covering prefill groups, all three sampler
    modes, batch buckets, and (where supported) the mixed chunked-prefill
    program. eos_id=-1 makes every finish budget-driven, so the minted
    entry names do not depend on sampled values."""
    from repro.serving.sampler import SamplingParams as SP

    enc = engine.tok.encode
    # phase 1: full house, heterogeneous sampling -> "full" bucket.
    # Budgets are STAGGERED so one slot frees while the rest are live:
    # the next admission then streams through the mixed chunked-prefill
    # program (where the stack supports it) instead of idle-batch prefill.
    engine.submit(enc("alpha"), max_new_tokens=24)
    engine.submit(enc("bravo bravo"), max_new_tokens=16,
                  sampling=SP(temperature=0.8))
    engine.submit(enc("charlie three"), max_new_tokens=12,
                  sampling=SP(temperature=0.7, top_k=8))
    engine.submit(enc("delta"), max_new_tokens=24)
    engine.step()
    # mid-flight admission: streams through the mixed program when the
    # stack supports chunked prefill, whole-prompt refill otherwise
    engine.submit(enc("echo echo echo"), max_new_tokens=8,
                  sampling=SP(temperature=0.9))
    engine.run_to_completion()
    # phase 2: greedy-only pair -> "greedy" mode at a smaller bucket
    engine.submit(enc("fox"), max_new_tokens=8)
    engine.submit(enc("golf four"), max_new_tokens=8)
    engine.run_to_completion()
    # phase 3: single temperature-only request -> "temp" mode, bs=1
    engine.submit(enc("hotel"), max_new_tokens=8,
                  sampling=SP(temperature=0.5))
    engine.run_to_completion()


# ---------------------------------------------------------------- report
@dataclasses.dataclass(frozen=True)
class AuditIssue:
    variant: str
    entry: str
    check: str       # "f64" | "donation" | "scatter" | "inventory"
    detail: str

    def render(self) -> str:
        return f"[{self.variant}] {self.entry}: {self.check}: {self.detail}"


@dataclasses.dataclass
class AuditReport:
    issues: List[AuditIssue]
    audited: Dict[str, List[str]]    # variant -> sorted entry names

    @property
    def rc(self) -> int:
        return 1 if self.issues else 0

    def render(self, verbose: bool = False) -> str:
        out = [i.render() for i in self.issues]
        n = sum(len(v) for v in self.audited.values())
        if verbose:
            for variant, names in sorted(self.audited.items()):
                for name in names:
                    out.append(f"audited [{variant}] {name}")
        out.append(f"jaxpr audit: {n} programs across "
                   f"{len(self.audited)} variants, "
                   f"{len(self.issues)} issues")
        return "\n".join(out)


def audit_program(variant: str, name: str, fn: Callable,
                  specs: tuple) -> List[AuditIssue]:
    issues: List[AuditIssue] = []
    for detail in check_f64(fn, specs):
        issues.append(AuditIssue(variant, name, "f64", detail))
    for detail in check_donation(fn, specs, expects_donation(name)):
        issues.append(AuditIssue(variant, name, "donation", detail))
    for detail in check_scatter_oob(fn, specs):
        issues.append(AuditIssue(variant, name, "scatter", detail))
    return issues


def load_inventory(path: Path) -> Optional[Dict[str, List[str]]]:
    if not path.exists():
        return None
    return {k: list(v) for k, v in json.loads(path.read_text()).items()}


def save_inventory(path: Path, audited: Dict[str, List[str]]) -> None:
    path.write_text(json.dumps(
        {k: sorted(v) for k, v in sorted(audited.items())}, indent=2) + "\n")


def check_inventory(audited: Dict[str, List[str]],
                    committed: Optional[Dict[str, List[str]]],
                    ) -> List[AuditIssue]:
    if committed is None:
        return [AuditIssue("*", "*", "inventory",
                           f"no committed inventory at "
                           f"{INVENTORY_DEFAULT.name}; run with "
                           "--write-inventory and commit the file")]
    issues: List[AuditIssue] = []
    for variant in sorted(set(audited) | set(committed)):
        have = set(audited.get(variant, ()))
        want = set(committed.get(variant, ()))
        for name in sorted(want - have):
            issues.append(AuditIssue(variant, name, "inventory",
                                     "in committed inventory but never "
                                     "compiled — dead variant?"))
        for name in sorted(have - want):
            issues.append(AuditIssue(variant, name, "inventory",
                                     "compiled but not in committed "
                                     "inventory — new variant; review and "
                                     "--write-inventory"))
    return issues


def run_audit(root: Path, inventory_path: Optional[Path] = None,
              write_inventory: bool = False) -> AuditReport:
    del root   # engines are built from installed repro modules, not paths
    inventory_path = inventory_path or INVENTORY_DEFAULT
    issues: List[AuditIssue] = []
    audited: Dict[str, List[str]] = {}
    for variant, paged, int8, tp in VARIANTS:
        engine = _build_engine(paged, int8, tp)
        recorder = instrument(engine)
        _drive(engine)
        audited[variant] = sorted(recorder.programs)
        for name, (fn, specs) in sorted(recorder.programs.items()):
            issues.extend(audit_program(variant, name, fn, specs))
    if write_inventory:
        save_inventory(inventory_path, audited)
    else:
        issues.extend(check_inventory(audited,
                                      load_inventory(inventory_path)))
    return AuditReport(issues, audited)
