"""Pallas TPU paged decode-attention kernel (block-table KV cache).

TPU adaptation of vLLM's PagedAttention (DESIGN.md §3): pages are 128–256
tokens (HBM->VMEM DMA wants wide contiguous lanes, unlike GPU's 16-token
pages), and the per-slot page list is delivered through *scalar prefetch*
(``PrefetchScalarGridSpec``) so the page index feeds each grid step's
BlockSpec index_map — the TPU analogue of the GPU kernel's pointer chase,
resolved at DMA-issue time from SMEM.

Grid: (batch, kv_head, page). Online softmax streams one page per step;
fp32 (m, l, acc) scratch persists across the page sweep. Pages past the
slot's length are predicated off with ``pl.when`` (no DMA, no FLOPs).
Supports an int8-quantized cache via per-token-per-head scales, dequantized
in VMEM after the DMA (halves decode HBM traffic — the memory-roofline win).

Layouts: q (B, H, D); k/v pages (P, page, KVH, D) -> out (B, H, D).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                  o_ref, m_scr, l_scr, acc_scr, *, page: int, n_pages: int,
                  group: int, sm_scale: float, quantized: bool):
    b = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]

    @pl.when(pi * page < length)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)             # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)       # (page, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        if quantized:
            k = k * ks_ref[0, :, 0][:, None]
            v = v * vs_ref[0, :, 0][:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                                 # (G, page)
        pos = pi * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        m_safe = jnp.maximum(m_new, NEG_INF / 2)
        p = jnp.exp(s - m_safe[:, None])
        p = jnp.where(pos < length, p, 0.0)
        corr = jnp.where(m_prev > NEG_INF / 2,
                         jnp.exp(jnp.maximum(m_prev, NEG_INF / 2) - m_safe), 0.0)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(pi == n_pages - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, block_table, lengths,
                    k_scale=None, v_scale=None, *, interpret: bool = False):
    """Decode attention. q: (B, H, D); pages (P, page, KVH, D);
    block_table (B, max_pages) int32; lengths (B,). Returns (B, H, D)."""
    B, H, D = q.shape
    P, page, KVH, _ = k_pages.shape
    max_pages = block_table.shape[1]
    group = H // KVH
    sm_scale = 1.0 / math.sqrt(D)
    quantized = k_scale is not None
    if not quantized:  # dummy scale operands keep one kernel signature
        k_scale = jnp.ones((P, page, KVH), jnp.float32)
        v_scale = jnp.ones((P, page, KVH), jnp.float32)

    # q reorganized to (B, KVH, G, D) so one grid step owns one kv head
    qr = q.reshape(B, KVH, group, D)

    def q_map(b, kvh, pi, bt, ln):
        return (b, kvh, 0, 0)

    def kv_map(b, kvh, pi, bt, ln):
        return (bt[b, pi], 0, kvh, 0)

    def sc_map(b, kvh, pi, bt, ln):
        return (bt[b, pi], 0, kvh)

    def o_map(b, kvh, pi, bt, ln):
        return (b, kvh, 0, 0)

    kernel = functools.partial(
        _paged_kernel, page=page, n_pages=max_pages, group=group,
        sm_scale=sm_scale, quantized=quantized)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KVH, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, group, D), q_map),
            pl.BlockSpec((1, page, 1, D), kv_map),
            pl.BlockSpec((1, page, 1, D), kv_map),
            pl.BlockSpec((1, page, 1), sc_map),
            pl.BlockSpec((1, page, 1), sc_map),
        ],
        out_specs=pl.BlockSpec((1, 1, group, D), o_map),
        scratch_shapes=[
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group, D), jnp.float32),
        ],
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, group, D), q.dtype),
        interpret=interpret,
    )(jnp.clip(block_table, 0, P - 1), lengths.astype(jnp.int32),
      qr, k_pages, v_pages, k_scale, v_scale)
    return out.reshape(B, H, D)
