"""Pallas TPU flash-attention (prefill) kernel.

Adaptation of FlashAttention-2 to the TPU memory hierarchy (DESIGN.md §3):
K/V stream HBM->VMEM in (block_k, D) tiles per BlockSpec; the online softmax
state (m, l) and the (block_q, D) output accumulator live in fp32 VMEM
scratch across the innermost grid dimension (TPU grids execute sequentially,
so scratch persists over the k-block sweep). Q/K/V blocks are MXU-aligned
(128-multiples); causal block skipping is grid-level: blocks strictly above
the diagonal are predicated off with ``pl.when`` before any compute issues.

Layouts: q (B, H, Tq, D); k/v (B, KVH, Tk, D), GQA via H // KVH head groups.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, window: int, block_q: int, block_k: int,
                  n_kb: int, sm_scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    def _body():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                                # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        m_safe = jnp.maximum(m_new, NEG_INF / 2)
        p = jnp.exp(s - m_safe[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.where(m_prev > NEG_INF / 2,
                         jnp.exp(jnp.maximum(m_prev, NEG_INF / 2) - m_safe), 0.0)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    if causal:
        pl.when(k_start <= q_start + block_q - 1)(_body)  # skip above-diagonal
    elif window > 0:
        pl.when((k_start <= q_start + block_q - 1)
                & (k_start + block_k > q_start - window))(_body)
    else:
        _body()

    @pl.when(ki == n_kb - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (B, H, Tq, D); k/v: (B, KVH, Tk, D) -> (B, H, Tq, D)."""
    B, H, Tq, D = q.shape
    KVH, Tk = k.shape[1], k.shape[2]
    assert H % KVH == 0
    block_q = min(block_q, Tq)
    block_k = min(block_k, Tk)
    assert Tq % block_q == 0 and Tk % block_k == 0, \
        "pad sequence to block multiples before calling the kernel"
    n_qb, n_kb = Tq // block_q, Tk // block_k
    group = H // KVH
    sm_scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _flash_kernel, causal=causal, window=window, block_q=block_q,
        block_k=block_k, n_kb=n_kb, sm_scale=sm_scale)

    return pl.pallas_call(
        kernel,
        grid=(B, H, n_qb, n_kb),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Tq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
