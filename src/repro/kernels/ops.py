"""Jit'd public wrappers for the Pallas kernels.

On TPU the kernels compile natively; on CPU (this container) they execute
in interpret mode, which is how the tests validate them. ``auto_interpret``
resolves that per backend so callers never pass the flag.

``SPROUT_KERNEL_IMPL`` overrides the "auto" resolution fleet-wide (e.g.
``SPROUT_KERNEL_IMPL=pallas_interpret`` forces the real kernel semantics
through the interpreter on CPU — the CI ``kernels-interpret`` job runs the
pallas suites this way so kernel parity is exercised on CPU runners, not
just the XLA reference path). An explicit ``impl=`` argument always wins;
the env var only redirects callers that asked for "auto".
"""
from __future__ import annotations

import os

import jax

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.paged_attention import paged_attention as _paged
from repro.kernels.rmsnorm import fused_rmsnorm as _rmsnorm

_IMPLS = ("xla", "pallas", "pallas_interpret")


def auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def resolve_impl(impl: str = "auto") -> str:
    """Resolve an ``impl`` request to a concrete backend: explicit wins,
    then the ``SPROUT_KERNEL_IMPL`` env override, then per-backend auto
    (native kernel on TPU, XLA reference elsewhere)."""
    if impl != "auto":
        return impl
    env = os.environ.get("SPROUT_KERNEL_IMPL", "").strip()
    if env:
        if env not in _IMPLS:
            raise ValueError(
                f"SPROUT_KERNEL_IMPL={env!r} not in {_IMPLS}")
        return env
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128):
    """q (B,H,Tq,D), k/v (B,KVH,Tk,D) -> (B,H,Tq,D). Pads to block size."""
    B, H, Tq, D = q.shape
    Tk = k.shape[2]
    bq, bk = min(block_q, Tq), min(block_k, Tk)
    pq = (-Tq) % bq
    pk = (-Tk) % bk
    if pq or pk:
        # padded keys are masked off by causality only when Tq==Tk; for
        # robustness fall back to the reference on ragged shapes.
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _flash(q, k, v, causal=causal, window=window, block_q=bq,
                  block_k=bk, interpret=auto_interpret())


def paged_attention(q, k_pages, v_pages, block_table, lengths,
                    k_scale=None, v_scale=None, *, impl: str = "auto"):
    """Decode attention over a block-table cache; see paged_attention.py.

    ``impl``: "auto" (kernel on TPU, reference elsewhere), "pallas"
    (native lowering), "pallas_interpret" (kernel semantics on CPU — how
    the tier-1 tests exercise the real kernel), or "xla" (the pure-jnp
    ``kernels/ref.py`` oracle, the serving engine's CPU fast path).
    """
    impl = resolve_impl(impl)
    if impl == "xla":
        return ref.paged_attention_ref(q, k_pages, v_pages, block_table,
                                       lengths, k_scale, v_scale)
    assert impl in ("pallas", "pallas_interpret"), impl
    return _paged(q, k_pages, v_pages, block_table, lengths, k_scale,
                  v_scale, interpret=impl == "pallas_interpret")


def fused_rmsnorm(x, scale, residual=None, *, eps: float = 1e-6):
    """(N,d) fused residual+RMSNorm; falls back to ref on ragged rows."""
    N = x.shape[0]
    block = 256 if N % 256 == 0 else (N if N <= 1024 else 0)
    if block == 0 or N % block:
        return ref.fused_rmsnorm_ref(x, scale, residual, eps)
    return _rmsnorm(x, scale, residual, block_rows=block, eps=eps,
                    interpret=auto_interpret())
