"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B, H, Tq, D); k/v: (B, KVH, Tk, D). Returns (B, H, Tq, D)."""
    B, H, Tq, D = q.shape
    KVH, Tk = k.shape[1], k.shape[2]
    g = H // KVH
    qg = q.reshape(B, KVH, g, Tq, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgtd,bksd->bkgts", qg, kf) / math.sqrt(D)
    qpos = jnp.arange(Tq)[:, None]
    kpos = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgts,bksd->bkgtd", p, vf)
    return out.reshape(B, H, Tq, D).astype(q.dtype)


def paged_attention_ref(q, k_pages, v_pages, block_table, lengths,
                        k_scale=None, v_scale=None):
    """Decode attention over a paged cache.

    q: (B, H, D); k_pages/v_pages: (P, ps, KVH, D) (int8 when scales given,
    scales (P, ps, KVH) f32); block_table: (B, max_pages) int32;
    lengths: (B,) tokens per slot. Returns (B, H, D).
    """
    B, H, D = q.shape
    P, ps, KVH, _ = k_pages.shape
    max_pages = block_table.shape[1]
    S = max_pages * ps
    g = H // KVH

    def per_slot(qb, bt, L):
        pages = jnp.clip(bt, 0, P - 1)
        kk = k_pages[pages].reshape(S, KVH, D).astype(jnp.float32)
        vv = v_pages[pages].reshape(S, KVH, D).astype(jnp.float32)
        if k_scale is not None:
            ks = k_scale[pages].reshape(S, KVH)
            vs = v_scale[pages].reshape(S, KVH)
            kk = kk * ks[..., None]
            vv = vv * vs[..., None]
        qh = qb.reshape(KVH, g, D).astype(jnp.float32)
        s = jnp.einsum("kgd,skd->kgs", qh, kk) / math.sqrt(D)
        valid = jnp.arange(S) < L
        s = jnp.where(valid[None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("kgs,skd->kgd", p, vv).reshape(H, D)

    return jax.vmap(per_slot)(q, block_table, lengths).astype(q.dtype)


def fused_rmsnorm_ref(x, scale, residual=None, eps: float = 1e-6):
    """y = rmsnorm(x [+ residual]) * scale; returns (y, x+residual)."""
    xr = x if residual is None else x + residual
    xf = xr.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)
    return y.astype(x.dtype), xr
