"""Pallas TPU kernels for the serving hot-spots the paper optimizes
(vLLM paging / FlashAttention on GPU -> TPU-native equivalents):
flash_attention (prefill), paged_attention (block-table decode, int8),
fused_rmsnorm. Public API: repro.kernels.ops; oracles: repro.kernels.ref.
Validated in interpret mode on CPU; native on TPU."""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
