"""Pallas TPU fused RMSNorm(+residual) kernel.

Fuses the residual add with the norm so the residual stream makes one
HBM round-trip instead of two (decode is HBM-bound; every byte matters).
Row-blocked: each grid step loads a (block_rows, d) tile into VMEM,
reduces in fp32, writes both the normalized output and the updated
residual stream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, res_ref, scale_ref, y_ref, resout_ref, *,
                    eps: float, with_residual: bool):
    x = x_ref[...].astype(jnp.float32)
    if with_residual:
        x = x + res_ref[...].astype(jnp.float32)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps) * scale_ref[...].astype(jnp.float32)
    y_ref[...] = y.astype(y_ref.dtype)
    resout_ref[...] = x.astype(resout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "eps", "interpret"))
def fused_rmsnorm(x, scale, residual=None, *, block_rows: int = 256,
                  eps: float = 1e-6, interpret: bool = False):
    """x: (N, d); scale: (d,); residual: optional (N, d).
    Returns (rmsnorm(x+residual)*scale, x+residual)."""
    N, d = x.shape
    block_rows = min(block_rows, N)
    assert N % block_rows == 0, "pad rows to a block multiple"
    with_residual = residual is not None
    res = residual if with_residual else x  # dummy operand, ignored in kernel

    kernel = functools.partial(_rmsnorm_kernel, eps=eps,
                               with_residual=with_residual)
    y, resout = pl.pallas_call(
        kernel,
        grid=(N // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((N, d), x.dtype),
                   jax.ShapeDtypeStruct((N, d), x.dtype)],
        interpret=interpret,
    )(x, res, scale)
    return y, resout
