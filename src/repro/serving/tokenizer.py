"""Self-contained byte-level tokenizer with chat-format specials.

ids 0..255 = raw bytes; specials follow. Any model with vocab_size >= 262
can serve text through it; it round-trips arbitrary UTF-8.
"""
from __future__ import annotations

from typing import List


class ByteTokenizer:
    BOS = 256
    EOS = 257
    SYS = 258   # <|system|>
    USR = 259   # <|user|>
    ASST = 260  # <|assistant|>
    END = 261   # <|end|>
    N_SPECIAL = 6

    SPECIAL_STRS = {"<|system|>": SYS, "<|user|>": USR,
                    "<|assistant|>": ASST, "<|end|>": END}

    @property
    def vocab_size(self) -> int:
        return 256 + self.N_SPECIAL

    def encode(self, text: str, bos: bool = False) -> List[int]:
        ids: List[int] = [self.BOS] if bos else []
        i = 0
        while i < len(text):
            matched = False
            if text[i] == "<":
                for s, tid in self.SPECIAL_STRS.items():
                    if text.startswith(s, i):
                        ids.append(tid)
                        i += len(s)
                        matched = True
                        break
            if not matched:
                ids.extend(text[i].encode("utf-8"))
                i += 1
        return ids

    def decode(self, ids: List[int]) -> str:
        rev = {v: k for k, v in self.SPECIAL_STRS.items()}
        out: List[str] = []
        buf = bytearray()
        for t in ids:
            t = int(t)
            if t < 256:
                buf.append(t)
            else:
                if buf:
                    out.append(buf.decode("utf-8", errors="replace"))
                    buf = bytearray()
                if t in rev:
                    out.append(rev[t])
                # BOS/EOS render as nothing
        if buf:
            out.append(buf.decode("utf-8", errors="replace"))
        return "".join(out)
