"""Block-table (paged) KV cache — storage layer for the Pallas decode kernel.

TPU adaptation of vLLM's PagedAttention (DESIGN.md §3): GPU vLLM uses
16-token pages because CUDA gathers are cheap; on TPU, HBM->VMEM DMA wants
>=512B contiguous lanes, so pages are 128–256 tokens and the per-sequence
block table is small enough to sit in SMEM for the kernel's scalar prefetch.

Storage:  k/v  (n_pages, page_size, n_kv, head_dim)
Tables:   block_table (n_slots, max_pages) int32 page id (-1 = unmapped)
          lengths     (n_slots,) tokens written per slot
Allocator: host-side free list; pages are allocated on demand at append
time and freed when a slot is released — memory scales with *live tokens*,
not n_slots x max_len (the entire point of paging).
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class PagedKVCache:
    def __init__(self, *, n_pages: int, page_size: int, n_kv: int,
                 head_dim: int, n_slots: int, max_len: int,
                 dtype=jnp.float32):
        assert page_size % 8 == 0, "page_size should be lane-aligned"
        self.page_size = page_size
        self.n_pages = n_pages
        self.max_pages = (max_len + page_size - 1) // page_size
        self.k = jnp.zeros((n_pages, page_size, n_kv, head_dim), dtype)
        self.v = jnp.zeros((n_pages, page_size, n_kv, head_dim), dtype)
        self.block_table = np.full((n_slots, self.max_pages), -1, np.int32)
        self.lengths = np.zeros(n_slots, np.int32)
        self._free: List[int] = list(range(n_pages - 1, -1, -1))

    # ----- allocator ---------------------------------------------------
    def pages_in_use(self) -> int:
        return self.n_pages - len(self._free)

    def _ensure_capacity(self, slot: int, new_len: int) -> None:
        need = (new_len + self.page_size - 1) // self.page_size
        if need > self.max_pages:
            raise MemoryError(
                f"slot needs {need} pages > max_len capacity {self.max_pages}")
        have = int(np.sum(self.block_table[slot] >= 0))
        for _ in range(need - have):
            if not self._free:
                raise MemoryError("paged KV cache exhausted")
            self.block_table[slot, have] = self._free.pop()
            have += 1

    def release(self, slot: int) -> None:
        for j in range(self.max_pages):
            p = int(self.block_table[slot, j])
            if p >= 0:
                self._free.append(p)
                self.block_table[slot, j] = -1
        self.lengths[slot] = 0

    # ----- writes ------------------------------------------------------
    def append(self, slot: int, k_tok: jnp.ndarray, v_tok: jnp.ndarray) -> None:
        """Append one token's K/V (n_kv, head_dim) to a slot."""
        pos = int(self.lengths[slot])
        self._ensure_capacity(slot, pos + 1)
        page = int(self.block_table[slot, pos // self.page_size])
        off = pos % self.page_size
        self.k = self.k.at[page, off].set(k_tok.astype(self.k.dtype))
        self.v = self.v.at[page, off].set(v_tok.astype(self.v.dtype))
        self.lengths[slot] = pos + 1

    def write_prompt(self, slot: int, k: jnp.ndarray, v: jnp.ndarray) -> None:
        """Bulk-write a prompt's K/V (T, n_kv, head_dim) after prefill."""
        T = k.shape[0]
        self._ensure_capacity(slot, T)
        ps = self.page_size
        for start in range(0, T, ps):
            page = int(self.block_table[slot, start // ps])
            n = min(ps, T - start)
            self.k = self.k.at[page, :n].set(k[start:start + n].astype(self.k.dtype))
            self.v = self.v.at[page, :n].set(v[start:start + n].astype(self.v.dtype))
        self.lengths[slot] = T

    # ----- reads (reference; the Pallas kernel reads directly) ---------
    def gather(self, slot: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Materialize a slot's K/V (length, n_kv, head_dim) — test oracle."""
        L = int(self.lengths[slot])
        pages = self.block_table[slot][: (L + self.page_size - 1) // self.page_size]
        k = self.k[np.asarray(pages)].reshape(-1, *self.k.shape[2:])[:L]
        v = self.v[np.asarray(pages)].reshape(-1, *self.v.shape[2:])[:L]
        return k, v

    def device_tables(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return jnp.asarray(self.block_table), jnp.asarray(self.lengths)
