"""Block-table (paged) KV cache: the serving engine's decode storage.

TPU adaptation of vLLM's PagedAttention (DESIGN.md §3): GPU vLLM uses
16-token pages because CUDA gathers are cheap; on TPU, HBM->VMEM DMA wants
>=512B contiguous lanes, so pages are 128–256 tokens and the per-sequence
block table is small enough to sit in SMEM for the kernel's scalar prefetch.

Two layers:

``PageAllocator`` — the host-side control structure the engine drives:
  block_table (n_slots, max_pages) int32 page id (-1 = unmapped)
  lengths     (n_slots,) tokens written per slot
  free list   min-heap of page ids, so allocation is lowest-id-first and
              pop/push order is deterministic regardless of how request
              lifetimes interleave; per-slot page counts are tracked
              incrementally (no O(max_pages) scans on the hot path).

With ``prefix_cache=True`` the allocator is additionally a **radix prefix
cache** (DESIGN.md §13): every FULL page of prompt tokens is content-hashed
with a hash *chained on its parent page's hash*, so a page's key encodes
its entire prefix and a flat dict IS the radix tree. Pages are refcounted
(``refcount`` counts slot holds); a slot that adopts indexed pages shares
them read-only, and a write into a shared page goes through copy-on-write
(``prepare_append``). Released prompt pages whose refcount reaches zero
are RETAINED in the index (reclaimable LRU "cached" state) so sequential
duplicate traffic hits too; allocation pressure evicts them oldest-first.
Budget attribution: a freshly allocated page is *owned* by (charged to)
the allocating slot's admission reservation; an adopted page whose owner
has released is *pinned* — active but charged to no reservation — and the
engine's admission gate counts ``pinned`` alongside committed reservations
so shared pages are paid for exactly once.

``PagedKVCache`` — a single-layer device page store (k/v as
(n_pages, page_size, n_kv, head_dim)) wrapping an allocator, with
coalesced per-page writes. The engine itself owns a layer-stacked page
store inside its decode program (models/model.py ``init_paged_cache``) and
uses the bare allocator; ``PagedKVCache`` remains the standalone storage
used by tests and as the ``gather()`` oracle the Pallas kernel is verified
against.

Memory scales with *live tokens*, not n_slots x max_len — the entire point
of paging, and the lever the engine's directive-aware page-budget admission
(serving/engine.py) uses to fit more concurrent requests per fixed HBM.
"""
from __future__ import annotations

import hashlib
import heapq
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np


class PageAllocator:
    """Host-side block-table allocator: deterministic, O(1) bookkeeping."""

    def __init__(self, *, n_pages: int, page_size: int, n_slots: int,
                 max_len: int, prefix_cache: bool = False,
                 kv_salt: str = ""):
        assert page_size % 8 == 0, "page_size should be lane-aligned"
        self.page_size = page_size
        self.n_pages = n_pages
        self.n_slots = n_slots
        self.max_pages = (max_len + page_size - 1) // page_size
        self.block_table = np.full((n_slots, self.max_pages), -1, np.int32)
        self.lengths = np.zeros(n_slots, np.int32)
        # min-heap => allocation is always the lowest-numbered free page and
        # therefore a pure function of the alloc/release history, never of
        # list-order accidents (reuse order used to depend on interleaving)
        self._free: List[int] = list(range(n_pages))
        heapq.heapify(self._free)
        # incremental per-slot page counts: the append hot path must not
        # rescan the block table per token
        self._slot_pages = np.zeros(n_slots, np.int32)
        # ----- radix prefix cache (DESIGN.md §13) ----------------------
        self.prefix_cache = prefix_cache
        # blake2b, NOT hash(): the chain keys must be identical across
        # PYTHONHASHSEEDs (SPL003) and across processes, and 128-bit
        # digests make a content collision — which would serve another
        # prompt's KV — practically impossible. The salt folds in the KV
        # dtype/quant mode so an int8 page can never satisfy an fp chain.
        self._root = hashlib.blake2b(
            f"{kv_salt}/{page_size}".encode(), digest_size=16).digest()
        self._index: Dict[bytes, int] = {}       # chain hash -> page id
        self._page_hash: Dict[int, bytes] = {}   # page id -> chain hash
        # ref-0 indexed pages, insertion-ordered = LRU (oldest first);
        # values unused (dict-as-ordered-set keeps pops deterministic)
        self._cached: Dict[int, None] = {}
        # slot holds per page; the index itself holds no refcount — a
        # cached page is exactly (refcount 0, indexed)
        self.refcount = np.zeros(n_pages, np.int32)
        # slot whose admission reservation the page is charged to; -1 for
        # adopted-only (pinned), cached, and free pages
        self._owner = np.full(n_pages, -1, np.int32)
        # active pages charged to NO reservation (owner released, adopters
        # remain): the engine's admission gate adds this to _committed
        self.pinned = 0
        # telemetry
        self.pages_adopted = 0
        self.cow_copies = 0
        self.cache_evictions = 0
        self.shared_peak = 0

    # ----- queries -----------------------------------------------------
    def pages_in_use(self) -> int:
        return self.n_pages - len(self._free)

    def free_pages(self) -> int:
        return len(self._free)

    def cached_pages(self) -> int:
        """Indexed pages with no live holder — retained for future prefix
        hits, reclaimed LRU-first under allocation pressure."""
        return len(self._cached)

    def reclaimable_pages(self) -> int:
        """Pages an allocation can actually obtain: free + cached."""
        return len(self._free) + len(self._cached)

    def live_tokens(self) -> int:
        return int(self.lengths.sum())

    def pages_needed(self, n_tokens: int) -> int:
        return (n_tokens + self.page_size - 1) // self.page_size

    def fragmentation(self) -> float:
        """Internal fragmentation: the fraction of allocated page capacity
        not holding a live token (partially filled tail pages)."""
        used = self.pages_in_use() * self.page_size
        return 1.0 - self.live_tokens() / used if used else 0.0

    def report(self) -> Dict[str, float]:
        """Telemetry snapshot the engine exports (serving/engine.py
        ``kv_stats``)."""
        rep = {
            "n_pages": self.n_pages,
            "page_size": self.page_size,
            "pages_in_use": self.pages_in_use(),
            "live_tokens": self.live_tokens(),
            "occupancy": self.pages_in_use() / max(self.n_pages, 1),
            "fragmentation": round(self.fragmentation(), 6),
        }
        if self.prefix_cache:
            rep.update(cached_pages=self.cached_pages(),
                       pinned_pages=self.pinned,
                       pages_adopted=self.pages_adopted,
                       cow_copies=self.cow_copies,
                       cache_evictions=self.cache_evictions,
                       shared_pages_peak=self.shared_peak)
        return rep

    # ----- allocation --------------------------------------------------
    def _alloc_page(self) -> int:
        """One free page id — reclaiming the LRU cached page when the heap
        is dry (its index entry dies with it; any chain suffix hanging off
        it becomes unreachable and ages out the same way)."""
        if self._free:
            return heapq.heappop(self._free)
        if self._cached:
            pid = next(iter(self._cached))
            del self._cached[pid]
            del self._index[self._page_hash.pop(pid)]
            self.cache_evictions += 1
            return pid
        raise MemoryError("paged KV cache exhausted")

    def ensure_capacity(self, slot: int, new_len: int) -> int:
        """Map enough pages for ``new_len`` tokens in ``slot``. Returns the
        number of pages newly mapped by this call (0 when already covered)
        so callers — e.g. the engine's per-chunk page growth — can account
        for incremental allocation."""
        need = self.pages_needed(new_len)
        if need > self.max_pages:
            raise MemoryError(
                f"slot needs {need} pages > max_len capacity {self.max_pages}")
        have = int(self._slot_pages[slot])
        if need > have and need - have > self.reclaimable_pages():
            raise MemoryError(
                f"paged KV cache exhausted: need {need - have} pages, "
                f"{self.reclaimable_pages()} reclaimable of {self.n_pages}")
        grown = max(0, need - have)
        while have < need:
            pid = self._alloc_page()
            self.block_table[slot, have] = pid
            self.refcount[pid] = 1
            self._owner[pid] = slot
            have += 1
        self._slot_pages[slot] = have
        return grown

    def _drop_hold(self, slot: int, pid: int) -> None:
        """Release one slot's hold on one page, with the owner/pinned and
        cached/free transitions (the single place refcounts go down)."""
        self.refcount[pid] -= 1
        r = int(self.refcount[pid])
        if int(self._owner[pid]) == slot:
            self._owner[pid] = -1
            if r > 0:
                # remaining holders adopted it: active but charged to no
                # reservation — the admission gate must count it
                self.pinned += 1
        elif int(self._owner[pid]) == -1 and r == 0:
            self.pinned -= 1
        if r == 0:
            if pid in self._page_hash:
                self._cached[pid] = None        # retained: future hits
            else:
                heapq.heappush(self._free, int(pid))

    def release(self, slot: int) -> None:
        """Unmap a slot: every hold is *decremented*, never blindly freed
        — shared pages survive their co-holders, and indexed pages whose
        refcount reaches zero are retained as cached (prefix_cache) or
        re-enter the free heap (plain paging; lowest-id-first reuse stays
        deterministic)."""
        for j in range(int(self._slot_pages[slot])):
            self._drop_hold(slot, int(self.block_table[slot, j]))
            self.block_table[slot, j] = -1
        self._slot_pages[slot] = 0
        self.lengths[slot] = 0

    # ----- radix prefix cache (DESIGN.md §13) --------------------------
    def _chain_hashes(self, token_ids: Sequence[int]) -> List[bytes]:
        """Chain key per FULL page of ``token_ids``: page j's key digests
        (parent key, page-j tokens), so equal keys imply equal whole
        prefixes — partial tail pages are never keyed (their content would
        change under every append)."""
        out: List[bytes] = []
        h = self._root
        ps = self.page_size
        for j in range(len(token_ids) // ps):
            chunk = b"".join(
                int(t).to_bytes(8, "little", signed=True)
                for t in token_ids[j * ps:(j + 1) * ps])
            h = hashlib.blake2b(h + chunk, digest_size=16).digest()
            out.append(h)
        return out

    def match_prefix(self, token_ids: Sequence[int]
                     ) -> Tuple[int, List[int], int]:
        """Longest indexed full-page prefix of ``token_ids``: returns
        (pages matched, their page ids, how many of them are currently
        cached ref-0 — i.e. would become *pinned* if adopted, which the
        engine's admission gate must budget for). Pure query: no state
        changes."""
        if not self.prefix_cache:
            return 0, [], 0
        pids: List[int] = []
        newly_pinned = 0
        for h in self._chain_hashes(token_ids):
            pid = self._index.get(h)
            if pid is None:
                break
            pids.append(pid)
            if int(self.refcount[pid]) == 0:
                newly_pinned += 1
        return len(pids), pids, newly_pinned

    def adopt(self, slot: int, page_ids: Sequence[int]) -> None:
        """Map an indexed page chain into ``slot``'s block table, sharing
        the pages (incref; zero new pages, zero prefill FLOPs for the
        span). The slot must be empty. Cached pages leave the LRU and
        become pinned; pages still held by their allocator just gain a
        reader."""
        assert int(self._slot_pages[slot]) == 0, "adopt into a mapped slot"
        for j, pid in enumerate(page_ids):
            if int(self.refcount[pid]) == 0:
                self._cached.pop(pid, None)
                self.pinned += 1                 # active, owned by no one
            self.refcount[pid] += 1
            self.block_table[slot, j] = pid
        self._slot_pages[slot] = len(page_ids)
        self.pages_adopted += len(page_ids)
        self.shared_peak = max(self.shared_peak,
                               int((self.refcount > 1).sum()))

    def register_prefix(self, slot: int, token_ids: Sequence[int]) -> int:
        """Index ``slot``'s full prompt pages under their chain keys (after
        the prompt K/V has been written). First registration wins: a key
        already present keeps its page (the slot keeps its private copy and
        future requests dedup against the incumbent). Returns pages newly
        indexed."""
        if not self.prefix_cache:
            return 0
        new = 0
        for j, h in enumerate(self._chain_hashes(token_ids)):
            if h in self._index:
                continue
            pid = int(self.block_table[slot, j])
            if pid < 0 or pid in self._page_hash:
                break
            self._index[h] = pid
            self._page_hash[pid] = h
            new += 1
        return new

    def prepare_append(self, slot: int, pos: int) -> Optional[Tuple[int, int]]:
        """Copy-on-write gate for a write at position ``pos``: if the page
        holding it is shared (refcount > 1, or adopted — not owned by this
        slot), remap the slot onto a fresh page and return (src, dst) so
        the caller copies the shared contents device-side BEFORE the write.
        An exclusively-owned page that is merely indexed is de-indexed in
        place (its content is about to change; no copy needed). Returns
        None when the write needs nothing."""
        j = pos // self.page_size
        if j >= int(self._slot_pages[slot]):
            return None                          # will be freshly mapped
        pid = int(self.block_table[slot, j])
        if pid < 0:
            return None
        if int(self.refcount[pid]) == 1 and int(self._owner[pid]) == slot:
            h = self._page_hash.pop(pid, None)
            if h is not None:
                del self._index[h]
            return None
        npid = self._alloc_page()
        self.block_table[slot, j] = npid
        self.refcount[npid] = 1
        self._owner[npid] = slot
        self.cow_copies += 1
        self._drop_hold(slot, pid)
        return pid, npid

    def invalidate_slot(self, slot: int) -> int:
        """Drop ``slot``'s OWNED pages from the index (quarantine path:
        their content is suspect and must never serve a future hit).
        Adopted pages stay indexed — this slot never wrote them (COW
        guarantees it), so their content is not implicated. Returns pages
        de-indexed."""
        n = 0
        for j in range(int(self._slot_pages[slot])):
            pid = int(self.block_table[slot, j])
            if pid >= 0 and int(self._owner[pid]) == slot:
                h = self._page_hash.pop(pid, None)
                if h is not None:
                    del self._index[h]
                    self._cached.pop(pid, None)
                    n += 1
        return n

    def exclusive_pages(self, slot: int) -> np.ndarray:
        """Per-table-entry mask of pages this slot may mutate wholesale
        (refcount 1, owned, unindexed) — the lane-fill paths (poison /
        scrub) must not touch shared or cached-index pages."""
        out = np.zeros(self.max_pages, bool)
        for j in range(int(self._slot_pages[slot])):
            pid = int(self.block_table[slot, j])
            out[j] = (pid >= 0 and int(self.refcount[pid]) == 1
                      and int(self._owner[pid]) == slot
                      and pid not in self._page_hash)
        return out

    # ----- device views ------------------------------------------------
    def device_tables(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return jnp.asarray(self.block_table), jnp.asarray(self.lengths)


class PagedKVCache:
    """Single-layer paged K/V storage over a ``PageAllocator``.

    Storage:  k/v  (n_pages, page_size, n_kv, head_dim)

    Writes are coalesced into per-page block updates: ``append`` accepts a
    run of T tokens and issues one device op per *touched page* (not per
    token), and ``write_prompt`` does the same for a whole prompt.
    """

    def __init__(self, *, n_pages: int, page_size: int, n_kv: int,
                 head_dim: int, n_slots: int, max_len: int,
                 dtype=jnp.float32):
        self.alloc = PageAllocator(n_pages=n_pages, page_size=page_size,
                                   n_slots=n_slots, max_len=max_len)
        self.page_size = page_size
        self.n_pages = n_pages
        self.max_pages = self.alloc.max_pages
        self.k = jnp.zeros((n_pages, page_size, n_kv, head_dim), dtype)
        self.v = jnp.zeros((n_pages, page_size, n_kv, head_dim), dtype)

    # ----- allocator passthrough ---------------------------------------
    @property
    def block_table(self) -> np.ndarray:
        return self.alloc.block_table

    @property
    def lengths(self) -> np.ndarray:
        return self.alloc.lengths

    def pages_in_use(self) -> int:
        return self.alloc.pages_in_use()

    def fragmentation(self) -> float:
        return self.alloc.fragmentation()

    def release(self, slot: int) -> None:
        self.alloc.release(slot)

    def device_tables(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return self.alloc.device_tables()

    # ----- writes ------------------------------------------------------
    def _write_run(self, slot: int, start: int, k: jnp.ndarray,
                   v: jnp.ndarray) -> None:
        """Write T contiguous tokens at positions [start, start+T) with one
        device update per touched page."""
        T = k.shape[0]
        ps = self.page_size
        t = 0
        while t < T:
            pos = start + t
            page = int(self.alloc.block_table[slot, pos // ps])
            off = pos % ps
            n = min(ps - off, T - t)
            self.k = self.k.at[page, off:off + n].set(
                k[t:t + n].astype(self.k.dtype))
            self.v = self.v.at[page, off:off + n].set(
                v[t:t + n].astype(self.v.dtype))
            t += n

    def append(self, slot: int, k_tok: jnp.ndarray, v_tok: jnp.ndarray) -> None:
        """Append K/V for one token (n_kv, head_dim) or a run of T tokens
        (T, n_kv, head_dim) to a slot; one device write per touched page.
        Writes landing in a shared page go through the allocator's
        copy-on-write gate first (the shared contents are duplicated onto
        the fresh page before the run lands)."""
        if k_tok.ndim == 2:
            k_tok, v_tok = k_tok[None], v_tok[None]
        pos = int(self.alloc.lengths[slot])
        cow = self.alloc.prepare_append(slot, pos)
        if cow is not None:
            src, dst = cow
            self.k = self.k.at[dst].set(self.k[src])
            self.v = self.v.at[dst].set(self.v[src])
        self.alloc.ensure_capacity(slot, pos + k_tok.shape[0])
        self._write_run(slot, pos, k_tok, v_tok)
        self.alloc.lengths[slot] = pos + k_tok.shape[0]

    def write_prompt(self, slot: int, k: jnp.ndarray, v: jnp.ndarray) -> None:
        """Bulk-write a prompt's K/V (T, n_kv, head_dim) after prefill."""
        T = k.shape[0]
        self.alloc.ensure_capacity(slot, T)
        self._write_run(slot, 0, k, v)
        self.alloc.lengths[slot] = T

    # ----- reads (reference; the Pallas kernel reads directly) ---------
    def gather(self, slot: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Materialize a slot's K/V (length, n_kv, head_dim) — test oracle."""
        L = int(self.alloc.lengths[slot])
        pages = self.alloc.block_table[slot][: self.alloc.pages_needed(L)]
        k = self.k[np.asarray(pages)].reshape(-1, *self.k.shape[2:])[:L]
        v = self.v[np.asarray(pages)].reshape(-1, *self.v.shape[2:])[:L]
        return k, v
