"""Block-table (paged) KV cache: the serving engine's decode storage.

TPU adaptation of vLLM's PagedAttention (DESIGN.md §3): GPU vLLM uses
16-token pages because CUDA gathers are cheap; on TPU, HBM->VMEM DMA wants
>=512B contiguous lanes, so pages are 128–256 tokens and the per-sequence
block table is small enough to sit in SMEM for the kernel's scalar prefetch.

Two layers:

``PageAllocator`` — the host-side control structure the engine drives:
  block_table (n_slots, max_pages) int32 page id (-1 = unmapped)
  lengths     (n_slots,) tokens written per slot
  free list   min-heap of page ids, so allocation is lowest-id-first and
              pop/push order is deterministic regardless of how request
              lifetimes interleave; per-slot page counts are tracked
              incrementally (no O(max_pages) scans on the hot path).

``PagedKVCache`` — a single-layer device page store (k/v as
(n_pages, page_size, n_kv, head_dim)) wrapping an allocator, with
coalesced per-page writes. The engine itself owns a layer-stacked page
store inside its decode program (models/model.py ``init_paged_cache``) and
uses the bare allocator; ``PagedKVCache`` remains the standalone storage
used by tests and as the ``gather()`` oracle the Pallas kernel is verified
against.

Memory scales with *live tokens*, not n_slots x max_len — the entire point
of paging, and the lever the engine's directive-aware page-budget admission
(serving/engine.py) uses to fit more concurrent requests per fixed HBM.
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np


class PageAllocator:
    """Host-side block-table allocator: deterministic, O(1) bookkeeping."""

    def __init__(self, *, n_pages: int, page_size: int, n_slots: int,
                 max_len: int):
        assert page_size % 8 == 0, "page_size should be lane-aligned"
        self.page_size = page_size
        self.n_pages = n_pages
        self.n_slots = n_slots
        self.max_pages = (max_len + page_size - 1) // page_size
        self.block_table = np.full((n_slots, self.max_pages), -1, np.int32)
        self.lengths = np.zeros(n_slots, np.int32)
        # min-heap => allocation is always the lowest-numbered free page and
        # therefore a pure function of the alloc/release history, never of
        # list-order accidents (reuse order used to depend on interleaving)
        self._free: List[int] = list(range(n_pages))
        heapq.heapify(self._free)
        # incremental per-slot page counts: the append hot path must not
        # rescan the block table per token
        self._slot_pages = np.zeros(n_slots, np.int32)

    # ----- queries -----------------------------------------------------
    def pages_in_use(self) -> int:
        return self.n_pages - len(self._free)

    def free_pages(self) -> int:
        return len(self._free)

    def live_tokens(self) -> int:
        return int(self.lengths.sum())

    def pages_needed(self, n_tokens: int) -> int:
        return (n_tokens + self.page_size - 1) // self.page_size

    def fragmentation(self) -> float:
        """Internal fragmentation: the fraction of allocated page capacity
        not holding a live token (partially filled tail pages)."""
        used = self.pages_in_use() * self.page_size
        return 1.0 - self.live_tokens() / used if used else 0.0

    def report(self) -> Dict[str, float]:
        """Telemetry snapshot the engine exports (serving/engine.py
        ``kv_stats``)."""
        return {
            "n_pages": self.n_pages,
            "page_size": self.page_size,
            "pages_in_use": self.pages_in_use(),
            "live_tokens": self.live_tokens(),
            "occupancy": self.pages_in_use() / max(self.n_pages, 1),
            "fragmentation": round(self.fragmentation(), 6),
        }

    # ----- allocation --------------------------------------------------
    def ensure_capacity(self, slot: int, new_len: int) -> int:
        """Map enough pages for ``new_len`` tokens in ``slot``. Returns the
        number of pages newly mapped by this call (0 when already covered)
        so callers — e.g. the engine's per-chunk page growth — can account
        for incremental allocation."""
        need = self.pages_needed(new_len)
        if need > self.max_pages:
            raise MemoryError(
                f"slot needs {need} pages > max_len capacity {self.max_pages}")
        have = int(self._slot_pages[slot])
        if need > have and need - have > len(self._free):
            raise MemoryError(
                f"paged KV cache exhausted: need {need - have} pages, "
                f"{len(self._free)} free of {self.n_pages}")
        grown = max(0, need - have)
        while have < need:
            self.block_table[slot, have] = heapq.heappop(self._free)
            have += 1
        self._slot_pages[slot] = have
        return grown

    def release(self, slot: int) -> None:
        """Unmap a slot. Pages re-enter the free heap, so the next
        allocation is again the lowest free id — deterministic reuse."""
        for j in range(int(self._slot_pages[slot])):
            heapq.heappush(self._free, int(self.block_table[slot, j]))
            self.block_table[slot, j] = -1
        self._slot_pages[slot] = 0
        self.lengths[slot] = 0

    # ----- device views ------------------------------------------------
    def device_tables(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return jnp.asarray(self.block_table), jnp.asarray(self.lengths)


class PagedKVCache:
    """Single-layer paged K/V storage over a ``PageAllocator``.

    Storage:  k/v  (n_pages, page_size, n_kv, head_dim)

    Writes are coalesced into per-page block updates: ``append`` accepts a
    run of T tokens and issues one device op per *touched page* (not per
    token), and ``write_prompt`` does the same for a whole prompt.
    """

    def __init__(self, *, n_pages: int, page_size: int, n_kv: int,
                 head_dim: int, n_slots: int, max_len: int,
                 dtype=jnp.float32):
        self.alloc = PageAllocator(n_pages=n_pages, page_size=page_size,
                                   n_slots=n_slots, max_len=max_len)
        self.page_size = page_size
        self.n_pages = n_pages
        self.max_pages = self.alloc.max_pages
        self.k = jnp.zeros((n_pages, page_size, n_kv, head_dim), dtype)
        self.v = jnp.zeros((n_pages, page_size, n_kv, head_dim), dtype)

    # ----- allocator passthrough ---------------------------------------
    @property
    def block_table(self) -> np.ndarray:
        return self.alloc.block_table

    @property
    def lengths(self) -> np.ndarray:
        return self.alloc.lengths

    def pages_in_use(self) -> int:
        return self.alloc.pages_in_use()

    def fragmentation(self) -> float:
        return self.alloc.fragmentation()

    def release(self, slot: int) -> None:
        self.alloc.release(slot)

    def device_tables(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return self.alloc.device_tables()

    # ----- writes ------------------------------------------------------
    def _write_run(self, slot: int, start: int, k: jnp.ndarray,
                   v: jnp.ndarray) -> None:
        """Write T contiguous tokens at positions [start, start+T) with one
        device update per touched page."""
        T = k.shape[0]
        ps = self.page_size
        t = 0
        while t < T:
            pos = start + t
            page = int(self.alloc.block_table[slot, pos // ps])
            off = pos % ps
            n = min(ps - off, T - t)
            self.k = self.k.at[page, off:off + n].set(
                k[t:t + n].astype(self.k.dtype))
            self.v = self.v.at[page, off:off + n].set(
                v[t:t + n].astype(self.v.dtype))
            t += n

    def append(self, slot: int, k_tok: jnp.ndarray, v_tok: jnp.ndarray) -> None:
        """Append K/V for one token (n_kv, head_dim) or a run of T tokens
        (T, n_kv, head_dim) to a slot; one device write per touched page."""
        if k_tok.ndim == 2:
            k_tok, v_tok = k_tok[None], v_tok[None]
        pos = int(self.alloc.lengths[slot])
        self.alloc.ensure_capacity(slot, pos + k_tok.shape[0])
        self._write_run(slot, pos, k_tok, v_tok)
        self.alloc.lengths[slot] = pos + k_tok.shape[0]

    def write_prompt(self, slot: int, k: jnp.ndarray, v: jnp.ndarray) -> None:
        """Bulk-write a prompt's K/V (T, n_kv, head_dim) after prefill."""
        T = k.shape[0]
        self.alloc.ensure_capacity(slot, T)
        self._write_run(slot, 0, k, v)
        self.alloc.lengths[slot] = T

    # ----- reads (reference; the Pallas kernel reads directly) ---------
    def gather(self, slot: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Materialize a slot's K/V (length, n_kv, head_dim) — test oracle."""
        L = int(self.alloc.lengths[slot])
        pages = self.alloc.block_table[slot][: self.alloc.pages_needed(L)]
        k = self.k[np.asarray(pages)].reshape(-1, *self.k.shape[2:])[:L]
        v = self.v[np.asarray(pages)].reshape(-1, *self.v.shape[2:])[:L]
        return k, v
