"""Scripted chaos scenario for the serving stack (DESIGN.md §12).

One deterministic four-hour storyline, shared by ``tests/test_faults.py``
and ``scripts/chaos.sh`` (via ``python -m repro.serving.chaos``):

  hour 0  fault-free warmup, drained — profiles warm past the LP's
          warmup gate (>=5 finishes per level) so later plans SOLVE;
  hour 1  fault-free, cut off after 2 fleet steps — the carried-over
          backlog is what hour 2's migration pass and lane poisons bite;
  hour 2  the injector ARMS and every fault class fires inside one
          ``run_hour``: the grid feed NaNs, stales and raises; the LP
          solve fails (plan-hold); a replica crashes mid-block; live
          lanes are KV-poisoned (caught by the in-scan finiteness
          verdict); a migration's destination fleet vanishes between
          evict and submit;
  hour 3  aftermath: the decayed fault score holds brownout, so batch
          admissions shed while premium/standard still serve under
          clamped-but-floor-respecting mixes.

Everything observable is a pure function of the fault plan + seeds: no
wall-clock feeds routing (tenant specs carry no latency targets, the
straggler detector is disabled), energy is token-count-derived, and
sampling is greedy — so two runs byte-diff equal under any
PYTHONHASHSEED, and a paired fault-free control run pins down what the
chaos run must still produce: the same greedy tokens per request, a
conserved carbon ledger, zero stranded work.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Dict, List, Optional, Tuple


from repro.core.carbon import CarbonIntensityProvider, WatchdogProvider
from repro.core.lp import TenantSpec
from repro.core.workload import N_LEVELS
from repro.serving.faults import FaultInjector, FaultPlan, FaultSpec, POINTS
from repro.serving.gateway import MigrationPlanner, SproutGateway
from repro.serving.scheduler import CarbonAwareScheduler, ServeRequest

# deadline-free tenant classes: latency targets would route on measured
# wall-clock decode seconds, which no two runs share — the chaos contract
# is bit-reproducibility, so only priorities and quality floors remain
CHAOS_TENANTS = (
    TenantSpec("premium", xi=0.03, q_floor_frac=0.97, priority=0),
    TenantSpec("standard", xi=0.12, q_floor_frac=0.80, priority=1),
    TenantSpec("batch", xi=0.35, priority=2),
)

RETRY_BUDGET = 3
ARMED_HOUR = 2


def _twin_provider(scale: float = 0.95) -> Tuple[CarbonIntensityProvider,
                                                 CarbonIntensityProvider]:
    """Two pools on near-identical grids: pool B's trace is pool A's
    scaled by ``scale``. The 5% differential is enough for the migration
    planner (hysteresis 0) to move backlog — giving migrate.dst_vanish
    a genuine attempt to sabotage — while keeping the served-carbon
    ledger comparable to the control run within a tight tolerance."""
    a = CarbonIntensityProvider("TX", "jun")
    b = CarbonIntensityProvider("TX", "jun")
    b.trace = b.trace * scale
    b.region = dataclasses.replace(b.region, key="TX2")
    return a, b


def default_plan() -> FaultPlan:
    """All seven injection points, occurrence-scripted relative to the
    arming step (hour 2's tick is the first armed opportunity)."""
    return FaultPlan([
        # hour-2 replan, pool TX: 1st fetch NaNs, 2nd re-serves stale
        FaultSpec("carbon.nan", "TX", occurrences=(0,)),
        FaultSpec("carbon.stale", "TX", occurrences=(1,)),
        # pool TX2's first fetch raises (transport timeout / 5xx)
        FaultSpec("carbon.exception", "TX2", occurrences=(0,)),
        # pool TX's LP solve sees non-finite carbon terms -> plan-hold
        FaultSpec("lp.fail", "TX", occurrences=(0,)),
        # replica 0 of pool TX dies on its 2nd armed step (work in flight)
        FaultSpec("replica.crash", "TX/0", occurrences=(1,)),
        # the 1st and 9th occupied lane consulted anywhere get KV-poisoned
        FaultSpec("decode.nonfinite", "*", occurrences=(0, 8)),
        # the first migration attempt's destination fleet vanishes
        FaultSpec("migrate.dst_vanish", "*", occurrences=(0,)),
    ])


def chaos_requests(hour: int, n: int) -> List[ServeRequest]:
    """Pre-rendered, fixed-level, greedy requests: the directive level is
    part of the request (no RNG draw at dispatch), so a retried request
    re-decodes the exact same prompt at the exact same level."""
    out = []
    for i in range(n):
        out.append(ServeRequest(
            0, f"chaos h{hour} i{i:02d}",
            max_new_tokens=4 + (i % 4),
            pre_rendered=True, directive_level=i % N_LEVELS,
            tenant=CHAOS_TENANTS[i % len(CHAOS_TENANTS)].name))
    return out


def build_model():
    """The reduced model the scenario serves (shared by tests/bench)."""
    import jax
    from repro.configs import reduced
    from repro.models import model as MD
    cfg = reduced("granite_3_2b").replace(vocab_size=512)
    params = MD.init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _build_gateway(cfg, params,
                   injector: Optional[FaultInjector]) -> SproutGateway:
    from repro.serving.engine import InferenceEngine
    prov_a, prov_b = _twin_provider()
    wd_a = WatchdogProvider(prov_a, max_stale_h=0.5, fault_injector=injector)
    wd_b = WatchdogProvider(prov_b, max_stale_h=0.5, fault_injector=injector)
    mk = lambda seed: InferenceEngine(cfg, params, n_slots=2, max_len=64,
                                      seed=seed)
    sched_kw = dict(straggler_factor=1e9, retry_budget=RETRY_BUDGET,
                    backoff_base_steps=1, probation_steps=4, clean_window=8)
    sched_a = CarbonAwareScheduler([mk(0)], **sched_kw)
    sched_b = CarbonAwareScheduler([mk(1)], **sched_kw)
    return SproutGateway(
        [(wd_a, sched_a), (wd_b, sched_b)],
        tenants=list(CHAOS_TENANTS), n_levels=N_LEVELS,
        replan_every=1.0, load_cap=4,
        migration=MigrationPlanner(hysteresis=0.0, cooldown_h=0.0,
                                   slo_margin=1.0),
        seed=7, fault_injector=injector,
        max_plan_holds=2, brownout_threshold=1.5, brownout_decay=0.5)


def _schedule() -> List[Tuple[List[ServeRequest], Optional[int]]]:
    return [
        (chaos_requests(0, 18), None),   # warmup, drained
        (chaos_requests(1, 10), 2),      # 2 steps only: backlog carries
        (chaos_requests(2, 12), None),   # the chaos hour (injector arms)
        (chaos_requests(3, 9), None),    # brownout aftermath
    ]


def run_scenario(cfg, params, *, plan: Optional[FaultPlan] = None,
                 seed: int = 0) -> Dict:
    """One full scenario run; ``plan=None`` is the fault-free control.
    Returns a JSON-serializable report of every deterministic observable."""
    inj = FaultInjector(plan, seed=seed) if plan is not None else None
    gw = _build_gateway(cfg, params, inj)
    if inj is not None:
        inj.armed = False
    order: List[int] = []            # submission index -> rid (0 = shed)
    tenants: List[str] = []
    orig_submit = gw.submit
    def recording_submit(req):
        tenants.append(req.tenant
                       or CHAOS_TENANTS[len(order) % 3].name)
        rid, key = orig_submit(req)
        order.append(rid)
        return rid, key
    gw.submit = recording_submit
    fins: Dict[int, object] = {}
    gw.on_finish = lambda _key, fin: fins.__setitem__(fin.rid, fin)

    hour_rows = []
    for h, (reqs, steps) in enumerate(_schedule()):
        if inj is not None and h == ARMED_HOUR:
            inj.armed = True
        row = gw.run_hour(float(h), reqs, steps=steps)
        hour_rows.append({
            "t": h, "routes": dict(sorted(row["routes"].items())),
            "served": row["served"], "faults": row["faults"],
            "shed": row["shed"], "brownout": bool(row["brownout"]),
            "wasted_g": round(row["wasted_g"], 9),
        })

    rejected = dict(gw.stats.rejected_reasons)
    carbon_by_rid: Dict[int, float] = {}
    for tr in gw.stats.telemetry:
        carbon_by_rid[tr.rid] = carbon_by_rid.get(tr.rid, 0.0) + tr.carbon_g
    requests = []
    for i, rid in enumerate(order):
        fin = fins.get(rid)
        if rid == 0:
            status, tokens, retries = "shed", [], 0
        elif fin is not None:
            status = "served"
            tokens = [int(t) for t in fin.token_ids]
            retries = int(fin.retries)
        elif rid in rejected:
            status, tokens, retries = "rejected", [], -1
        else:
            status, tokens, retries = "stranded", [], -1
        requests.append({"i": i, "tenant": tenants[i], "status": status,
                         "tokens": tokens, "retries": retries,
                         "carbon_g": round(carbon_by_rid.get(rid, 0.0), 9)})

    st = gw.stats
    report = {
        "requests": requests,
        "hours": hour_rows,
        "ledger": {
            "carbon_g": round(st.carbon_g, 9),
            "wasted_g": round(st.wasted_g, 9),
            "carbon_by_pool": {k: round(v, 9) for k, v
                               in sorted(st.carbon_by_pool.items())},
            "wasted_by_pool": {k: round(v, 9) for k, v
                               in sorted(st.wasted_by_pool.items())},
        },
        "served": st.requests,
        "faults": st.faults,
        "shed": st.shed,
        "plan_holds": st.plan_holds,
        "rejected": sorted(rejected.items()),
        "plans": [[p.pool, p.tenant, p.solver, bool(p.degraded)]
                  for p in st.plans],
        "watchdog": {p.key: dict(p.provider.faults) for p in gw.pools},
        "injected": ([[e.point, e.target, e.occurrence]
                      for e in inj.events] if inj is not None else []),
        "residual_load": int(sum(p.load() for p in gw.pools)),
    }
    return report


def check_pair(control: Dict, chaos: Dict,
               ledger_rtol: float = 0.10) -> Dict[str, bool]:
    """The chaos contract, as named booleans (all must hold)."""
    by_i = lambda rep: {r["i"]: r for r in rep["requests"]}
    ctl, cha = by_i(control), by_i(chaos)
    common = [i for i in ctl if ctl[i]["status"] == "served"
              and cha[i]["status"] == "served"]
    retried = [i for i in common if cha[i]["retries"] > 0]
    # served-side carbon must track the control's over the SAME request
    # set (brownout sheds some the control serves); within that set chaos
    # may serve a request in the sister pool (5% intensity skew) or a
    # different hour, hence the tolerance
    ctl_carbon = sum(ctl[i]["carbon_g"] for i in common)
    cha_served = sum(cha[i]["carbon_g"] for i in common)
    pool_sum = (sum(chaos["ledger"]["carbon_by_pool"].values())
                + sum(chaos["ledger"]["wasted_by_pool"].values()))
    return {
        "zero_stranded": (
            not any(r["status"] == "stranded" for r in chaos["requests"])
            and chaos["residual_load"] == 0),
        "all_points_fired": (
            {e[0] for e in chaos["injected"]} == set(POINTS)),
        "outputs_bit_identical": all(
            cha[i]["tokens"] == ctl[i]["tokens"] for i in common),
        "retried_requests_recovered": (
            len(retried) > 0
            and all(cha[i]["tokens"] == ctl[i]["tokens"] for i in retried)),
        "retries_bounded": all(
            r["retries"] <= RETRY_BUDGET for r in chaos["requests"]
            if r["status"] == "served"),
        "ledger_internally_conserved": (
            abs(chaos["ledger"]["carbon_g"] - pool_sum)
            <= 1e-8 + 1e-6 * chaos["ledger"]["carbon_g"]),
        "ledger_tracks_control": (
            abs(cha_served - ctl_carbon) <= ledger_rtol * ctl_carbon),
        "waste_accounted": chaos["ledger"]["wasted_g"] > 0,
        "plan_held": chaos["plan_holds"] >= 1,
        "degraded_plan_recorded": any(p[3] for p in chaos["plans"]),
        "brownout_shed_batch_only": (
            chaos["shed"] > 0
            and all(r["tenant"] == "batch" for r in chaos["requests"]
                    if r["status"] == "shed")),
        "control_untouched": (
            control["faults"] == 0 and control["shed"] == 0
            and control["plan_holds"] == 0
            and not any(r["status"] != "served"
                        for r in control["requests"])),
    }


def digest(control: Dict, chaos: Dict) -> str:
    """Canonical hash of both reports — byte-equal across interpreter
    runs and PYTHONHASHSEEDs, the value scripts/chaos.sh diffs."""
    blob = json.dumps({"control": control, "chaos": chaos},
                      sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


def run_chaos(cfg=None, params=None, seed: int = 0) -> Dict:
    """Paired control + chaos runs, the checks, and the digest."""
    if cfg is None or params is None:
        cfg, params = build_model()
    control = run_scenario(cfg, params, plan=None, seed=seed)
    chaos = run_scenario(cfg, params, plan=default_plan(), seed=seed)
    checks = check_pair(control, chaos)
    return {"control": control, "chaos": chaos, "checks": checks,
            "ok": all(checks.values()),
            "digest": digest(control, chaos)}


def main() -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true",
                    help="dump the full paired reports, not the summary")
    args = ap.parse_args()
    out = run_chaos(seed=args.seed)
    if args.full:
        print(json.dumps(out, indent=2, sort_keys=True))
    else:
        summary = {
            "digest": out["digest"], "ok": out["ok"],
            "checks": out["checks"],
            "chaos": {k: out["chaos"][k] for k in
                      ("served", "faults", "shed", "plan_holds")},
            "injected": out["chaos"]["injected"],
        }
        print(json.dumps(summary, indent=2, sort_keys=True))
    return 0 if out["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
