"""Token sampling: greedy / temperature / top-k / top-p, jit-friendly.

Entry points sharing the same masking math:

* ``sample_logits``          — one ``SamplingParams`` for the whole batch
                               (Python-level branching; fine outside jit).
* ``sample_logits_batched``  — per-row parameter *arrays* (temperature,
                               top_k, top_p), fully traceable: greedy and
                               sampled rows coexist in one batch with no
                               Python fallback. Row ``i`` draws with
                               ``fold_in(key, i)`` so a batched call is
                               token-for-token identical to a per-row loop
                               that folds the same row index (the property
                               the sampler equivalence tests pin down).
* ``greedy_sample``          — argmax with the batched calling convention,
                               for jitted decode loops whose batch is known
                               host-side to be all-greedy (XLA sort on CPU
                               is ~10x the cost of the tiny decode step, so
                               the engine compiles a sampler-free variant).

``sample_logits_batched`` performs exactly one sort per call: the top-k
threshold and the top-p nucleus cutoff are both read off the same
descending-sorted copy of the scaled logits (masking entries below the
top-k threshold *in sorted order* is identical to re-sorting the masked
row, so the reference two-pass formulation is preserved bit-for-bit).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0   # 0 => greedy
    top_k: int = 0             # 0 => off
    top_p: float = 1.0         # 1 => off


def _mask_threshold(scaled, top_k, top_p):
    """Per-row mask threshold from one descending sort of ``scaled``.

    Returns (B, 1) threshold: entries with ``scaled < threshold`` leave the
    candidate set. Rows with top_k == 0 / top_p == 1 contribute -inf (off).
    """
    V = scaled.shape[-1]
    sorted_desc = jnp.sort(scaled, axis=-1)[:, ::-1]
    kth = jnp.take_along_axis(
        sorted_desc, jnp.clip(top_k - 1, 0, V - 1)[:, None], axis=-1)
    k_thresh = jnp.where(top_k[:, None] > 0, kth, -jnp.inf)
    # top-p runs on the top-k-masked distribution; in sorted order that is
    # just "entries below the kth value become -inf" (order is unchanged)
    sorted_masked = jnp.where(sorted_desc < k_thresh, -jnp.inf, sorted_desc)
    probs = jax.nn.softmax(sorted_masked, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # smallest set with cumulative prob >= top_p; clamp so top_p <= 0
    # collapses to the single top token instead of wrapping to index -1
    # (= smallest logit = no masking at all)
    keep_sorted = cum - probs < top_p[:, None]
    cutoff_idx = jnp.maximum(jnp.sum(keep_sorted, axis=-1) - 1, 0)
    cutoff = jnp.take_along_axis(sorted_masked, cutoff_idx[:, None], axis=-1)
    p_thresh = jnp.where(top_p[:, None] < 1.0, cutoff, -jnp.inf)
    return jnp.maximum(k_thresh, p_thresh)


def _row_keys(key, B, fold_ids):
    """Per-row PRNG keys. ``fold_ids`` (B,) int32 overrides the fold index
    so a bucketed sub-batch folds by *slot id* rather than lane position —
    tokens are then invariant to which compiled bucket served the row."""
    ids = jnp.arange(B) if fold_ids is None else fold_ids
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(ids)


def sample_logits_batched(logits, key, temperature, top_k, top_p,
                          fold_ids=None):
    """Per-row sampling. logits (B,V); temperature/top_k/top_p (B,) arrays.

    Rows with temperature <= 0 are argmax; the rest are categorical draws
    over temperature-scaled, top-k- then top-p-masked logits. Row ``i``
    uses ``jax.random.fold_in(key, i)`` (or ``fold_ids[i]`` when given) so
    the draw for a row does not depend on batch composition. Returns (B,)
    int32.
    """
    B = logits.shape[0]
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits.astype(jnp.float32) / jnp.maximum(temperature, 1e-6)[:, None]
    thresh = _mask_threshold(scaled, top_k, top_p)
    masked = jnp.where(scaled < thresh, -jnp.inf, scaled)
    row_keys = _row_keys(key, B, fold_ids)
    drawn = jax.vmap(
        lambda k, l: jax.random.categorical(k, l, axis=-1))(row_keys, masked)
    return jnp.where(temperature > 0.0, drawn.astype(jnp.int32), greedy)


def greedy_sample(logits, key, *unused, fold_ids=None):
    """Argmax with the (logits, key, *params) batched signature."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample_temperature_only(logits, key, temperature, top_k, top_p,
                            fold_ids=None):
    """`sample_logits_batched` minus the sort-based threshold, for jitted
    loops whose batch is known host-side to use no top-k/top-p. Draws are
    bit-identical to the full path in that case (the threshold there is
    -inf and masks nothing), without paying the per-step vocab sort."""
    B = logits.shape[0]
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits.astype(jnp.float32) / jnp.maximum(temperature, 1e-6)[:, None]
    row_keys = _row_keys(key, B, fold_ids)
    drawn = jax.vmap(
        lambda k, l: jax.random.categorical(k, l, axis=-1))(row_keys, scaled)
    return jnp.where(temperature > 0.0, drawn.astype(jnp.int32), greedy)


def sample_logits(logits, key, params: SamplingParams):
    """logits: (B, V) -> (B,) int32 tokens. One SamplingParams per batch."""
    if params.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / params.temperature
    if params.top_k > 0 or params.top_p < 1.0:
        B = logits.shape[0]
        thresh = _mask_threshold(
            logits,
            jnp.full((B,), params.top_k, jnp.int32),
            jnp.full((B,), params.top_p, jnp.float32))
        logits = jnp.where(logits < thresh, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
