"""Serving substrate: tokenizer, sampler, slot-based continuous batching
engine (JetStream-style — the TPU-native adaptation of vLLM's continuous
batching), block-table KV paging for the Pallas decode kernel, the
carbon-aware scheduler that wires SPROUT's directive selector into the
request path, and the SproutGateway that closes the control loop between
the LP optimizer and one or more regional scheduler pools.
"""
from repro.serving.tokenizer import ByteTokenizer
from repro.serving.sampler import (sample_logits, sample_logits_batched,
                                   SamplingParams)
from repro.serving.kv_cache import PageAllocator, PagedKVCache
from repro.serving.engine import InferenceEngine, RequestState, FinishedRequest
from repro.serving.faults import (FaultEvent, FaultInjector, FaultPlan,
                                  FaultSpec, no_faults)
from repro.serving.scheduler import (CarbonAwareScheduler, ReplicaHealth,
                                     ServeRequest)
from repro.serving.gateway import (GatewayPool, GatewayStats,
                                   MigrationPlanner, MigrationRecord,
                                   SproutGateway, serve_request_from)

__all__ = ["ByteTokenizer", "sample_logits", "sample_logits_batched",
           "SamplingParams", "PageAllocator", "PagedKVCache",
           "InferenceEngine", "RequestState", "FinishedRequest",
           "FaultEvent", "FaultInjector", "FaultPlan", "FaultSpec",
           "no_faults", "CarbonAwareScheduler", "ReplicaHealth",
           "ServeRequest", "GatewayPool", "GatewayStats",
           "MigrationPlanner", "MigrationRecord", "SproutGateway",
           "serve_request_from"]
