"""Serving substrate: tokenizer, sampler, slot-based continuous batching
engine (JetStream-style — the TPU-native adaptation of vLLM's continuous
batching), block-table KV paging for the Pallas decode kernel, and the
carbon-aware scheduler that wires SPROUT's directive selector into the
request path.
"""
from repro.serving.tokenizer import ByteTokenizer
from repro.serving.sampler import (sample_logits, sample_logits_batched,
                                   SamplingParams)
from repro.serving.engine import InferenceEngine, RequestState, FinishedRequest
from repro.serving.scheduler import CarbonAwareScheduler, ServeRequest

__all__ = ["ByteTokenizer", "sample_logits", "sample_logits_batched",
           "SamplingParams", "InferenceEngine", "RequestState",
           "FinishedRequest", "CarbonAwareScheduler", "ServeRequest"]
