"""SproutGateway: the live control loop between the LP optimizer and the
serving fleet (Fig. 5, closed for real engines).

Until now the repo had two halves that never talked: a paper-faithful
control plane (``core/``) exercised only in simulation, and a device-
resident serving engine (``serving/``) whose ``CarbonAwareScheduler`` drew
directive levels from a static ``level_fn``. The gateway is the missing
component 1 of Fig. 5 — it owns

* one or more regional pools, each a ``CarbonIntensityProvider`` plus a
  ``CarbonAwareScheduler`` over real ``InferenceEngine`` replicas;
* a mix-exposing ``core.policies.Policy`` — ``SproutPolicy``,
  ``SproutStaticPolicy``, or anything whose ``begin_hour`` maintains a
  directive-level distribution ``.x`` — and ONE shared ``LevelProfiles``
  (per-level energy/time are properties of the model, not of the region);

and closes the loop in both directions:

  plan:      every ``replan_every`` simulated hours, each pool's current
             carbon intensity feeds ``policy.begin_hour`` (the Eq. 2-7 LP)
             and the resulting mix x is installed as that pool's scheduler
             ``level_fn`` — the LP is now literally in the request path;
  feedback:  every finished request's ENGINE-MEASURED telemetry (prompt /
             generated token counts and per-request decode-only seconds,
             ``FinishedRequest.decode_s``) is converted to (kWh, s) by
             ``EnergyModel.measure`` and fed to ``LevelProfiles.update``
             plus Eq. 1 carbon accounting via ``request_carbon`` — so the
             next re-plan optimizes over what the fleet actually did.

Multi-region routing (the new scenario axis): ``submit`` sends each
request to the greenest pool whose in-flight load is under ``load_cap``;
when every pool is saturated it falls back to the least-loaded one, so
carbon-chasing never starves throughput.

Two levers track the grid WITHIN the hour (DESIGN.md §8):

  forecast:  with ``forecast_horizon > 0`` each re-plan solves the LP at
             the forecast-weighted effective intensity over the next
             ``forecast_horizon`` hours (``provider.forecast`` +
             ``core.lp.forecast_weighted_intensity``) instead of the
             instantaneous value, so a pool facing a dirty hour shifts
             its directive mix pre-emptively;
  migration: a ``MigrationPlanner`` runs at every re-plan tick and moves
             queued / rejected / preempted work from dirty pools to green
             ones over the SAME verbatim-token requeue path failover uses
             (scheduler.evict -> submit), evicting decode-in-flight
             requests only when the redo economics clear a hysteresis
             band — admission chose a pool once; migration lets the
             choice follow the grid.

SLOs (DESIGN.md §10) make the quality/carbon trade per tenant and per
deadline. With ``tenants=[TenantSpec, ...]`` the gateway solves ONE LP
per (pool, tenant class) — each class carries its own Eq. 3 relaxation,
an absolute quality floor, and TTFT/TPOT latency targets — and installs
a composite per-request ``level_fn`` that draws each request's directive
level from its tenant's mix. Admission then routes on *predicted
completion time* (queue depth × measured per-level decode seconds from
``LevelProfiles`` telemetry) jointly with the planning intensity: the
greenest pool wins only while its queue would not bust the request's
deadline, so a dirty-but-idle pool beats a green-but-queued one for
latency-sensitive work. The ``MigrationPlanner`` prices SLO risk (a
request within its migration-redo time of its deadline never moves) and
``drain_pool`` migrates a pool's whole backlog ahead of maintenance over
the same verbatim-token requeue path.

``policy=None`` (and ``tenants=None``) degenerates to an L0-only gateway
(the BASE scheme over the same fleet) — the paired baseline
``benchmarks/serving_bench.py`` measures against.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.carbon import PUE, CarbonIntensityProvider, request_carbon
from repro.core.energy import A100_40GB, LLAMA2_13B, EnergyModel, \
    HardwareSpec, ModelProfile
from repro.core.lp import TenantSpec, forecast_weighted_intensity, \
    solve_tenant_lps
from repro.core.policies import LevelProfiles, Policy
from repro.core.workload import N_LEVELS, Request
from repro.serving.engine import FinishedRequest
from repro.serving.faults import FaultInjector, no_faults
from repro.serving.scheduler import CarbonAwareScheduler, ServeRequest


@dataclasses.dataclass
class GatewayPool:
    """One regional serving pool: its grid signal, its fleet, its plan."""
    key: str
    provider: CarbonIntensityProvider
    scheduler: CarbonAwareScheduler
    x: np.ndarray                      # installed directive mix (aggregate)
    routed: int = 0                    # requests routed here
    # per-tenant-class mixes from the (pool, tenant) LP solves; the
    # composite level_fn draws each request's level from its class's mix
    x_by_tenant: Dict[str, np.ndarray] = dataclasses.field(
        default_factory=dict)

    def load(self, max_priority: Optional[int] = None) -> int:
        """In-flight work: scheduler backlog + engine queues + live slots.

        With ``max_priority``, scheduler backlog at a worse priority is
        excluded: scheduler dispatch is priority-ordered, so a premium
        request jumps the batch work still PENDING at the scheduler.
        Work already inside an engine is counted in full regardless of
        priority — engine queues admit FIFO and occupied slots cannot be
        jumped — so the filtered count stays an honest wait estimate,
        never an optimistic one. This is the queue-depth that
        predicted-completion routing multiplies by."""
        in_engines = sum(eng.load() for eng in self.scheduler.engines
                         if eng is not None)
        if max_priority is None:
            return len(self.scheduler.pending) + in_engines
        return in_engines + sum(1 for r in self.scheduler.pending
                                if r.priority <= max_priority)

    def slot_count(self) -> int:
        """Decode parallelism: total slots across the pool's live engines
        — the divisor that turns queue depth into service waves."""
        return sum(eng.n_slots for eng in self.scheduler.engines
                   if eng is not None)

    def tp_degree(self) -> int:
        """Tensor-parallel width of the pool's fleet: the max sharding
        degree over live engines (DESIGN.md §14). A pool is a sharded
        fleet, not N independent replicas — the gateway prices its carbon
        with ``EnergyModel.with_chips(tp_degree())`` so the LP mix and
        migration economics see multi-chip energy. Defaults to 1 for
        engines predating the ``tp_degree`` attribute (test doubles)."""
        sched_tp = getattr(self.scheduler, "tp_degree", None)
        if callable(sched_tp):
            return sched_tp()
        return max((getattr(eng, "tp_degree", 1)
                    for eng in self.scheduler.engines if eng is not None),
                   default=1)

    def chunked_fraction(self) -> float:
        """Fraction of the pool's slots served by engines with chunked
        (continuous-batching) admission. 1.0 means an arrival never waits
        for a slot-epoch boundary: its prefill interleaves into the live
        decode scan; 0.0 is the whole-prompt-stall world."""
        slots = self.slot_count()
        if slots == 0:
            return 0.0
        chunked = sum(eng.n_slots for eng in self.scheduler.engines
                      if eng is not None
                      and getattr(eng, "chunked_admission", False))
        return chunked / slots

    def kv_stats(self) -> Dict[str, float]:
        """Fleet KV-memory telemetry: allocator occupancy/fragmentation
        summed over the pool's live engines (engine.kv_stats)."""
        stats = [eng.kv_stats() for eng in self.scheduler.engines
                 if eng is not None]
        if not stats:
            return {"engines": 0}
        layouts = {s.get("layout", "dense") for s in stats}
        out: Dict[str, float] = {
            "engines": len(stats),
            "layout": layouts.pop() if len(layouts) == 1 else "mixed",
        }
        # .get defaults: a pool may mix paged and dense replicas (elastic
        # scale-up can add either), and their stat schemas differ
        for key in ("pages_in_use", "live_tokens", "kv_bytes_in_use",
                    "kv_bytes_capacity", "committed_pages",
                    "prefill_tokens_computed", "prefill_tokens_cached"):
            if any(key in s for s in stats):
                out[key] = sum(s.get(key, 0) for s in stats)
        for key in ("occupancy", "fragmentation"):
            out[key] = float(np.mean([s.get(key, 0.0) for s in stats]))
        return out


@dataclasses.dataclass
class PlanRecord:
    """One LP re-plan: what the optimizer saw and what it installed.
    ``k0`` is the PLANNING intensity (forecast-weighted when a horizon is
    set); ``k0_now`` keeps the instantaneous value for comparison."""
    t: float
    pool: str
    k0: float
    x: np.ndarray
    q_lb: float = 0.0
    expected_quality: float = 0.0
    solver: str = "warmup"
    k0_now: float = 0.0
    horizon_h: float = 0.0
    tenant: str = ""           # "" = the aggregate (tenant-less) plan
    # degraded-mode plan (DESIGN.md §12): the LP solve failed (solver is
    # "hold" — last-good mix held — or "static-safe" after N consecutive
    # holds) or the pool's carbon signal watchdog is past its staleness
    # bound; consumers treat the mix as a fallback, not a fresh optimum
    degraded: bool = False


@dataclasses.dataclass
class MigrationRecord:
    """One cross-pool move the MigrationPlanner executed."""
    t: float
    rid: int
    src: str
    dst: str
    kind: str                  # pending | rejected | queued | decoding
    level: int                 # -1 when the level is not yet drawn
    est_saving_g: float        # planner's estimate, not realized carbon
    trigger: str = "carbon"    # carbon (greener grid) | drain (maintenance)


@dataclasses.dataclass
class TelemetryRecord:
    """One finished request as the control plane saw it."""
    pool: str
    rid: int
    level: int
    prompt_tokens: int
    gen_tokens: int
    decode_s: float
    energy_kwh: float                  # incl. PUE
    carbon_g: float
    k0: float
    tenant: str = ""
    latency_s: float = 0.0             # end-to-end (incl. any migration redo)
    slo_met: bool = True               # finished by its deadline (or none)
    cached_tokens: int = 0             # prompt tokens the prefix cache served


@dataclasses.dataclass
class GatewayStats:
    carbon_g: float = 0.0
    energy_kwh: float = 0.0
    requests: int = 0
    level_counts: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(N_LEVELS))
    telemetry: List[TelemetryRecord] = dataclasses.field(default_factory=list)
    plans: List[PlanRecord] = dataclasses.field(default_factory=list)
    rejected: int = 0
    migrated: int = 0
    migrations: List[MigrationRecord] = dataclasses.field(
        default_factory=list)
    # per-tenant SLO bookkeeping: requests finished / deadlines met, keyed
    # by tenant class name ("" = untagged traffic)
    tenant_requests: Dict[str, int] = dataclasses.field(default_factory=dict)
    tenant_slo_met: Dict[str, int] = dataclasses.field(default_factory=dict)
    # ----- fault/chaos ledger (DESIGN.md §12) -----
    # carbon_g above is the POOL-ATTRIBUTED total: served + wasted.
    # wasted_g is the discarded-work share (migration redos, fault
    # requeues); per-pool splits let the chaos suite assert the ledger
    # stays conserved under churn (served + wasted sums match the
    # fault-free total within accounting tolerance).
    wasted_g: float = 0.0
    carbon_by_pool: Dict[str, float] = dataclasses.field(default_factory=dict)
    wasted_by_pool: Dict[str, float] = dataclasses.field(default_factory=dict)
    faults: int = 0            # fault-caused requeues harvested fleet-wide
    shed: int = 0              # admissions shed by brownout
    plan_holds: int = 0        # LP failures answered by holding last-good
    # (rid, reason) for every request drain() parked as rejected — the
    # audit trail that lets a chaos run prove zero work was STRANDED
    # (every submitted rid is either served or here, with a reason)
    rejected_reasons: List[Tuple[int, str]] = dataclasses.field(
        default_factory=list)

    @property
    def carbon_per_request(self) -> float:
        return self.carbon_g / max(self.requests, 1)

    def slo_attainment(self, tenant: Optional[str] = None) -> float:
        """Fraction of finished requests that met their deadline — for one
        tenant class, or fleet-wide when ``tenant`` is None. 1.0 when the
        class has served nothing (no deadline has been missed)."""
        if tenant is None:
            n = sum(self.tenant_requests.values())
            met = sum(self.tenant_slo_met.values())
        else:
            n = self.tenant_requests.get(tenant, 0)
            met = self.tenant_slo_met.get(tenant, 0)
        return met / n if n else 1.0


@dataclasses.dataclass(frozen=True)
class _Candidate:
    """One migratable unit of work in a source pool, with the numbers the
    decision rule needs. ``remaining`` is the token budget still unserved
    (equal to ``budget`` for anything that has not started decoding);
    ``prompt_len`` is 0 until the prompt has been tokenized/admitted."""
    rid: int
    kind: str                  # pending | rejected | queued | decoding
    level: Optional[int]       # None until the directive level is drawn
    budget: int                # full max_new budget on a (re)start
    remaining: int
    prompt_len: int = 0
    deadline_at: float = math.inf      # absolute deadline (monotonic clock)
    tenant: str = ""


class MigrationPlanner:
    """Cross-region request migration at re-plan ticks (DESIGN.md §8).

    Every tick the planner compares pools' PLANNING intensities (forecast-
    weighted when the gateway has a horizon) and moves work from dirty
    pools into the greenest pool with spare capacity, over the exact
    verbatim-token requeue path failover already uses. The decision rule,
    per candidate request:

      queued work  (pending / rejected / engine queue — nothing invested):
          save = (k_src − k_dst) · kwh_tok(level) · remaining
      decoding work (a live slot — prefill + partial decode invested):
          save = k_src · kwh_tok · remaining  −  k_dst · kwh_tok · budget
          (finish-here cost vs redo-from-the-prompt cost at the
          destination; eviction releases the slot and its KV pages)

    and the move happens only when ALL of:
      * the destination clears the hysteresis band:
        k_dst < (1 − hysteresis) · k_src — small crossings don't trigger;
      * save > min_saving_g (grams over the request's remaining budget);
      * the request hasn't migrated within ``cooldown_h`` simulated hours
        (a band alone cannot stop ping-pong when the oscillation exceeds
        it; the cooldown bounds moves per request regardless of trace);
      * the destination stays under the gateway's load cap.

    What never migrates: work inside a prefill or decode dispatch (the
    planner only runs between fleet steps, at re-plan ticks), and decoding
    requests whose redo cost exceeds the saving. ``kwh_tok`` comes from
    the gateway's LevelProfiles telemetry (per-level kWh over mean
    generated tokens), falling back to the roofline model until profiles
    exist. Savings are planner ESTIMATES for ordering/thresholding;
    realized carbon is still accounted at finish time from the serving
    pool's live intensity.
    """

    def __init__(self, *, hysteresis: float = 0.15,
                 min_saving_g: float = 0.0, cooldown_h: float = 2.0,
                 evict_decoding: bool = True,
                 respect_load_cap: bool = True,
                 max_moves_per_tick: int = 256,
                 slo_margin: float = 2.0):
        assert 0.0 <= hysteresis < 1.0
        assert slo_margin >= 1.0, "a margin below 1 would plan to miss"
        self.hysteresis = hysteresis
        self.min_saving_g = min_saving_g
        self.cooldown_h = cooldown_h
        self.evict_decoding = evict_decoding
        self.respect_load_cap = respect_load_cap
        self.max_moves_per_tick = max_moves_per_tick
        self.slo_margin = slo_margin
        self._last_move: Dict[int, float] = {}

    # ----- candidate enumeration --------------------------------------
    @staticmethod
    def _candidates(sched: CarbonAwareScheduler) -> List[_Candidate]:
        """Cheapest-to-move first: parked/queued work costs nothing to
        move; decoding work (listed last) forfeits its progress."""
        out: List[_Candidate] = []
        for req, _reason in sched.rejected:
            lvl = req.directive_level if (req.pre_rendered
                                          or req.prompt_token_ids) else None
            out.append(_Candidate(req.rid, "rejected", lvl,
                                  req.max_new_tokens, req.max_new_tokens,
                                  deadline_at=req.deadline_at,
                                  tenant=req.tenant))
        for req in sched.pending:
            lvl = req.directive_level if (req.pre_rendered
                                          or req.prompt_token_ids) else None
            out.append(_Candidate(req.rid, "pending", lvl,
                                  req.max_new_tokens, req.max_new_tokens,
                                  deadline_at=req.deadline_at,
                                  tenant=req.tenant))
        for eng in sched.engines:
            if eng is None:
                continue
            for st in eng.queue:
                out.append(_Candidate(st.rid, "queued", st.directive_level,
                                      st.max_new_tokens, st.max_new_tokens,
                                      len(st.prompt_ids),
                                      deadline_at=st.deadline_at,
                                      tenant=st.tenant))
            for st in eng.slots:
                if st is not None:
                    rem = max(st.max_new_tokens - len(st.generated), 0)
                    out.append(_Candidate(st.rid, "decoding",
                                          st.directive_level,
                                          st.max_new_tokens, rem,
                                          st.prompt_len,
                                          deadline_at=st.deadline_at,
                                          tenant=st.tenant))
        return out

    def _slo_safe(self, gw: "SproutGateway", cand: _Candidate,
                  dst: "GatewayPool") -> bool:
        """SLO risk pricing: a request within its migration-redo time of
        its deadline never moves. The redo time is the predicted
        completion of the FULL budget at the destination (queue depth ×
        measured per-level decode seconds), padded by ``slo_margin`` —
        the estimate rides on telemetry, so plan conservatively."""
        if math.isinf(cand.deadline_at):
            return True
        redo = gw.predicted_completion_s(dst, max_new=cand.budget,
                                         tenant=cand.tenant)
        return cand.deadline_at - time.monotonic() >= self.slo_margin * redo

    def _dst_has_room(self, gw: "SproutGateway", dst: "GatewayPool") -> bool:
        return (not self.respect_load_cap) or dst.load() < gw.load_cap

    @staticmethod
    def _dst_can_serve(dst: "GatewayPool", cand: _Candidate) -> bool:
        """Fleets can be heterogeneous (max_len / page budgets differ):
        never migrate a request into a pool where no live engine can hold
        its budget — or where its prompt would be TRUNCATED to fit, which
        would silently change the output. Without this guard an evicted
        request could end up parked as rejected at the destination (lost
        work the admission-only gateway would have finished)."""
        for eng in dst.scheduler.engines:
            if eng is None:
                continue
            if cand.budget + 1 >= eng.max_len:
                continue           # engine.submit would reject the budget
            if cand.prompt_len and \
                    cand.prompt_len + cand.budget >= eng.max_len:
                continue           # dispatch would truncate the prompt
            if eng.paged and cand.prompt_len and \
                    eng._pages_for(cand.prompt_len,
                                   cand.budget) > eng.pages.n_pages:
                continue           # worst-case reservation can never fit
            return True
        return False

    # ----- the tick ----------------------------------------------------
    def plan(self, gw: "SproutGateway") -> int:
        """Run one migration pass; returns the number of requests moved.
        Called by the gateway at every re-plan tick, after mixes install."""
        if len(gw.pools) < 2:
            return 0
        # a draining pool is leaving the fleet: never a migration target
        alive = [p for p in gw.pools
                 if any(e is not None for e in p.scheduler.engines)
                 and p.key not in gw.draining]
        if not alive:
            return 0
        k = {p.key: gw.plan_intensity(p) for p in gw.pools}
        dst_order = sorted(alive, key=lambda p: k[p.key])
        moved = 0
        for src in sorted(gw.pools, key=lambda p: -k[p.key]):
            k_src = k[src.key]
            dsts = [d for d in dst_order if d is not src
                    and k[d.key] < (1.0 - self.hysteresis) * k_src]
            if not dsts:
                continue
            for cand in self._candidates(src.scheduler):
                if moved >= self.max_moves_per_tick:
                    return moved
                if gw.t - self._last_move.get(cand.rid,
                                              -np.inf) < self.cooldown_h:
                    continue
                if cand.kind == "decoding" and not self.evict_decoding:
                    continue
                if not any(self._dst_has_room(gw, d) for d in dsts):
                    break              # every green pool is at capacity
                dst = next((d for d in dsts
                            if self._dst_has_room(gw, d)
                            and self._dst_can_serve(d, cand)
                            and self._slo_safe(gw, cand, d)), None)
                if dst is None:
                    continue           # no green pool can hold THIS request
                kwh_tok = gw.kwh_per_token(cand.level, mix=dst.x)
                if cand.kind == "decoding":
                    save = kwh_tok * (k_src * cand.remaining
                                      - k[dst.key] * cand.budget)
                else:
                    save = (k_src - k[dst.key]) * kwh_tok * cand.remaining
                if save <= self.min_saving_g:
                    continue
                req = src.scheduler.evict(cand.rid)
                if req is None:        # finished between enumeration/evict
                    continue
                if cand.kind == "decoding":
                    # the eviction discards the source's prefill + partial
                    # decode; charge that work to the source pool NOW so
                    # realized carbon never flatters migration (the redo
                    # cost the decision rule priced in is real)
                    gw.account_wasted(src, cand.prompt_len,
                                      cand.budget - cand.remaining)
                if gw.fault_injector.fire("migrate.dst_vanish", dst.key):
                    # the destination fleet dies between evict and submit:
                    # its replicas crash through the health machine (their
                    # own in-flight work fault-requeues there), and the
                    # evicted request goes home to its source pool under
                    # the bounded-retry rules — never stranded in limbo
                    for di, deng in enumerate(dst.scheduler.engines):
                        if deng is not None:
                            dst.scheduler._bench(
                                di, fault_reason="migrate.dst_vanish")
                    gw._requeue_vanished(src, req)
                    continue
                dst.scheduler.submit(req)
                self._last_move[cand.rid] = gw.t
                moved += 1
                st = gw.stats
                st.migrated += 1
                st.migrations.append(MigrationRecord(
                    gw.t, cand.rid, src.key, dst.key, cand.kind,
                    -1 if cand.level is None else cand.level, save))
                if len(st.migrations) > 2 * SproutGateway.PLAN_CAP:
                    del st.migrations[: -SproutGateway.PLAN_CAP]
        return moved

    # ----- capacity drain ---------------------------------------------
    def drain(self, gw: "SproutGateway", src: "GatewayPool") -> int:
        """Capacity-drain trigger (maintenance, not carbon): move EVERY
        movable request out of ``src`` over the same verbatim-token
        requeue path, spreading across the least-loaded capable pools.

        Unlike the carbon pass this ignores the hysteresis band, savings
        threshold, cooldown and load cap — the pool is going away, so the
        only questions are "can the destination serve it at all"
        (``_dst_can_serve``) and "is redoing a decoding request SLO-safe"
        (a near-deadline decoding request finishes faster in place; the
        pool keeps serving until the maintenance deadline, so leaving it
        is safe — and strands nothing). Returns the number moved."""
        dsts = [p for p in gw.pools
                if p is not src and p.key not in gw.draining
                and any(e is not None for e in p.scheduler.engines)]
        if not dsts:
            return 0
        moved = 0
        for cand in self._candidates(src.scheduler):
            # a decoding request only moves to a destination where the
            # redo is itself SLO-safe — checking "some safe pool exists"
            # and then shipping to a different one would waste the
            # partial decode AND miss the deadline
            ok = [d for d in dsts if self._dst_can_serve(d, cand)
                  and (cand.kind != "decoding"
                       or self._slo_safe(gw, cand, d))]
            dst = min(ok, key=lambda d: d.load(), default=None)
            if dst is None:
                continue       # no pool can take it: finish here pre-drain
            req = src.scheduler.evict(cand.rid)
            if req is None:    # finished between enumeration and evict
                continue
            if cand.kind == "decoding":
                gw.account_wasted(src, cand.prompt_len,
                                  cand.budget - cand.remaining)
            dst.scheduler.submit(req)
            moved += 1
            st = gw.stats
            st.migrated += 1
            st.migrations.append(MigrationRecord(
                gw.t, cand.rid, src.key, dst.key, cand.kind,
                -1 if cand.level is None else cand.level, 0.0,
                trigger="drain"))
            if len(st.migrations) > 2 * SproutGateway.PLAN_CAP:
                del st.migrations[: -SproutGateway.PLAN_CAP]
        return moved


PoolSpec = Tuple[Union[str, CarbonIntensityProvider], CarbonAwareScheduler]


class SproutGateway:
    """Fig. 5 component 1 over real engines — see the module docstring."""

    # long-lived control loop: aggregates run forever, per-record logs are
    # ring-buffered (oldest trimmed) so memory is bounded under real traffic
    TELEMETRY_CAP = 100_000
    PLAN_CAP = 10_000
    # each pool's scheduler draws rids from a disjoint range: migration
    # preserves a request's rid across pools, so per-pool counters starting
    # at 1 would let a migrated rid collide with a destination-native one
    # (evict-by-rid would then pop the wrong request)
    RID_STRIDE = 10_000_000

    def __init__(self, pools: Sequence[PoolSpec], *,
                 policy: Optional[Policy] = None,
                 tenants: Optional[Sequence[TenantSpec]] = None,
                 energy: Optional[EnergyModel] = None,
                 model_profile: ModelProfile = LLAMA2_13B,
                 hw: HardwareSpec = A100_40GB,
                 n_levels: int = N_LEVELS,
                 q: Optional[np.ndarray] = None,
                 k1: Optional[float] = None,
                 replan_every: float = 1.0,
                 load_cap: int = 16,
                 forecast_horizon: float = 0.0,
                 forecast_decay: float = 0.5,
                 migration: Optional[MigrationPlanner] = None,
                 seed: int = 0,
                 fault_injector: Optional["FaultInjector"] = None,
                 max_plan_holds: int = 3,
                 brownout_threshold: float = 4.0,
                 brownout_decay: float = 0.5):
        assert pools, "gateway needs at least one regional pool"
        if policy is not None and tenants is None:
            # the gateway installs the policy's directive-level mix x as
            # each pool's level_fn (it never routes via policy.assign), so
            # only mix-exposing policies fit — SproutPolicy,
            # SproutStaticPolicy, or anything with a matching .x
            x = getattr(policy, "x", None)
            if x is None or len(np.asarray(x)) != n_levels:
                raise ValueError(
                    f"policy {type(policy).__name__} must expose a "
                    f"directive-level mix .x of length {n_levels}; got "
                    f"{'none' if x is None else len(np.asarray(x))}")
        self.policy = policy
        # tenant classes by name; with tenants set the gateway solves its
        # own per-(pool, tenant) LPs (the policy's single mix would lose
        # the per-class floors) and stamps deadlines/priorities at submit
        self.tenants: Optional[Dict[str, TenantSpec]] = (
            {t.name: t for t in tenants} if tenants else None)
        if self.tenants:
            self.default_tenant = ("standard" if "standard" in self.tenants
                                   else next(iter(self.tenants)))
        self.energy = energy or EnergyModel(hw)
        self.model_profile = model_profile
        self.hw = hw
        self.n_levels = n_levels
        self.k1 = (k1 if k1 is not None
                   else hw.embodied_gco2 / hw.lifetime_s)
        self.replan_every = replan_every
        self.load_cap = load_cap
        self.forecast_horizon = forecast_horizon
        self.forecast_decay = forecast_decay
        self.migration = migration
        # pools being emptied ahead of maintenance: key -> deadline hour
        # (admission skips them; re-plan ticks keep draining their backlog)
        self.draining: Dict[str, float] = {}
        self.rng = np.random.default_rng(seed)
        self.profiles = LevelProfiles.fresh(n_levels)
        # REAL per-level decode seconds (engine-measured wall time, not the
        # roofline model): the .p vector is the "measured per-level decode
        # seconds" predicted-completion routing multiplies queue depth by.
        # Kept separate from self.profiles, whose .p carries target-hardware
        # modeled seconds for the Eq. 2 embodied-carbon term.
        self.latency_profiles = LevelProfiles.fresh(n_levels)
        # per-level generated-token sums from telemetry: with level_counts
        # they give mean tokens per level, the denominator that turns the
        # LevelProfiles per-REQUEST energies into the per-TOKEN energies
        # the migration decision rule prices budgets with
        self._tok_sum = np.zeros(n_levels)
        self.q = (np.asarray(q, float) if q is not None
                  else np.ones(n_levels) / n_levels)
        # observed task mix (decayed counts): the weights each tenant's
        # per-task q vectors are combined with at its LP solve
        self._task_counts: Dict[str, float] = {}
        self.stats = GatewayStats(level_counts=np.zeros(n_levels))
        self.t = 0.0
        self._last_replan: Optional[float] = None
        # ----- degraded-mode control plane (DESIGN.md §12) -----
        # ONE injector is shared by every layer (gateway, schedulers,
        # watchdog providers wired by the caller): its per-(point, target)
        # counters make a scripted FaultPlan land at the same opportunity
        # regardless of which layer consults first
        self.fault_injector = fault_injector or no_faults()
        self.max_plan_holds = max_plan_holds
        self.brownout_threshold = brownout_threshold
        self.brownout_decay = brownout_decay
        # consecutive LP-solve failures per pool: held plans past
        # max_plan_holds fall back to the static safe mix
        self._plan_holds: Dict[str, int] = {}
        # decayed fault pressure driving brownout (decays each replan)
        self._fault_score = 0.0
        # optional observer called as on_finish(pool_key, FinishedRequest)
        # after each request is accounted — benches/tests use it to keep
        # the full FinishedRequest (telemetry records drop token ids)
        self.on_finish = None

        self.pools: List[GatewayPool] = []
        for j, (spec, sched) in enumerate(pools):
            provider = (spec if isinstance(spec, CarbonIntensityProvider)
                        else CarbonIntensityProvider(spec))
            if len(sched.directives) < n_levels:
                raise ValueError(
                    f"pool {provider.region.key}: scheduler renders "
                    f"{len(sched.directives)} directive levels but the "
                    f"gateway plans over {n_levels} — pass a matching "
                    f"DirectiveSet to the CarbonAwareScheduler")
            pool = GatewayPool(provider.region.key, provider, sched,
                               x=np.eye(n_levels)[0])
            # the scheduler's level_fn now reads the pool's LIVE plan —
            # this is the wire that puts the LP in the request path. It is
            # a COMPOSITE per-request selector: each request draws from
            # its service class's (pool, tenant) mix (untagged requests
            # are mapped onto the default class at submit, so their
            # deadlines/priorities AND their SLO ledger entries are the
            # default class's).
            sched.level_fn = self._level_fn_for(pool)
            # disjoint rid ranges per pool (see RID_STRIDE): only bump a
            # fresh counter so a scheduler reused across gateways keeps
            # its sequence monotonic
            sched._rid = max(sched._rid, j * self.RID_STRIDE)
            # chaos wiring: the pool key names the scheduler's injection
            # targets ("CA/0" = replica 0 of pool CA); a gateway-supplied
            # injector replaces the schedulers' default no-fault ones so
            # one plan scripts the whole fleet
            sched.name = pool.key
            if fault_injector is not None:
                sched.fault_injector = self.fault_injector
            self.pools.append(pool)

    def _level_fn_for(self, pool: GatewayPool):
        """Composite per-request directive selector for one pool (the
        ``per_request`` mark tells the scheduler to pass the request).
        Gateway-routed traffic always carries a tenant tag by the time it
        dispatches (``submit`` maps untagged requests onto the default
        class); the ``pool.x`` fallback covers requests fed straight into
        the scheduler and mixes installed before the first tenant plan."""
        def fn(req: Optional[ServeRequest] = None) -> int:
            x = pool.x
            if req is not None and self.tenants:
                x = pool.x_by_tenant.get(self._tenant_of(req).name, pool.x)
            return int(self.rng.choice(self.n_levels, p=x))
        fn.per_request = True
        return fn

    def _tenant_of(self, req: ServeRequest) -> TenantSpec:
        """The request's service class (the default class when untagged).
        Only meaningful when the gateway runs with tenants."""
        assert self.tenants is not None
        return self.tenants.get(req.tenant) or \
            self.tenants[self.default_tenant]

    # ----- planning ---------------------------------------------------
    def set_quality(self, q: np.ndarray) -> None:
        """Install a fresh evaluator preference vector (Eq. 5's q)."""
        self.q = np.asarray(q, float)

    def plan_intensity(self, pool: GatewayPool) -> float:
        """The intensity the control plane PLANS against for a pool: the
        forecast-weighted effective value over ``forecast_horizon`` hours
        when a horizon is set (the LP objective is linear in k0, so this
        scalar solves the window exactly), else the instantaneous signal.
        Accounting always uses the live instantaneous intensity."""
        if self.forecast_horizon > 0:
            return forecast_weighted_intensity(
                pool.provider.forecast(self.t, self.forecast_horizon),
                decay=self.forecast_decay)
        return pool.provider.intensity(self.t)

    def kwh_per_token(self, level: Optional[int] = None,
                      mix: Optional[np.ndarray] = None) -> float:
        """Per-generated-token energy (kWh, incl. PUE) at a directive
        level, from LevelProfiles telemetry (per-level kWh over mean
        generated tokens); ``level=None`` takes the expectation under
        ``mix`` (the destination pool's plan — an undrawn request will
        draw its level there). Roofline fallback until telemetry exists."""
        fallback = self.energy.request_energy_kwh(
            self.model_profile, 0, 1) * PUE
        counts = np.maximum(self.stats.level_counts, 1)
        mean_tok = np.maximum(self._tok_sum / counts, 1.0)
        per_level = np.where(self.stats.level_counts > 0,
                             self.profiles.e / mean_tok, fallback)
        if level is not None:
            return float(per_level[min(level, self.n_levels - 1)])
        w = (np.asarray(mix, float) if mix is not None
             else np.ones(self.n_levels) / self.n_levels)
        return float(per_level @ w)

    def service_s(self, level: Optional[int] = None,
                  mix: Optional[np.ndarray] = None) -> float:
        """Measured decode seconds per request at a directive level (from
        the ``latency_profiles`` telemetry — real engine wall time), or
        the expectation under ``mix``. 0.0 until telemetry exists: with
        nothing measured, predicted completion degrades to "everything is
        feasible" and routing falls back to pure greenness."""
        per_level = np.where(self.latency_profiles.counts > 0,
                             self.latency_profiles.p, 0.0)
        if level is not None:
            return float(per_level[min(level, self.n_levels - 1)])
        w = (np.asarray(mix, float) if mix is not None
             else np.ones(self.n_levels) / self.n_levels)
        return float(per_level @ w)

    def predicted_completion_s(self, pool: GatewayPool,
                               max_new: Optional[int] = None,
                               tenant: str = "") -> float:
        """How long a request admitted NOW would take to finish in this
        pool: queue depth over decode parallelism (service waves) times
        the measured per-level decode seconds, under the mix the request
        would draw from. This is the latency half of admission routing —
        a green pool with a deep queue loses to a dirty idle one when the
        wait would bust the deadline. ``max_new`` is accepted for callers
        that price a specific budget; the estimate currently keys on the
        profiled per-mix mean (budgets enter via the mix's level draw).

        Chunked-admission engines change the wait model: an arrival's
        prefill streams into the live decode scan instead of stalling
        behind a slot-epoch boundary, so service overlaps the residual
        current wave — on average half a wave of alignment wait vanishes
        per chunked slot. The estimate subtracts that overlap credit,
        scaled by the pool's chunked slot fraction, and never drops below
        the request's own service time."""
        del max_new
        slots = pool.slot_count()
        if slots == 0:
            return math.inf
        x = pool.x
        prio = None
        if self.tenants and tenant in self.tenants:
            x = pool.x_by_tenant.get(tenant, pool.x)
            # priority-ordered dispatch: the queue this class waits behind
            # is its own class and better, not the whole backlog
            prio = self.tenants[tenant].priority
        svc = self.service_s(mix=x)
        queued = pool.load(prio) / slots
        waves = 1.0 + queued
        if queued > 0:
            waves = max(1.0, waves - 0.5 * pool.chunked_fraction())
        return svc * waves

    def replan(self, t: Optional[float] = None) -> None:
        """Re-solve the directive LP per pool at its planning intensity
        (forecast-weighted when a horizon is set) and install the mixes;
        then run the migration pass, so backlog follows the same signal
        the fresh plans were solved against. ``policy=None`` pins every
        pool to L0 (migration still runs — it is a routing decision)."""
        if t is not None:
            self.t = t
        self._last_replan = self.t
        # halve EVERY task count each re-plan (not just arriving tasks):
        # a task that stops arriving decays away instead of skewing the
        # tenant LPs' task weighting forever; tiny tails are dropped
        self._task_counts = {k: v / 2 for k, v in self._task_counts.items()
                             if v / 2 >= 0.01}
        # amortized trim: cut back to the cap only at 2x, so steady state
        # is O(1) per replan rather than a full shift every time
        if len(self.stats.plans) > 2 * self.PLAN_CAP:
            del self.stats.plans[: -self.PLAN_CAP]
        # fault pressure decays per replan tick: brownout lifts once the
        # fleet stops faulting, without a manual all-clear
        self._fault_score *= self.brownout_decay
        for pool in self.pools:
            k0_now = pool.provider.intensity(self.t)
            k0 = self.plan_intensity(pool)
            # degraded signal: the pool's watchdog (when wrapped) is past
            # its staleness bound — plans still solve, flagged degraded
            sick = bool(getattr(pool.provider, "degraded", False))
            k0_solve = k0
            if self.fault_injector.fire("lp.fail", pool.key):
                # bad telemetry reaching the solver; the injected NaN is
                # rejected by solve_directive_lp's input validation — the
                # genuine failure path, not an injector shortcut
                k0_solve = float("nan")
            if self.tenants is not None:
                try:
                    self._replan_tenants(pool, k0_solve, k0_now, sick)
                    self._plan_holds[pool.key] = 0
                except (ValueError, FloatingPointError):
                    self._plan_hold(pool, k0, k0_now)
                continue
            if self.policy is None:
                pool.x = np.eye(self.n_levels)[0]
                self.stats.plans.append(PlanRecord(
                    self.t, pool.key, k0, pool.x.copy(), solver="l0-fixed",
                    k0_now=k0_now, horizon_h=self.forecast_horizon,
                    degraded=sick))
                continue
            try:
                self.policy.begin_hour(self.t, k0_solve, self.profiles,
                                       self.q, {})
            except (ValueError, FloatingPointError):
                self._plan_hold(pool, k0, k0_now)
                continue
            self._plan_holds[pool.key] = 0
            pool.x = np.asarray(self.policy.x, float).copy()
            sol = getattr(self.policy, "last_solution", None)
            if self.brownout and sol is not None:
                # overload/fault pressure: push the mix toward the cheap
                # levels as far as the solved quality floor allows — the
                # floor itself (Eq. 3 + any q_lb_floor) is never crossed
                pool.x = self._brownout_clamp(pool.x, self.q, sol.q_lb)
            self.stats.plans.append(PlanRecord(
                self.t, pool.key, k0, pool.x.copy(),
                q_lb=(sol.q_lb if sol else 0.0),
                expected_quality=(sol.expected_quality if sol
                                  else float(self.q @ pool.x)),
                solver=(sol.solver if sol else "warmup"),
                k0_now=k0_now, horizon_h=self.forecast_horizon,
                degraded=sick))
        # capacity drains run before the carbon pass: a draining pool's
        # backlog must leave regardless of where the grid is greener
        for key in list(self.draining):
            self._drain_planner().drain(self, self._pool(key))
        if self.migration is not None:
            self.migration.plan(self)

    def _replan_tenants(self, pool: GatewayPool, k0: float,
                        k0_now: float, sick: bool = False) -> None:
        """One LP per (pool, tenant class): each class's xi, absolute
        quality floor and task-weighted q vector shape its own mix. The
        pool's aggregate ``x`` (used by migration's energy expectation
        and untagged traffic) is the served-share-weighted mean of the
        class mixes. Warmup matches SproutPolicy: uniform mixes until
        every level has ≥5 profiled requests."""
        if self.profiles.counts.min() < 5:
            uniform = np.ones(self.n_levels) / self.n_levels
            pool.x = uniform.copy()
            for name in self.tenants:
                pool.x_by_tenant[name] = uniform.copy()
            self.stats.plans.append(PlanRecord(
                self.t, pool.key, k0, uniform.copy(), solver="warmup",
                k0_now=k0_now, horizon_h=self.forecast_horizon,
                degraded=sick))
            return
        k_min = min(p.provider.k_min for p in self.pools)
        k_max = max(p.provider.k_max for p in self.pools)
        sols = solve_tenant_lps(
            self.profiles.e, self.profiles.p, list(self.tenants.values()),
            self.q, k0=k0, k1=self.k1, k0_min=k_min, k0_max=k_max,
            task_weights=self._task_counts)
        share = np.array([max(self.stats.tenant_requests.get(n, 0), 1)
                          for n in sols], float)
        share = share / share.sum()
        pool.x = np.zeros(self.n_levels)
        for w, (name, sol) in zip(share, sols.items()):
            x_t = sol.x.copy()
            if self.brownout:
                # brownout presses each class toward its cheapest levels;
                # sol.q_lb already folds in the class's absolute floor
                # (q_floor_frac · q0), so a premium guarantee holds by
                # construction even while batch work gets clamped hard
                q_eff = self.tenants[name].effective_q(self.q,
                                                       self._task_counts)
                x_t = self._brownout_clamp(x_t, q_eff, sol.q_lb)
            pool.x_by_tenant[name] = x_t
            pool.x += w * x_t
            self.stats.plans.append(PlanRecord(
                self.t, pool.key, k0, x_t.copy(), q_lb=sol.q_lb,
                expected_quality=sol.expected_quality, solver=sol.solver,
                k0_now=k0_now, horizon_h=self.forecast_horizon,
                tenant=name, degraded=sick))

    # ----- degraded mode (DESIGN.md §12) ------------------------------
    @property
    def brownout(self) -> bool:
        """Fleet under fault pressure: decayed fault score past the
        threshold. While true, admission sheds batch-priority work and
        fresh plans are clamped toward the cheap levels."""
        return self._fault_score >= self.brownout_threshold

    def _plan_hold(self, pool: GatewayPool, k0: float,
                   k0_now: float) -> None:
        """The LP solve failed (non-finite telemetry / carbon terms): hold
        the pool's last-good mix. After ``max_plan_holds`` CONSECUTIVE
        failures the held plan itself is stale — fall to the static safe
        mix (pure L0: full quality, no optimizer in the loop), the same
        configuration the policy-less BASE gateway runs."""
        n = self._plan_holds.get(pool.key, 0) + 1
        self._plan_holds[pool.key] = n
        self.stats.plan_holds += 1
        self._fault_score += 1.0
        if n > self.max_plan_holds:
            safe = np.eye(self.n_levels)[0]
            pool.x = safe.copy()
            if self.tenants:
                for name in self.tenants:
                    pool.x_by_tenant[name] = safe.copy()
            solver = "static-safe"
        else:
            solver = "hold"            # pool.x keeps its last-good mix
        self.stats.plans.append(PlanRecord(
            self.t, pool.key, k0, pool.x.copy(), solver=solver,
            k0_now=k0_now, horizon_h=self.forecast_horizon, degraded=True))

    def _brownout_clamp(self, x: np.ndarray, q_vec: np.ndarray,
                        floor: float) -> np.ndarray:
        """Blend a solved mix toward the cheapest level exactly as far as
        its quality floor allows: the result is ``(1-a)·x + a·e_cheap``
        with the largest ``a`` in [0, 1] keeping ``q·x' >= floor``. The
        solved mix already satisfies the floor, so the clamp can only
        move along a segment whose floor-feasible prefix we stay inside —
        quality guarantees survive brownout by construction."""
        q_vec = np.asarray(q_vec, float)
        x = np.asarray(x, float)
        cheap = np.eye(self.n_levels)[self.n_levels - 1]
        qx = float(q_vec @ x)
        q_cheap = float(q_vec[-1])
        if q_cheap >= floor - 1e-12:
            return cheap               # even all-cheap clears the floor
        a = (qx - floor) / max(qx - q_cheap, 1e-12)
        a = float(np.clip(a, 0.0, 1.0))
        return (1.0 - a) * x + a * cheap

    def _requeue_vanished(self, src: GatewayPool, req: ServeRequest) -> None:
        """A migration's destination vanished between evict and submit:
        the evicted request goes home to its SOURCE pool under the same
        bounded-retry rules engine faults use — retry counted, backoff
        stamped, rejected with a reason once the budget is spent."""
        req.retries += 1
        req.last_fault = "migrate.dst_vanish"
        self.stats.faults += 1
        self._fault_score += 1.0
        sched = src.scheduler
        if req.retries > sched.retry_budget:
            sched.rejected.append(
                (req, f"retry budget exhausted ({sched.retry_budget}) "
                      f"after fault migrate.dst_vanish"))
            return
        sched._backoff[req.rid] = sched.steps + \
            sched.backoff_base_steps * 2 ** (req.retries - 1)
        sched.submit(req)

    def _pool(self, key: str) -> GatewayPool:
        for p in self.pools:
            if p.key == key:
                return p
        raise KeyError(f"no pool for region {key!r}")

    def _drain_planner(self) -> MigrationPlanner:
        """The planner drains ride on: the configured one, else a lazily
        created default (drain must work on admission-only gateways)."""
        if self.migration is not None:
            return self.migration
        if not hasattr(self, "_fallback_planner"):
            self._fallback_planner = MigrationPlanner()
        return self._fallback_planner

    def tick(self, t: float) -> None:
        """Advance the gateway clock; re-plan when the interval elapsed."""
        self.t = t
        if (self._last_replan is None
                or t - self._last_replan >= self.replan_every - 1e-9):
            self.replan()

    # ----- request path ----------------------------------------------
    def submit(self, req: ServeRequest) -> Tuple[int, str]:
        """Route a request; returns (rid, pool key).

        Without a deadline: the greenest pool under ``load_cap``
        (least-loaded when all pools are saturated). Greenness is the
        PLANNING intensity — the same forecast-weighted signal
        re-planning and migration use — so admission never sends work to
        an instantaneously-green pool the next tick's migration pass
        would immediately pull it back out of.

        With a deadline (stamped here from the tenant's TTFT/TPOT targets
        when the caller left it unset): pools are scored on PREDICTED
        COMPLETION TIME jointly with greenness — greenest-first among the
        pools whose predicted completion fits the deadline, falling back
        to the fastest pool when no green pool can make it. That is the
        quality/latency/carbon triangle in one line: a dirty-but-idle
        pool wins exactly when the green pool's queue would bust the
        deadline.

        Pools whose fleet is entirely gone, and pools draining ahead of
        maintenance, are skipped while any alternative exists. The
        ``pool.x`` aggregate installed by re-planning remains in use for
        migration's energy expectation and for requests fed straight
        into a pool's scheduler, bypassing this router."""
        if self.tenants is not None:
            spec = self._tenant_of(req)
            req.tenant = spec.name
            req.priority = spec.priority
            if math.isinf(req.deadline_s) and math.isinf(req.deadline_at):
                req.deadline_s = spec.deadline_for(req.max_new_tokens)
        if self.brownout and req.priority >= 2:
            # brownout sheds the BATCH tier first: deferrable work is
            # turned away at the door (rid 0 = not admitted) so the
            # faulting fleet's remaining capacity serves latency- and
            # quality-bound tenants; premium/standard always admit
            self.stats.shed += 1
            return 0, "shed"
        if req.task:
            self._task_counts[req.task] = \
                self._task_counts.get(req.task, 0.0) + 1.0
        alive = [p for p in self.pools
                 if any(e is not None for e in p.scheduler.engines)]
        open_ = [p for p in alive if p.key not in self.draining]
        candidates = open_ or alive or self.pools
        by_carbon = sorted(candidates, key=self.plan_intensity)
        deadline = req.deadline_s if math.isinf(req.deadline_at) else \
            req.deadline_at - time.monotonic()
        if not math.isinf(deadline):
            fits = [p for p in by_carbon
                    if self.predicted_completion_s(
                        p, max_new=req.max_new_tokens,
                        tenant=req.tenant) <= deadline]
            pool = (next((p for p in fits if p.load() < self.load_cap),
                         fits[0]) if fits
                    else min(candidates, key=lambda p:
                             self.predicted_completion_s(
                                 p, max_new=req.max_new_tokens,
                                 tenant=req.tenant)))
        else:
            pool = next((p for p in by_carbon if p.load() < self.load_cap),
                        min(candidates, key=lambda p: p.load()))
        rid = pool.scheduler.submit(req)
        pool.routed += 1
        return rid, pool.key

    def drain_pool(self, region: str, deadline: Optional[float] = None
                   ) -> int:
        """Capacity-drain trigger: empty a pool ahead of maintenance.

        Marks the pool as draining — admission stops routing to it and
        every re-plan tick keeps moving its backlog to the least-loaded
        capable pools over the verbatim-token requeue path — and runs one
        drain pass immediately. ``deadline`` (simulated hours) is an
        operator RECORD of when maintenance begins (inspectable via
        ``self.draining``); it does not alter the decision rule — what
        governs each move is the REQUEST's own deadline (a decoding
        request whose redo elsewhere would bust it finishes in place,
        which is safe because the pool keeps serving until maintenance
        actually starts). Returns the number of requests moved by the
        immediate pass. Call ``undrain_pool`` after maintenance to
        rejoin the fleet."""
        pool = self._pool(region)
        self.draining[region] = self.t if deadline is None else deadline
        return self._drain_planner().drain(self, pool)

    def undrain_pool(self, region: str) -> None:
        """Maintenance over: the pool takes traffic again."""
        self.draining.pop(self._pool(region).key, None)

    def step(self) -> int:
        """One fleet step across every pool; harvests finished telemetry
        and the schedulers' fault events (each fault feeds the brownout
        pressure score and charges the discarded work to the pool's
        wasted-carbon ledger — a retried request's first attempt burned
        real energy that conservation accounting must not drop)."""
        tokens = 0
        for pool in self.pools:
            tokens += pool.scheduler.step()
            ev = pool.scheduler.fault_events
            if ev:
                pool.scheduler.fault_events = []
                for _reason, rst in ev:
                    self.stats.faults += 1
                    self._fault_score += 1.0
                    self.account_wasted(pool, rst.prompt_len,
                                        len(rst.generated))
            if pool.scheduler.finished:
                for fin in pool.scheduler.finished:
                    self._account(pool, fin)
                pool.scheduler.finished = []
        return tokens

    def drain(self, max_steps: int = 100000) -> None:
        """Serve until every pool is idle. A pool whose fleet is entirely
        gone can never serve its backlog — its pending requests are parked
        as rejected instead of spinning here and skewing routing load."""
        for _ in range(max_steps):
            tokens = self.step()
            if not any(p.load() for p in self.pools):
                break
            if tokens == 0:
                for p in self.pools:
                    # only park a backlog when the pool can NEVER serve it:
                    # no live engines and none benched on probation (a
                    # probationary replica will be re-admitted in a few
                    # scheduler steps and the backlog drains through it)
                    if p.scheduler.pending and not any(
                            e is not None for e in p.scheduler.engines) \
                            and not p.scheduler.has_recoverable_replica():
                        p.scheduler.rejected.extend(
                            (req, "no live engines in pool")
                            for req in p.scheduler.pending)
                        p.scheduler.pending = []
        for pool in self.pools:
            self.stats.rejected += len(pool.scheduler.rejected)
            self.stats.rejected_reasons.extend(
                (req.rid, reason) for req, reason in pool.scheduler.rejected)
            if len(self.stats.rejected_reasons) > 2 * self.PLAN_CAP:
                del self.stats.rejected_reasons[: -self.PLAN_CAP]
            pool.scheduler.rejected = []

    # ----- feedback ---------------------------------------------------
    def energy_for(self, pool: GatewayPool) -> EnergyModel:
        """The energy model priced for this pool's fleet geometry: a
        tp-sharded pool is metered as ``n_chips = tp_degree`` (per-chip
        HBM + collective bytes, fleet power — DESIGN.md §14). tp=1 pools
        get ``self.energy`` back unchanged (``with_chips`` is identity),
        so single-chip accounting stays bit-identical."""
        return self.energy.with_chips(pool.tp_degree())

    def account_wasted(self, pool: GatewayPool, prompt_tokens: int,
                       gen_tokens: int) -> None:
        """Charge the source pool for work a decoding eviction discards
        (its prefill + partial generation restart from scratch at the
        destination). Adds carbon/energy WITHOUT incrementing the request
        count, so carbon-per-request comparisons against the admission-only
        gateway include the redo cost the migration decision rule priced
        in — realized savings are never flattered by free restarts."""
        k0 = pool.provider.intensity(self.t)
        kwh, secs = self.energy_for(pool).measure(
            self.model_profile, prompt_tokens, max(gen_tokens, 0))
        kwh *= PUE
        wasted = request_carbon(k0, kwh, secs, self.hw.embodied_gco2,
                                self.hw.lifetime_s, pue=1.0)
        st = self.stats
        st.carbon_g += wasted
        st.energy_kwh += kwh
        # conservation ledger: carbon_g = Σ carbon_by_pool + Σ wasted_by_pool
        st.wasted_g += wasted
        st.wasted_by_pool[pool.key] = \
            st.wasted_by_pool.get(pool.key, 0.0) + wasted

    def _account(self, pool: GatewayPool, fin: FinishedRequest) -> None:
        """Engine telemetry -> kWh (EnergyModel.measure) -> Eq. 1 carbon +
        LevelProfiles feedback. This is the loop's return edge: the next
        ``replan`` solves over exactly these measured profiles."""
        k0 = pool.provider.intensity(self.t)
        # Eq. 1 credit for the radix prefix cache (DESIGN.md §13): prompt
        # tokens served from cached pages were never prefilled, so the
        # prefill term of the energy model only charges the computed span
        cached = getattr(fin, "cached_tokens", 0)
        kwh, secs = self.energy_for(pool).measure(
            self.model_profile, max(fin.prompt_tokens - cached, 0),
            fin.gen_tokens, fin.decode_s)
        kwh *= PUE
        carbon = request_carbon(k0, kwh, secs, self.hw.embodied_gco2,
                                self.hw.lifetime_s, pue=1.0)
        self.profiles.update(fin.directive_level, kwh, secs)
        # real decode seconds feed the latency profiles that predicted-
        # completion routing and migration SLO pricing multiply queue
        # depth by (self.profiles.p stays modeled target-hardware time)
        self.latency_profiles.update(fin.directive_level, 0.0, fin.decode_s)
        st = self.stats
        st.carbon_g += carbon
        st.carbon_by_pool[pool.key] = \
            st.carbon_by_pool.get(pool.key, 0.0) + carbon
        st.energy_kwh += kwh
        st.requests += 1
        st.level_counts[fin.directive_level] += 1
        self._tok_sum[fin.directive_level] += fin.gen_tokens
        st.tenant_requests[fin.tenant] = \
            st.tenant_requests.get(fin.tenant, 0) + 1
        st.tenant_slo_met[fin.tenant] = \
            st.tenant_slo_met.get(fin.tenant, 0) + int(fin.slo_met)
        st.telemetry.append(TelemetryRecord(
            pool.key, fin.rid, fin.directive_level, fin.prompt_tokens,
            fin.gen_tokens, fin.decode_s, kwh, carbon, k0,
            tenant=fin.tenant, latency_s=fin.latency_s,
            slo_met=fin.slo_met, cached_tokens=cached))
        if len(st.telemetry) > 2 * self.TELEMETRY_CAP:
            # amortized: one O(cap) shift per cap appends, not per request
            del st.telemetry[: -self.TELEMETRY_CAP]
        if self.on_finish is not None:
            self.on_finish(pool.key, fin)

    # ----- convenience ------------------------------------------------
    def run_hour(self, t: float, requests: Sequence[ServeRequest],
                 on_inflight=None, steps: Optional[int] = None) -> Dict:
        """One simulated hour: tick (re-plan if due), route, serve, account.
        Returns a summary of what this hour did. ``on_inflight(gateway)``,
        if given, runs after one fleet step with the hour's work in flight —
        the hook for fault/elasticity scenarios (fail a replica, scale up)
        without hand-rolling the hour's accounting.

        ``steps=None`` drains the fleet to idle (every request finishes
        inside its hour). ``steps=k`` runs exactly k fleet steps instead,
        so unfinished backlog RIDES OVER to the next hour — the load shape
        that gives the next tick's forecast re-plan and migration pass
        something to act on (the intensity-crossover scenario in
        examples/carbon_aware_serving.py and the migration benchmark)."""
        n0 = self.stats.requests
        c0 = self.stats.carbon_g
        m0 = self.stats.migrated
        f0 = self.stats.faults
        s0 = self.stats.shed
        w0 = self.stats.wasted_g
        lv0 = self.stats.level_counts.copy()
        tr0 = dict(self.stats.tenant_requests)
        tm0 = dict(self.stats.tenant_slo_met)
        self.tick(t)
        routes: Dict[str, int] = {p.key: 0 for p in self.pools}
        for req in requests:
            _, key = self.submit(req)
            # .get: brownout shedding introduces the synthetic "shed" key
            routes[key] = routes.get(key, 0) + 1
        # KV telemetry is sampled with the hour's work in flight (after
        # drain the pages are back on the free heap and occupancy is 0)
        self.step()
        kv = {p.key: p.kv_stats() for p in self.pools}
        if on_inflight is not None:
            on_inflight(self)
        if steps is None:
            self.drain()
        else:
            for _ in range(max(steps - 1, 0)):
                self.step()
        mix = self.stats.level_counts - lv0
        # per-tenant deadline attainment over THIS hour's finishes
        slo: Dict[str, float] = {}
        for name, n in self.stats.tenant_requests.items():
            dn = n - tr0.get(name, 0)
            if dn > 0:
                dm = self.stats.tenant_slo_met.get(name, 0) - tm0.get(name, 0)
                slo[name] = dm / dn
        return {
            "t": t,
            "k0": {p.key: p.provider.intensity(t) for p in self.pools},
            "x": {p.key: p.x.copy() for p in self.pools},
            "routes": routes,
            "served": self.stats.requests - n0,
            "carbon_g": self.stats.carbon_g - c0,
            "level_mix": mix / max(mix.sum(), 1),
            "kv": kv,
            "migrated": self.stats.migrated - m0,
            "slo": slo,
            "draining": sorted(self.draining),
            "faults": self.stats.faults - f0,
            "shed": self.stats.shed - s0,
            "wasted_g": self.stats.wasted_g - w0,
            "brownout": self.brownout,
        }


def serve_request_from(req: Request, *, token_scale: float = 8.0,
                       min_new: int = 2, max_new: int = 40,
                       prompt: Optional[str] = None,
                       tenant: str = "",
                       deadline_s: float = float("inf")) -> ServeRequest:
    """Bridge a synthetic ``core.workload.Request`` onto the real engine:
    the per-level generation lengths the workload model predicts become
    per-level token budgets (scaled down to the reduced config), so the
    engine's MEASURED telemetry carries the paper's L0>=L1>=L2 brevity
    structure without needing an instruction-following model. The task
    family rides along so tenant LPs can weight their per-task q vectors
    by the live mix; ``tenant``/``deadline_s`` tag the request for the
    gateway's SLO layer (an unset deadline is stamped from the tenant's
    TTFT/TPOT targets at submit)."""
    budgets = [int(np.clip(round(g / token_scale), min_new, max_new))
               for g in req.gen_tokens]
    return ServeRequest(
        0, prompt or f"[{req.task}] request {req.rid}",
        max_new_tokens=budgets[0], max_new_by_level=budgets,
        task=req.task, tenant=tenant, deadline_s=deadline_s)
