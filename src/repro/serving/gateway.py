"""SproutGateway: the live control loop between the LP optimizer and the
serving fleet (Fig. 5, closed for real engines).

Until now the repo had two halves that never talked: a paper-faithful
control plane (``core/``) exercised only in simulation, and a device-
resident serving engine (``serving/``) whose ``CarbonAwareScheduler`` drew
directive levels from a static ``level_fn``. The gateway is the missing
component 1 of Fig. 5 — it owns

* one or more regional pools, each a ``CarbonIntensityProvider`` plus a
  ``CarbonAwareScheduler`` over real ``InferenceEngine`` replicas;
* a mix-exposing ``core.policies.Policy`` — ``SproutPolicy``,
  ``SproutStaticPolicy``, or anything whose ``begin_hour`` maintains a
  directive-level distribution ``.x`` — and ONE shared ``LevelProfiles``
  (per-level energy/time are properties of the model, not of the region);

and closes the loop in both directions:

  plan:      every ``replan_every`` simulated hours, each pool's current
             carbon intensity feeds ``policy.begin_hour`` (the Eq. 2-7 LP)
             and the resulting mix x is installed as that pool's scheduler
             ``level_fn`` — the LP is now literally in the request path;
  feedback:  every finished request's ENGINE-MEASURED telemetry (prompt /
             generated token counts and per-request decode-only seconds,
             ``FinishedRequest.decode_s``) is converted to (kWh, s) by
             ``EnergyModel.measure`` and fed to ``LevelProfiles.update``
             plus Eq. 1 carbon accounting via ``request_carbon`` — so the
             next re-plan optimizes over what the fleet actually did.

Multi-region routing (the new scenario axis): ``submit`` sends each
request to the greenest pool whose in-flight load is under ``load_cap``;
when every pool is saturated it falls back to the least-loaded one, so
carbon-chasing never starves throughput.

``policy=None`` degenerates to an L0-only gateway (the BASE scheme over
the same fleet) — the paired baseline ``benchmarks/serving_bench.py``
measures against.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.carbon import PUE, CarbonIntensityProvider, request_carbon
from repro.core.energy import A100_40GB, LLAMA2_13B, EnergyModel, \
    HardwareSpec, ModelProfile
from repro.core.policies import LevelProfiles, Policy
from repro.core.workload import N_LEVELS, Request
from repro.serving.engine import FinishedRequest
from repro.serving.scheduler import CarbonAwareScheduler, ServeRequest


@dataclasses.dataclass
class GatewayPool:
    """One regional serving pool: its grid signal, its fleet, its plan."""
    key: str
    provider: CarbonIntensityProvider
    scheduler: CarbonAwareScheduler
    x: np.ndarray                      # installed directive mix
    routed: int = 0                    # requests routed here

    def load(self) -> int:
        """In-flight work: scheduler backlog + engine queues + live slots."""
        return len(self.scheduler.pending) + sum(
            eng.load() for eng in self.scheduler.engines if eng is not None)

    def kv_stats(self) -> Dict[str, float]:
        """Fleet KV-memory telemetry: allocator occupancy/fragmentation
        summed over the pool's live engines (engine.kv_stats)."""
        stats = [eng.kv_stats() for eng in self.scheduler.engines
                 if eng is not None]
        if not stats:
            return {"engines": 0}
        layouts = {s.get("layout", "dense") for s in stats}
        out: Dict[str, float] = {
            "engines": len(stats),
            "layout": layouts.pop() if len(layouts) == 1 else "mixed",
        }
        # .get defaults: a pool may mix paged and dense replicas (elastic
        # scale-up can add either), and their stat schemas differ
        for key in ("pages_in_use", "live_tokens", "kv_bytes_in_use",
                    "kv_bytes_capacity", "committed_pages"):
            if any(key in s for s in stats):
                out[key] = sum(s.get(key, 0) for s in stats)
        for key in ("occupancy", "fragmentation"):
            out[key] = float(np.mean([s.get(key, 0.0) for s in stats]))
        return out


@dataclasses.dataclass
class PlanRecord:
    """One LP re-plan: what the optimizer saw and what it installed."""
    t: float
    pool: str
    k0: float
    x: np.ndarray
    q_lb: float = 0.0
    expected_quality: float = 0.0
    solver: str = "warmup"


@dataclasses.dataclass
class TelemetryRecord:
    """One finished request as the control plane saw it."""
    pool: str
    rid: int
    level: int
    prompt_tokens: int
    gen_tokens: int
    decode_s: float
    energy_kwh: float                  # incl. PUE
    carbon_g: float
    k0: float


@dataclasses.dataclass
class GatewayStats:
    carbon_g: float = 0.0
    energy_kwh: float = 0.0
    requests: int = 0
    level_counts: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(N_LEVELS))
    telemetry: List[TelemetryRecord] = dataclasses.field(default_factory=list)
    plans: List[PlanRecord] = dataclasses.field(default_factory=list)
    rejected: int = 0

    @property
    def carbon_per_request(self) -> float:
        return self.carbon_g / max(self.requests, 1)


PoolSpec = Tuple[Union[str, CarbonIntensityProvider], CarbonAwareScheduler]


class SproutGateway:
    """Fig. 5 component 1 over real engines — see the module docstring."""

    # long-lived control loop: aggregates run forever, per-record logs are
    # ring-buffered (oldest trimmed) so memory is bounded under real traffic
    TELEMETRY_CAP = 100_000
    PLAN_CAP = 10_000

    def __init__(self, pools: Sequence[PoolSpec], *,
                 policy: Optional[Policy] = None,
                 energy: Optional[EnergyModel] = None,
                 model_profile: ModelProfile = LLAMA2_13B,
                 hw: HardwareSpec = A100_40GB,
                 n_levels: int = N_LEVELS,
                 q: Optional[np.ndarray] = None,
                 replan_every: float = 1.0,
                 load_cap: int = 16,
                 seed: int = 0):
        assert pools, "gateway needs at least one regional pool"
        if policy is not None:
            # the gateway installs the policy's directive-level mix x as
            # each pool's level_fn (it never routes via policy.assign), so
            # only mix-exposing policies fit — SproutPolicy,
            # SproutStaticPolicy, or anything with a matching .x
            x = getattr(policy, "x", None)
            if x is None or len(np.asarray(x)) != n_levels:
                raise ValueError(
                    f"policy {type(policy).__name__} must expose a "
                    f"directive-level mix .x of length {n_levels}; got "
                    f"{'none' if x is None else len(np.asarray(x))}")
        self.policy = policy
        self.energy = energy or EnergyModel(hw)
        self.model_profile = model_profile
        self.hw = hw
        self.n_levels = n_levels
        self.replan_every = replan_every
        self.load_cap = load_cap
        self.rng = np.random.default_rng(seed)
        self.profiles = LevelProfiles.fresh(n_levels)
        self.q = (np.asarray(q, float) if q is not None
                  else np.ones(n_levels) / n_levels)
        self.stats = GatewayStats(level_counts=np.zeros(n_levels))
        self.t = 0.0
        self._last_replan: Optional[float] = None

        self.pools: List[GatewayPool] = []
        for spec, sched in pools:
            provider = (spec if isinstance(spec, CarbonIntensityProvider)
                        else CarbonIntensityProvider(spec))
            if len(sched.directives) < n_levels:
                raise ValueError(
                    f"pool {provider.region.key}: scheduler renders "
                    f"{len(sched.directives)} directive levels but the "
                    f"gateway plans over {n_levels} — pass a matching "
                    f"DirectiveSet to the CarbonAwareScheduler")
            pool = GatewayPool(provider.region.key, provider, sched,
                               x=np.eye(n_levels)[0])
            # the scheduler's level_fn now reads the pool's LIVE plan —
            # this is the wire that puts the LP in the request path
            sched.level_fn = (lambda p=pool: int(
                self.rng.choice(self.n_levels, p=p.x)))
            self.pools.append(pool)

    # ----- planning ---------------------------------------------------
    def set_quality(self, q: np.ndarray) -> None:
        """Install a fresh evaluator preference vector (Eq. 5's q)."""
        self.q = np.asarray(q, float)

    def replan(self, t: Optional[float] = None) -> None:
        """Re-solve the directive LP per pool at its CURRENT intensity and
        install the mixes. ``policy=None`` pins every pool to L0."""
        if t is not None:
            self.t = t
        self._last_replan = self.t
        # amortized trim: cut back to the cap only at 2x, so steady state
        # is O(1) per replan rather than a full shift every time
        if len(self.stats.plans) > 2 * self.PLAN_CAP:
            del self.stats.plans[: -self.PLAN_CAP]
        for pool in self.pools:
            k0 = pool.provider.intensity(self.t)
            if self.policy is None:
                pool.x = np.eye(self.n_levels)[0]
                self.stats.plans.append(PlanRecord(
                    self.t, pool.key, k0, pool.x.copy(), solver="l0-fixed"))
                continue
            self.policy.begin_hour(self.t, k0, self.profiles, self.q, {})
            pool.x = np.asarray(self.policy.x, float).copy()
            sol = getattr(self.policy, "last_solution", None)
            self.stats.plans.append(PlanRecord(
                self.t, pool.key, k0, pool.x.copy(),
                q_lb=(sol.q_lb if sol else 0.0),
                expected_quality=(sol.expected_quality if sol
                                  else float(self.q @ pool.x)),
                solver=(sol.solver if sol else "warmup")))

    def tick(self, t: float) -> None:
        """Advance the gateway clock; re-plan when the interval elapsed."""
        self.t = t
        if (self._last_replan is None
                or t - self._last_replan >= self.replan_every - 1e-9):
            self.replan()

    # ----- request path ----------------------------------------------
    def submit(self, req: ServeRequest) -> Tuple[int, str]:
        """Route to the greenest pool under ``load_cap`` (least-loaded when
        all pools are saturated); returns (rid, pool key). Pools whose
        fleet is entirely gone are skipped while any alternative exists."""
        alive = [p for p in self.pools
                 if any(e is not None for e in p.scheduler.engines)]
        candidates = alive or self.pools
        by_carbon = sorted(
            candidates, key=lambda p: p.provider.intensity(self.t))
        pool = next((p for p in by_carbon if p.load() < self.load_cap),
                    min(candidates, key=lambda p: p.load()))
        rid = pool.scheduler.submit(req)
        pool.routed += 1
        return rid, pool.key

    def step(self) -> int:
        """One fleet step across every pool; harvests finished telemetry."""
        tokens = 0
        for pool in self.pools:
            tokens += pool.scheduler.step()
            if pool.scheduler.finished:
                for fin in pool.scheduler.finished:
                    self._account(pool, fin)
                pool.scheduler.finished = []
        return tokens

    def drain(self, max_steps: int = 100000) -> None:
        """Serve until every pool is idle. A pool whose fleet is entirely
        gone can never serve its backlog — its pending requests are parked
        as rejected instead of spinning here and skewing routing load."""
        for _ in range(max_steps):
            tokens = self.step()
            if not any(p.load() for p in self.pools):
                break
            if tokens == 0:
                for p in self.pools:
                    if p.scheduler.pending and not any(
                            e is not None for e in p.scheduler.engines):
                        p.scheduler.rejected.extend(
                            (req, "no live engines in pool")
                            for req in p.scheduler.pending)
                        p.scheduler.pending = []
        for pool in self.pools:
            self.stats.rejected += len(pool.scheduler.rejected)
            pool.scheduler.rejected = []

    # ----- feedback ---------------------------------------------------
    def _account(self, pool: GatewayPool, fin: FinishedRequest) -> None:
        """Engine telemetry -> kWh (EnergyModel.measure) -> Eq. 1 carbon +
        LevelProfiles feedback. This is the loop's return edge: the next
        ``replan`` solves over exactly these measured profiles."""
        k0 = pool.provider.intensity(self.t)
        kwh, secs = self.energy.measure(
            self.model_profile, fin.prompt_tokens, fin.gen_tokens,
            fin.decode_s)
        kwh *= PUE
        carbon = request_carbon(k0, kwh, secs, self.hw.embodied_gco2,
                                self.hw.lifetime_s, pue=1.0)
        self.profiles.update(fin.directive_level, kwh, secs)
        st = self.stats
        st.carbon_g += carbon
        st.energy_kwh += kwh
        st.requests += 1
        st.level_counts[fin.directive_level] += 1
        st.telemetry.append(TelemetryRecord(
            pool.key, fin.rid, fin.directive_level, fin.prompt_tokens,
            fin.gen_tokens, fin.decode_s, kwh, carbon, k0))
        if len(st.telemetry) > 2 * self.TELEMETRY_CAP:
            # amortized: one O(cap) shift per cap appends, not per request
            del st.telemetry[: -self.TELEMETRY_CAP]

    # ----- convenience ------------------------------------------------
    def run_hour(self, t: float, requests: Sequence[ServeRequest],
                 on_inflight=None) -> Dict:
        """One simulated hour: tick (re-plan if due), route, serve, account.
        Returns a summary of what this hour did. ``on_inflight(gateway)``,
        if given, runs after one fleet step with the hour's work in flight —
        the hook for fault/elasticity scenarios (fail a replica, scale up)
        without hand-rolling the hour's accounting."""
        n0 = self.stats.requests
        c0 = self.stats.carbon_g
        lv0 = self.stats.level_counts.copy()
        self.tick(t)
        routes: Dict[str, int] = {p.key: 0 for p in self.pools}
        for req in requests:
            _, key = self.submit(req)
            routes[key] += 1
        # KV telemetry is sampled with the hour's work in flight (after
        # drain the pages are back on the free heap and occupancy is 0)
        self.step()
        kv = {p.key: p.kv_stats() for p in self.pools}
        if on_inflight is not None:
            on_inflight(self)
        self.drain()
        mix = self.stats.level_counts - lv0
        return {
            "t": t,
            "k0": {p.key: p.provider.intensity(t) for p in self.pools},
            "x": {p.key: p.x.copy() for p in self.pools},
            "routes": routes,
            "served": self.stats.requests - n0,
            "carbon_g": self.stats.carbon_g - c0,
            "level_mix": mix / max(mix.sum(), 1),
            "kv": kv,
        }


def serve_request_from(req: Request, *, token_scale: float = 8.0,
                       min_new: int = 2, max_new: int = 40,
                       prompt: Optional[str] = None) -> ServeRequest:
    """Bridge a synthetic ``core.workload.Request`` onto the real engine:
    the per-level generation lengths the workload model predicts become
    per-level token budgets (scaled down to the reduced config), so the
    engine's MEASURED telemetry carries the paper's L0>=L1>=L2 brevity
    structure without needing an instruction-following model."""
    budgets = [int(np.clip(round(g / token_scale), min_new, max_new))
               for g in req.gen_tokens]
    return ServeRequest(
        0, prompt or f"[{req.task}] request {req.rid}",
        max_new_tokens=budgets[0], max_new_by_level=budgets)
