"""Slot-based continuous-batching inference engine (JetStream-style).

TPU adaptation of vLLM's continuous batching: a fixed decode batch of
``n_slots``; each slot owns a linear KV region of ``max_len`` tokens.
Requests are prefilled one at a time (batch-1 prefill, the common TPU
serving pattern) and *inserted* into a free slot; every ``step()`` decodes
one token for all live slots. Finished slots are freed and refilled from
the queue. Prefill-compute and decode-compute are separate jitted programs,
so decode latency is never blocked on prefill compilation.

Fine-grained GPU-style paging is intentionally replaced by per-slot linear
regions + the block-table Pallas decode kernel (kernels/paged_attention.py)
for the HBM-limited regime — see DESIGN.md §3 (hardware adaptation).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as MD
from repro.models.common import ModelConfig
from repro.serving.sampler import SamplingParams, sample_logits
from repro.serving.tokenizer import ByteTokenizer


@dataclasses.dataclass
class RequestState:
    rid: int
    prompt_ids: List[int]
    max_new_tokens: int
    sampling: SamplingParams
    directive_level: int = 0
    slot: int = -1
    generated: List[int] = dataclasses.field(default_factory=list)
    prompt_len: int = 0
    done: bool = False
    t_submit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0


@dataclasses.dataclass
class FinishedRequest:
    rid: int
    token_ids: List[int]
    text: str
    prompt_tokens: int
    gen_tokens: int
    ttft_s: float
    latency_s: float
    directive_level: int


class InferenceEngine:
    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 4,
                 max_len: int = 256, eos_id: int = ByteTokenizer.EOS,
                 tokenizer: Optional[ByteTokenizer] = None, seed: int = 0):
        assert cfg.family in ("dense", "moe", "hybrid", "ssm", "vlm"), \
            f"serving engine drives decoder-style models, got {cfg.family}"
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.tok = tokenizer or ByteTokenizer()
        self.key = jax.random.PRNGKey(seed)

        self.cache = MD.init_cache(cfg, n_slots, max_len)
        self.slots: List[Optional[RequestState]] = [None] * n_slots
        self.positions = np.zeros(n_slots, np.int64)   # next position per slot
        self.last_token = np.zeros(n_slots, np.int64)
        self.queue: List[RequestState] = []
        self.finished: List[FinishedRequest] = []
        self.steps = 0
        self.decode_tokens = 0

        self._prefill_jit: Dict[int, Callable] = {}

        def _decode(params, tokens, positions, cache):
            return MD.decode_step(cfg, params, tokens, positions, cache)

        self._decode_jit = jax.jit(_decode, donate_argnums=(3,))

        def _insert(batch_cache, one_cache, slot):
            return jax.tree.map(
                lambda full, one: jax.lax.dynamic_update_index_in_dim(
                    full, one[:, 0].astype(full.dtype), slot, 1),
                batch_cache, one_cache)

        self._insert_jit = jax.jit(_insert, donate_argnums=(0,))

    # ------------------------------------------------------------------
    def submit(self, prompt_ids: List[int], *, max_new_tokens: int = 64,
               sampling: SamplingParams = SamplingParams(),
               directive_level: int = 0, rid: Optional[int] = None) -> int:
        rid = rid if rid is not None else len(self.finished) + len(self.queue) + 1000
        st = RequestState(rid, list(prompt_ids), max_new_tokens, sampling,
                          directive_level, t_submit=time.monotonic())
        self.queue.append(st)
        return rid

    # ------------------------------------------------------------------
    def _prefill_fn(self, plen: int) -> Callable:
        """Jitted batch-1 prefill at a padded bucket length."""
        if plen not in self._prefill_jit:
            cfg = self.cfg

            def _prefill(params, tokens, lengths):
                logits, cache, _ = MD.prefill(cfg, params, tokens,
                                              max_len=self.max_len,
                                              lengths=lengths)
                # last valid position's logits
                last = jnp.take_along_axis(
                    logits, (lengths - 1)[:, None, None], axis=1)[:, 0]
                return last, cache

            self._prefill_jit[plen] = jax.jit(_prefill)
        return self._prefill_jit[plen]

    @staticmethod
    def _bucket(n: int) -> int:
        b = 16
        while b < n:
            b *= 2
        return b

    def _try_prefill(self) -> None:
        for slot in range(self.n_slots):
            if self.slots[slot] is not None or not self.queue:
                continue
            st = self.queue.pop(0)
            ids = st.prompt_ids[: self.max_len - st.max_new_tokens - 1]
            st.prompt_len = len(ids)
            plen = min(self._bucket(len(ids)), self.max_len)
            toks = np.zeros((1, plen), np.int32)
            toks[0, : len(ids)] = ids
            lengths = np.array([len(ids)], np.int32)
            logits, one_cache = self._prefill_fn(plen)(
                self.params, jnp.asarray(toks), jnp.asarray(lengths))
            self.key, sk = jax.random.split(self.key)
            first = int(sample_logits(logits, sk, st.sampling)[0])
            self.cache = [self._insert_jit(bc, oc, slot)
                          for bc, oc in zip(self.cache, one_cache)]
            st.slot = slot
            st.generated = [first]
            st.t_first_token = time.monotonic()
            self.slots[slot] = st
            self.positions[slot] = st.prompt_len
            self.last_token[slot] = first
            if first == self.eos_id:
                self._finish(slot)

    # ------------------------------------------------------------------
    def _finish(self, slot: int) -> None:
        st = self.slots[slot]
        assert st is not None
        st.done = True
        st.t_done = time.monotonic()
        gen = st.generated[:-1] if st.generated and st.generated[-1] == self.eos_id \
            else st.generated
        self.finished.append(FinishedRequest(
            st.rid, gen, self.tok.decode(gen), st.prompt_len, len(gen),
            st.t_first_token - st.t_submit, st.t_done - st.t_submit,
            st.directive_level))
        self.slots[slot] = None

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One continuous-batching step: refill slots, decode one token."""
        self._try_prefill()
        live = [i for i, s in enumerate(self.slots) if s is not None]
        if not live:
            return 0
        tokens = jnp.asarray(self.last_token[:, None], jnp.int32)
        positions = jnp.asarray(self.positions, jnp.int32)
        logits, self.cache = self._decode_jit(self.params, tokens, positions,
                                              self.cache)
        self.key, sk = jax.random.split(self.key)
        # per-slot sampling params may differ; group greedy vs sampled
        nxt = np.array(jax.device_get(
            sample_logits(logits, sk, SamplingParams())))
        sampled_any = any(self.slots[i].sampling.temperature > 0 for i in live)
        if sampled_any:
            for i in live:
                sp = self.slots[i].sampling
                if sp.temperature > 0:
                    self.key, sk = jax.random.split(self.key)
                    nxt[i] = int(sample_logits(logits[i:i + 1], sk, sp)[0])
        self.steps += 1
        for i in live:
            st = self.slots[i]
            self.positions[i] += 1
            tok = int(nxt[i])
            st.generated.append(tok)
            self.last_token[i] = tok
            self.decode_tokens += 1
            hit_len = (len(st.generated) >= st.max_new_tokens
                       or st.prompt_len + len(st.generated) >= self.max_len - 1)
            if tok == self.eos_id or hit_len:
                self._finish(i)
        return len(live)

    # ------------------------------------------------------------------
    def run_to_completion(self, max_steps: int = 100000) -> List[FinishedRequest]:
        while (self.queue or any(s is not None for s in self.slots)) \
                and self.steps < max_steps:
            self.step()
        return self.finished

    # ------------------------------------------------------------------
    def drain_slots(self) -> List[RequestState]:
        """Preemption support: evict live requests for requeueing (their
        generation restarts on another replica — prefix tokens preserved)."""
        out = []
        for i, st in enumerate(self.slots):
            if st is not None:
                st.slot = -1
                out.append(st)
                self.slots[i] = None
        return out
