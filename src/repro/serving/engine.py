"""Slot-based continuous-batching inference engine (JetStream-style).

TPU adaptation of vLLM's continuous batching: a fixed decode batch of
``n_slots``; each slot owns a linear KV region of ``max_len`` tokens.
Queued requests are prefilled in bucketed batches across all free slots and
*inserted* into those slots with a single donated tree-level cache update.
Finished slots are freed and refilled from the queue. Prefill-compute and
decode-compute are separate jitted programs, so decode latency is never
blocked on prefill compilation.

The decode hot path is **device-resident**: per-slot positions, last
tokens, live mask, generation counters and stacked sampling parameters
(temperature / top-k / top-p arrays) live inside one jitted program that
runs up to ``decode_block`` decode+sample steps under ``jax.lax.scan``
before the host looks at anything. Sampling is fused into the decode step
(``models.model.decode_sample_step`` + ``sampler.sample_logits_batched``),
so greedy and sampled slots coexist in one batch with no per-slot Python
re-sampling, and the engine performs exactly one ``jax.device_get`` per
block of up to ``decode_block`` decoded tokens. The block length shrinks
to the soonest deterministic finish (length caps), so a freed slot is
refilled — and prefill runs — at the earliest step it can matter; EOS
inside a block just masks the slot until the block ends.

Two serving-shape mechanisms sit on top of that loop (DESIGN.md §3):

**Batch-bucketed entry points** (SHARK-Engine style ``decode_bs{N}``
function tables): each dispatch selects a compiled program sized to the
power-of-two ceiling of *live occupancy* instead of always paying
``n_slots``-batch FLOPs. The bucketed program gathers the participating
rows of every cache leaf (axis 1) on entry and scatters them back on
exit — one gather/scatter pair per block, amortized over up to
``decode_block`` steps — and folds the sampler PRNG by *slot id* so drawn
tokens are invariant to which bucket served a row. At full occupancy the
un-gathered identity program runs, byte-identical to the fixed-batch
world. Paged caches need no gather at all (the page store is shared; only
the tiny block table is row-selected host-side). MoE stacks pin
``bs = n_slots``: expert-capacity routing is batch-shape-dependent, so
bucketing would perturb their token streams.

**Chunked prefill** (Sarathi/vLLM continuous batching): with
``prefill_chunk > 0`` and live decode lanes, an arriving request is NOT
prefilled in a whole-prompt stall. It is admitted as a *chunk task*: each
scan step of the fused program processes the mixed batch of decode lanes
plus at most one ``prefill_chunk``-token prompt chunk for the admitted
lane (``models.model.prefill_chunk_step``), so live lanes keep emitting
tokens while the newcomer's KV fills in. The final chunk samples the
request's first token *inside the scan* and flips the lane live — first
tokens arrive in-band through the same one-``device_get``-per-block
fetch. Idle engines (no live lanes) keep the batched whole-prompt prefill
path, which is strictly faster when there is nothing to stall.

The KV cache has two layouts (DESIGN.md §3). The default dense layout
gives each slot a linear ``max_len`` region, so memory is
``n_slots x max_len`` regardless of what the slots hold. ``paged=True``
switches the same fused loop onto the block-table paged store: prompt K/V
is bulk-written into pages at prefill, in-loop appends write through a
device block table (dead lanes redirected to a dropped out-of-bounds
page), and decode attention runs the Pallas paged kernel
(kernels/paged_attention.py) or its XLA reference per ``paged_impl``.
Memory then scales with *live tokens*, and admission is governed by a
page budget: a request occupies a slot only while its worst-case page
reservation — derived from its (directive-level-selected) token budget —
fits, so brief-directive traffic packs more concurrent requests into the
same HBM. ``kv_int8=True`` stores pages as int8 with per-token-per-head
scales, halving decode HBM traffic end to end.

``prefix_cache=True`` (paged only) additionally turns the page store into
a **radix prefix cache** (DESIGN.md §13): full prompt pages are
content-hashed and shared across requests. Admission consults the index
first — a hit maps the cached pages into the new slot's block table (zero
prefill FLOPs and zero new pages for the shared span) and the request is
admitted as a chunk task whose prompt streaming *starts at the first
uncached token*; divergent appends into a shared page go through
copy-on-write before the write lands, so the fused scan programs are
untouched and token streams stay bit-identical to the cache-off engine
under greedy sampling. Admission's reservation is prefix-aware
(``_pages_for`` subtracts adopted pages, plus one for a potential COW)
and the gate counts *pinned* shared pages so a page is paid for once,
never per adopter.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as MD
from repro.models.common import ModelConfig
from repro.serving.kv_cache import PageAllocator
from repro.serving.sampler import (SamplingParams, greedy_sample,
                                   sample_logits_batched,
                                   sample_temperature_only)
from repro.serving.tokenizer import ByteTokenizer


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


@dataclasses.dataclass
class RequestState:
    rid: int
    prompt_ids: List[int]
    max_new_tokens: int
    sampling: SamplingParams
    directive_level: int = 0
    slot: int = -1
    generated: List[int] = dataclasses.field(default_factory=list)
    prompt_len: int = 0
    done: bool = False
    # t_submit is the ORIGINAL submission time: preserved across failover
    # requeue and cross-pool migration (engine.submit accepts it), so
    # latency — and therefore deadline attainment — is measured end to end
    # including any redo, not per-engine
    t_submit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    # SLO identity: the tenant service class, dispatch priority, and the
    # absolute completion deadline (monotonic clock; inf = no deadline).
    # Carried through requeue/migration unchanged and reported on the
    # FinishedRequest.
    tenant: str = ""
    deadline_at: float = float("inf")
    priority: int = 1
    # fault-recovery bookkeeping (DESIGN.md §12): how many fault-caused
    # requeues this request has survived, and what the last fault was
    # ("decode.nonfinite", "replica.crash", ...). Carried through the
    # verbatim requeue path and reported on the FinishedRequest so the
    # chaos suite can assert bounded retries end to end.
    retries: int = 0
    last_fault: str = ""
    # decode-only device seconds attributed to THIS request: each warm
    # decode block's wall time is partitioned per step across the slots
    # that decoded in it, so summed attribution equals device time (the
    # property energy accounting needs); compile dispatches charge nothing
    decode_s: float = 0.0
    # paged admission: the exact page count this request was charged at
    # admission. With prefix-aware reservations the charge depends on
    # cache state at admission time, so every release site must repay
    # this stored amount — a recompute would drift (DESIGN.md §13)
    reserved_pages: int = 0
    # prompt tokens served from the radix prefix cache (prefill skipped);
    # reported on FinishedRequest so Eq. 1 accounting can credit them
    cached_tokens: int = 0


@dataclasses.dataclass
class _ChunkTask:
    """An admitted-but-still-prefilling request: its prompt streams into
    the fused scan ``chunk`` tokens per step while other lanes decode.
    ``next`` is the first prompt position not yet dispatched — it starts
    at the first *uncached* token when a prefix hit adopted pages, so the
    shared span is never recomputed."""
    slot: int
    ids: List[int]
    plen: int
    next: int = 0
    chunk: int = 0


@dataclasses.dataclass
class FinishedRequest:
    rid: int
    token_ids: List[int]
    text: str
    prompt_tokens: int
    gen_tokens: int
    ttft_s: float
    latency_s: float
    directive_level: int
    decode_s: float = 0.0   # decode-only seconds attributed to this request
    tenant: str = ""        # SLO service class ("" = untagged)
    deadline_at: float = float("inf")   # absolute deadline (monotonic)
    t_done: float = 0.0     # finish time (monotonic) for attainment checks
    retries: int = 0        # fault-caused requeues survived (DESIGN.md §12)
    cached_tokens: int = 0  # prompt tokens served from the prefix cache

    @property
    def slo_met(self) -> bool:
        """Did this request finish by its deadline? (True when untagged.)"""
        return self.t_done <= self.deadline_at


class InferenceEngine:
    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 4,
                 max_len: int = 256, eos_id: int = ByteTokenizer.EOS,
                 tokenizer: Optional[ByteTokenizer] = None, seed: int = 0,
                 decode_block: int = 8, paged: bool = False,
                 page_size: int = 32, n_pages: Optional[int] = None,
                 kv_int8: bool = False, paged_impl: str = "auto",
                 prefill_chunk: int = 0, prefix_cache: bool = False,
                 tp_degree: int = 1):
        assert cfg.family in ("dense", "moe", "hybrid", "ssm", "vlm"), \
            f"serving engine drives decoder-style models, got {cfg.family}"
        assert decode_block >= 1
        if kv_int8:
            # params are dtype-independent of the cache; only cache init and
            # the decode read/write paths consult kv_cache_dtype
            cfg = cfg.replace(kv_cache_dtype="int8")
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.decode_block = decode_block
        self.paged = paged
        self.paged_impl = paged_impl
        self.prefill_chunk = prefill_chunk
        # chunked prefill serves the same stacks as paged decode; anything
        # else silently keeps the whole-prompt path (callers need not care)
        self._chunked_ok = (prefill_chunk > 0
                            and MD.chunked_prefill_supported(cfg))
        # the radix prefix cache rides the paged store, and a prefix hit
        # is always served through the chunk program (the uncached suffix
        # must attend over adopted pages) — so it requires both; anything
        # else silently keeps plain paging
        self._prefix_ok = (paged and prefix_cache
                           and MD.chunked_prefill_supported(cfg))
        self.prefix_cache = self._prefix_ok
        self.prefill_tokens_computed = 0   # prompt tokens actually prefilled
        self.prefill_tokens_cached = 0     # prompt tokens served from cache
        # batch bucketing changes the decode batch shape; MoE expert
        # capacity is batch-shape-dependent, so MoE stacks pin bs=n_slots
        self._bucketing = cfg.n_experts == 0
        self._task: Optional[_ChunkTask] = None
        self.tok = tokenizer or ByteTokenizer()
        self.key = jax.random.PRNGKey(seed)

        if paged:
            assert MD.paged_supported(cfg), \
                f"paged decode unsupported for {cfg.name}"
            max_pages = (max_len + page_size - 1) // page_size
            # default budget: the dense layout's worst-case footprint, so
            # paged-vs-dense comparisons start from equal HBM
            n_pages = n_pages if n_pages is not None else n_slots * max_pages
            self.pages = PageAllocator(n_pages=n_pages, page_size=page_size,
                                       n_slots=n_slots, max_len=max_len,
                                       prefix_cache=self._prefix_ok,
                                       kv_salt=cfg.kv_cache_dtype)
            # page-budget admission state: sum of slotted requests'
            # admission-time reservations. The exact charge is stored on
            # the state (RequestState.reserved_pages) because prefix-aware
            # reservations depend on cache contents at admission — a
            # release-time recompute would drift the ledger. The standing
            # invariant is _committed + pages.pinned <= n_pages: every
            # page a slotted request can ever demand is covered by its own
            # reservation or already active-and-unowned (pinned), so
            # mid-decode page growth can never hit MemoryError
            self.cache = MD.init_paged_cache(cfg, n_pages, page_size)
            self._committed = 0
        else:
            self.pages = None
            self.cache = MD.init_cache(cfg, n_slots, max_len)
        # Tensor-parallel decode (DESIGN.md §14): shard params and the KV
        # store over the mesh "model" axis and let SPMD propagation carry
        # the shardings through the unchanged fused programs. Only the
        # *placement* changes — params/cache are device_put under the
        # ShardSpec and entry-point names gain a _tp{T} suffix so bucketed
        # programs compiled for different meshes never collide. tp_degree=1
        # is byte-identical to the pre-TP engine (no mesh is built).
        assert tp_degree >= 1
        self.tp_degree = tp_degree
        if tp_degree > 1:
            from repro.launch.mesh import make_tp_mesh
            from repro.launch.sharding import serving_shard_spec
            mesh = make_tp_mesh(tp_degree)
            self.shard_spec = serving_shard_spec(
                cfg, mesh, self.params, self.cache, paged=paged)
            self.params = jax.device_put(self.params, self.shard_spec.params)
            self.cache = jax.device_put(self.cache, self.shard_spec.cache)
        else:
            self.shard_spec = None
        self._tp_suffix = self.shard_spec.suffix if self.shard_spec else ""
        self.slots: List[Optional[RequestState]] = [None] * n_slots
        # host mirrors of the device decode state (scheduling decisions
        # only; pushed to device per block, refreshed from the block fetch)
        self.positions = np.zeros(n_slots, np.int64)   # next position per slot
        self.last_token = np.zeros(n_slots, np.int64)
        self.live = np.zeros(n_slots, bool)
        self.gen_count = np.zeros(n_slots, np.int64)
        self.max_new = np.ones(n_slots, np.int64)
        self.temp = np.zeros(n_slots, np.float32)
        self.top_k = np.zeros(n_slots, np.int64)
        self.top_p = np.ones(n_slots, np.float32)
        self.queue: List[RequestState] = []
        self.finished: List[FinishedRequest] = []
        # lanes quarantined for non-finite logits (or poisoned by fault
        # injection): the scheduler harvests these each step and requeues
        # them over the verbatim-token path under its retry budget
        self.faulted: List[RequestState] = []
        # high-water marks, sampled at maximal residency inside step() —
        # after prefill admission / page growth, BEFORE same-step finishes
        # release slots and pages (a post-step observer would undercount
        # requests that are admitted and complete within one block)
        self.peak_concurrent = 0
        self.peak_pages_in_use = 0
        self.steps = 0
        self.decode_tokens = 0
        self.decode_syncs = 0          # host round trips on the decode path
        self.last_decode_s = 0.0       # decode-only wall time, last dispatch
        self.chunk_steps = 0           # prompt chunks streamed into the scan
        self.pages_grown_chunked = 0   # pages mapped per-chunk, not at insert
        self._next_rid = 1000

        def _prefill(params, tokens, lengths):
            logits, cache, _ = MD.prefill(cfg, params, tokens,
                                          max_len=self.max_len,
                                          lengths=lengths)
            # last valid position's logits
            last = jnp.take_along_axis(
                logits, (lengths - 1)[:, None, None], axis=1)[:, 0]
            return last, cache

        self._prefill_jit = jax.jit(_prefill)   # retraces per (nb, plen)

        def _insert(batch_cache, one_cache, slots):
            # one tree-level donated update for the whole layer stack:
            # every cache leaf is (n_layers, batch, ...), so scattering the
            # prefill rows into their slots along axis 1 covers all layers
            # of all segments in a single program
            return jax.tree.map(
                lambda full, one: full.at[:, slots].set(one.astype(full.dtype)),
                batch_cache, one_cache)

        self._insert_jit = jax.jit(_insert, donate_argnums=(0,))

        def _paged_insert(cache, one_cache, page_ids, offs):
            # bulk-write prompt K/V into pages: one scatter per tree leaf
            # for the whole bucketed prefill batch. page_ids/offs are
            # (nb, T) with over-length / pad entries pointing at the
            # out-of-bounds page id (scatter drops them). kpos has no paged
            # counterpart — validity is positional (index < length).
            T = page_ids.shape[1]
            out = []
            for seg_full, seg_one in zip(cache, one_cache):
                d = dict(seg_full)
                for name in seg_full:
                    d[name] = seg_full[name].at[:, page_ids, offs].set(
                        seg_one[name][:, :, :T].astype(seg_full[name].dtype))
                out.append(d)
            return out

        self._paged_insert_jit = jax.jit(_paged_insert, donate_argnums=(0,))

        def _fill_slot(cache, slot, value):
            # constant-fill one dense lane's float leaves (poison = NaN,
            # scrub = 0.0 — both values are traced operands, so the two
            # uses share ONE compiled program). Int leaves (kpos, int8
            # K/V) are left alone: non-finiteness rides the float scales.
            return jax.tree.map(
                lambda a: (a.at[:, slot].set(value.astype(a.dtype))
                           if jnp.issubdtype(a.dtype, jnp.floating) else a),
                cache)

        self._fill_slot_jit = jax.jit(_fill_slot, donate_argnums=(0,))

        def _fill_pages(cache, page_ids, value):
            # constant-fill the given pages across every layer/segment of
            # the paged store; out-of-bounds ids (unmapped table entries,
            # sanitized host-side) scatter-drop. Float leaves only.
            out = []
            for seg in cache:
                d = dict(seg)
                for nm in seg:
                    if jnp.issubdtype(seg[nm].dtype, jnp.floating):
                        d[nm] = seg[nm].at[:, page_ids].set(
                            value.astype(seg[nm].dtype))
                out.append(d)
            return out

        self._fill_pages_jit = jax.jit(_fill_pages, donate_argnums=(0,))

        def _copy_page(cache, src, dst):
            # copy-on-write support (DESIGN.md §13): duplicate one page's
            # contents onto a fresh page across every layer/segment and
            # EVERY leaf — int8 K/V and their scales included, the copy
            # must be bit-exact — before a divergent write lands in it
            out = []
            for seg in cache:
                d = dict(seg)
                for nm in seg:
                    d[nm] = seg[nm].at[:, dst].set(seg[nm][:, src])
                out.append(d)
            return out

        self._copy_page_jit = jax.jit(_copy_page, donate_argnums=(0,))
        # compiled entry-point table (SHARK-Engine style function tables):
        # "decode_bs{N}_k{K}_{mode}" / "mixed_bs{N}_k{K}_c{C}_{mode}" fused
        # programs plus "prefill_bs{N}_p{P}" whole-prompt shapes. The bench
        # warmup drives every variant it will measure and asserts the table
        # does not grow inside a measured window (warm paths only).
        self.entry_points: Dict[str, Callable] = {}

    # ------------------------------------------------------------------
    def submit(self, prompt_ids: List[int], *, max_new_tokens: int = 64,
               sampling: Optional[SamplingParams] = None,
               directive_level: int = 0, rid: Optional[int] = None,
               tenant: str = "", deadline_at: float = float("inf"),
               priority: int = 1, t_submit: Optional[float] = None,
               retries: int = 0, last_fault: str = "") -> int:
        # fresh default per call — a def-time SamplingParams() default would
        # be one shared instance across every default-submitted request
        sampling = sampling if sampling is not None else SamplingParams()
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {max_new_tokens}")
        if max_new_tokens + 1 >= self.max_len:
            raise ValueError(
                f"max_new_tokens={max_new_tokens} leaves no room for a prompt "
                f"in a max_len={self.max_len} KV region; need "
                f"max_new_tokens + 1 < max_len")
        if not prompt_ids:
            raise ValueError("empty prompt")
        if self.paged:
            need = self._pages_for(
                len(prompt_ids[: self.max_len - max_new_tokens - 1]),
                max_new_tokens)
            if need > self.pages.n_pages:
                raise ValueError(
                    f"request needs {need} pages > page budget "
                    f"{self.pages.n_pages} (page_size="
                    f"{self.pages.page_size})")
        if rid is None:
            rid = self._next_rid
            self._next_rid += 1
        st = RequestState(rid, list(prompt_ids), max_new_tokens, sampling,
                          directive_level,
                          t_submit=(time.monotonic() if t_submit is None
                                    else t_submit),
                          tenant=tenant, deadline_at=deadline_at,
                          priority=priority, retries=retries,
                          last_fault=last_fault)
        self.queue.append(st)
        return rid

    # ------------------------------------------------------------------
    def load(self) -> int:
        """In-flight work: queued requests + occupied slots. The load
        signal shared by scheduler dispatch and gateway routing."""
        return len(self.queue) + sum(s is not None for s in self.slots)

    @property
    def chunked_admission(self) -> bool:
        """True when this engine admits new work by streaming prompt
        chunks into the live decode scan (no whole-prompt stall): the
        signal the gateway's predicted-completion model and the
        scheduler's dispatch ordering key on."""
        return self._chunked_ok

    # ------------------------------------------------------------------
    @staticmethod
    def _bucket(n: int) -> int:
        return max(16, _next_pow2(n))

    def _slot_cap(self, prompt_len: int, max_new: int) -> int:
        """Most tokens a request can ever write into its KV region (prompt
        + generated-minus-last, under the max_len-2 position cap), plus one
        page-rounding-safe token of slack. The SINGLE cap expression both
        the admission reservation and per-block page growth derive from —
        the no-MemoryError-mid-decode invariant is that growth never
        exceeds the reservation, i.e. this function."""
        return min(prompt_len + max_new, self.max_len - 1)

    def _pages_for(self, prompt_len: int, max_new: int,
                   cached_pages: int = 0, cow_pages: int = 0) -> int:
        """Worst-case page reservation for a request — the admission unit.
        Directive-aware by construction: ``max_new`` is the budget the
        drawn directive level selected, so L2-brief requests reserve few
        pages and more of them fit a fixed page budget. Prefix-aware on
        top (DESIGN.md §13): adopted cached pages cost nothing new and are
        subtracted; a fully-cached page-aligned prompt adds ``cow_pages``
        (one) back for the copy-on-write its 1-token recompute triggers."""
        return (self.pages.pages_needed(self._slot_cap(prompt_len, max_new))
                - cached_pages + cow_pages)

    def _try_prefill(self) -> None:
        """Fill free slots from the queue, batching prefill per padded
        bucket length instead of strictly batch-1. In paged mode a request
        is admitted only while its worst-case page reservation fits the
        remaining budget (FIFO — admission never reorders the queue), so
        concurrency is bounded by live-token demand, not slot count.

        With chunked prefill enabled and decode lanes live, admission goes
        through the chunk task instead: one request at a time streams its
        prompt into the fused scan and the live lanes never stall. The
        whole-prompt path below only runs on an otherwise-idle engine,
        where a batched prefill is strictly faster than chunking."""
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not free or not self.queue:
            return
        if self._prefix_ok:
            # a prefix HIT is admitted through the chunk task even on an
            # otherwise-idle engine: the uncached suffix must attend over
            # the adopted pages, which only the chunk program's
            # block-table reads can do — the whole-prompt batch prefill
            # recomputes from position 0 and would forfeit the hit. FIFO:
            # if the single task lane is busy, the head waits
            head = self.queue[0]
            ids0 = head.prompt_ids[: self.max_len - head.max_new_tokens - 1]
            if self.pages.match_prefix(ids0)[0] > 0:
                if self._task is None:
                    self._admit_chunk_task(free[0])
                return
        if self._chunked_ok and self.live.any():
            if self._task is None:
                self._admit_chunk_task(free[0])
            return
        taken: List[Tuple[int, RequestState, List[int]]] = []
        for slot in free:
            if not self.queue:
                break
            st = self.queue[0]
            # submit() guarantees max_len - max_new_tokens - 1 >= 1, so the
            # truncated prompt is never empty
            ids = st.prompt_ids[: self.max_len - st.max_new_tokens - 1]
            if self._prefix_ok and self.pages.match_prefix(ids)[0] > 0:
                break     # hits admit via the chunk task on a later call
            if self.paged:
                need = self._pages_for(len(ids), st.max_new_tokens)
                # pinned = shared pages charged to no reservation; they
                # are as occupied as committed ones (zero for plain paged
                # engines, so the historical gate is unchanged)
                if (self._committed + need + self.pages.pinned
                        > self.pages.n_pages):
                    break              # wait for pages to free up
                self._committed += need
                st.reserved_pages = need
            self.queue.pop(0)
            st.prompt_len = len(ids)
            taken.append((slot, st, ids))
        groups: Dict[int, List[Tuple[int, RequestState, List[int]]]] = {}
        for slot, st, ids in taken:
            plen = min(self._bucket(len(ids)), self.max_len)
            groups.setdefault(plen, []).append((slot, st, ids))
        for plen, grp in groups.items():
            self._prefill_group(plen, grp)

    def _prefill_group(self, plen: int,
                       grp: List[Tuple[int, RequestState, List[int]]]) -> None:
        # pad the batch to a power of two so prefill/insert trace at most
        # log2(n_slots)+1 shapes; pad rows scatter to slot index n_slots,
        # which is out of bounds and therefore dropped by the insert
        nb = len(grp)
        npad = _next_pow2(nb)
        toks = np.zeros((npad, plen), np.int32)
        lengths = np.ones(npad, np.int32)
        temps = np.zeros(npad, np.float32)
        topks = np.zeros(npad, np.int32)
        topps = np.ones(npad, np.float32)
        slots = np.full(npad, self.n_slots, np.int32)
        for b, (slot, st, ids) in enumerate(grp):
            toks[b, : len(ids)] = ids
            lengths[b] = len(ids)
            temps[b] = st.sampling.temperature
            topks[b] = st.sampling.top_k
            topps[b] = st.sampling.top_p
            slots[b] = slot
        prefill_fn = self.entry_points.setdefault(
            f"prefill_bs{npad}_p{plen}{self._tp_suffix}", self._prefill_jit)
        logits, one_cache = prefill_fn(
            self.params, jnp.asarray(toks), jnp.asarray(lengths))
        self.key, sk = jax.random.split(self.key)
        # sproutlint: allow(SPL001) — the one sanctioned sync per prefill
        # group; budget lives in repro.analysis.config.ALLOWLIST
        firsts = np.asarray(jax.device_get(sample_logits_batched(
            logits, sk, jnp.asarray(temps), jnp.asarray(topks),
            jnp.asarray(topps))))
        if self.paged:
            # map prompt tokens onto pages: allocate per slot (host), then
            # one donated scatter writes the whole bucket through the table
            ps = self.pages.page_size
            P = self.pages.n_pages
            page_ids = np.full((npad, plen), P, np.int32)    # OOB = dropped
            offs = np.zeros((npad, plen), np.int32)
            for b, (slot, st, ids) in enumerate(grp):
                self.pages.ensure_capacity(slot, len(ids))
                self.pages.lengths[slot] = len(ids)
                t = np.arange(len(ids))
                page_ids[b, : len(ids)] = \
                    self.pages.block_table[slot, t // ps]
                offs[b, : len(ids)] = t % ps
            self.cache = self._paged_insert_jit(
                self.cache, one_cache, jnp.asarray(page_ids),
                jnp.asarray(offs))
            if self._prefix_ok:
                # index the freshly written full prompt pages so later
                # requests sharing this prefix adopt instead of recompute
                for slot, st, ids in grp:
                    self.pages.register_prefix(slot, ids)
        else:
            self.cache = self._insert_jit(self.cache, one_cache,
                                          jnp.asarray(slots))
        for _, _, ids in grp:
            self.prefill_tokens_computed += len(ids)
        t_first = time.monotonic()
        for b, (slot, st, _) in enumerate(grp):
            first = int(firsts[b])
            st.slot = slot
            st.generated = [first]
            st.t_first_token = t_first
            self.slots[slot] = st
            self.positions[slot] = st.prompt_len
            self.last_token[slot] = first
            self.gen_count[slot] = 1
            self.max_new[slot] = st.max_new_tokens
            self.temp[slot] = st.sampling.temperature
            self.top_k[slot] = st.sampling.top_k
            self.top_p[slot] = st.sampling.top_p
            alive = (first != self.eos_id
                     and st.max_new_tokens > 1
                     and st.prompt_len + 1 < self.max_len - 1)
            self.live[slot] = alive
            if not alive:
                self._finish(slot)

    def _admit_chunk_task(self, slot: int) -> None:
        """Admit queue head into ``slot`` as a chunk task: host mirrors are
        pre-staged (positions at prompt_len, lane dead) and the prompt is
        streamed into the fused scan by subsequent ``step()`` dispatches.
        The lane flips live — and the first token emits — inside the scan
        when the final chunk lands."""
        st = self.queue[0]
        ids = st.prompt_ids[: self.max_len - st.max_new_tokens - 1]
        cached_tokens = 0
        adopted: List[int] = []
        newly_pinned = 0
        cow = 0
        if self._prefix_ok:
            m, pids, newly_pinned = self.pages.match_prefix(ids)
            if m > 0:
                # adopt every matched full page, but always leave >= 1
                # prompt token to compute: the final prompt token's logits
                # seed the first sampled token, so a fully cached
                # page-aligned prompt still streams a 1-token chunk —
                # whose KV write lands INSIDE the last shared page and
                # triggers the copy-on-write budgeted below
                cached_tokens = min(m * self.pages.page_size, len(ids) - 1)
                cow = 1 if m * self.pages.page_size > cached_tokens else 0
                adopted = pids
        if self.paged:
            need = self._pages_for(len(ids), st.max_new_tokens,
                                   cached_pages=len(adopted), cow_pages=cow)
            # shared pages are paid for exactly once: active-but-unowned
            # (pinned) pages, plus the cached pages THIS adoption would
            # pin, join the committed reservations on the left of the gate
            if (self._committed + need + self.pages.pinned + newly_pinned
                    > self.pages.n_pages):
                return             # wait for pages to free up (FIFO)
            self._committed += need
            st.reserved_pages = need
        self.queue.pop(0)
        st.prompt_len = len(ids)
        st.slot = slot
        st.generated = []
        if adopted:
            self.pages.adopt(slot, adopted)
            self.pages.lengths[slot] = cached_tokens
            st.cached_tokens = cached_tokens
            self.prefill_tokens_cached += cached_tokens
        self.prefill_tokens_computed += len(ids) - cached_tokens
        self.slots[slot] = st
        self.positions[slot] = len(ids)
        self.last_token[slot] = 0
        self.live[slot] = False
        self.gen_count[slot] = 0
        self.max_new[slot] = st.max_new_tokens
        self.temp[slot] = st.sampling.temperature
        self.top_k[slot] = st.sampling.top_k
        self.top_p[slot] = st.sampling.top_p
        # prefix engines admit through this path with prefill_chunk == 0:
        # they stream page_size-token chunks (one full page per scan step)
        self._task = _ChunkTask(slot=slot, ids=ids, plen=len(ids),
                                next=cached_tokens,
                                chunk=(self.prefill_chunk
                                       or self.pages.page_size))

    # ------------------------------------------------------------------
    def _finish(self, slot: int) -> None:
        st = self.slots[slot]
        assert st is not None
        st.done = True
        st.t_done = time.monotonic()
        gen = st.generated[:-1] if st.generated and st.generated[-1] == self.eos_id \
            else st.generated
        self.finished.append(FinishedRequest(
            st.rid, gen, self.tok.decode(gen), st.prompt_len, len(gen),
            st.t_first_token - st.t_submit, st.t_done - st.t_submit,
            st.directive_level, st.decode_s, st.tenant, st.deadline_at,
            st.t_done, st.retries, st.cached_tokens))
        self.slots[slot] = None
        self.live[slot] = False
        if self.paged:
            # decref, not free: shared pages survive their co-holders, and
            # the ledger repays exactly the admission-time charge
            self.pages.release(slot)
            self._committed -= st.reserved_pages

    # ------------------------------------------------------------------
    _SAMPLE_FNS = {"greedy": greedy_sample,
                   "temp": sample_temperature_only,
                   "full": sample_logits_batched}

    def _fused_for(self, k: int, mode: str, bs: int,
                   chunk_c: int) -> Tuple[Callable, bool]:
        """Jitted device-resident loop: k fused decode+sample steps over a
        ``bs``-row bucket, optionally interleaving one ``chunk_c``-token
        prompt chunk per step. Returns (entry point, was-already-warm).

        ``mode`` is a host-side static specialization over the bucket's
        sampling params: "greedy" compiles no sampler at all, "temp"
        (temperature only) skips the sort-based top-k/top-p threshold, and
        "full" carries the lot. All variants split the PRNG key per step
        and fold per slot id, so the key stream — and the drawn tokens for
        any slot a cheaper variant is valid for — are identical across
        them and invariant to which bucket served the slot.

        ``bs < n_slots`` compiles the *bucketed* program: every dense
        cache leaf is gathered on its slot axis (axis 1) once on entry and
        scattered back once on exit — pad rows carry slot id ``n_slots``,
        whose gather clamps harmlessly (the lane is forced dead) and whose
        scatter-back is out of bounds and dropped. At ``bs == n_slots``
        no gather is compiled: the identity program is the fixed-batch
        fused loop unchanged. Paged caches are never gathered (the page
        store is slot-agnostic; the host selects block-table rows).

        ``chunk_c > 0`` compiles the *mixed* program: each scan step
        additionally runs ``prefill_chunk_step`` for the task lane's next
        chunk (scan xs), and on the final chunk samples the request's
        first token in-scan, flips the lane live, and emits it in-band."""
        name = (f"decode_bs{bs}_k{k}_{mode}" if chunk_c == 0
                else f"mixed_bs{bs}_k{k}_c{chunk_c}_{mode}")
        name += self._tp_suffix
        warm = name in self.entry_points
        if not warm:
            cfg, eos_id, max_len = self.cfg, self.eos_id, self.max_len
            sample_fn = self._SAMPLE_FNS[mode]
            paged, paged_impl = self.paged, self.paged_impl
            bucketed = bs < self.n_slots
            has_chunk = chunk_c > 0

            def fused(params, cache, block_table, state, chunk):
                rows = state["rows"]          # (bs,) slot ids; pad = n_slots
                if bucketed and not paged:
                    part = jax.tree.map(lambda a: a[:, rows], cache)
                else:
                    part = cache
                fold = rows if bucketed else None

                def body(carry, xs):
                    part, st = carry
                    if has_chunk:
                        key, sk, ck = jax.random.split(st["key"], 3)
                    else:
                        key, sk = jax.random.split(st["key"])
                    nxt, part, rowok = MD.decode_sample_step(
                        cfg, params, st["last"][:, None], st["pos"], part,
                        sk, (st["temp"], st["topk"], st["topp"]),
                        sample_fn,
                        block_table=block_table if paged else None,
                        live=st["live"] if paged else None,
                        paged_impl=paged_impl, fold_ids=fold, with_ok=True)
                    # sticky per-lane health: once a LIVE lane's logits go
                    # non-finite the verdict stays False for the block (dead
                    # lanes' logits are scratch and don't count) — the host
                    # quarantines the lane from the existing block fetch
                    ok2 = st["ok"] & (rowok | ~st["live"])
                    nxt = jnp.where(st["live"], nxt, st["last"]).astype(jnp.int32)
                    pos2 = jnp.where(st["live"], st["pos"] + 1, st["pos"])
                    gc2 = jnp.where(st["live"], st["gc"] + 1, st["gc"])
                    # same finish rule as the host bookkeeping: EOS, token
                    # budget, or KV-region cap (prompt_len + gen >= max_len-1)
                    hit = ((nxt == eos_id) | (gc2 >= st["max_new"])
                           | (pos2 >= max_len - 2))
                    live2 = st["live"] & ~hit
                    emit_t, emit_v = nxt, st["live"]
                    if has_chunk:
                        ctoks, cpos0, clen, cfinal = xs
                        lane = st["chunk_lane"]
                        logits, part = MD.prefill_chunk_step(
                            cfg, params, ctoks, cpos0, clen, lane, part,
                            block_table=block_table if paged else None)
                        # chunk-lane health: any dispatched chunk (clen > 0)
                        # with non-finite logits marks the lane bad — its
                        # half-written KV is garbage even before it samples
                        cok = (clen == 0) | jnp.isfinite(logits).all()
                        ok2 = ok2 & (cok | (jnp.arange(bs) != lane))
                        first = sample_fn(
                            logits[None], ck, st["temp"][lane][None],
                            st["topk"][lane][None], st["topp"][lane][None],
                            fold_ids=rows[lane][None])[0].astype(jnp.int32)
                        plen = cpos0 + clen
                        alive = ((first != eos_id)
                                 & (st["max_new"][lane] > 1)
                                 & (plen + 1 < max_len - 1))
                        upd = (jnp.arange(bs) == lane) & cfinal
                        nxt = jnp.where(upd, first, nxt)
                        pos2 = jnp.where(upd, plen, pos2)
                        gc2 = jnp.where(upd, 1, gc2)
                        live2 = jnp.where(upd, alive, live2)
                        emit_t = jnp.where(upd, first, emit_t)
                        emit_v = emit_v | upd
                    st2 = dict(st, key=key, last=nxt, pos=pos2, gc=gc2,
                               live=live2, ok=ok2)
                    return (part, st2), (emit_t, emit_v)

                (part, st), (toks, valid) = jax.lax.scan(
                    body, (part, state), chunk if has_chunk else None,
                    length=k, unroll=1 if has_chunk else min(k, 8))
                if bucketed and not paged:
                    cache = jax.tree.map(
                        lambda full, p_: full.at[:, rows].set(
                            p_.astype(full.dtype)),
                        cache, part)
                else:
                    cache = part
                return cache, toks, valid, st["live"], st["ok"]

            # the block table is a fresh tiny input per dispatch (the host
            # allocator owns it), so it is NOT donated; the cache is
            self.entry_points[name] = jax.jit(fused, donate_argnums=(1,))
        return self.entry_points[name], warm

    def _pick_k(self) -> int:
        """Block length: the power-of-two ceiling of the soonest
        *deterministic* finish (token budget / KV cap), capped at
        ``decode_block``. Steps past a slot's finish run dead (live-masked,
        nothing emitted), trading < rem wasted lockstep steps for fewer
        dispatches and at most log2(decode_block)+1 compiled variants;
        prefill of freed slots runs between blocks, so its delay is bounded
        by the same overshoot."""
        live_idx = np.nonzero(self.live)[0]
        rem = int(min(
            min(self.max_new[i] - self.gen_count[i],
                self.max_len - 1 - (self.positions[i] + 1))
            for i in live_idx))
        return min(self.decode_block, _next_pow2(max(1, rem)))

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One continuous-batching dispatch: refill free slots (chunk-task
        admission when lanes are live, bucketed batch prefill when idle),
        then run up to ``decode_block`` fused scan steps — each decoding
        every live lane and streaming at most one prompt chunk — in the
        compiled entry point bucketed to live occupancy. Returns the
        number of tokens emitted (0 if idle)."""
        self._try_prefill()
        self.peak_concurrent = max(
            self.peak_concurrent, sum(s is not None for s in self.slots))
        if self.paged:
            self.peak_pages_in_use = max(self.peak_pages_in_use,
                                         self.pages.pages_in_use())
        task = self._task
        if not self.live.any() and task is None:
            return 0
        # ----- bucket selection: live lanes plus the chunk-task lane ----
        lanes = set(int(i) for i in np.nonzero(self.live)[0])
        if task is not None:
            lanes.add(task.slot)
        rows = np.sort(np.fromiter(lanes, np.int64))
        bs = (min(self.n_slots, _next_pow2(len(rows)))
              if self._bucketing else self.n_slots)
        if bs == self.n_slots:
            rows_full = np.arange(self.n_slots, dtype=np.int64)
        else:
            rows_full = np.full(bs, self.n_slots, np.int64)
            rows_full[: len(rows)] = rows
        # ----- block length + per-step chunk arrays ---------------------
        k = self._pick_k() if self.live.any() else self.decode_block
        chunk_c = 0
        chunk_xs = None
        finishing = False
        nxt_p = 0
        if task is not None:
            chunk_c = task.chunk
            rem = -(-(task.plen - task.next) // chunk_c)
            # shrink the block toward the chunks actually left, so a short
            # tail does not pay (and a fresh variant does not compile for)
            # a full block of dead chunk steps
            k = max(1, min(k, _next_pow2(rem)))
            ctoks = np.zeros((k, chunk_c), np.int32)
            cpos0 = np.zeros(k, np.int32)
            clen = np.zeros(k, np.int32)
            cfin = np.zeros(k, bool)
            nxt_p = task.next
            for s in range(k):
                if nxt_p < task.plen:
                    n = min(chunk_c, task.plen - nxt_p)
                    ctoks[s, :n] = task.ids[nxt_p:nxt_p + n]
                    cpos0[s] = nxt_p
                    clen[s] = n
                    cfin[s] = nxt_p + n == task.plen
                    nxt_p += n
                    self.chunk_steps += 1
            finishing = nxt_p == task.plen
            chunk_xs = (jnp.asarray(ctoks), jnp.asarray(cpos0),
                        jnp.asarray(clen), jnp.asarray(cfin))
        # greedy rows (temp<=0) draw via argmax and ignore top-k/top-p, so
        # only the *sampled* rows' params decide how much sampler to
        # compile; the chunk lane counts — its first token draws in-scan
        consider = np.zeros(self.n_slots, bool)
        consider[rows] = True
        drawn = consider & (self.temp > 0)
        if not drawn.any():
            mode = "greedy"
        elif np.any((self.top_k[drawn] > 0) | (self.top_p[drawn] < 1.0)):
            mode = "full"
        else:
            mode = "temp"
        block_table = None
        if self.paged:
            if self._prefix_ok:
                # copy-on-write (DESIGN.md §13): a write landing in a
                # shared or adopted page remaps the lane onto a fresh page
                # and duplicates the contents device-side BEFORE this
                # block's appends. In practice only the fully-cached
                # page-aligned prompt case fires (its 1-token recompute
                # writes into the last adopted page); the admission
                # reservation budgeted that page, so _alloc cannot fail.
                # The live-lane sweep is a belt-and-braces invariant —
                # decode appends always land past the shared span
                targets = [(int(i), int(self.positions[i]))
                           for i in np.nonzero(self.live)[0]]
                if task is not None:
                    targets.append((task.slot, task.next))
                for tslot, tpos in targets:
                    cw = self.pages.prepare_append(tslot, tpos)
                    if cw is not None:
                        self.cache = self._copy_page_jit(
                            self.cache, jnp.asarray(cw[0], jnp.int32),
                            jnp.asarray(cw[1], jnp.int32))
            # grow each live slot's page map to cover this block's appends
            # (bounded by the slot's own cap, so growth never exceeds the
            # admission-time reservation and can never throw here)
            for i in np.nonzero(self.live)[0]:
                st = self.slots[i]
                self.pages.ensure_capacity(
                    int(i), min(int(self.positions[i]) + k,
                                self._slot_cap(st.prompt_len,
                                               st.max_new_tokens)))
            if task is not None:
                # per-chunk page growth: map only what this block writes
                # (prompt chunks, plus up to k decode appends after an
                # in-block transition) instead of the whole prompt at once
                st = self.slots[task.slot]
                cap = self._slot_cap(st.prompt_len, st.max_new_tokens)
                tgt = min(nxt_p + (k if finishing else 0), cap)
                self.pages_grown_chunked += self.pages.ensure_capacity(
                    task.slot, tgt)
            self.peak_pages_in_use = max(self.peak_pages_in_use,
                                         self.pages.pages_in_use())
            bt = np.full((bs, self.pages.max_pages), -1, np.int32)
            real = rows_full < self.n_slots
            bt[real] = self.pages.block_table[rows_full[real]]
            block_table = jnp.asarray(bt)
        # ----- bucketed device state (host mirrors, gathered) -----------
        self.key, bk = jax.random.split(self.key)
        real = rows_full < self.n_slots

        def gath(a, fill, dtype):
            out = np.full(bs, fill, dtype)
            out[real] = a[rows_full[real]]
            return jnp.asarray(out)

        state = {
            "last": gath(self.last_token, 0, np.int32),
            "pos": gath(self.positions, 0, np.int32),
            "live": gath(self.live, False, bool),
            "gc": gath(self.gen_count, 0, np.int32),
            "max_new": gath(self.max_new, 1, np.int32),
            "temp": gath(self.temp, 0.0, np.float32),
            "topk": gath(self.top_k, 0, np.int32),
            "topp": gath(self.top_p, 1.0, np.float32),
            "key": bk,
            "rows": jnp.asarray(rows_full, jnp.int32),
            # per-lane finiteness verdict, accumulated across the block's
            # scan steps (sticky-False once a live lane's logits go bad)
            "ok": jnp.ones(bs, bool),
        }
        if task is not None:
            lane_pos = int(np.nonzero(rows_full == task.slot)[0][0])
            state["chunk_lane"] = jnp.asarray(lane_pos, jnp.int32)
        fn, warm = self._fused_for(k, mode, bs, chunk_c)
        t_dec = time.monotonic()
        self.cache, toks, valid, live_dev, ok_dev = fn(
            self.params, self.cache, block_table, state, chunk_xs)
        # sproutlint: allow(SPL001) — the single host<->device sync for
        # this block of <= k*bs tokens; the per-lane finiteness verdict
        # rides the SAME fetch (no extra sync for fault detection); budget
        # in analysis.config.ALLOWLIST
        toks, valid, live_final, ok_final = jax.device_get(
            (toks, valid, live_dev, ok_dev))
        # decode-only wall time for this dispatch; 0.0 when this variant
        # just compiled, so the straggler detector never samples a compile
        self.last_decode_s = (time.monotonic() - t_dec) if warm else 0.0
        self.decode_syncs += 1
        self.steps += k
        finish_order: List[Tuple[int, int]] = []
        n_decoded = 0
        # partition each step's share of the block wall time across the
        # slots live at that step, so per-request decode_s sums to the
        # device's decode wall time (compile dispatches report 0.0);
        # dead tail steps (block overshoot past the last finish) have no
        # live slot, so their time is spread over the block's decoding
        # slots pro rata — nothing goes unattributed
        dt_step = self.last_decode_s / k
        live_steps = valid.sum(axis=1)                       # (k,)
        share = dt_step / np.maximum(live_steps, 1)
        dead_s = dt_step * int((live_steps == 0).sum())
        total_valid = max(int(valid.sum()), 1)
        for b, i in enumerate(int(x) for x in rows_full):
            if i >= self.n_slots:
                continue
            st = self.slots[i]
            if st is None:
                continue
            col = valid[:, b]
            news = [int(t) for t in toks[col, b]]
            st.decode_s += float(share[col].sum()) \
                + dead_s * len(news) / total_valid
            if not ok_final[b]:
                # non-finite logits: every token this lane emitted in the
                # block is suspect. Record them on the state (the wasted-
                # work ledger charges discarded tokens) and quarantine —
                # no finish, no mirror advance; the requeue path resets
                # generation from the verbatim prompt.
                st.generated.extend(news)
                self._quarantine(i, "decode.nonfinite")
                continue
            st.generated.extend(news)
            n_decoded += len(news)
            self.decode_tokens += len(news)
            self.gen_count[i] += len(news)
            self.positions[i] += len(news)
            if self.paged:    # live tokens in pages == appended positions
                self.pages.lengths[i] = self.positions[i]
            if news:
                self.last_token[i] = news[-1]
            self.live[i] = bool(live_final[b])
            # a dead lane finishes only if it emitted this block: the
            # chunk-task lane sits occupied-but-dead (col all False) until
            # its final chunk flips it live in-scan
            if not live_final[b] and news:
                finish_order.append((int(np.nonzero(col)[0][-1]), i))
        # a quarantine above may have torn the chunk task down with its
        # lane (self._task reset, pages released) — only advance the task
        # if it is still the one we dispatched
        if task is not None and self._task is task:
            i = task.slot
            task.next = nxt_p
            if finishing:
                # the first token emitted from inside the scan: it lands at
                # the pre-staged position, so it is not a position advance
                self.positions[i] -= 1
                st = self.slots[i]
                if st is not None and st.t_first_token == 0.0:
                    st.t_first_token = time.monotonic()
                if st is not None and self._prefix_ok:
                    # the whole prompt's KV is now written: index its full
                    # pages for future prefix hits (adopted pages are
                    # already indexed and skip; first registration wins)
                    self.pages.register_prefix(i, task.ids)
                self._task = None
            if self.paged:
                self.pages.lengths[i] = (int(self.positions[i]) if finishing
                                         else task.next)
        # finish in (step-within-block, slot) order so completion order is
        # identical to single-step execution
        for _, i in sorted(finish_order):
            self._finish(i)
        return n_decoded

    # ------------------------------------------------------------------
    def run_to_completion(self, max_steps: int = 100000) -> List[FinishedRequest]:
        while (self.queue or any(s is not None for s in self.slots)) \
                and self.steps < max_steps:
            self.step()
        return self.finished

    # ------------------------------------------------------------------
    def drain_slots(self) -> List[RequestState]:
        """Preemption support: evict live requests for requeueing (their
        generation restarts on another replica — prefix tokens preserved)."""
        out = []
        for i, st in enumerate(self.slots):
            if st is not None:
                st.slot = -1
                out.append(st)
                self.slots[i] = None
                self.live[i] = False
                if self.paged:
                    self.pages.release(i)
                    self._committed -= st.reserved_pages
                st.reserved_pages = 0
                st.cached_tokens = 0
        # a mid-prefill chunk task is evicted with its slot; its prompt ids
        # are verbatim, so resubmission elsewhere restarts identically
        self._task = None
        return out

    # ------------------------------------------------------------------
    def evict(self, rid: int) -> Optional[RequestState]:
        """Pull ONE request out of the engine for migration/requeue.

        Queued requests are simply unqueued; a slotted request releases its
        slot — and, in paged mode, every page it holds plus its admission
        reservation — exactly as a full ``drain_slots`` would, but for a
        single rid. The caller owns the returned state (its prompt ids are
        verbatim, so a resubmission elsewhere regenerates identically under
        deterministic sampling); ``None`` if the rid is unknown or already
        finished. Generated-so-far tokens are discarded: the migration
        decision rule (serving/gateway.py MigrationPlanner) prices that
        redo cost in before evicting a decoding request.
        """
        for j, st in enumerate(self.queue):
            if st.rid == rid:
                return self.queue.pop(j)
        for i, st in enumerate(self.slots):
            if st is not None and st.rid == rid:
                st.slot = -1
                st.generated = []
                self.slots[i] = None
                self.live[i] = False
                if self.paged:
                    self.pages.release(i)
                    self._committed -= st.reserved_pages
                st.reserved_pages = 0
                st.cached_tokens = 0
                if self._task is not None and self._task.slot == i:
                    self._task = None
                return st
        return None

    # ------------------------------------------------------------------
    def _fill_lane(self, slot: int, value: float) -> None:
        """Constant-fill one lane's KV (float leaves) with ``value``.

        Paged mode fills only the pages the lane's block-table row maps;
        unmapped entries (-1) are sanitized to the out-of-bounds page id so
        the scatter drops them — a raw -1 would wrap to the LAST page and
        corrupt whichever request owns it. No host sync: the fill is a
        donated device program."""
        if self.paged:
            bt = self.pages.block_table[slot].astype(np.int32).copy()
            if self._prefix_ok:
                # never fill a page other lanes can read: shared/adopted
                # pages and index-retained pages are masked to OOB; only
                # this lane's exclusive private pages are touched
                bt[~self.pages.exclusive_pages(slot)] = -1
            bt[bt < 0] = self.pages.n_pages          # OOB = dropped
            self.cache = self._fill_pages_jit(
                self.cache, jnp.asarray(bt), jnp.float32(value))
        else:
            self.cache = self._fill_slot_jit(
                self.cache, jnp.asarray(slot, jnp.int32), jnp.float32(value))

    def poison_lane(self, slot: int) -> None:
        """Fault injection: corrupt a lane's KV with NaN so the next fused
        block's logits for that lane are *genuinely* non-finite (masked
        softmax keeps p=0 rows, but 0 * NaN = NaN through ``p @ v``). The
        in-scan finiteness verdict — not the injector — must then catch
        it, exercising the real detection path end to end."""
        self._fill_lane(slot, float("nan"))

    def _scrub_lane(self, slot: int) -> None:
        """Zero a quarantined lane's KV before its pages/slot are reused:
        NaN left behind would contaminate the next occupant through the
        same 0 * NaN propagation that made detection possible."""
        self._fill_lane(slot, 0.0)

    def _quarantine(self, slot: int, reason: str) -> None:
        """Pull a poisoned lane out of service: scrub its KV, release its
        pages and admission reservation, reset the host mirrors, and hand
        the request to ``self.faulted`` for the scheduler's bounded-retry
        requeue. The request's prompt ids are verbatim, so the redo
        regenerates bit-identical tokens under deterministic sampling."""
        st = self.slots[slot]
        assert st is not None
        st.slot = -1
        st.last_fault = reason
        if self._prefix_ok:
            # suspect content must never serve a future prefix hit: drop
            # this slot's OWNED pages from the radix index before the
            # scrub (adopted pages stay — COW guarantees the lane never
            # wrote them, so their content is not implicated)
            self.pages.invalidate_slot(slot)
        self._scrub_lane(slot)       # before release: needs the block table
        self.slots[slot] = None
        self.live[slot] = False
        self.positions[slot] = 0
        self.last_token[slot] = 0
        self.gen_count[slot] = 0
        if self._task is not None and self._task.slot == slot:
            self._task = None
        if self.paged:
            self.pages.release(slot)
            self._committed -= st.reserved_pages
        st.reserved_pages = 0
        st.cached_tokens = 0
        self.faulted.append(st)

    # ------------------------------------------------------------------
    def kv_stats(self) -> Dict[str, float]:
        """KV-memory telemetry (exported by scheduler/gateway summaries).

        Paged engines report allocator occupancy/fragmentation plus bytes
        actually mapped (pages_in_use x page_bytes, across every layer's
        store); dense engines report their fixed n_slots x max_len
        footprint for comparison under a common schema."""
        leaves = jax.tree_util.tree_leaves(self.cache)
        total_bytes = sum(
            x.size * x.dtype.itemsize for x in leaves)
        if not self.paged:
            live = int(sum(self.positions[i] for i, s in enumerate(self.slots)
                           if s is not None))
            return {"layout": "dense", "kv_bytes_capacity": total_bytes,
                    "kv_bytes_in_use": total_bytes, "live_tokens": live,
                    "pages_in_use": 0, "occupancy": 1.0,
                    "fragmentation": 0.0}
        rep = self.pages.report()
        page_bytes = sum(x.size * x.dtype.itemsize // self.pages.n_pages
                         for x in leaves)
        rep.update(layout="paged", page_bytes=page_bytes,
                   kv_bytes_capacity=total_bytes,
                   kv_bytes_in_use=rep["pages_in_use"] * page_bytes,
                   peak_pages_in_use=self.peak_pages_in_use,
                   peak_kv_bytes_in_use=self.peak_pages_in_use * page_bytes,
                   committed_pages=self._committed)
        if self._prefix_ok:
            rep.update(prefill_tokens_computed=self.prefill_tokens_computed,
                       prefill_tokens_cached=self.prefill_tokens_cached)
        return rep
