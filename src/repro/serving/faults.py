"""Deterministic fault injection for the serving stack (DESIGN.md §12).

Chaos engineering only pays off when a failure is *reproducible*: a fault
that fires from wall-clock jitter or an unseeded coin flip cannot anchor a
regression test, and CI cannot byte-diff two runs of it. Everything here
is therefore a pure function of the committed ``FaultPlan`` plus a seed:

* ``FaultSpec`` — one scripted fault: a named injection ``point``, an
  optional ``target`` (pool key, replica index, rid — "*" matches any),
  and the zero-based ``occurrences`` of that (point, target) pair at
  which it fires. A probabilistic ``prob`` mode exists for soak-style
  plans; its draws come from a PRNG seeded by ``zlib.crc32(point)`` xor
  the plan seed, never from global random state.
* ``FaultPlan`` — an ordered collection of specs. Plans are data: tests
  and ``scripts/chaos.sh`` build them inline, and the same plan replayed
  against the same fleet produces the same faults at the same steps
  under any PYTHONHASHSEED.
* ``FaultInjector`` — the runtime: every instrumented site calls
  ``fire(point, target)`` exactly once per opportunity; the injector
  counts the opportunity deterministically and answers "does a scripted
  fault land here, now?". Fired faults are logged to ``events``.

Injection points threaded through the stack (the site consults the
injector; the *failure itself* then happens through the genuine
mechanism — a poisoned KV lane really produces non-finite logits, a
crashed replica really drains through the health machine):

=======================  ====================================================
point                    site / genuine failure
=======================  ====================================================
``carbon.stale``         WatchdogProvider: the grid feed stops updating
``carbon.nan``           WatchdogProvider: feed returns a non-finite value
``carbon.exception``     WatchdogProvider: feed raises (timeout, 5xx, ...)
``lp.fail``              SproutGateway.replan: the directive LP solve fails
``replica.crash``        CarbonAwareScheduler.step: replica dies mid-block
                         (or mid-chunk-prefill — whatever is in flight)
``decode.nonfinite``     CarbonAwareScheduler.step: a live lane's KV is
                         poisoned (InferenceEngine.poison_lane) so the next
                         fused block's logits are genuinely non-finite
``migrate.dst_vanish``   MigrationPlanner: the destination fleet vanishes
                         between evict and submit
=======================  ====================================================
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

POINTS = (
    "carbon.stale",
    "carbon.nan",
    "carbon.exception",
    "lp.fail",
    "replica.crash",
    "decode.nonfinite",
    "migrate.dst_vanish",
)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scripted fault. ``occurrences`` are zero-based indices into the
    deterministic per-(point, target) opportunity counter; ``prob`` adds
    seeded per-opportunity firing on top (0.0 = scripted-only)."""
    point: str
    target: str = "*"
    occurrences: Tuple[int, ...] = (0,)
    prob: float = 0.0

    def __post_init__(self):
        if self.point not in POINTS:
            raise ValueError(
                f"unknown injection point {self.point!r}; known: {POINTS}")
        if not (0.0 <= self.prob <= 1.0):
            raise ValueError(f"prob must be in [0, 1], got {self.prob}")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One fault that actually fired (the injector's audit log)."""
    point: str
    target: str
    occurrence: int


class FaultPlan:
    """An ordered, immutable-ish set of FaultSpecs (plans are data)."""

    def __init__(self, specs: Sequence[FaultSpec] = ()):
        self.specs: List[FaultSpec] = list(specs)

    def for_point(self, point: str) -> List[FaultSpec]:
        return [s for s in self.specs if s.point == point]

    def __len__(self) -> int:
        return len(self.specs)

    def __iter__(self):
        return iter(self.specs)


class FaultInjector:
    """Seed-deterministic runtime for a FaultPlan.

    Each instrumented site calls ``fire(point, target)`` once per
    opportunity. The injector keeps one opportunity counter per
    (point, target) pair — NOT per spec — so a plan edit never shifts
    when an unrelated spec fires. ``fire`` with a concrete target also
    advances the wildcard counter for that point, so "the 3rd carbon
    fetch anywhere" and "the 3rd fetch for pool CA" are both scriptable.

    The probabilistic mode draws from ``np.random.default_rng`` seeded by
    ``seed ^ crc32(point)``: per-point streams, so adding a prob spec on
    one point never perturbs another point's draws.
    """

    def __init__(self, plan: Optional[FaultPlan] = None, seed: int = 0):
        self.plan = plan or FaultPlan()
        self.seed = seed
        self.counts: Dict[Tuple[str, str], int] = {}
        self.events: List[FaultEvent] = []
        self._rngs: Dict[str, np.random.Generator] = {}
        # sites set this False to disarm injection in a fault-free control
        # run sharing the same wiring (the chaos tests' paired baseline)
        self.armed = True

    # ------------------------------------------------------------------
    def _rng(self, point: str) -> np.random.Generator:
        if point not in self._rngs:
            self._rngs[point] = np.random.default_rng(
                self.seed ^ zlib.crc32(point.encode()))
        return self._rngs[point]

    def _bump(self, point: str, target: str) -> int:
        key = (point, target)
        n = self.counts.get(key, 0)
        self.counts[key] = n + 1
        return n

    def fire(self, point: str, target: str = "*") -> bool:
        """One injection opportunity at (point, target); True = fault."""
        if not self.armed:
            # disarmed consults do not count: a plan's occurrence indices
            # are relative to ARMING, so a scenario can run a fault-free
            # warmup phase of any length and still script "the 2nd armed
            # opportunity" without counting the warmup's consults
            return False
        n = self._bump(point, target)
        n_any = n if target == "*" else self._bump(point, "*")
        for spec in self.plan.for_point(point):
            if spec.target == target and n in spec.occurrences:
                break
            if spec.target == "*" and target != "*" \
                    and n_any in spec.occurrences:
                break
            if spec.prob > 0.0 and spec.target in ("*", target) \
                    and float(self._rng(point).random()) < spec.prob:
                break
        else:
            return False
        self.events.append(FaultEvent(point, target, n))
        return True

    # ------------------------------------------------------------------
    def fired(self, point: Optional[str] = None) -> int:
        """How many faults actually fired (optionally for one point)."""
        if point is None:
            return len(self.events)
        return sum(1 for e in self.events if e.point == point)


def no_faults() -> FaultInjector:
    """An armed injector with an empty plan: every site runs clean. The
    default wiring, so instrumented code never branches on None."""
    return FaultInjector(FaultPlan())
