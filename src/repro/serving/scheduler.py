"""Carbon-aware scheduler: SPROUT's directive selector in the request path
(Fig. 5 components 1–3) + fleet-level fault tolerance.

* directive selection: draws a level from the optimizer's current x and
  renders the directive as a system prompt before tokenization;
* replica pool: least-loaded dispatch over multiple InferenceEngines;
* fault tolerance: ``fail_replica`` drains in-flight requests back into the
  global queue (preemption-safe — the serving analogue of checkpoint/restart);
* straggler mitigation: replicas whose per-step decode latency exceeds
  ``straggler_factor`` x fleet median are drained and benched.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.directives import DirectiveSet
from repro.serving.engine import FinishedRequest, InferenceEngine, RequestState
from repro.serving.sampler import SamplingParams
from repro.serving.tokenizer import ByteTokenizer


@dataclasses.dataclass
class ServeRequest:
    rid: int
    user_prompt: str
    system_prompt: Optional[str] = None
    max_new_tokens: int = 64
    sampling: SamplingParams = SamplingParams()


class CarbonAwareScheduler:
    def __init__(self, engines: Sequence[InferenceEngine],
                 directives: DirectiveSet = DirectiveSet(),
                 level_fn: Optional[Callable[[], int]] = None,
                 tokenizer: Optional[ByteTokenizer] = None,
                 straggler_factor: float = 4.0):
        self.engines: List[Optional[InferenceEngine]] = list(engines)
        self.directives = directives
        self.level_fn = level_fn or (lambda: 0)
        self.tok = tokenizer or ByteTokenizer()
        self.straggler_factor = straggler_factor
        self.pending: List[ServeRequest] = []
        self.finished: List[FinishedRequest] = []
        self._rid = 0
        self._step_times: Dict[int, List[float]] = {}

    # ------------------------------------------------------------------
    def submit(self, req: ServeRequest) -> int:
        if req.rid == 0:
            self._rid += 1
            req.rid = self._rid
        self.pending.append(req)
        return req.rid

    def _dispatch(self) -> None:
        live = [(i, e) for i, e in enumerate(self.engines) if e is not None]
        if not live:
            return
        while self.pending:
            req = self.pending.pop(0)
            level = self.level_fn()
            text = self.directives.apply(req.user_prompt, level,
                                         req.system_prompt)
            ids = self.tok.encode(text, bos=True)
            idx, eng = min(live, key=lambda ie: len(ie[1].queue)
                           + sum(s is not None for s in ie[1].slots))
            eng.submit(ids, max_new_tokens=req.max_new_tokens,
                       sampling=req.sampling, directive_level=level,
                       rid=req.rid)

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One fleet step; returns number of live decode lanes."""
        self._dispatch()
        lanes = 0
        for i, eng in enumerate(self.engines):
            if eng is None:
                continue
            t0 = time.monotonic()
            lanes += eng.step()
            dt = time.monotonic() - t0
            self._step_times.setdefault(i, []).append(dt)
            if len(self._step_times[i]) > 50:
                self._step_times[i] = self._step_times[i][-50:]
            if eng.finished:
                self.finished.extend(eng.finished)
                eng.finished = []
        self._check_stragglers()
        return lanes

    def _check_stragglers(self) -> None:
        meds = {i: float(np.median(t)) for i, t in self._step_times.items()
                if len(t) >= 20 and self.engines[i] is not None}
        if len(meds) < 2:
            return
        fleet_med = float(np.median(list(meds.values())))
        for i, m in meds.items():
            if m > self.straggler_factor * fleet_med:
                self.fail_replica(i)   # bench + requeue its work

    # ------------------------------------------------------------------
    def fail_replica(self, idx: int) -> int:
        """Node failure / preemption: requeue all of the replica's work."""
        eng = self.engines[idx]
        if eng is None:
            return 0
        drained = eng.drain_slots()
        requeued = 0
        for st in drained + eng.queue:
            self.pending.append(ServeRequest(
                st.rid, self.tok.decode(st.prompt_ids),
                max_new_tokens=st.max_new_tokens, sampling=st.sampling))
            requeued += 1
        eng.queue = []
        self.engines[idx] = None
        self._step_times.pop(idx, None)
        return requeued

    def add_replica(self, eng: InferenceEngine) -> None:
        """Elastic scale-up: plug a fresh engine into the pool."""
        for i, e in enumerate(self.engines):
            if e is None:
                self.engines[i] = eng
                return
        self.engines.append(eng)

    # ------------------------------------------------------------------
    def run(self, max_steps: int = 100000) -> List[FinishedRequest]:
        steps = 0
        while (self.pending or any(
                e is not None and (e.queue or any(s is not None for s in e.slots))
                for e in self.engines)) and steps < max_steps:
            self.step()
            steps += 1
        return self.finished
