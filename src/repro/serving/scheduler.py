"""Carbon-aware scheduler: SPROUT's directive selector in the request path
(Fig. 5 components 1–3) + fleet-level fault tolerance.

* directive selection: draws a level from the optimizer's current x and
  renders the directive as a system prompt before tokenization;
* replica pool: least-loaded dispatch over multiple InferenceEngines;
* fault tolerance: ``fail_replica`` drains in-flight requests back into the
  global queue (preemption-safe — the serving analogue of checkpoint/restart);
* straggler mitigation: replicas whose *per-decode-step* latency exceeds
  ``straggler_factor`` x fleet median are drained and benched. Engines decode
  in fused multi-token blocks (engine.decode_block), so wall time per
  ``step()`` is normalized by the lockstep decode steps that dispatch
  executed — a batch-wide matmul costs the same whether 1 or n_slots lanes
  are live, so per-step (not per-token) time is the occupancy-independent
  hardware-speed signal.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.directives import DirectiveSet
from repro.serving.engine import FinishedRequest, InferenceEngine, RequestState
from repro.serving.sampler import SamplingParams
from repro.serving.tokenizer import ByteTokenizer


@dataclasses.dataclass
class ServeRequest:
    rid: int
    user_prompt: str
    system_prompt: Optional[str] = None
    max_new_tokens: int = 64
    # default_factory, NOT a class-level instance: a single shared default
    # object across every request would alias all of their sampling state
    sampling: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)
    # failover requeue: user_prompt is already directive-rendered ChatML —
    # dispatch must not wrap it again (the prompt would nest and grow on
    # every failover); directive_level records the original draw
    pre_rendered: bool = False
    directive_level: int = 0
    # failover also carries the ORIGINAL token ids: a decode()/encode()
    # round trip is lossy in general (byte fallbacks, specials typed as
    # text, BOS placement), so dispatch submits these verbatim when set
    prompt_token_ids: Optional[List[int]] = None
    # per-directive-level generation budget (the serving-side effect of a
    # brevity directive); indexed by the drawn level at dispatch time
    max_new_by_level: Optional[Sequence[int]] = None
    # ----- SLO identity (gateway-side service classes) -----
    # tenant class name + task family: the gateway's composite level_fn
    # draws this request's directive level from the (pool, tenant) LP mix
    tenant: str = ""
    task: str = ""
    # absolute completion deadline on the monotonic clock (inf = none);
    # the gateway stamps it from the tenant's TTFT/TPOT targets when the
    # caller leaves ``deadline_s`` (relative seconds) unset
    deadline_at: float = float("inf")
    deadline_s: float = float("inf")
    # dispatch order within a pool (lower first; stable within a class) —
    # premium work never queues behind batch work on the same fleet
    priority: int = 1
    # original submission time (stamped once by the first scheduler.submit
    # and preserved across requeue/migration): deadlines and latency are
    # end-to-end properties of the REQUEST, not of any one engine
    t_submit: float = 0.0


class CarbonAwareScheduler:
    def __init__(self, engines: Sequence[InferenceEngine],
                 directives: DirectiveSet = DirectiveSet(),
                 level_fn: Optional[Callable[[], int]] = None,
                 tokenizer: Optional[ByteTokenizer] = None,
                 straggler_factor: float = 4.0):
        self.engines: List[Optional[InferenceEngine]] = list(engines)
        self.directives = directives
        self.level_fn = level_fn or (lambda: 0)
        self.tok = tokenizer or ByteTokenizer()
        self.straggler_factor = straggler_factor
        self.pending: List[ServeRequest] = []
        self.finished: List[FinishedRequest] = []
        # requests no engine can serve (e.g. token budget exceeds the KV
        # region): kept with the rejection reason instead of being lost
        self.rejected: List[tuple] = []
        self._rid = 0
        self._step_times: Dict[int, List[float]] = {}

    # ------------------------------------------------------------------
    def submit(self, req: ServeRequest) -> int:
        if req.rid == 0:
            self._rid += 1
            req.rid = self._rid
        if req.t_submit == 0.0:
            # first entry into the serving system: the end-to-end latency
            # clock (and any relative deadline) starts here, and survives
            # failover requeue / cross-pool migration untouched
            req.t_submit = time.monotonic()
            if req.deadline_at == float("inf") and \
                    req.deadline_s != float("inf"):
                req.deadline_at = req.t_submit + req.deadline_s
        self.pending.append(req)
        return req.rid

    def _draw_level(self, req: ServeRequest) -> int:
        """Directive draw for one request. A gateway-installed composite
        ``level_fn`` marks itself ``per_request`` and receives the request
        (its tenant/task select the mix); plain zero-arg selectors keep
        working unchanged."""
        fn = self.level_fn
        return int(fn(req) if getattr(fn, "per_request", False) else fn())

    def _dispatch(self) -> None:
        live = [(i, e) for i, e in enumerate(self.engines) if e is not None]
        if not live:
            return
        # priority order, stable within a class (sorted is stable): premium
        # dispatches — and therefore prefills — before batch every step
        self.pending.sort(key=lambda r: r.priority)
        while self.pending:
            req = self.pending.pop(0)
            if req.prompt_token_ids is not None:
                # failover requeue: resubmit the original ids verbatim
                level = req.directive_level
                ids = list(req.prompt_token_ids)
            else:
                if req.pre_rendered:
                    level = req.directive_level
                    text = req.user_prompt
                else:
                    level = self._draw_level(req)
                    text = self.directives.apply(req.user_prompt, level,
                                                 req.system_prompt)
                ids = self.tok.encode(text, bos=True)
            max_new = req.max_new_tokens
            if req.max_new_by_level is not None:
                max_new = int(req.max_new_by_level[
                    min(level, len(req.max_new_by_level) - 1)])
            # least-loaded first; on ties prefer chunked-admission engines
            # — their prefill interleaves into the live decode scan, so
            # the same load implies a shorter time-to-first-token there
            by_load = sorted(
                live, key=lambda ie: (ie[1].load(),
                                      not getattr(ie[1], "chunked_admission",
                                                  False)))
            last_err = None
            for idx, eng in by_load:
                try:
                    eng.submit(ids, max_new_tokens=max_new,
                               sampling=req.sampling, directive_level=level,
                               rid=req.rid, tenant=req.tenant,
                               deadline_at=req.deadline_at,
                               priority=req.priority,
                               t_submit=req.t_submit or None)
                    break
                except ValueError as err:
                    # engine precondition (budget/empty prompt); a pool may
                    # be heterogeneous (different max_len), so try the rest
                    last_err = err
            else:
                # no engine can serve it: park the request with the reason
                # instead of losing it or aborting the fleet step
                self.rejected.append((req, str(last_err)))

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One fleet step; returns number of tokens decoded fleet-wide."""
        self._dispatch()
        lanes = 0
        for i, eng in enumerate(self.engines):
            if eng is None:
                continue
            steps0 = eng.steps
            n_tok = eng.step()
            lanes += n_tok
            n_steps = eng.steps - steps0
            if n_steps > 0 and eng.last_decode_s > 0:
                # idle dispatches would poison the latency distribution with
                # near-zero samples; per-step (not per-token) keeps the
                # signal independent of how many slots happen to be live,
                # and engine-reported decode-only time excludes prefill and
                # compile dispatches (reported as 0.0)
                dt = eng.last_decode_s / n_steps
                self._step_times.setdefault(i, []).append(dt)
                if len(self._step_times[i]) > 50:
                    self._step_times[i] = self._step_times[i][-50:]
            if eng.finished:
                self.finished.extend(eng.finished)
                eng.finished = []
        self._check_stragglers()
        return lanes

    def _check_stragglers(self) -> None:
        meds = {i: float(np.median(t)) for i, t in self._step_times.items()
                if len(t) >= 20 and self.engines[i] is not None}
        if len(meds) < 2:
            return
        fleet_med = float(np.median(list(meds.values())))
        for i, m in meds.items():
            if m > self.straggler_factor * fleet_med:
                self.fail_replica(i)   # bench + requeue its work

    # ------------------------------------------------------------------
    def _as_requeue(self, st: RequestState) -> ServeRequest:
        """Wrap an engine RequestState for resubmission — anywhere.

        Carries the ORIGINAL token ids so dispatch resubmits them verbatim:
        a decode()/encode(bos=True) round trip would re-tokenize lossily
        (the decoded text is kept for debugging). Shared by failover
        requeue and cross-pool migration — migration is a routing decision
        over this same path, not a new serialization format."""
        return ServeRequest(
            st.rid, self.tok.decode(st.prompt_ids),
            max_new_tokens=st.max_new_tokens, sampling=st.sampling,
            pre_rendered=True, directive_level=st.directive_level,
            prompt_token_ids=list(st.prompt_ids), tenant=st.tenant,
            deadline_at=st.deadline_at, priority=st.priority,
            t_submit=st.t_submit)

    def fail_replica(self, idx: int) -> int:
        """Node failure / preemption: requeue all of the replica's work."""
        eng = self.engines[idx]
        if eng is None:
            return 0
        drained = eng.drain_slots()
        requeued = 0
        for st in drained + eng.queue:
            self.pending.append(self._as_requeue(st))
            requeued += 1
        eng.queue = []
        self.engines[idx] = None
        self._step_times.pop(idx, None)
        return requeued

    # ------------------------------------------------------------------
    def evict(self, rid: int) -> Optional[ServeRequest]:
        """Pull one request out of this pool for cross-pool migration,
        wherever it currently lives: the scheduler backlog, the parked
        rejected list, an engine queue, or a live slot (engine.evict —
        which releases the slot and its KV pages). Returns a requeue-ready
        ``ServeRequest`` (token ids verbatim for already-dispatched work),
        or ``None`` if the rid is unknown or already finished."""
        for j, req in enumerate(self.pending):
            if req.rid == rid:
                return self.pending.pop(j)
        for j, (req, _reason) in enumerate(self.rejected):
            if req.rid == rid:
                return self.rejected.pop(j)[0]
        for eng in self.engines:
            if eng is None:
                continue
            st = eng.evict(rid)
            if st is not None:
                return self._as_requeue(st)
        return None

    def add_replica(self, eng: InferenceEngine) -> None:
        """Elastic scale-up: plug a fresh engine into the pool."""
        for i, e in enumerate(self.engines):
            if e is None:
                self.engines[i] = eng
                return
        self.engines.append(eng)

    # ------------------------------------------------------------------
    def run(self, max_steps: int = 100000) -> List[FinishedRequest]:
        steps = 0
        while (self.pending or any(
                e is not None and (e.queue or any(s is not None for s in e.slots))
                for e in self.engines)) and steps < max_steps:
            self.step()
            steps += 1
        return self.finished
