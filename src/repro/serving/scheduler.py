"""Carbon-aware scheduler: SPROUT's directive selector in the request path
(Fig. 5 components 1–3) + fleet-level fault tolerance.

* directive selection: draws a level from the optimizer's current x and
  renders the directive as a system prompt before tokenization;
* replica pool: least-loaded dispatch over multiple InferenceEngines;
* fault tolerance (DESIGN.md §12): replicas carry a health state machine
  (healthy → suspect → dead) instead of the old one-way ``fail_replica``.
  A faulting replica is drained (its in-flight requests requeue over the
  verbatim-token path — the serving analogue of checkpoint/restart) and
  benched with *probation*: after an exponentially growing cooldown it is
  re-admitted as suspect, and a clean window promotes it back to healthy,
  so transient faults never permanently shrink the fleet. Fault-caused
  requeues are bounded per request: ``retries`` counts them, dispatch is
  deferred by an exponential step-based backoff, and a request past the
  retry budget parks in ``rejected`` with a reason — never a crash loop.
* straggler mitigation: replicas whose *per-decode-step* latency exceeds
  ``straggler_factor`` x fleet median are drained and benched (with
  probation — a transient slowdown earns its way back). Engines decode
  in fused multi-token blocks (engine.decode_block), so wall time per
  ``step()`` is normalized by the lockstep decode steps that dispatch
  executed — a batch-wide matmul costs the same whether 1 or n_slots lanes
  are live, so per-step (not per-token) time is the occupancy-independent
  hardware-speed signal.
* fault injection: every chaos entry point (replica crash, lane poison)
  consults the pool's seed-deterministic ``FaultInjector``; the injected
  failure then flows through the genuine mechanism (drain/health/requeue,
  in-scan finiteness detection) rather than a parallel test-only path.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.directives import DirectiveSet
from repro.serving.engine import FinishedRequest, InferenceEngine, RequestState
from repro.serving.faults import FaultInjector, no_faults
from repro.serving.sampler import SamplingParams
from repro.serving.tokenizer import ByteTokenizer


@dataclasses.dataclass
class ServeRequest:
    rid: int
    user_prompt: str
    system_prompt: Optional[str] = None
    max_new_tokens: int = 64
    # default_factory, NOT a class-level instance: a single shared default
    # object across every request would alias all of their sampling state
    sampling: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)
    # failover requeue: user_prompt is already directive-rendered ChatML —
    # dispatch must not wrap it again (the prompt would nest and grow on
    # every failover); directive_level records the original draw
    pre_rendered: bool = False
    directive_level: int = 0
    # failover also carries the ORIGINAL token ids: a decode()/encode()
    # round trip is lossy in general (byte fallbacks, specials typed as
    # text, BOS placement), so dispatch submits these verbatim when set
    prompt_token_ids: Optional[List[int]] = None
    # per-directive-level generation budget (the serving-side effect of a
    # brevity directive); indexed by the drawn level at dispatch time
    max_new_by_level: Optional[Sequence[int]] = None
    # ----- SLO identity (gateway-side service classes) -----
    # tenant class name + task family: the gateway's composite level_fn
    # draws this request's directive level from the (pool, tenant) LP mix
    tenant: str = ""
    task: str = ""
    # absolute completion deadline on the monotonic clock (inf = none);
    # the gateway stamps it from the tenant's TTFT/TPOT targets when the
    # caller leaves ``deadline_s`` (relative seconds) unset
    deadline_at: float = float("inf")
    deadline_s: float = float("inf")
    # dispatch order within a pool (lower first; stable within a class) —
    # premium work never queues behind batch work on the same fleet
    priority: int = 1
    # original submission time (stamped once by the first scheduler.submit
    # and preserved across requeue/migration): deadlines and latency are
    # end-to-end properties of the REQUEST, not of any one engine
    t_submit: float = 0.0
    # fault-recovery bookkeeping: fault-caused requeues survived so far and
    # the last fault class — carried through requeue/migration so the retry
    # budget is a property of the request, not of any one replica
    retries: int = 0
    last_fault: str = ""


@dataclasses.dataclass
class ReplicaHealth:
    """One replica's health-state record (healthy → suspect → dead).

    ``engine`` parks the benched engine object while dead-on-probation;
    ``permanent=True`` (the deprecated ``fail_replica`` path, and genuine
    hardware loss) means no re-admission. ``probations`` counts bench
    cycles and doubles the next cooldown, so a flapping replica spends
    exponentially more time on the bench."""
    state: str = "healthy"
    faults: int = 0           # faults since the last healthy promotion
    clean_steps: int = 0      # consecutive fault-free steps while suspect
    probations: int = 0      # bench cycles so far (backs off re-admission)
    benched_at_step: int = -1
    engine: Optional[InferenceEngine] = None
    permanent: bool = False


class CarbonAwareScheduler:
    def __init__(self, engines: Sequence[InferenceEngine],
                 directives: DirectiveSet = DirectiveSet(),
                 level_fn: Optional[Callable[[], int]] = None,
                 tokenizer: Optional[ByteTokenizer] = None,
                 straggler_factor: float = 4.0,
                 fault_injector: Optional[FaultInjector] = None,
                 retry_budget: int = 3, backoff_base_steps: int = 2,
                 fault_threshold: int = 2, probation_steps: int = 8,
                 clean_window: int = 16):
        self.engines: List[Optional[InferenceEngine]] = list(engines)
        self.directives = directives
        self.level_fn = level_fn or (lambda: 0)
        self.tok = tokenizer or ByteTokenizer()
        self.straggler_factor = straggler_factor
        # chaos wiring (DESIGN.md §12): the injector is always present —
        # the default empty plan makes every consult a cheap no — and the
        # fault parameters bound recovery work per request / per replica
        self.fault_injector = fault_injector or no_faults()
        self.retry_budget = retry_budget
        self.backoff_base_steps = backoff_base_steps
        self.fault_threshold = fault_threshold
        self.probation_steps = probation_steps
        self.clean_window = clean_window
        self.name = ""              # pool key (gateway-stamped) for targets
        self.pending: List[ServeRequest] = []
        self.finished: List[FinishedRequest] = []
        # requests no engine can serve (e.g. token budget exceeds the KV
        # region, or the retry budget is exhausted): kept with the
        # rejection reason instead of being lost
        self.rejected: List[tuple] = []
        self._rid = 0
        self._step_times: Dict[int, List[float]] = {}
        # fleet-step counter: the time base for retry backoff and probation
        # cooldowns (steps, not wall-clock, so chaos runs replay exactly)
        self.steps = 0
        self.health: Dict[int, ReplicaHealth] = {
            i: ReplicaHealth() for i in range(len(self.engines))}
        # rid -> earliest scheduler step at which dispatch may retry it
        self._backoff: Dict[int, int] = {}
        # (reason, RequestState) per fault-caused requeue this harvest
        # window: the gateway drains these into its wasted-work ledger and
        # brownout fault score
        self.fault_events: List[Tuple[str, RequestState]] = []

    # ------------------------------------------------------------------
    def submit(self, req: ServeRequest) -> int:
        if req.rid == 0:
            self._rid += 1
            req.rid = self._rid
        if req.t_submit == 0.0:
            # first entry into the serving system: the end-to-end latency
            # clock (and any relative deadline) starts here, and survives
            # failover requeue / cross-pool migration untouched
            req.t_submit = time.monotonic()
            if req.deadline_at == float("inf") and \
                    req.deadline_s != float("inf"):
                req.deadline_at = req.t_submit + req.deadline_s
        self.pending.append(req)
        return req.rid

    def _draw_level(self, req: ServeRequest) -> int:
        """Directive draw for one request. A gateway-installed composite
        ``level_fn`` marks itself ``per_request`` and receives the request
        (its tenant/task select the mix); plain zero-arg selectors keep
        working unchanged."""
        fn = self.level_fn
        return int(fn(req) if getattr(fn, "per_request", False) else fn())

    def _dispatch(self) -> None:
        live = [(i, e) for i, e in enumerate(self.engines) if e is not None]
        if not live:
            return
        # priority order, stable within a class (sorted is stable): premium
        # dispatches — and therefore prefills — before batch every step
        self.pending.sort(key=lambda r: r.priority)
        deferred: List[ServeRequest] = []
        while self.pending:
            req = self.pending.pop(0)
            if self._backoff.get(req.rid, 0) > self.steps:
                # retry backoff: the request sits out until its stamp —
                # an immediate redispatch onto a fleet that just poisoned
                # or crashed under it tends to fault again
                deferred.append(req)
                continue
            if req.prompt_token_ids is not None:
                # failover requeue: resubmit the original ids verbatim
                level = req.directive_level
                ids = list(req.prompt_token_ids)
            else:
                if req.pre_rendered:
                    level = req.directive_level
                    text = req.user_prompt
                else:
                    level = self._draw_level(req)
                    text = self.directives.apply(req.user_prompt, level,
                                                 req.system_prompt)
                ids = self.tok.encode(text, bos=True)
            max_new = req.max_new_tokens
            if req.max_new_by_level is not None:
                max_new = int(req.max_new_by_level[
                    min(level, len(req.max_new_by_level) - 1)])
            # least-loaded first; on ties prefer chunked-admission engines
            # — their prefill interleaves into the live decode scan, so
            # the same load implies a shorter time-to-first-token there
            by_load = sorted(
                live, key=lambda ie: (ie[1].load(),
                                      not getattr(ie[1], "chunked_admission",
                                                  False)))
            last_err = None
            for idx, eng in by_load:
                try:
                    eng.submit(ids, max_new_tokens=max_new,
                               sampling=req.sampling, directive_level=level,
                               rid=req.rid, tenant=req.tenant,
                               deadline_at=req.deadline_at,
                               priority=req.priority,
                               t_submit=req.t_submit or None,
                               retries=req.retries,
                               last_fault=req.last_fault)
                    self._backoff.pop(req.rid, None)
                    break
                except ValueError as err:
                    # engine precondition (budget/empty prompt); a pool may
                    # be heterogeneous (different max_len), so try the rest
                    last_err = err
            else:
                # no engine can serve it: park the request with the reason
                # instead of losing it or aborting the fleet step
                self.rejected.append((req, str(last_err)))
        self.pending.extend(deferred)

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One fleet step; returns number of tokens decoded fleet-wide."""
        self.steps += 1
        self._consult_injector()
        self._tick_probation()
        self._dispatch()
        lanes = 0
        for i, eng in enumerate(self.engines):
            if eng is None:
                continue
            steps0 = eng.steps
            n_tok = eng.step()
            lanes += n_tok
            n_steps = eng.steps - steps0
            if n_steps > 0 and eng.last_decode_s > 0:
                # idle dispatches would poison the latency distribution with
                # near-zero samples; per-step (not per-token) keeps the
                # signal independent of how many slots happen to be live,
                # and engine-reported decode-only time excludes prefill and
                # compile dispatches (reported as 0.0)
                dt = eng.last_decode_s / n_steps
                self._step_times.setdefault(i, []).append(dt)
                if len(self._step_times[i]) > 50:
                    self._step_times[i] = self._step_times[i][-50:]
            if eng.finished:
                self.finished.extend(eng.finished)
                eng.finished = []
            if eng.faulted:
                # lanes the engine quarantined this block (non-finite
                # logits): bounded-retry requeue + a health strike on the
                # replica that produced them
                for st in eng.faulted:
                    self._requeue_faulted(st, st.last_fault
                                          or "decode.nonfinite")
                eng.faulted = []
                self._record_fault(i)
            elif self.health.setdefault(i, ReplicaHealth()).state \
                    == "suspect":
                h = self.health[i]
                h.clean_steps += 1
                if h.clean_steps >= self.clean_window:
                    # served a full clean window: promoted back to
                    # healthy with a clean slate (probation debt cleared)
                    h.state, h.faults, h.probations = "healthy", 0, 0
        self._check_stragglers()
        return lanes

    # ------------------------------------------------------------------
    def _consult_injector(self) -> None:
        """One injection opportunity per live replica (crash) and per
        occupied lane (KV poison) per fleet step. The injected failure
        then flows through the genuine mechanism: a crash drains through
        the health machine; a poisoned lane is caught by the engine's
        in-scan finiteness verdict, not by the injector."""
        inj = self.fault_injector
        for i, eng in enumerate(self.engines):
            if eng is None:
                continue
            if inj.fire("replica.crash", f"{self.name}/{i}"):
                self._bench(i, fault_reason="replica.crash")
                continue
            for slot, st in enumerate(eng.slots):
                if st is not None and inj.fire("decode.nonfinite",
                                               str(st.rid)):
                    eng.poison_lane(slot)

    def _tick_probation(self) -> None:
        """Re-admit benched replicas whose probation cooldown elapsed.
        The cooldown doubles with each bench cycle, so a replica that
        keeps faulting spends exponentially longer on the bench."""
        for idx, h in list(self.health.items()):
            if h.state != "dead" or h.engine is None:
                continue
            wait = self.probation_steps * (2 ** max(h.probations - 1, 0))
            if self.steps - h.benched_at_step >= wait:
                self._readmit(idx)

    def _readmit(self, idx: int) -> None:
        h = self.health[idx]
        eng, h.engine = h.engine, None
        h.state = "suspect"
        # one strike from re-benching: a probationary replica that faults
        # again goes straight back to the bench (with a longer cooldown)
        h.faults = max(self.fault_threshold - 1, 0)
        h.clean_steps = 0
        if self.engines[idx] is None:
            self.engines[idx] = eng
        else:
            # its old index was taken by elastic scale-up: append, and
            # move the health record to the replica's new index
            self.engines.append(eng)
            new_idx = len(self.engines) - 1
            self.health[new_idx] = h
            self.health[idx] = ReplicaHealth(state="healthy")

    def _record_fault(self, idx: int) -> None:
        """One health strike against a replica: healthy → suspect on the
        first, bench (with probation) at ``fault_threshold``."""
        h = self.health.setdefault(idx, ReplicaHealth())
        h.faults += 1
        h.clean_steps = 0
        if h.state == "healthy":
            h.state = "suspect"
        if h.faults >= self.fault_threshold and \
                self.engines[idx] is not None:
            self._bench(idx, fault_reason=None)

    def _requeue_faulted(self, st: RequestState, reason: str) -> None:
        """Bounded-retry requeue of a fault-interrupted request: retries
        increment, dispatch backs off exponentially (in fleet steps), and
        a request past the budget parks in ``rejected`` with the reason —
        the fleet never spins on a poisoned request."""
        st.retries += 1
        st.last_fault = reason
        self.fault_events.append((reason, st))
        req = self._as_requeue(st)
        if st.retries > self.retry_budget:
            self.rejected.append((
                req, f"retry budget exhausted ({self.retry_budget}) "
                     f"after fault {reason}"))
            return
        self._backoff[st.rid] = self.steps + \
            self.backoff_base_steps * (2 ** (st.retries - 1))
        self.pending.append(req)

    def _check_stragglers(self) -> None:
        meds = {i: float(np.median(t)) for i, t in self._step_times.items()
                if len(t) >= 20 and self.engines[i] is not None}
        if len(meds) < 2:
            return
        fleet_med = float(np.median(list(meds.values())))
        for i, m in meds.items():
            if m > self.straggler_factor * fleet_med:
                # bench + requeue its work; a transient slowdown (noisy
                # neighbor, thermal) earns re-admission through probation
                self._bench(i, fault_reason=None)

    # ------------------------------------------------------------------
    def _as_requeue(self, st: RequestState) -> ServeRequest:
        """Wrap an engine RequestState for resubmission — anywhere.

        Carries the ORIGINAL token ids so dispatch resubmits them verbatim:
        a decode()/encode(bos=True) round trip would re-tokenize lossily
        (the decoded text is kept for debugging). Shared by failover
        requeue and cross-pool migration — migration is a routing decision
        over this same path, not a new serialization format."""
        return ServeRequest(
            st.rid, self.tok.decode(st.prompt_ids),
            max_new_tokens=st.max_new_tokens, sampling=st.sampling,
            pre_rendered=True, directive_level=st.directive_level,
            prompt_token_ids=list(st.prompt_ids), tenant=st.tenant,
            deadline_at=st.deadline_at, priority=st.priority,
            t_submit=st.t_submit, retries=st.retries,
            last_fault=st.last_fault)

    def _bench(self, idx: int, *, permanent: bool = False,
               fault_reason: Optional[str] = None) -> int:
        """Take a replica out of service: drain its in-flight work back
        into the backlog and mark it dead. ``fault_reason`` set means the
        replica crashed under its slotted requests — those requeue through
        the bounded-retry path (their generated-so-far tokens are wasted
        work the gateway will charge); queued-but-unstarted requests lost
        nothing and requeue plain either way. Unless ``permanent``, the
        engine object is parked on the health record for probation
        re-admission."""
        eng = self.engines[idx]
        if eng is None:
            return 0
        drained = eng.drain_slots()
        requeued = 0
        for st in drained:
            if fault_reason is not None:
                self._requeue_faulted(st, fault_reason)
            else:
                self.pending.append(self._as_requeue(st))
            requeued += 1
        for st in eng.queue:
            self.pending.append(self._as_requeue(st))
            requeued += 1
        eng.queue = []
        h = self.health.setdefault(idx, ReplicaHealth())
        h.state = "dead"
        h.permanent = permanent
        h.engine = None if permanent else eng
        h.benched_at_step = self.steps
        h.probations += 1
        h.faults = 0
        h.clean_steps = 0
        self.engines[idx] = None
        self._step_times.pop(idx, None)
        return requeued

    def fail_replica(self, idx: int) -> int:
        """Deprecated: permanent replica loss with plain requeue (the
        pre-health-machine semantics, kept for callers that model
        irrecoverable node loss). New code should let the health machine
        bench replicas — ``_bench`` via fault strikes — so transients
        recover through probation."""
        warnings.warn(
            "fail_replica is deprecated: replicas now carry health states "
            "(healthy/suspect/dead) with probation re-admission; this "
            "alias benches the replica permanently",
            DeprecationWarning, stacklevel=2)
        return self._bench(idx, permanent=True)

    def has_recoverable_replica(self) -> bool:
        """True while any benched replica is parked for probation — the
        pool can still regain capacity without outside help (the gateway's
        drain logic keys on this before parking stranded work)."""
        return any(h.state == "dead" and h.engine is not None
                   for h in self.health.values())

    def tp_degree(self) -> int:
        """Widest tensor-parallel sharding across live replicas — the
        fleet geometry the gateway's energy accounting prices a request
        at (GatewayPool.tp_degree forwards here-equivalent logic;
        DESIGN.md §14). 1 when the fleet is empty or unsharded."""
        return max((getattr(e, "tp_degree", 1)
                    for e in self.engines if e is not None), default=1)

    # ------------------------------------------------------------------
    def evict(self, rid: int) -> Optional[ServeRequest]:
        """Pull one request out of this pool for cross-pool migration,
        wherever it currently lives: the scheduler backlog, the parked
        rejected list, an engine queue, or a live slot (engine.evict —
        which releases the slot and its KV pages). Returns a requeue-ready
        ``ServeRequest`` (token ids verbatim for already-dispatched work),
        or ``None`` if the rid is unknown or already finished."""
        for j, req in enumerate(self.pending):
            if req.rid == rid:
                return self.pending.pop(j)
        for j, (req, _reason) in enumerate(self.rejected):
            if req.rid == rid:
                return self.rejected.pop(j)[0]
        for eng in self.engines:
            if eng is None:
                continue
            st = eng.evict(rid)
            if st is not None:
                return self._as_requeue(st)
        return None

    def add_replica(self, eng: InferenceEngine) -> None:
        """Elastic scale-up: plug a fresh engine into the pool (a fresh
        replica starts healthy, clearing any stale record — but never a
        benched-on-probation slot, whose parked engine must keep its
        health record for re-admission)."""
        for i, e in enumerate(self.engines):
            if e is None:
                h = self.health.get(i)
                if h is not None and h.state == "dead" \
                        and h.engine is not None:
                    continue         # reserved: probation will refill it
                self.engines[i] = eng
                self.health[i] = ReplicaHealth()
                return
        self.engines.append(eng)
        self.health[len(self.engines) - 1] = ReplicaHealth()

    # ------------------------------------------------------------------
    def run(self, max_steps: int = 100000) -> List[FinishedRequest]:
        steps = 0
        while (self.pending or any(
                e is not None and (e.queue or any(s is not None for s in e.slots))
                for e in self.engines)) and steps < max_steps:
            self.step()
            steps += 1
        return self.finished
