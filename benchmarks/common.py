"""Shared benchmark helpers: timed runs + CSV emission."""
from __future__ import annotations

import time
from typing import Callable, Dict, List


def timed(fn: Callable, *args, repeat: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6  # microseconds


def emit(rows: List[Dict]) -> None:
    """Print ``name,us_per_call,derived`` CSV rows."""
    for r in rows:
        name = r["name"]
        us = r.get("us_per_call", 0.0)
        derived = ";".join(f"{k}={v}" for k, v in r.items()
                           if k not in ("name", "us_per_call"))
        print(f"{name},{us:.1f},{derived}", flush=True)
