"""Shared benchmark helpers: timed runs + CSV/JSON emission."""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Callable, Dict, List


def timed(fn: Callable, *args, repeat: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6  # microseconds


def emit(rows: List[Dict]) -> None:
    """Print ``name,us_per_call,derived`` CSV rows."""
    for r in rows:
        name = r["name"]
        us = r.get("us_per_call", 0.0)
        derived = ";".join(f"{k}={v}" for k, v in r.items()
                           if k not in ("name", "us_per_call"))
        print(f"{name},{us:.1f},{derived}", flush=True)


def emit_json(filename: str, rows: List[Dict], meta: Dict = None) -> Path:
    """Write rows keyed by name to ``<repo-root>/<filename>`` so successive
    PRs accumulate a machine-readable perf trajectory."""
    path = Path(__file__).resolve().parents[1] / filename
    payload = {"meta": meta or {},
               "rows": {r["name"]: {k: v for k, v in r.items()
                                    if k != "name"} for r in rows}}
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    return path
