"""§Roofline source table: read the dry-run artifacts and report the three
roofline terms per (arch x shape x mesh)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def run():
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        name = f"roofline.{rec['arch']}.{rec['shape']}.{rec['mesh']}"
        if rec.get("status") == "skipped":
            rows.append({"name": name, "status": "skipped"})
            continue
        if rec.get("status") != "ok":
            rows.append({"name": name, "status": "error",
                         "error": rec.get("error", "?")[:60]})
            continue
        r = rec["roofline"]
        rows.append({
            "name": name,
            "compute_s": f"{r['compute_s']:.3e}",
            "memory_s": f"{r['memory_s']:.3e}",
            "collective_s": f"{r['collective_s']:.3e}",
            "dominant": r["dominant"],
            "useful_ratio": f"{r['useful_ratio']:.3f}",
            "roofline_frac": f"{r['roofline_fraction']:.3f}",
            "peak_mem_GB": f"{rec['memory_analysis'].get('peak_bytes_est', 0) / 1e9:.1f}",
        })
    if not rows:
        rows.append({"name": "roofline.missing",
                     "note": "run python -m repro.launch.dryrun --all first"})
    return rows


if __name__ == "__main__":
    emit(run())
