"""Fig. 15: SPROUT stays effective across seasons (Feb / Jun / Oct)."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import SproutSimulation, summarize
from repro.core.carbon import REGIONS, SEASONS


def run(hours=24 * 5, cap=60):
    rows = []
    for season in SEASONS:
        for region in REGIONS:
            sim = SproutSimulation(region=region, season=season, hours=hours,
                                   seed=5, requests_per_hour_cap=cap,
                                   schemes=["BASE", "SPROUT"])
            s = summarize(sim.run())
            rows.append({
                "name": f"fig15.{season}.{region}",
                "carbon_savings_pct": f"{s['SPROUT']['carbon_savings_pct']:.1f}",
                "norm_pref_pct": f"{s['SPROUT']['normalized_preference_pct']:.1f}",
            })
    return rows


if __name__ == "__main__":
    emit(run())
