"""Fig. 13: without the offline evaluator SPROUT misses directive-friendly
phases — lower savings AND lower preference when friendliness is high."""
from __future__ import annotations


from benchmarks.common import emit
from repro.core import SproutSimulation, summarize
from repro.core.workload import Workload


def _mixture_schedule(hours):
    sched = []
    for h in range(hours):
        friendly = 0.85 if (h // 24) % 2 == 0 else 0.2   # alternating phases
        f = friendly / 4
        u = (1 - friendly) / 2
        sched.append({"alpaca": u, "gsm8k": u, "mmlu": f, "naturalqa": f,
                      "scienceqa": f, "triviaqa": f})
    return sched


def run(hours=24 * 5, cap=80):
    rows = []
    for with_eval in (True, False):
        w = Workload(seed=6, mixture_schedule=_mixture_schedule(hours))
        sim = SproutSimulation(region="CA", hours=hours, seed=3, workload=w,
                               requests_per_hour_cap=cap,
                               schemes=["BASE", "SPROUT"],
                               with_evaluator=with_eval)
        sim.invoker.grace = 4
        if not with_eval:
            # paper's ablation: quality feedback exists but never refreshes —
            # seed q once from an unfriendly-phase sample, then freeze
            wu = Workload(seed=8, mixture_schedule=_mixture_schedule(hours))
            pool = [wu.sample_request(30.0) for _ in range(600)]
            rep = sim.evaluator.evaluate(pool)
            sim.q_est = rep.q
            sim.task_q = rep.q_by_task or {}
        stats = sim.run()
        s = summarize(stats)
        rows.append({
            "name": f"fig13.evaluator_{'on' if with_eval else 'off'}",
            "carbon_savings_pct": f"{s['SPROUT']['carbon_savings_pct']:.1f}",
            "norm_pref_pct": f"{s['SPROUT']['normalized_preference_pct']:.1f}",
            "n_evals": len(stats["SPROUT"].eval_times),
        })
    return rows


if __name__ == "__main__":
    emit(run())
