"""Fig. 11: per-request carbon CDF (normalized to BASE) at constant
environmental carbon intensities 200/300/400 gCO2/kWh — SPROUT's CDF moves
toward CO2_OPT as intensity rises."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import SproutSimulation


class _ConstProvider:
    def __init__(self, ci, lo=55.0, hi=500.0):
        self.trace = np.full(24 * 7, float(ci))
        self.k_min, self.k_max = lo, hi

    def intensity(self, t):
        return float(self.trace[int(t) % len(self.trace)])


def run(hours=24 * 4, cap=80):
    rows = []
    for ci in (200, 300, 400):
        sim = SproutSimulation(region="CA", hours=hours, seed=1,
                               requests_per_hour_cap=cap,
                               schemes=["BASE", "CO2_OPT", "SPROUT"])
        sim.provider = _ConstProvider(ci)   # constant-intensity environment
        # steady-state analysis (paper Fig. 11): quality feedback is warm
        pool = [sim.workload.sample_request(i * 0.01) for i in range(2000)]
        rep = sim.evaluator.evaluate(pool)
        sim.q_est = rep.q
        sim.task_q = rep.q_by_task
        stats = sim.run()
        for scheme in ("CO2_OPT", "SPROUT"):
            norm = np.asarray(stats[scheme].per_request_norm)
            norm = norm[len(norm) // 4:]    # post-warmup
            frac_below_40 = float((norm < 0.4).mean())
            rows.append({
                "name": f"fig11.ci{ci}.{scheme}",
                "n_requests": len(norm),
                "p50_norm_carbon": f"{np.percentile(norm, 50):.3f}",
                "frac_below_0.4xBASE": f"{frac_below_40:.2f}",
            })
    return rows


if __name__ == "__main__":
    emit(run())
