"""Fig. 14: (a) evaluator carbon overhead (<1% of server emissions);
(b) evaluations land in the low-carbon-intensity part of each region's
distribution."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import SproutSimulation, summarize
from repro.core.carbon import REGIONS


def run(hours=24 * 14, cap=60):
    rows = []
    for region in REGIONS:
        sim = SproutSimulation(region=region, season="jun", hours=hours,
                               seed=4, requests_per_hour_cap=cap,
                               schemes=["BASE", "SPROUT"])
        stats = sim.run()
        s = summarize(stats)
        evals = stats["SPROUT"].eval_times
        trace = sim.provider.trace[:hours]
        if evals:
            ci_at_eval = np.array([trace[int(t)] for t in evals])
            pctile = float(np.mean([np.mean(trace <= c) for c in ci_at_eval]))
        else:
            pctile = float("nan")
        rows.append({
            "name": f"fig14.{region}",
            "eval_overhead_pct": f"{s['SPROUT']['eval_overhead_pct']:.3f}",
            "n_evals": len(evals),
            "eval_ci_percentile": f"{pctile:.2f}",
        })
    return rows


if __name__ == "__main__":
    emit(run())
