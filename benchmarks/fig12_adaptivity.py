"""Fig. 12: SPROUT's directive mix adapts to carbon intensity AND evaluator
preference drift across four controlled periods."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import SproutSimulation
from repro.core.workload import Workload

# (carbon intensity, friendly-task weight) per 48h period — mirrors the
# paper's period narrative: rising CI, then preference shifts
PERIODS = [(100.0, 0.15), (300.0, 0.15), (300.0, 0.05), (380.0, 0.75)]
HOURS_PER_PERIOD = 48


class _PeriodProvider:
    def __init__(self):
        self.trace = np.concatenate([
            np.full(HOURS_PER_PERIOD, ci) for ci, _ in PERIODS])
        self.k_min, self.k_max = 55.0, 500.0

    def intensity(self, t):
        return float(self.trace[int(t) % len(self.trace)])


def _mixture_schedule():
    sched = []
    for _, friendly in PERIODS:
        f = friendly / 4
        u = (1 - friendly) / 2
        mix = {"alpaca": u, "gsm8k": u, "mmlu": f, "naturalqa": f,
               "scienceqa": f, "triviaqa": f}
        sched.extend([mix] * HOURS_PER_PERIOD)
    return sched


def run(cap=80):
    hours = HOURS_PER_PERIOD * len(PERIODS)
    w = Workload(seed=5, mixture_schedule=_mixture_schedule())
    sim = SproutSimulation(region="CA", hours=hours, seed=2, workload=w,
                           requests_per_hour_cap=cap,
                           schemes=["BASE", "SPROUT"])
    sim.provider = _PeriodProvider()
    sim.invoker.grace = 4   # let q refresh within each period
    stats = sim.run()
    mixes = np.stack(stats["SPROUT"].hourly_mix)
    rows = []
    for i, (ci, friendly) in enumerate(PERIODS):
        seg = mixes[i * HOURS_PER_PERIOD + 12:(i + 1) * HOURS_PER_PERIOD]
        m = seg.mean(axis=0)
        rows.append({
            "name": f"fig12.period{i}",
            "ci": ci, "friendly_frac": friendly,
            "mix_L0/L1/L2": "/".join(f"{x:.2f}" for x in m),
        })
    # adaptivity assertions encoded as derived fields
    p0 = mixes[12:HOURS_PER_PERIOD].mean(0)
    p3 = mixes[3 * HOURS_PER_PERIOD + 12:].mean(0)
    rows.append({"name": "fig12.shift",
                 "L0_period0": f"{p0[0]:.2f}", "L0_period3": f"{p3[0]:.2f}",
                 "adapts": str(bool(p3[0] < p0[0]))})
    return rows


if __name__ == "__main__":
    emit(run())
