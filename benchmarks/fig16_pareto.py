"""Fig. 16: Pareto front over the preference coefficient xi — even at a
strict 95% preference floor SPROUT keeps >=40% savings (paper claim)."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import SproutSimulation, summarize


def run(hours=24 * 5, cap=60, region="CA"):
    rows = []
    for xi in (0.02, 0.05, 0.1, 0.2, 0.3):
        sim = SproutSimulation(region=region, season="jun", hours=hours,
                               seed=6, xi=xi, requests_per_hour_cap=cap,
                               schemes=["BASE", "SPROUT"])
        s = summarize(sim.run())
        rows.append({
            "name": f"fig16.xi{xi}",
            "carbon_savings_pct": f"{s['SPROUT']['carbon_savings_pct']:.1f}",
            "norm_pref_pct": f"{s['SPROUT']['normalized_preference_pct']:.1f}",
        })
    return rows


if __name__ == "__main__":
    emit(run())
