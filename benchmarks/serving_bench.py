"""Real serving microbenchmarks on the CPU engine (tiny model): decode
throughput, prefill latency, LP solve time, evaluator cost, and the
closed-loop gateway's carbon-per-request against an L0-only baseline —
the measured (not modeled) numbers in this container.

Decode throughput is measured in the steady state: the engine is warmed
with one identical workload first, so the number reflects the serving hot
path (device-resident fused decode blocks) rather than one-off XLA
compilation. ``serve.engine_decode_k1`` runs the same engine pinned to
single-token blocks for an apples-to-apples view of what multi-token
stepping buys, and ``serve.ttft_under_load`` measures the
continuous-batching payoff: arrival TTFT against saturated decode lanes,
chunked admission vs the slot-epoch baseline. Results also land in
``BENCH_serving.json`` at the repo root so future PRs have a perf
trajectory to compare against.
"""
from __future__ import annotations

import argparse
import json
import math
import os

# must precede `import jax` (the backend reads XLA_FLAGS once, at init):
# the serve.sharded_decode row builds real tp=2 engines (DESIGN.md §14),
# which need multiple devices — on CPU that means forced host devices.
# Every row in this file runs under the 8-device CPU client, so numbers
# are only comparable to baselines produced the same way.
_FORCE_DEVICES = "--xla_force_host_platform_device_count=8"
if _FORCE_DEVICES not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = \
        f"{os.environ.get('XLA_FLAGS', '')} {_FORCE_DEVICES}".strip()

import jax
import numpy as np

import time
from pathlib import Path

from benchmarks.common import emit, emit_json, timed
from repro.analysis import frozen_entry_points
from repro.configs import reduced
from repro.core import A100_40GB, CarbonIntensityProvider, EnergyModel
from repro.core.energy import LLAMA2_13B
from repro.core.lp import TenantSpec, solve_directive_lp
from repro.core.policies import SproutPolicy
from repro.core.quality import QualityEvaluator
from repro.core.workload import Workload
from repro.models import model as MD
from repro.serving import (ByteTokenizer, CarbonAwareScheduler, FaultInjector,
                           FaultPlan, FaultSpec, InferenceEngine,
                           MigrationPlanner, SamplingParams, ServeRequest,
                           SproutGateway, serve_request_from)

DECODE_BLOCK = 16


PAGE_SIZE = 16   # reduced CPU config; 128-256 on TPU (DESIGN.md §3)


def _load(eng, tok, sampling=SamplingParams(), n_req=8, max_new=32):
    for _ in range(n_req):
        eng.submit(tok.encode("benchmark prompt " * 3), max_new_tokens=max_new,
                   sampling=sampling)


def _run_tracked(eng, max_steps: int = 100000):
    """run_to_completion with the engine's residency high-water marks reset
    first: returns (us_total, peaks). Peaks come from the ENGINE (sampled
    at maximal residency inside step(), before same-step finishes release
    slots/pages — an outside observer would undercount requests that are
    admitted and complete within one block), so only decode work is inside
    the clock. Caps iterations like run_to_completion so an engine stall
    cannot hang the benchmark."""
    eng.peak_concurrent = 0
    eng.peak_pages_in_use = 0
    us_total = 0.0
    steps = 0
    while (eng.queue or any(s is not None for s in eng.slots)) \
            and steps < max_steps:
        t0 = time.perf_counter()
        eng.step()
        us_total += (time.perf_counter() - t0) * 1e6
        steps += 1
    s = eng.kv_stats()
    peaks = {"concurrent": eng.peak_concurrent,
             "pages_in_use": eng.peak_pages_in_use,
             "kv_bytes_in_use": s.get("peak_kv_bytes_in_use",
                                      s["kv_bytes_in_use"])}
    return us_total, peaks


def _decode_row(cfg, params, tok, name, *, decode_block,
                sampling=SamplingParams(), n_req=8, max_new=32, repeats=3,
                **engine_kwargs):
    eng = InferenceEngine(cfg, params, n_slots=4, max_len=128,
                          decode_block=decode_block, **engine_kwargs)
    _load(eng, tok, sampling, n_req, max_new)
    eng.run_to_completion()          # warm: compile the program variants
    # best-of-3 by throughput: stochastic EOS (sampled rows) can surface a
    # block length / prefill shape the warm run never compiled, landing one
    # XLA compile inside a timed run, and also varies the token count per
    # repeat — selecting by tok/s (not wall time) keeps the steady-state
    # number comparable across runs
    best = None
    for _ in range(repeats):
        eng.finished = []
        syncs0 = eng.decode_syncs
        _load(eng, tok, sampling, n_req, max_new)
        us_total, peaks = _run_tracked(eng)
        toks = sum(f.gen_tokens for f in eng.finished)
        rate = toks / (us_total / 1e6)
        if best is None or rate > best[0]:
            best = (rate, us_total, toks, eng.decode_syncs - syncs0, peaks)
    rate, us_total, toks, syncs, peaks = best
    row = {"name": name, "us_per_call": us_total, "tokens": toks,
           "tok_per_s": round(rate, 1),
           "tok_per_sync": round(toks / max(syncs, 1), 1),
           "decode_block": decode_block}
    if eng.paged:
        st = eng.kv_stats()
        row.update(page_size=eng.pages.page_size, n_pages=eng.pages.n_pages,
                   peak_pages_in_use=peaks["pages_in_use"],
                   peak_kv_bytes_in_use=peaks["kv_bytes_in_use"],
                   kv_bytes_capacity=st["kv_bytes_capacity"])
    return row


def _sharded_decode_row(cfg, params, tok, dense_row, *, decode_block,
                        n_req=8, max_new=32, repeats=3):
    """Tensor-parallel fused decode (DESIGN.md §14): the tp=2 engine on
    forced host devices vs the tp=1 ``serve.engine_decode`` row (identical
    settings). Forced host devices share one CPU's cores, so tp=2 buys no
    real throughput here — the row tracks sharding OVERHEAD (the vs_tp1
    ratio), asserts greedy token identity tp=1 vs tp=2, and reports the
    multi-chip roofline's modeled J/token for the 13B accounting target
    (per-chip HBM + interconnect collective bytes, fleet power)."""
    row = _decode_row(cfg, params, tok, "serve.sharded_decode",
                      decode_block=decode_block, n_req=n_req,
                      max_new=max_new, repeats=repeats, tp_degree=2)

    def greedy_toks(tp):
        eng = InferenceEngine(cfg, params, n_slots=4, max_len=128,
                              decode_block=decode_block, tp_degree=tp)
        _load(eng, tok, n_req=3, max_new=12)
        eng.run_to_completion()
        return {f.rid: f.token_ids for f in eng.finished}

    identical = greedy_toks(1) == greedy_toks(2)
    assert identical, "tp=2 greedy decode diverged from tp=1"
    em1 = EnergyModel(A100_40GB)
    em2 = em1.with_chips(2)
    row.update(
        tp_degree=2,
        tok_per_s_tp1=dense_row["tok_per_s"],
        tok_per_s_vs_tp1=round(row["tok_per_s"] / dense_row["tok_per_s"], 3),
        token_identical=identical,
        modeled_j_per_token_tp1=round(
            em1.joules_per_token(LLAMA2_13B), 4),
        modeled_j_per_token_tp2=round(
            em2.joules_per_token(LLAMA2_13B), 4),
        modeled_collective_bytes_per_token=round(
            em2.collective_bytes_per_token(LLAMA2_13B)))
    return row


def _capacity_row(cfg, params, tok):
    """Concurrency under one fixed HBM budget, mixed-length directive
    workload: the dense layout fits budget/(max_len*bytes) slots; the
    paged engine admits against worst-case page reservations, so brief
    requests pack. Both serve identical request streams."""
    budgets = [48, 24, 8]            # L0/L1/L2-style per-level token caps
    n_req = 16

    def submit_all(eng):
        for i in range(n_req):
            eng.submit(tok.encode(f"req {i:02d}"),
                       max_new_tokens=budgets[i % 3])

    # dense: 4 slots x 128 tokens == 512 cached tokens of HBM
    dense = InferenceEngine(cfg, params, n_slots=4, max_len=128,
                            decode_block=16, eos_id=-1)
    submit_all(dense)
    dense_us, dense_peaks = _run_tracked(dense)
    # paged: the SAME 512-token budget as 32 pages; slots are plentiful
    paged = InferenceEngine(cfg, params, n_slots=16, max_len=128,
                            decode_block=16, eos_id=-1, paged=True,
                            page_size=PAGE_SIZE, n_pages=32)
    submit_all(paged)
    paged_us, paged_peaks = _run_tracked(paged)
    return {"name": "serve.paged_capacity",
            # both drains are timed work (cold engines, so this is a case
            # cost for trend-watching, not a steady-state latency claim)
            "us_per_call": dense_us + paged_us,
            "dense_drain_us": round(dense_us, 1),
            "paged_drain_us": round(paged_us, 1),
            "hbm_budget_tokens": 32 * PAGE_SIZE,
            "dense_peak_concurrent": dense_peaks["concurrent"],
            "paged_peak_concurrent": paged_peaks["concurrent"],
            "concurrency_ratio": round(
                paged_peaks["concurrent"]
                / max(dense_peaks["concurrent"], 1), 2),
            "paged_peak_pages": paged_peaks["pages_in_use"],
            "budgets": budgets, "requests": n_req}


def _ttft_under_load_row(cfg, params, tok, *, n_arrivals=8, bg_lanes=4,
                         bg_new=96, max_new=4, prompt_reps=8, chunk=16,
                         decode_block=DECODE_BLOCK, max_len=128,
                         assert_thresholds=True):
    """Time-to-first-token for an arrival against saturated decode lanes:
    the continuous-batching payoff, measured.

    Both engines are paged with the SAME page budget (pages are the HBM;
    slots are bookkeeping). The slot-epoch baseline is the pre-bucketing
    world: ``n_slots == bg_lanes`` because a fixed-batch engine pays
    full-batch FLOPs for every provisioned slot whether live or not, so
    slots are sized to the decode load — an arrival queues until a lane's
    token budget runs out. The chunked engine provisions spare lanes
    (``2 * bg_lanes``; bucketed entry points make idle lanes free) and
    admits the arrival as a chunk task interleaved into the live decode
    scan, so its first token lands within a couple of blocks.

    Every trial re-saturates the background lanes (finished background
    requests are replaced with identical budgets) before submitting the
    arrival, and the arrival's TTFT comes from engine telemetry
    (``FinishedRequest.ttft_s``). Warm trials run first until the compiled
    entry-point table stops growing; the measured window then asserts the
    table stayed frozen, so the p50/p95 describe warm paths only."""
    arr_ids = tok.encode("arrival " * prompt_reps)
    n_pages = (bg_lanes + 2) * (max_len // PAGE_SIZE)

    def measure(n_slots, prefill_chunk):
        eng = InferenceEngine(cfg, params, n_slots=n_slots, max_len=max_len,
                              decode_block=decode_block, eos_id=-1,
                              paged=True, page_size=PAGE_SIZE,
                              n_pages=n_pages, prefill_chunk=prefill_chunk)
        inflight = set()

        def harvest(skip=-1):
            for f in eng.finished:
                if f.rid != skip:
                    inflight.discard(f.rid)
            eng.finished = [f for f in eng.finished if f.rid == skip]

        def top_up():
            # identical budgets keep background completions synchronized,
            # which keeps the block-length (k) variant set small and
            # warmable; TTFT spread comes from the arrival's phase within
            # the background budget cycle, which differs per trial
            while len(inflight) < bg_lanes:
                inflight.add(eng.submit(tok.encode("bg"),
                                        max_new_tokens=bg_new))

        def trial():
            top_up()
            for _ in range(10 * bg_new):   # re-saturate: all lanes live,
                if (int(np.sum(eng.live)) >= bg_lanes and not eng.queue
                        and getattr(eng, "_task", None) is None):
                    break                  # nothing mid-admission
                eng.step()
                harvest()
                top_up()
            rid = eng.submit(list(arr_ids), max_new_tokens=max_new)
            for _ in range(10 * bg_new):
                eng.step()
                fin = next((f for f in eng.finished if f.rid == rid), None)
                harvest(skip=rid)
                top_up()
                if fin is not None:
                    eng.finished = []
                    return fin.ttft_s
            raise AssertionError("arrival never finished under load")

        # warm until the entry-point table is a fixed point across a whole
        # trial (two quiet trials in a row), then measure against it
        quiet = 0
        for _ in range(12):
            before = len(eng.entry_points)
            trial()
            quiet = quiet + 1 if len(eng.entry_points) == before else 0
            if quiet >= 2:
                break
        # shared analysis-API guard (repro.analysis.frozen_entry_points):
        # a cold compile inside the measured window raises with the exact
        # minted/retired names instead of the old count-only assert
        with frozen_entry_points(eng, "TTFT measurement window"):
            ttfts = [trial() for _ in range(n_arrivals)]
        return (float(np.percentile(ttfts, 50)),
                float(np.percentile(ttfts, 95)))

    t0 = time.perf_counter()
    se_p50, se_p95 = measure(bg_lanes, 0)
    ch_p50, ch_p95 = measure(2 * bg_lanes, chunk)
    us_total = (time.perf_counter() - t0) * 1e6
    speedup = se_p95 / max(ch_p95, 1e-9)
    if assert_thresholds:
        assert speedup >= 2.0, \
            f"chunked p95 TTFT speedup {speedup:.2f}x < 2x vs slot-epoch"
    return {"name": "serve.ttft_under_load",
            "us_per_call": us_total,
            "ttft_p50_ms_slot_epoch": round(se_p50 * 1e3, 3),
            "ttft_p95_ms_slot_epoch": round(se_p95 * 1e3, 3),
            "ttft_p50_ms_chunked": round(ch_p50 * 1e3, 3),
            "ttft_p95_ms_chunked": round(ch_p95 * 1e3, 3),
            "ttft_p95_speedup": round(speedup, 2),
            "entry_points_stable": True,
            "arrivals": n_arrivals, "bg_lanes": bg_lanes, "bg_new": bg_new,
            "prompt_tokens": len(arr_ids), "prefill_chunk": chunk,
            "page_budget": n_pages}


def _gateway_row(cfg, params, *, hours=5, warmup_hours=2, per_hour=14):
    """Closed control loop vs L0-only over the SAME request stream on a
    dirty grid (TX: fossil-baseline ERCOT trace). Both gateways serve real
    engines; carbon-per-request is compared over the post-warmup window
    (the SPROUT gateway spends ``warmup_hours`` profiling at a uniform mix
    before the LP has per-level energies to solve over)."""
    region = "TX"
    w = Workload(seed=2)
    q = QualityEvaluator(sample_size=300).evaluate(
        [w.sample_request(i * 0.1) for i in range(600)]).q
    streams = [[w.sample_request(h + i * 0.01) for i in range(per_hour)]
               for h in range(hours)]

    def run_one(use_lp):
        prov = CarbonIntensityProvider(region, "jun")
        # eos_id=-1: budget-bound decoding on the tiny random model, so
        # measured token counts carry the per-level brevity structure
        eng = InferenceEngine(cfg, params, n_slots=4, max_len=192,
                              decode_block=DECODE_BLOCK, eos_id=-1)
        policy = SproutPolicy(
            k0_min=prov.k_min, k0_max=prov.k_max, xi=0.1,
            k1=A100_40GB.embodied_gco2 / A100_40GB.lifetime_s,
            explore=0.0) if use_lp else None
        gw = SproutGateway([(prov, CarbonAwareScheduler([eng]))],
                           policy=policy, energy=EnergyModel(A100_40GB),
                           q=q, load_cap=10 * per_hour)
        carbon = served = 0.0
        for h in range(hours):
            reqs = [serve_request_from(r, token_scale=6.0, max_new=48)
                    for r in streams[h]]
            s = gw.run_hour(float(h), reqs)
            if h >= warmup_hours:
                carbon += s["carbon_g"]
                served += s["served"]
        return carbon / max(served, 1), gw

    t0 = time.perf_counter()
    sprout_g, sprout_gw = run_one(True)
    l0_g, _ = run_one(False)
    us_total = (time.perf_counter() - t0) * 1e6
    last_plan = sprout_gw.stats.plans[-1]
    return {"name": "serve.gateway_carbon_per_request",
            "us_per_call": us_total,
            "gateway_g_per_req": round(sprout_g, 6),
            "l0_g_per_req": round(l0_g, 6),
            "savings_pct": round(100 * (1 - sprout_g / l0_g), 2),
            "expected_quality": round(last_plan.expected_quality, 4),
            "q_lb": round(last_plan.q_lb, 4),
            "region": region, "hours": hours,
            "warmup_hours": warmup_hours}


def _migration_row(cfg, params, *, hours=3, per_hour=10, max_new=24,
                   steps_hour0=2):
    """Cross-region migration vs the admission-only gateway on a two-region
    intensity-crossover trace: hour 0 is green in CA / dirty in TX, hours
    1+ reverse. The hour-0 batch is served for only ``steps_hour0`` fleet
    steps, so a queued backlog rides across the crossover; with the
    MigrationPlanner on, the re-plan tick moves that backlog to the newly
    green pool, while the admission-only gateway leaves it pinned where it
    was admitted. Same request stream both ways, greedy sampling — so
    migrated requests' outputs must be token-identical to the unmigrated
    run, which this row asserts (correctness, not a perf threshold)."""
    trace_a = [80.0] + [420.0] * (hours - 1)
    trace_b = [420.0] + [80.0] * (hours - 1)
    horizon = 2.0

    def run_one(migrate):
        pa = CarbonIntensityProvider("CA", "jun")
        pa.trace = np.asarray(trace_a)
        pb = CarbonIntensityProvider("TX", "jun")
        pb.trace = np.asarray(trace_b)

        def mk(seed):
            return InferenceEngine(cfg, params, n_slots=2, max_len=128,
                                   decode_block=DECODE_BLOCK, eos_id=-1,
                                   seed=seed)
        gw = SproutGateway(
            [(pa, CarbonAwareScheduler([mk(0)])),
             (pb, CarbonAwareScheduler([mk(1)]))],
            policy=None, energy=EnergyModel(A100_40GB),
            migration=MigrationPlanner() if migrate else None,
            forecast_horizon=horizon, load_cap=10 * per_hour)
        fins = {}
        gw.on_finish = lambda key, fin: fins.__setitem__(fin.rid,
                                                         fin.token_ids)
        for h in range(hours):
            reqs = ([ServeRequest(0, f"xover {i}", max_new_tokens=max_new)
                     for i in range(per_hour)] if h == 0 else [])
            gw.run_hour(float(h), reqs,
                        steps=steps_hour0 if h == 0 else None)
        gw.drain()
        return gw, fins

    t0 = time.perf_counter()
    gw_mig, fins_mig = run_one(True)
    gw_base, fins_base = run_one(False)
    us_total = (time.perf_counter() - t0) * 1e6
    migrated_rids = sorted(m.rid for m in gw_mig.stats.migrations)
    assert migrated_rids, "crossover trace produced no migrations"
    assert all(fins_mig[r] == fins_base[r] for r in migrated_rids), \
        "migrated outputs diverged from the unmigrated run"
    mig_g = gw_mig.stats.carbon_per_request
    base_g = gw_base.stats.carbon_per_request
    assert mig_g < base_g, \
        "migration must beat the admission-only gateway on a crossover"
    return {"name": "serve.migration_carbon_per_request",
            "us_per_call": us_total,
            "migration_g_per_req": round(mig_g, 6),
            "admission_only_g_per_req": round(base_g, 6),
            "savings_pct": round(100 * (1 - mig_g / base_g), 2),
            "migrated": len(migrated_rids),
            "token_identical": True,
            "hours": hours, "per_hour": per_hour,
            "forecast_horizon_h": horizon,
            "trace": "CA 80->420 / TX 420->80, crossover at hour 1"}


def _warm_engines(gw, tok, *, max_new):
    """Compile every engine's prefill/decode variants BEFORE the measured
    window: the crossover hour flips routing onto the other pool, and a
    cold pool's XLA compiles (seconds) would read as deadline misses that
    have nothing to do with scheduling. The fused loop compiles one
    program per block length (powers of two up to ``decode_block``), and
    the block length is the soonest deterministic finish — so warm with
    one single-slot request per budget ``k+1`` (its first post-prefill
    remaining budget is exactly k), plus one two-request batch for the
    batched-prefill shape. Warmed work never touches the gateway ledgers
    (engine.finished is cleared before the scheduler can harvest it)."""
    for pool in gw.pools:
        for eng in pool.scheduler.engines:
            if eng is None:
                continue
            # prefill/insert programs: one per (batch, bucket). Directive
            # rendering inflates prompts (L0 ≈ bucket 32, L1 ≈ 64, L2 ≈
            # 128 for the bench's prompt template), and the engine groups
            # prefill per bucket, so each bucket appears both as a full
            # pair (npad 2) and as a lone refill (npad 1)
            for n_tok in (16, 17, 33, 65):
                ids = tok.encode("w" * n_tok)[:n_tok]
                for batch in (2, 1):
                    for _ in range(batch):
                        eng.submit(list(ids), max_new_tokens=2)
                    eng.run_to_completion()
            # full-budget decode on both slots (the steady-state program)
            eng.submit(tok.encode("[warm] request a"), max_new_tokens=max_new)
            eng.submit(tok.encode("[warm] request b"), max_new_tokens=max_new)
            eng.run_to_completion()
            # every (bucket x block-length) variant: bucketed entry points
            # compile per occupancy bucket AND per k, so a lone k-sweep no
            # longer covers a half-full engine — drive each power-of-two
            # occupancy through each k (equal budgets keep the pair in
            # lockstep, so each run pins exactly one decode_bs{bs}_k{k})
            k = 1
            while k <= eng.decode_block:
                bs = 1
                while bs <= eng.n_slots:
                    for _ in range(bs):
                        eng.submit(tok.encode("[warm] request k"),
                                   max_new_tokens=k + 1)
                    eng.run_to_completion()
                    bs *= 2
                k *= 2
            # chunked-admission engines additionally compile mixed
            # (decode + prefill-chunk) programs: drive one chunk-task
            # admission against a live lane so the mixed variant is warm
            if getattr(eng, "chunked_admission", False):
                eng.submit(tok.encode("[warm] background"),
                           max_new_tokens=max_new)
                eng.step()
                eng.submit(tok.encode("[warm] " + "arrival " * 8),
                           max_new_tokens=3)
                eng.run_to_completion()
            eng.finished = []


def _calibrate_latency_s(cfg, params, tok, *, max_new, n_slots=2,
                         max_len=192):
    """Measured steady-state seconds to serve one full-budget request on a
    warm engine — the yardstick the SLO bench derives deadlines from, so
    the scenario is about QUEUEING (deadline = a fixed multiple of warm
    service time) rather than about how fast this particular CPU is."""
    eng = InferenceEngine(cfg, params, n_slots=n_slots, max_len=max_len,
                          decode_block=DECODE_BLOCK, eos_id=-1)
    lat = 0.0
    for _ in range(2):               # first pass compiles, second measures
        eng.finished = []
        for i in range(2 * n_slots):
            eng.submit(tok.encode(f"[calibrate] request {i}"),
                       max_new_tokens=max_new)
        fins = eng.run_to_completion()
        lat = float(np.mean([f.latency_s for f in fins]))
    return lat


def _slo_row(cfg, params, *, hours=6, warmup_hours=2, per_hour=32,
             max_new=48, assert_thresholds=True):
    """The quality/latency/carbon triangle, measured: per-tenant SLOs
    (premium/standard/batch with quality floors + deadlines, one LP per
    (pool, tenant), priority dispatch, predicted-completion routing)
    against an SLO-blind L0-only gateway over the SAME request stream on
    a two-region crossover trace.

    Deadlines are calibrated multiples of the measured warm service time
    (premium = 8x, standard = 20x), so attainment reflects queueing
    decisions, not absolute CPU speed. Attainment and carbon are compared
    over the post-warmup window (the tenant LPs spend ``warmup_hours``
    profiling at a uniform mix, which also warms XLA); attainment for
    BOTH gateways is computed offline from per-request telemetry latency
    against the same deadlines, so the blind gateway's number is not an
    artifact of it skipping the deadline stamp."""
    tok = ByteTokenizer()
    svc = _calibrate_latency_s(cfg, params, tok, max_new=max_new)
    deadlines = {"premium": 8.0 * svc, "standard": 20.0 * svc,
                 "batch": math.inf}
    half = max(hours // 2, 1)
    trace_a = [80.0] * half + [420.0] * (hours - half)
    trace_b = [420.0] * half + [80.0] * (hours - half)
    w = Workload(seed=4)
    rep = QualityEvaluator(sample_size=300).evaluate(
        [w.sample_request(i * 0.1) for i in range(600)])
    cycle = ("premium", "standard", "standard", "batch")
    streams = [[(w.sample_request(h + i * 0.01), cycle[i % len(cycle)])
                for i in range(per_hour)] for h in range(hours)]
    # every class solves over the evaluator's per-task preference vectors
    # (batch included — its looseness is its xi and missing floor/deadline,
    # not a different idea of what quality means)
    tenants = (
        TenantSpec("premium", xi=0.03, q_floor_frac=0.97, priority=0,
                   ttft_s=deadlines["premium"], tpot_s=0.0,
                   q_by_task=rep.q_by_task),
        TenantSpec("standard", xi=0.12, q_floor_frac=0.80, priority=1,
                   ttft_s=deadlines["standard"], tpot_s=0.0,
                   q_by_task=rep.q_by_task),
        TenantSpec("batch", xi=0.35, priority=2, q_by_task=rep.q_by_task),
    )

    def run_one(slo):
        pa = CarbonIntensityProvider("CA", "jun")
        pa.trace = np.asarray(trace_a)
        pb = CarbonIntensityProvider("TX", "jun")
        pb.trace = np.asarray(trace_b)

        def mk(seed):
            return InferenceEngine(cfg, params, n_slots=2, max_len=192,
                                   decode_block=DECODE_BLOCK, eos_id=-1,
                                   seed=seed)
        gw = SproutGateway(
            [(pa, CarbonAwareScheduler([mk(0)])),
             (pb, CarbonAwareScheduler([mk(1)]))],
            tenants=tenants if slo else None, policy=None,
            energy=EnergyModel(A100_40GB), q=rep.q,
            load_cap=10 * per_hour)
        _warm_engines(gw, tok, max_new=max_new)
        carbon = served = 0.0
        tel0 = 0
        for h in range(hours):
            reqs = [serve_request_from(r, token_scale=6.0, max_new=max_new,
                                       tenant=name)
                    for r, name in streams[h]]
            s = gw.run_hour(float(h), reqs)
            if h < warmup_hours:
                tel0 = len(gw.stats.telemetry)
            else:
                carbon += s["carbon_g"]
                served += s["served"]
        tel = gw.stats.telemetry[tel0:]
        att = {}
        for name, dl in deadlines.items():
            lats = [t.latency_s for t in tel if t.tenant == name]
            att[name] = (float(np.mean([la <= dl for la in lats]))
                         if lats else 1.0)
        return carbon / max(served, 1), att, gw

    t0 = time.perf_counter()
    slo_g, slo_att, slo_gw = run_one(True)
    blind_g, blind_att, _ = run_one(False)
    us_total = (time.perf_counter() - t0) * 1e6
    savings = 100 * (1 - slo_g / blind_g)
    prem_plans = [p for p in slo_gw.stats.plans if p.tenant == "premium"
                  and p.solver != "warmup"]
    if assert_thresholds:
        assert slo_att["premium"] >= 0.95, \
            f"premium attainment {slo_att['premium']:.2%} < 95%"
        assert savings >= 25.0, \
            f"carbon savings {savings:.1f}% < 25% vs the SLO-blind L0 run"
        assert prem_plans and all(
            p.expected_quality >= p.q_lb - 1e-9 for p in prem_plans), \
            "premium quality floor violated by an installed plan"
    return {"name": "serve.slo_attainment",
            "us_per_call": us_total,
            "premium_attainment": round(slo_att["premium"], 4),
            "standard_attainment": round(slo_att["standard"], 4),
            "premium_attainment_slo_blind": round(blind_att["premium"], 4),
            "slo_g_per_req": round(slo_g, 6),
            "blind_l0_g_per_req": round(blind_g, 6),
            "carbon_savings_pct": round(savings, 2),
            "premium_deadline_s": round(deadlines["premium"], 4),
            "calibrated_service_s": round(svc, 4),
            "hours": hours, "warmup_hours": warmup_hours,
            "per_hour": per_hour,
            "trace": "CA 80->420 / TX 420->80 crossover at mid-run"}


def _drain_row(cfg, params, *, per_hour=10, max_new=16):
    """The maintenance protocol, measured: a loaded green pool is drained
    ahead of maintenance — its backlog migrates to the other pool over
    the verbatim-token requeue path, admission stops routing to it, and
    NOTHING is stranded or rejected (asserted, also in smoke: the drain
    guarantee is deterministic, unlike wall-clock attainment)."""
    t0 = time.perf_counter()
    pa = CarbonIntensityProvider("CA", "jun")
    pa.trace = np.asarray([80.0, 80.0])
    pb = CarbonIntensityProvider("TX", "jun")
    pb.trace = np.asarray([420.0, 420.0])

    def mk(seed):
        return InferenceEngine(cfg, params, n_slots=2, max_len=128,
                               decode_block=DECODE_BLOCK, eos_id=-1,
                               seed=seed)
    gw = SproutGateway(
        [(pa, CarbonAwareScheduler([mk(0)])),
         (pb, CarbonAwareScheduler([mk(1)]))],
        policy=None, energy=EnergyModel(A100_40GB), load_cap=10 * per_hour)
    reqs = [ServeRequest(0, f"maint {i}", max_new_tokens=max_new)
            for i in range(per_hour)]
    s0 = gw.run_hour(0.0, reqs, steps=1)     # partial service: backlog rides
    assert s0["routes"]["CA"] == per_hour, "green pool should take the burst"
    moved = gw.drain_pool("CA", deadline=1.0)
    drained_empty = gw.pools[0].load() == 0
    _, key = gw.submit(ServeRequest(0, "post-drain", max_new_tokens=max_new))
    gw.run_hour(1.0, [])
    gw.drain()
    st = gw.stats
    assert drained_empty, "drain pass left work in the draining pool"
    assert key == "TX", "admission routed into a draining pool"
    assert st.rejected == 0, f"{st.rejected} requests stranded as rejected"
    assert st.requests == per_hour + 1, "a drained request never finished"
    us_total = (time.perf_counter() - t0) * 1e6
    return {"name": "serve.pool_drain",
            "us_per_call": us_total,
            "moved": moved,
            "drained_pool_emptied": drained_empty,
            "stranded": int(st.rejected),
            "served": int(st.requests),
            "drain_migrations": sum(m.trigger == "drain"
                                    for m in st.migrations),
            "requests": per_hour}


def _fault_recovery_row(cfg, params, *, n_req=6, max_new=12):
    """Fault recovery, measured (DESIGN.md §12): a two-replica fleet takes
    a scripted lane poison and a scripted replica crash mid-run, and must
    still serve every request with greedy tokens bit-identical to an
    undisturbed twin fleet — the recovery guarantees are deterministic, so
    (like the drain row) they are asserted even at smoke size."""
    t0 = time.perf_counter()

    def fleet(plan):
        # decode_block < max_new so lanes stay live across several fleet
        # steps: the injector only sees opportunities on in-flight work
        sched = CarbonAwareScheduler(
            [InferenceEngine(cfg, params, n_slots=2, max_len=128,
                             decode_block=4, eos_id=-1, seed=s)
             for s in (0, 1)],
            fault_injector=FaultInjector(plan), straggler_factor=1e9,
            retry_budget=3, backoff_base_steps=1, probation_steps=2,
            clean_window=4)
        sched.name = "P"
        for i in range(n_req):
            sched.submit(ServeRequest(0, f"fault recovery {i}",
                                      max_new_tokens=max_new))
        return sched

    # poison the first occupied lane, then crash replica 1 mid-decode (its
    # third crash consult = the third fleet step, when its lanes are live)
    plan = FaultPlan([FaultSpec("decode.nonfinite", "*", occurrences=(0,)),
                      FaultSpec("replica.crash", "P/1", occurrences=(2,))])
    chaos, control = fleet(plan), fleet(FaultPlan())
    fins = {f.rid: f for f in chaos.run(max_steps=2000)}
    ref = {f.rid: f for f in control.run(max_steps=2000)}
    stranded = len(chaos.pending) + len(chaos.rejected) + \
        sum(e.load() for e in chaos.engines if e is not None)
    identical = (set(fins) == set(ref) and all(
        fins[r].token_ids == ref[r].token_ids for r in fins))
    retries_total = sum(f.retries for f in fins.values())
    assert stranded == 0, f"{stranded} requests stranded after faults"
    assert identical, "retried greedy outputs diverged from fault-free run"
    assert chaos.fault_injector.fired() == 2, "scripted faults did not land"
    assert 0 < retries_total <= 3 * n_req, "retry counts out of budget"
    us_total = (time.perf_counter() - t0) * 1e6
    return {"name": "serve.fault_recovery",
            "us_per_call": us_total,
            "served": len(fins),
            "stranded": stranded,
            "token_identical": identical,
            "retries_total": retries_total,
            "faults_injected": chaos.fault_injector.fired(),
            "requests": n_req}


def _prefix_cache_row(cfg, params, tok, *, n_dup=6, n_unique=4, max_new=24,
                      assert_thresholds=True):
    """Radix prefix cache (DESIGN.md §13), measured: a duplicate-heavy
    trace (one shared system prompt warmed at deploy, distinct user
    suffixes) vs an all-unique trace on identical prefix-cache engines,
    plus a cache-off twin of the duplicate run. The deterministic
    guarantees — bit-identical tokens vs cache-off, zero skip on unique
    traffic, the full-cover copy-on-write firing — are asserted even at
    smoke size; the >= 30% prefill-skip threshold on the duplicate trace
    only in the full run. Key meanings: benchmarks/README.md."""
    t0 = time.perf_counter()
    shared = "system: you are a terse assistant; cite sources. "  # 3 pages
    dup = [(shared + f"question {i}?", max_new) for i in range(n_dup)]
    # a page-aligned prompt that is ENTIRELY cached full pages: its 1-token
    # recompute (first-token logits) must trigger the copy-on-write path
    dup.append((shared[:2 * PAGE_SIZE], max_new))
    uniq = [(f"user {i}: completely distinct prompt {i * i}", max_new)
            for i in range(n_unique)]

    def run(reqs, prefix):
        eng = InferenceEngine(cfg, params, n_slots=2, max_len=96,
                              decode_block=8, eos_id=-1, paged=True,
                              page_size=PAGE_SIZE, prefix_cache=prefix)
        # deploy-time warmup: prefill the shared system prompt once so the
        # trace measures steady-state hit behavior, then snapshot counters
        eng.submit(tok.encode(shared), max_new_tokens=1)
        eng.run_to_completion()
        c0 = eng.prefill_tokens_computed
        for p, mnt in reqs:
            eng.submit(tok.encode(p), max_new_tokens=mnt)
        eng.run_to_completion()
        toks = {f.rid: f.token_ids for f in eng.finished}
        return eng, toks, eng.prefill_tokens_computed - c0

    ed, toks_on, comp_d = run(dup, True)
    _, toks_off, _ = run(dup, False)
    eu, _, comp_u = run(uniq, True)
    cached_d = ed.prefill_tokens_cached
    cached_u = eu.prefill_tokens_cached
    pct_dup = 100.0 * cached_d / max(cached_d + comp_d, 1)
    pct_uniq = 100.0 * cached_u / max(cached_u + comp_u, 1)
    identical = toks_on == toks_off
    assert identical, "prefix-cache-on tokens diverged from cache-off"
    assert pct_uniq == 0.0, "unique traffic must never hit the cache"
    assert ed.pages.cow_copies >= 1, "full-cover duplicate did not COW"
    assert ed.pages.pages_adopted > 0 and ed.pages.shared_peak > 0
    if assert_thresholds:
        assert pct_dup >= 30.0, \
            f"duplicate-heavy trace skipped only {pct_dup:.1f}% of prefill"
    us_total = (time.perf_counter() - t0) * 1e6
    return {"name": "serve.prefix_cache",
            "us_per_call": us_total,
            "prefill_tokens_total_dup": int(cached_d + comp_d),
            "prefill_tokens_skipped_dup": int(cached_d),
            "prefill_skipped_pct_dup": round(pct_dup, 2),
            "prefill_skipped_pct_unique": round(pct_uniq, 2),
            "pages_adopted": int(ed.pages.pages_adopted),
            "pages_shared_peak": int(ed.pages.shared_peak),
            "cow_copies": int(ed.pages.cow_copies),
            "cache_evictions": int(ed.pages.cache_evictions),
            "token_identical": identical,
            "requests": len(dup)}


# required keys per bench case the smoke job guards (schema only — values
# just have to exist and be finite, no perf thresholds)
_SMOKE_REQUIRED = {
    "serve.paged_decode": ("tok_per_s", "tok_per_sync",
                           "tok_per_s_vs_dense"),
    "serve.sharded_decode": ("tok_per_s", "tok_per_s_tp1",
                             "tok_per_s_vs_tp1", "token_identical",
                             "modeled_j_per_token_tp1",
                             "modeled_j_per_token_tp2",
                             "modeled_collective_bytes_per_token"),
    "serve.ttft_under_load": ("ttft_p95_ms_slot_epoch",
                              "ttft_p95_ms_chunked", "ttft_p95_speedup",
                              "entry_points_stable"),
    "serve.gateway_carbon_per_request": ("gateway_g_per_req",
                                         "l0_g_per_req", "savings_pct"),
    "serve.migration_carbon_per_request": ("migration_g_per_req",
                                           "admission_only_g_per_req",
                                           "savings_pct", "migrated",
                                           "token_identical"),
    "serve.slo_attainment": ("premium_attainment",
                             "premium_attainment_slo_blind",
                             "slo_g_per_req", "blind_l0_g_per_req",
                             "carbon_savings_pct"),
    "serve.pool_drain": ("moved", "drained_pool_emptied", "stranded",
                         "served"),
    "serve.fault_recovery": ("served", "stranded", "token_identical",
                             "retries_total", "faults_injected"),
    "serve.prefix_cache": ("prefill_skipped_pct_dup",
                           "prefill_skipped_pct_unique",
                           "pages_shared_peak", "cow_copies",
                           "token_identical"),
}


def _assert_bench_schema(path) -> None:
    """BENCH_serving.json schema guard: the named cases exist with their
    required keys, and every number in the payload is finite."""
    data = json.loads(Path(path).read_text())
    assert "meta" in data and "rows" in data, "missing meta/rows"
    for name, keys in _SMOKE_REQUIRED.items():
        assert name in data["rows"], f"missing bench case {name}"
        row = data["rows"][name]
        assert "us_per_call" in row, name
        for k in keys:
            assert k in row, f"{name} missing key {k}"

    def walk(x, where):
        if isinstance(x, dict):
            for k, v in x.items():
                walk(v, f"{where}.{k}")
        elif isinstance(x, (list, tuple)):
            for i, v in enumerate(x):
                walk(v, f"{where}[{i}]")
        elif isinstance(x, bool):
            pass
        elif isinstance(x, (int, float)):
            assert math.isfinite(x), f"non-finite value at {where}: {x}"

    walk(data, "$")


def run_smoke():
    """CI bench-smoke: the paged / gateway / migration cases at tiny sizes,
    written to BENCH_serving_smoke.json (the real perf-trajectory file is
    never clobbered by a smoke run) and schema-checked. Catches bench rot —
    renamed keys, broken cases, NaNs — without asserting any performance."""
    rows = []
    cfg = reduced("granite_3_2b").replace(vocab_size=512)
    params = MD.init_model(cfg, jax.random.PRNGKey(0))
    tok = ByteTokenizer()
    # best-of-3 even at smoke size: the dense/paged rows feed the BANDED
    # tok_per_s_vs_dense ratio, and a single tiny (36-token) repeat is
    # noisy enough on a shared runner to blow a +/-30% band on its own
    rows.append(_decode_row(cfg, params, tok, "serve.engine_decode",
                            decode_block=8, n_req=3, max_new=12, repeats=3))
    rows.append(_decode_row(cfg, params, tok, "serve.paged_decode",
                            decode_block=8, paged=True, page_size=PAGE_SIZE,
                            n_req=3, max_new=12, repeats=3))
    rows[-1]["tok_per_s_vs_dense"] = round(
        rows[-1]["tok_per_s"] / rows[0]["tok_per_s"], 3)
    rows.append(_sharded_decode_row(cfg, params, tok, rows[0],
                                    decode_block=8, n_req=3, max_new=12,
                                    repeats=3))
    # tiny TTFT-under-load case: exercises chunked admission + the
    # warm-entry-point assertion; the 2x speedup threshold is only
    # asserted in the full run (no perf thresholds on CI runners)
    rows.append(_ttft_under_load_row(cfg, params, tok, n_arrivals=3,
                                     bg_lanes=2, bg_new=24, max_new=3,
                                     prompt_reps=4, chunk=8,
                                     decode_block=8, max_len=64,
                                     assert_thresholds=False))
    e = [1.74e-5, 8.3e-6, 3.8e-6]
    p = [0.32, 0.15, 0.06]
    q = [0.45, 0.39, 0.16]
    _, us_lp = timed(lambda: solve_directive_lp(
        e, p, q, k0=200.0, k1=1e-3, k0_min=55, k0_max=331), repeat=5)
    rows.append({"name": "serve.lp_solve", "us_per_call": us_lp})
    rows.append(_gateway_row(cfg, params, hours=3, warmup_hours=1,
                             per_hour=4))
    rows.append(_migration_row(cfg, params, hours=2, per_hour=6,
                               max_new=12, steps_hour0=1))
    # SLO case at smoke size: schema + finiteness only (wall-clock
    # attainment thresholds are asserted in the full run, not on shared
    # CI runners); the drain guarantees ARE asserted — deterministic
    rows.append(_slo_row(cfg, params, hours=3, warmup_hours=1, per_hour=8,
                         max_new=12, assert_thresholds=False))
    rows.append(_drain_row(cfg, params, per_hour=6, max_new=8))
    rows.append(_fault_recovery_row(cfg, params, n_req=4))
    # prefix-cache case at smoke size: the deterministic guarantees are
    # asserted; the >=30% duplicate-trace skip threshold only in the full
    # run (same convention as the SLO/TTFT thresholds)
    rows.append(_prefix_cache_row(cfg, params, tok, n_dup=3, n_unique=2,
                                  max_new=12, assert_thresholds=False))
    path = emit_json("BENCH_serving_smoke.json", rows,
                     meta={"model": "granite_3_2b:reduced(vocab=512)",
                           "methodology": "smoke (tiny sizes, CI rot guard "
                                          "— numbers are NOT comparable to "
                                          "BENCH_serving.json)"})
    _assert_bench_schema(path)
    print(f"# wrote {path}", flush=True)
    print("BENCH_SMOKE_OK", flush=True)
    return rows


def run():
    rows = []
    cfg = reduced("granite_3_2b").replace(vocab_size=512)
    params = MD.init_model(cfg, jax.random.PRNGKey(0))
    tok = ByteTokenizer()

    rows.append(_decode_row(cfg, params, tok, "serve.engine_decode",
                            decode_block=DECODE_BLOCK))
    rows.append(_decode_row(cfg, params, tok, "serve.engine_decode_k1",
                            decode_block=1))
    rows.append(_decode_row(
        cfg, params, tok, "serve.engine_decode_sampled",
        decode_block=DECODE_BLOCK,
        sampling=SamplingParams(temperature=0.9, top_k=50, top_p=0.95)))
    # the paged hot path at equal occupancy (same slots / lengths / load);
    # KV memory now scales with live tokens (peak_kv_bytes_in_use)
    rows.append(_decode_row(cfg, params, tok, "serve.paged_decode",
                            decode_block=DECODE_BLOCK, paged=True,
                            page_size=PAGE_SIZE))
    rows[-1]["tok_per_s_vs_dense"] = round(
        rows[-1]["tok_per_s"] / rows[0]["tok_per_s"], 3)
    rows.append(_decode_row(cfg, params, tok, "serve.paged_decode_int8",
                            decode_block=DECODE_BLOCK, paged=True,
                            page_size=PAGE_SIZE, kv_int8=True))
    # tensor-parallel decode: tp=2 vs the tp=1 engine_decode row above
    rows.append(_sharded_decode_row(cfg, params, tok, rows[0],
                                    decode_block=DECODE_BLOCK))
    rows.append(_capacity_row(cfg, params, tok))

    # the continuous-batching payoff: arrival TTFT against saturated
    # decode lanes, chunked admission vs the slot-epoch baseline (the
    # >= 2x p95 speedup is asserted — this is the tentpole's claim)
    rows.append(_ttft_under_load_row(cfg, params, tok))

    # LP solve latency (control plane — must be microseconds-scale)
    e = [1.74e-5, 8.3e-6, 3.8e-6]
    p = [0.32, 0.15, 0.06]
    q = [0.45, 0.39, 0.16]
    _, us_lp = timed(lambda: solve_directive_lp(
        e, p, q, k0=200.0, k1=1e-3, k0_min=55, k0_max=331), repeat=50)
    rows.append({"name": "serve.lp_solve", "us_per_call": us_lp})

    w = Workload(seed=1)
    pool = [w.sample_request(i * 0.1) for i in range(1000)]
    ev = QualityEvaluator(sample_size=500)
    _, us_ev = timed(lambda: ev.evaluate(pool), repeat=3)
    rows.append({"name": "serve.quality_eval_500", "us_per_call": us_ev})

    # the closed loop, end to end: LP -> scheduler -> engine telemetry -> LP
    rows.append(_gateway_row(cfg, params))

    # cross-region migration on an intensity-crossover trace (vs the
    # admission-only gateway over the same stream, outputs token-identical)
    rows.append(_migration_row(cfg, params))

    # the SLO triangle: per-tenant floors + deadlines vs an SLO-blind
    # L0-only gateway (premium attainment and carbon savings asserted),
    # plus the maintenance drain protocol (zero-stranded asserted)
    rows.append(_slo_row(cfg, params))
    rows.append(_drain_row(cfg, params))
    rows.append(_fault_recovery_row(cfg, params))

    # the radix prefix cache on duplicate-heavy vs unique traffic: >= 30%
    # of prefill tokens skipped on the duplicate trace is asserted, and
    # the on-vs-off token streams must be bit-identical
    rows.append(_prefix_cache_row(cfg, params, tok))

    # modeled HBM bytes/token (§4 roofline, 13B target @ ctx=512): the
    # numbers the paged+int8 serving path acts on
    em = EnergyModel(A100_40GB)
    paged_row = next(r for r in rows if r["name"] == "serve.paged_decode")
    path = emit_json("BENCH_serving.json", rows,
                     meta={"model": "granite_3_2b:reduced(vocab=512)",
                           "n_slots": 4, "max_len": 128,
                           "decode_block": DECODE_BLOCK,
                           "page_size": PAGE_SIZE,
                           "paged_peak_page_occupancy": round(
                               paged_row["peak_pages_in_use"]
                               / paged_row["n_pages"], 4),
                           "modeled_hbm_bytes_per_token": round(
                               em.decode_bytes_per_token(LLAMA2_13B, 512)),
                           "modeled_kv_bytes_per_token": round(
                               em.decode_kv_bytes_per_token(LLAMA2_13B, 512)),
                           "modeled_kv_bytes_per_token_int8": round(
                               em.decode_kv_bytes_per_token(
                                   LLAMA2_13B.with_int8_kv(), 512)),
                           "methodology": "steady-state (warmed engine)"})
    _assert_bench_schema(path)
    print(f"# wrote {path}", flush=True)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-model rot guard for CI: runs the paged/"
                         "gateway/migration cases at small sizes, writes "
                         "BENCH_serving_smoke.json and asserts the schema "
                         "(no perf thresholds)")
    args = ap.parse_args()
    emit(run_smoke() if args.smoke else run())
