"""Real serving microbenchmarks on the CPU engine (tiny model): decode
throughput, prefill latency, LP solve time, evaluator cost, and the
closed-loop gateway's carbon-per-request against an L0-only baseline —
the measured (not modeled) numbers in this container.

Decode throughput is measured in the steady state: the engine is warmed
with one identical workload first, so the number reflects the serving hot
path (device-resident fused decode blocks) rather than one-off XLA
compilation. ``serve.engine_decode_k1`` runs the same engine pinned to
single-token blocks for an apples-to-apples view of what multi-token
stepping buys. Results also land in ``BENCH_serving.json`` at the repo
root so future PRs have a perf trajectory to compare against.
"""
from __future__ import annotations

import jax
import numpy as np

import time

from benchmarks.common import emit, emit_json, timed
from repro.configs import reduced
from repro.core import A100_40GB, CarbonIntensityProvider, EnergyModel
from repro.core.lp import solve_directive_lp
from repro.core.policies import SproutPolicy
from repro.core.quality import QualityEvaluator
from repro.core.workload import Workload
from repro.models import model as MD
from repro.serving import (ByteTokenizer, CarbonAwareScheduler,
                           InferenceEngine, SamplingParams, SproutGateway,
                           serve_request_from)

DECODE_BLOCK = 16


def _load(eng, tok, sampling=SamplingParams()):
    for _ in range(8):
        eng.submit(tok.encode("benchmark prompt " * 3), max_new_tokens=32,
                   sampling=sampling)


def _decode_row(cfg, params, tok, name, *, decode_block,
                sampling=SamplingParams()):
    eng = InferenceEngine(cfg, params, n_slots=4, max_len=128,
                          decode_block=decode_block)
    _load(eng, tok, sampling)
    eng.run_to_completion()          # warm: compile the program variants
    # best-of-3 by throughput: stochastic EOS (sampled rows) can surface a
    # block length / prefill shape the warm run never compiled, landing one
    # XLA compile inside a timed run, and also varies the token count per
    # repeat — selecting by tok/s (not wall time) keeps the steady-state
    # number comparable across runs
    best = None
    for _ in range(3):
        eng.finished = []
        syncs0 = eng.decode_syncs
        _load(eng, tok, sampling)
        _, us_total = timed(eng.run_to_completion)
        toks = sum(f.gen_tokens for f in eng.finished)
        rate = toks / (us_total / 1e6)
        if best is None or rate > best[0]:
            best = (rate, us_total, toks, eng.decode_syncs - syncs0)
    rate, us_total, toks, syncs = best
    return {"name": name, "us_per_call": us_total, "tokens": toks,
            "tok_per_s": round(rate, 1),
            "tok_per_sync": round(toks / max(syncs, 1), 1),
            "decode_block": decode_block}


def _gateway_row(cfg, params, *, hours=5, warmup_hours=2, per_hour=14):
    """Closed control loop vs L0-only over the SAME request stream on a
    dirty grid (TX: fossil-baseline ERCOT trace). Both gateways serve real
    engines; carbon-per-request is compared over the post-warmup window
    (the SPROUT gateway spends ``warmup_hours`` profiling at a uniform mix
    before the LP has per-level energies to solve over)."""
    region = "TX"
    w = Workload(seed=2)
    q = QualityEvaluator(sample_size=300).evaluate(
        [w.sample_request(i * 0.1) for i in range(600)]).q
    streams = [[w.sample_request(h + i * 0.01) for i in range(per_hour)]
               for h in range(hours)]

    def run_one(use_lp):
        prov = CarbonIntensityProvider(region, "jun")
        # eos_id=-1: budget-bound decoding on the tiny random model, so
        # measured token counts carry the per-level brevity structure
        eng = InferenceEngine(cfg, params, n_slots=4, max_len=192,
                              decode_block=DECODE_BLOCK, eos_id=-1)
        policy = SproutPolicy(
            k0_min=prov.k_min, k0_max=prov.k_max, xi=0.1,
            k1=A100_40GB.embodied_gco2 / A100_40GB.lifetime_s,
            explore=0.0) if use_lp else None
        gw = SproutGateway([(prov, CarbonAwareScheduler([eng]))],
                           policy=policy, energy=EnergyModel(A100_40GB),
                           q=q, load_cap=10 * per_hour)
        carbon = served = 0.0
        for h in range(hours):
            reqs = [serve_request_from(r, token_scale=6.0, max_new=48)
                    for r in streams[h]]
            s = gw.run_hour(float(h), reqs)
            if h >= warmup_hours:
                carbon += s["carbon_g"]
                served += s["served"]
        return carbon / max(served, 1), gw

    t0 = time.perf_counter()
    sprout_g, sprout_gw = run_one(True)
    l0_g, _ = run_one(False)
    us_total = (time.perf_counter() - t0) * 1e6
    last_plan = sprout_gw.stats.plans[-1]
    return {"name": "serve.gateway_carbon_per_request",
            "us_per_call": us_total,
            "gateway_g_per_req": round(sprout_g, 6),
            "l0_g_per_req": round(l0_g, 6),
            "savings_pct": round(100 * (1 - sprout_g / l0_g), 2),
            "expected_quality": round(last_plan.expected_quality, 4),
            "q_lb": round(last_plan.q_lb, 4),
            "region": region, "hours": hours,
            "warmup_hours": warmup_hours}


def run():
    rows = []
    cfg = reduced("granite_3_2b").replace(vocab_size=512)
    params = MD.init_model(cfg, jax.random.PRNGKey(0))
    tok = ByteTokenizer()

    rows.append(_decode_row(cfg, params, tok, "serve.engine_decode",
                            decode_block=DECODE_BLOCK))
    rows.append(_decode_row(cfg, params, tok, "serve.engine_decode_k1",
                            decode_block=1))
    rows.append(_decode_row(
        cfg, params, tok, "serve.engine_decode_sampled",
        decode_block=DECODE_BLOCK,
        sampling=SamplingParams(temperature=0.9, top_k=50, top_p=0.95)))

    # LP solve latency (control plane — must be microseconds-scale)
    e = [1.74e-5, 8.3e-6, 3.8e-6]
    p = [0.32, 0.15, 0.06]
    q = [0.45, 0.39, 0.16]
    _, us_lp = timed(lambda: solve_directive_lp(
        e, p, q, k0=200.0, k1=1e-3, k0_min=55, k0_max=331), repeat=50)
    rows.append({"name": "serve.lp_solve", "us_per_call": us_lp})

    w = Workload(seed=1)
    pool = [w.sample_request(i * 0.1) for i in range(1000)]
    ev = QualityEvaluator(sample_size=500)
    _, us_ev = timed(lambda: ev.evaluate(pool), repeat=3)
    rows.append({"name": "serve.quality_eval_500", "us_per_call": us_ev})

    # the closed loop, end to end: LP -> scheduler -> engine telemetry -> LP
    rows.append(_gateway_row(cfg, params))

    path = emit_json("BENCH_serving.json", rows,
                     meta={"model": "granite_3_2b:reduced(vocab=512)",
                           "n_slots": 4, "max_len": 128,
                           "decode_block": DECODE_BLOCK,
                           "methodology": "steady-state (warmed engine)"})
    print(f"# wrote {path}", flush=True)
    return rows


if __name__ == "__main__":
    emit(run())
