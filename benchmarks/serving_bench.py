"""Real serving microbenchmarks on the CPU engine (tiny model): decode
throughput, prefill latency, LP solve time, evaluator cost — the measured
(not modeled) numbers in this container."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, timed
from repro.configs import reduced
from repro.core.lp import solve_directive_lp
from repro.core.quality import QualityEvaluator
from repro.core.workload import Workload
from repro.models import model as MD
from repro.serving import ByteTokenizer, InferenceEngine


def run():
    rows = []
    cfg = reduced("granite_3_2b").replace(vocab_size=512)
    params = MD.init_model(cfg, jax.random.PRNGKey(0))
    tok = ByteTokenizer()

    eng = InferenceEngine(cfg, params, n_slots=4, max_len=128)
    for i in range(4):
        eng.submit(tok.encode(f"warmup {i}"), max_new_tokens=4)
    eng.run_to_completion()

    eng = InferenceEngine(cfg, params, n_slots=4, max_len=128)
    for i in range(8):
        eng.submit(tok.encode("benchmark prompt " * 3), max_new_tokens=32)
    _, us_total = timed(eng.run_to_completion)
    toks = sum(f.gen_tokens for f in eng.finished)
    rows.append({"name": "serve.engine_decode", "us_per_call": us_total,
                 "tokens": toks,
                 "tok_per_s": f"{toks / (us_total / 1e6):.1f}"})

    # LP solve latency (control plane — must be microseconds-scale)
    e = [1.74e-5, 8.3e-6, 3.8e-6]
    p = [0.32, 0.15, 0.06]
    q = [0.45, 0.39, 0.16]
    _, us_lp = timed(lambda: solve_directive_lp(
        e, p, q, k0=200.0, k1=1e-3, k0_min=55, k0_max=331), repeat=50)
    rows.append({"name": "serve.lp_solve", "us_per_call": us_lp})

    w = Workload(seed=1)
    pool = [w.sample_request(i * 0.1) for i in range(1000)]
    ev = QualityEvaluator(sample_size=500)
    _, us_ev = timed(lambda: ev.evaluate(pool), repeat=3)
    rows.append({"name": "serve.quality_eval_500", "us_per_call": us_ev})
    return rows


if __name__ == "__main__":
    emit(run())
