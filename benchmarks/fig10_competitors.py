"""Fig. 10: SPROUT vs BASE / CO2_OPT / MODEL_OPT / SPROUT_STA / ORACLE
(+ beyond-paper SPROUT_TASK) — savings & preference per scheme."""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.core import SproutSimulation, summarize

SCHEMES = ["BASE", "CO2_OPT", "MODEL_OPT", "SPROUT_STA", "SPROUT",
           "SPROUT_TASK", "ORACLE"]


def run(hours=24 * 7, cap=80, regions=("CA", "TX")):
    rows = []
    for region in regions:
        sim = SproutSimulation(region=region, season="jun", hours=hours,
                               seed=0, requests_per_hour_cap=cap,
                               schemes=SCHEMES)
        _, us = timed(sim.run)
        s = summarize(sim.stats)
        for scheme in SCHEMES:
            rows.append({
                "name": f"fig10.{region}.{scheme}",
                "carbon_savings_pct": f"{s[scheme]['carbon_savings_pct']:.1f}",
                "norm_pref_pct": f"{s[scheme]['normalized_preference_pct']:.1f}",
            })
    return rows


if __name__ == "__main__":
    emit(run())
