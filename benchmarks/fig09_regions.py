"""Fig. 9: carbon savings + normalized preference across the five grid
regions (headline: >40% savings at >=90% preference everywhere)."""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.core import SproutSimulation, summarize
from repro.core.carbon import REGIONS


def run(hours=24 * 7, cap=80):
    rows = []
    for region in REGIONS:
        sim = SproutSimulation(region=region, season="jun", hours=hours,
                               seed=0, requests_per_hour_cap=cap,
                               schemes=["BASE", "SPROUT"])
        _, us = timed(sim.run)
        s = summarize(sim.stats)
        rows.append({
            "name": f"fig09.{region}",
            "us_per_call": us,
            "carbon_savings_pct": f"{s['SPROUT']['carbon_savings_pct']:.1f}",
            "norm_pref_pct": f"{s['SPROUT']['normalized_preference_pct']:.1f}",
        })
    return rows


if __name__ == "__main__":
    emit(run())
