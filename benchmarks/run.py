"""Benchmark aggregator: one module per paper table/figure + the roofline
table. Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run fig09 fig16
"""
from __future__ import annotations

import sys
import time
import traceback

from benchmarks.common import emit

MODULES = [
    "fig02_tokens_vs_carbon",
    "fig04_task_sensitivity",
    "fig09_regions",
    "fig10_competitors",
    "fig11_cdf",
    "fig12_adaptivity",
    "fig13_evaluator",
    "fig14_overhead",
    "fig15_seasons",
    "fig16_pareto",
    "serving_bench",
    "roofline_table",
]


def main() -> None:
    want = [a for a in sys.argv[1:] if not a.startswith("-")]
    mods = [m for m in MODULES if not want or any(w in m for w in want)]
    print("name,us_per_call,derived")
    t0 = time.time()
    for name in mods:
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            emit(mod.run())
        except Exception as e:
            traceback.print_exc(file=sys.stderr)
            print(f"{name}.ERROR,0,{type(e).__name__}: {str(e)[:100]}")
    print(f"# total_wall_s={time.time() - t0:.1f}", file=sys.stderr)


if __name__ == "__main__":
    main()
