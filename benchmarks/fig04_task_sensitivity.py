"""Fig. 3/4: directive sensitivity per task — carbon and correctness vary
with (task, level); concise directives help lookup tasks, hurt reasoning."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core.energy import A100_40GB, LLAMA2_13B, EnergyModel
from repro.core.workload import N_LEVELS, TASKS, Workload


def run():
    em = EnergyModel(A100_40GB)
    w = Workload(seed=11)
    per_task = {t: [] for t in TASKS}
    for i in range(6000):
        r = w.sample_request(i * 0.01)
        per_task[r.task].append(r)
    rows = []
    for task, reqs in per_task.items():
        pref = np.zeros(N_LEVELS)
        carbon = np.zeros(N_LEVELS)
        rng = np.random.default_rng(0)
        for r in reqs:
            pref[r.judge_pick(rng)] += 1
            for l in range(N_LEVELS):
                carbon[l] += em.request_energy_kwh(
                    LLAMA2_13B, r.prompt_tokens, float(r.gen_tokens[l])) \
                    * 100 * 1.2
        pref /= max(pref.sum(), 1)
        carbon /= max(len(reqs), 1)
        rows.append({
            "name": f"fig04.{task}",
            "n": len(reqs),
            "pref_L0/L1/L2": "/".join(f"{p:.2f}" for p in pref),
            "gCO2_L0/L1/L2": "/".join(f"{c:.4f}" for c in carbon),
            "carbon_saving_L1_pct": f"{100 * (1 - carbon[1] / carbon[0]):.1f}",
        })
    return rows


if __name__ == "__main__":
    emit(run())
