"""Fig. 2: request carbon vs (a) model size and (b) generated tokens.

Validates the paper's two anchors on our energy model: carbon/request is
linear in generated tokens (R^2), and the 13B-vs-7B cost ratio.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed
from repro.core.energy import A100_40GB, LLAMA2_7B, LLAMA2_13B, EnergyModel


def run():
    em = EnergyModel(A100_40GB)
    ci = 100.0  # gCO2/kWh, constant (paper §II-B) with PUE 1.2
    toks = np.arange(25, 801, 25)
    rows = []
    for model, key in ((LLAMA2_13B, "13b"), (LLAMA2_7B, "7b")):
        carbon = np.array([em.request_energy_kwh(model, 200, int(t)) * ci * 1.2
                           for t in toks])
        A = np.vstack([toks, np.ones_like(toks)]).T
        coef, res, *_ = np.linalg.lstsq(A.astype(float), carbon, rcond=None)
        ss_tot = float(((carbon - carbon.mean()) ** 2).sum())
        r2 = 1.0 - float(res[0]) / ss_tot if len(res) else 1.0
        rows.append({"name": f"fig02.linear_{key}",
                     "slope_g_per_tok": f"{coef[0]:.3e}",
                     "r2": f"{r2:.4f}"})
    _, us = timed(lambda: em.request_energy_kwh(LLAMA2_13B, 200, 400),
                  repeat=100)
    ratio = (em.request_energy_kwh(LLAMA2_13B, 200, 400)
             / em.request_energy_kwh(LLAMA2_7B, 200, 400))
    rows.append({"name": "fig02.size_ratio_13b_over_7b",
                 "us_per_call": us, "ratio": f"{ratio:.2f}"})
    return rows


if __name__ == "__main__":
    emit(run())
