"""Train a small LM for a few hundred steps on CPU: WSD schedule,
microbatched AdamW, checkpoint/restore mid-run (fault-tolerance path).

    PYTHONPATH=src python examples/train_small.py [--steps 200]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import reduced
from repro.training import (AdamWConfig, SyntheticLM, checkpoint,
                            make_train_step, train_state_init, wsd_schedule)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt", default="/tmp/sprout_train_small")
    args = ap.parse_args()

    cfg = reduced("minicpm_2b").replace(n_layers=4, d_model=128, d_ff=256,
                                        n_heads=4, n_kv_heads=4,
                                        vocab_size=512)
    st = train_state_init(cfg, jax.random.PRNGKey(0))
    data = SyntheticLM(cfg.vocab_size, seed=1)
    step = jax.jit(make_train_step(
        cfg, AdamWConfig(lr=3e-3), microbatches=2,
        schedule=wsd_schedule(args.steps, warmup=10)))

    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i, 16, 64).items()}
        st.params, st.opt, m = step(st.params, st.opt, batch)
        if i % 20 == 0:
            print(f"step {i:4d}  loss={float(m['loss']):.4f}  "
                  f"gnorm={float(m['grad_norm']):.3f}")
        if i == args.steps // 2:
            checkpoint.save({"params": st.params, "opt": st.opt}, args.ckpt,
                            step=i, n_shards=4)
            print(f"  checkpointed at step {i}; restoring (restart drill)")
            restored = checkpoint.restore(args.ckpt,
                                          {"params": st.params, "opt": st.opt})
            st.params, st.opt = restored["params"], restored["opt"]
    print(f"final loss: {float(m['loss']):.4f}")


if __name__ == "__main__":
    main()
