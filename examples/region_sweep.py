"""Reproduce the paper's headline table: SPROUT vs competitors across the
five grid regions (shortened horizon for CPU time).

    PYTHONPATH=src python examples/region_sweep.py [--hours 72]
"""
import argparse

from repro.core import SproutSimulation, summarize
from repro.core.carbon import REGIONS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hours", type=int, default=72)
    ap.add_argument("--schemes", default="BASE,CO2_OPT,SPROUT,ORACLE")
    args = ap.parse_args()
    schemes = args.schemes.split(",")

    print(f"{'region':8s} " + " ".join(f"{s:>22s}" for s in schemes[1:]))
    for region in REGIONS:
        sim = SproutSimulation(region=region, season="jun", hours=args.hours,
                               seed=0, requests_per_hour_cap=60,
                               schemes=schemes)
        s = summarize(sim.run())
        cells = [f"{s[x]['carbon_savings_pct']:5.1f}%/"
                 f"{s[x]['normalized_preference_pct']:5.1f}%"
                 for x in schemes[1:]]
        print(f"{region:8s} " + " ".join(f"{c:>22s}" for c in cells))
    print("(cells: carbon savings % / normalized generation preference %)")


if __name__ == "__main__":
    main()
