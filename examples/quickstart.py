"""Quickstart: SPROUT in 40 lines.

Builds a tiny model, serves three prompts at each directive level through
the real engine, and prices the carbon difference with a live(-shaped)
grid-intensity lookup.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import reduced
from repro.core import (A100_40GB, LLAMA2_13B, CarbonIntensityProvider,
                        DirectiveSet, EnergyModel, request_carbon)
from repro.models import model as MD
from repro.serving import ByteTokenizer, InferenceEngine


def main():
    cfg = reduced("granite_3_2b").replace(vocab_size=512)
    params = MD.init_model(cfg, jax.random.PRNGKey(0))
    tok = ByteTokenizer()
    directives = DirectiveSet()
    grid = CarbonIntensityProvider("CA", "jun")
    energy = EnergyModel(A100_40GB)

    print(f"grid carbon intensity now: {grid.intensity(12):.0f} gCO2/kWh")
    for level in range(len(directives)):
        eng = InferenceEngine(cfg, params, n_slots=2, max_len=96)
        prompt = directives.apply("Explain photosynthesis.", level)
        eng.submit(tok.encode(prompt, bos=True),
                   max_new_tokens=32 >> level)   # directive shortens output
        fin = eng.run_to_completion()[0]
        kwh = energy.request_energy_kwh(LLAMA2_13B, fin.prompt_tokens,
                                        fin.gen_tokens)
        t13b = energy.request_time(LLAMA2_13B, fin.prompt_tokens,
                                   fin.gen_tokens)
        g = request_carbon(grid.intensity(12), kwh, t13b,
                           A100_40GB.embodied_gco2, A100_40GB.lifetime_s)
        print(f"L{level}: {fin.gen_tokens:3d} tokens -> {g * 1000:.3f} mgCO2 "
              f"(13B-scale estimate)  text={fin.text[:40]!r}")


if __name__ == "__main__":
    main()
