"""End-to-end carbon-aware serving driver (the paper's system, for real).

The closed-loop ``SproutGateway`` fronts two regional pools of real
continuous-batching engines: every simulated hour it re-solves the
directive LP per pool from that pool's live carbon intensity and installs
the mix as the pool's directive selector; every finished request's
engine-measured telemetry (token counts + decode-only seconds) flows back
through ``EnergyModel.measure`` into the level profiles the next re-plan
optimizes over. Requests route to the greenest pool under a load cap; one
replica fails mid-run and its requests are requeued (fault tolerance).

Act two is the intensity-crossover scenario: a burst lands in the green
region, the regions' intensities cross before the backlog is served, and
the next re-plan tick MIGRATES the queued work to the newly green pool
over the same verbatim-token requeue path failover uses (DESIGN.md §8) —
carbon tracked within the hour, outputs unchanged.

Act three is the SLO layer (DESIGN.md §10): premium/standard/batch
service classes each get their own (pool, tenant) LP with per-class
quality floors and latency targets, admission routes on predicted
completion time jointly with greenness, and one pool is DRAINED ahead of
maintenance — its backlog migrates out with nothing stranded.

    PYTHONPATH=src python examples/carbon_aware_serving.py
"""
import jax
import numpy as np

from repro.configs import reduced
from repro.core import (A100_40GB, DEFAULT_TENANTS, CarbonIntensityProvider,
                        EnergyModel, QualityEvaluator, Workload)
from repro.core.policies import SproutPolicy
from repro.models import model as MD
from repro.serving import (CarbonAwareScheduler, InferenceEngine,
                           MigrationPlanner, ServeRequest, SproutGateway,
                           serve_request_from)

PROMPTS = ["Summarize the water cycle.", "What is 17 * 23?",
           "Name the largest ocean.", "Why is the sky blue?",
           "Define entropy briefly.", "Who wrote Hamlet?"]


def main():
    cfg = reduced("llama2_13b").replace(vocab_size=512)
    params = MD.init_model(cfg, jax.random.PRNGKey(0))
    workload = Workload(seed=0)
    evaluator = QualityEvaluator(sample_size=200)

    def engine(seed):
        # eos_id=-1: the tiny random model has no meaningful EOS; decoding
        # is budget-bound so measured token counts carry the per-level
        # brevity structure the directives stand for
        return InferenceEngine(cfg, params, n_slots=2, max_len=96,
                               seed=seed, eos_id=-1)

    providers = [CarbonIntensityProvider("SA", "jun"),
                 CarbonIntensityProvider("TX", "jun")]
    pools = [(providers[0], CarbonAwareScheduler([engine(1), engine(2)])),
             (providers[1], CarbonAwareScheduler([engine(4)]))]
    policy = SproutPolicy(
        k0_min=min(p.k_min for p in providers),
        k0_max=max(p.k_max for p in providers),
        k1=A100_40GB.embodied_gco2 / A100_40GB.lifetime_s)
    gw = SproutGateway(pools, policy=policy, energy=EnergyModel(A100_40GB),
                       load_cap=6)

    for hour in range(6):
        # refresh quality feedback from a synthetic sample pool (Eq. 5's q)
        sample = [workload.sample_request(hour + i * 0.01)
                  for i in range(400)]
        gw.set_quality(evaluator.evaluate(sample).q)

        reqs = [serve_request_from(workload.sample_request(hour + i * 0.01),
                                   token_scale=16.0, max_new=24,
                                   prompt=PROMPTS[i % len(PROMPTS)])
                for i in range(8)]
        def fail_sa_replica(g):
            # node failure with the hour's work in flight: the replica
            # dies mid-decode and its requests are requeued
            n = g.pools[0].scheduler.fail_replica(0)
            print(f"  [hour 3] SA replica 0 failed; requeued {n} requests")
            g.pools[0].scheduler.add_replica(engine(5))

        s = gw.run_hour(float(hour), reqs,
                        on_inflight=fail_sa_replica if hour == 3 else None)
        ks = " ".join(f"{k}={v:4.0f}" for k, v in s["k0"].items())
        rt = " ".join(f"{k}={v}" for k, v in s["routes"].items())
        mix = np.round(s["level_mix"], 2)
        print(f"hour {hour}: CI[{ks}]  served={s['served']:2d}  "
              f"routes[{rt}]  levels={mix}  "
              f"x_SA={np.round(s['x']['SA'], 2)}")
    st = gw.stats
    print(f"total carbon (13B-scale estimate): {st.carbon_g:.4f} gCO2 "
          f"across {st.requests} requests "
          f"({1000 * st.carbon_per_request:.3f} mg/req)")
    print(f"profiled per-level energy (kWh): {np.round(gw.profiles.e, 9)}")
    crossover_demo(cfg, params)
    slo_drain_demo(cfg, params)


def crossover_demo(cfg, params):
    """Act two: hour 0 is green in SA and dirty in TX; hour 1 reverses.
    A burst submitted at hour 0 is only partially served (``steps=1``), so
    its backlog rides across the crossover — and the hour-1 re-plan tick
    migrates it to TX instead of finishing it on SA's now-dirty grid."""
    print("\n== intensity-crossover migration ==")
    sa = CarbonIntensityProvider("SA", "jun")
    sa.trace = np.array([60.0, 480.0, 480.0])
    tx = CarbonIntensityProvider("TX", "jun")
    tx.trace = np.array([480.0, 90.0, 90.0])

    def engine(seed):
        return InferenceEngine(cfg, params, n_slots=2, max_len=96,
                               seed=seed, eos_id=-1)

    gw = SproutGateway(
        [(sa, CarbonAwareScheduler([engine(1)])),
         (tx, CarbonAwareScheduler([engine(2)]))],
        policy=None, energy=EnergyModel(A100_40GB), load_cap=64,
        forecast_horizon=2.0, migration=MigrationPlanner())
    burst = [ServeRequest(0, f"burst {i}", max_new_tokens=16)
             for i in range(10)]
    for hour in range(3):
        s = gw.run_hour(float(hour), burst if hour == 0 else [],
                        steps=1 if hour == 0 else None)
        ks = " ".join(f"{k}={v:3.0f}" for k, v in s["k0"].items())
        rt = " ".join(f"{k}={v}" for k, v in s["routes"].items())
        print(f"hour {hour}: CI[{ks}]  routes[{rt}]  "
              f"served={s['served']:2d}  migrated={s['migrated']}  "
              f"carbon={1000 * s['carbon_g']:.3f}mg")
    for m in gw.stats.migrations[:3]:
        print(f"  migrated rid={m.rid} {m.src}->{m.dst} ({m.kind}, "
              f"est. saving {1000 * m.est_saving_g:.3f} mg)")
    st = gw.stats
    print(f"crossover total: {1000 * st.carbon_per_request:.3f} mg/req, "
          f"{st.migrated} of {st.requests} requests migrated")


def slo_drain_demo(cfg, params):
    """Act three: service classes + the maintenance drain. Premium work
    carries a hard quality floor and a deadline; batch work chases carbon.
    At hour 2 the TX pool is drained ahead of maintenance — admission
    stops routing to it and its backlog migrates out, nothing stranded."""
    print("\n== tenant SLOs + capacity drain ==")
    workload = Workload(seed=3)
    providers = [CarbonIntensityProvider("CA", "jun"),
                 CarbonIntensityProvider("TX", "jun")]

    def engine(seed):
        return InferenceEngine(cfg, params, n_slots=2, max_len=96,
                               seed=seed, eos_id=-1)

    gw = SproutGateway(
        [(providers[0], CarbonAwareScheduler([engine(1)])),
         (providers[1], CarbonAwareScheduler([engine(2)]))],
        tenants=DEFAULT_TENANTS, energy=EnergyModel(A100_40GB),
        # cap low enough that the hour's burst overflows into TX — the
        # drained pool must actually hold work for act three to show the
        # backlog migrating out (not just the admission skip)
        load_cap=4)
    cycle = ("premium", "standard", "standard", "batch")

    def drain_tx(g):
        # drains WITH the hour's work in flight (run_hour's on_inflight
        # hook) — between hours the fleet is idle and there would be no
        # backlog to migrate, only the admission skip
        moved = g.drain_pool("TX", deadline=2.0)
        print(f"  [hour 2] draining TX for maintenance "
              f"(moved {moved} backlogged requests)")

    for hour in range(4):
        reqs = [serve_request_from(workload.sample_request(hour + i * 0.01),
                                   token_scale=16.0, max_new=24,
                                   tenant=cycle[i % len(cycle)])
                for i in range(8)]
        s = gw.run_hour(float(hour), reqs,
                        on_inflight=drain_tx if hour == 2 else None)
        rt = " ".join(f"{k}={v}" for k, v in s["routes"].items())
        slo = " ".join(f"{k}={v:.0%}" for k, v in sorted(s["slo"].items()))
        drain = f"  draining={','.join(s['draining'])}" if s["draining"] \
            else ""
        print(f"hour {hour}: routes[{rt}]  served={s['served']:2d}  "
              f"slo[{slo}]{drain}")
    st = gw.stats
    assert st.rejected == 0 and gw.pools[1].load() == 0
    print(f"drained TX empty, {st.rejected} stranded; attainment: "
          + " ".join(f"{n}={st.slo_attainment(n):.0%}"
                     for n in ("premium", "standard", "batch")))


if __name__ == "__main__":
    main()
