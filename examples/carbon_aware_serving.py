"""End-to-end carbon-aware serving driver (the paper's system, for real).

A two-replica fleet serves batched requests through the full SPROUT loop:
the LP optimizer re-plans each simulated hour from the live carbon
intensity + profiled level costs + evaluator feedback; the scheduler renders
the chosen directive as a system prompt; the engines run true
continuous-batching decode on a tiny model; one replica fails mid-run and
its requests are requeued (fault tolerance).

    PYTHONPATH=src python examples/carbon_aware_serving.py
"""
import jax
import numpy as np

from repro.configs import reduced
from repro.core import (A100_40GB, LLAMA2_13B, CarbonIntensityProvider,
                        DirectiveSet, EnergyModel, QualityEvaluator,
                        Workload, solve_directive_lp)
from repro.core.policies import LevelProfiles
from repro.models import model as MD
from repro.serving import (CarbonAwareScheduler, InferenceEngine,
                           ServeRequest)

PROMPTS = ["Summarize the water cycle.", "What is 17 * 23?",
           "Name the largest ocean.", "Why is the sky blue?",
           "Define entropy briefly.", "Who wrote Hamlet?"]


def main():
    cfg = reduced("llama2_13b").replace(vocab_size=512)
    params = MD.init_model(cfg, jax.random.PRNGKey(0))
    grid = CarbonIntensityProvider("SA", "jun")
    energy = EnergyModel(A100_40GB)
    directives = DirectiveSet()
    profiles = LevelProfiles.fresh()
    workload = Workload(seed=0)
    evaluator = QualityEvaluator(sample_size=200)
    q = np.ones(3) / 3
    x = np.ones(3) / 3
    rng = np.random.default_rng(0)

    level_choice = {"x": x}
    sched = CarbonAwareScheduler(
        [InferenceEngine(cfg, params, n_slots=2, max_len=96, seed=1),
         InferenceEngine(cfg, params, n_slots=2, max_len=96, seed=2)],
        directives,
        level_fn=lambda: int(rng.choice(3, p=level_choice["x"])))

    total_g = 0.0
    for hour in range(6):
        k0 = grid.intensity(hour)
        # profile-driven LP re-plan (Eq. 2-7)
        if profiles.counts.min() >= 2:
            sol = solve_directive_lp(profiles.e, profiles.p, q, k0=k0,
                                     k1=A100_40GB.embodied_gco2 / A100_40GB.lifetime_s,
                                     k0_min=grid.k_min, k0_max=grid.k_max)
            level_choice["x"] = sol.x
        # refresh quality feedback from a synthetic sample pool
        pool = [workload.sample_request(hour + i * 0.01) for i in range(400)]
        q = evaluator.evaluate(pool).q

        for i, ptxt in enumerate(PROMPTS):
            sched.submit(ServeRequest(0, ptxt, max_new_tokens=24))
        if hour == 3:
            n = sched.fail_replica(0)      # node failure mid-run
            print(f"  [hour 3] replica 0 failed; requeued {n} requests")
            sched.add_replica(InferenceEngine(cfg, params, n_slots=2,
                                              max_len=96, seed=3))
        done = sched.run()
        for f in done:
            kwh = energy.request_energy_kwh(LLAMA2_13B, f.prompt_tokens,
                                            f.gen_tokens)
            total_g += k0 * kwh * 1.2
            profiles.update(f.directive_level, kwh, f.latency_s)
        mix = np.bincount([f.directive_level for f in done], minlength=3)
        print(f"hour {hour}: CI={k0:5.0f}  served={len(done):2d}  "
              f"levels L0/L1/L2={mix[0]}/{mix[1]}/{mix[2]}  x={np.round(level_choice['x'], 2)}")
        sched.finished = []
    print(f"total carbon (13B-scale estimate): {total_g:.3f} gCO2")


if __name__ == "__main__":
    main()
