"""scripts/docs_check.py: the doc-reference lint in the CI lint job.

Fixture repos are built in tmp dirs and checked via --root through a
subprocess (the same way lint.sh invokes it), so the exit code and the
error listing are what's under test. The real repo passing is covered
too — that's the assertion the lint job actually runs.
"""

import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
SCRIPT = ROOT / "scripts" / "docs_check.py"

DESIGN = "# design\n\n## §1 Scope\n\nwords.\n\n## §4b Control\n\nwords.\n"


def _run(root: Path):
    proc = subprocess.run(
        [sys.executable, str(SCRIPT), "--root", str(root)],
        capture_output=True, text=True)
    return proc.returncode, proc.stdout + proc.stderr


def _fixture(tmp_path: Path, *, design=DESIGN, readme="# readme\n",
             code=None, extra=None) -> Path:
    (tmp_path / "DESIGN.md").write_text(design)
    (tmp_path / "README.md").write_text(readme)
    if code is not None:
        (tmp_path / "src").mkdir()
        (tmp_path / "src" / "mod.py").write_text(code)
    for rel, text in (extra or {}).items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(text)
    return tmp_path


def test_resolving_refs_and_links_pass(tmp_path):
    root = _fixture(
        tmp_path,
        readme="see [design](DESIGN.md) and [§4b](DESIGN.md#4b)\n"
               "per DESIGN.md §1 and DESIGN.md §§4b\n",
        code='"""Docstring citing DESIGN.md §1."""\nX = 1  # DESIGN.md §4b\n')
    rc, out = _run(root)
    assert rc == 0, out
    assert "DOCS_CHECK_OK" in out


def test_dangling_section_ref_fails(tmp_path):
    root = _fixture(tmp_path, code='"""See DESIGN.md §7 for details."""\n')
    rc, out = _run(root)
    assert rc == 1
    assert "dangling reference DESIGN.md §7" in out
    assert "mod.py:1" in out


def test_dead_relative_link_fails(tmp_path):
    root = _fixture(
        tmp_path,
        readme="intro [rows](benchmarks/README.md) outro\n"
               "[ok-url](https://example.com) [ok-frag](#anchor)\n")
    rc, out = _run(root)
    assert rc == 1
    assert "dead link -> benchmarks/README.md" in out
    assert "example.com" not in out  # absolute URLs are never checked


def test_missing_required_doc_fails(tmp_path):
    (tmp_path / "DESIGN.md").write_text(DESIGN)
    rc, out = _run(tmp_path)
    assert rc == 1
    assert "required doc missing: README.md" in out


def test_shell_scripts_are_scanned(tmp_path):
    root = _fixture(tmp_path, extra={
        "scripts/job.sh": "#!/bin/sh\n# gate per DESIGN.md §9\n"})
    rc, out = _run(root)
    assert rc == 1
    assert "job.sh:2" in out and "§9" in out


def test_link_fragments_are_stripped_before_existence_check(tmp_path):
    root = _fixture(tmp_path, readme="[sec](DESIGN.md#%C2%A71-scope)\n")
    rc, out = _run(root)
    assert rc == 0, out


def test_this_repo_passes():
    rc, out = _run(ROOT)
    assert rc == 0, out
