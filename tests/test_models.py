"""Model zoo: per-arch smoke, prefill/decode consistency, attention paths,
MoE dispatch invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, reduced
from repro.models import model as MD
from repro.models import moe as MOE
from repro.models.common import ModelConfig


def _batch_for(cfg, key, B=2, T=16):
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model))
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(key, (B, cfg.n_patches, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    """Reduced config: one forward/train step on CPU, shapes + no NaNs."""
    cfg = reduced(arch)
    key = jax.random.PRNGKey(0)
    params = MD.init_model(cfg, key)
    batch = _batch_for(cfg, key)
    loss, metrics = MD.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: MD.loss_fn(cfg, p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ["granite_3_2b", "starcoder2_15b",
                                  "hymba_1_5b", "xlstm_1_3b",
                                  "deepseek_v3_671b", "whisper_base",
                                  "internvl2_26b"])
def test_prefill_decode_matches_full_forward(arch):
    """decode(t+1) after prefill(0..t) == train-mode forward logits at t+1."""
    cfg = reduced(arch)
    key = jax.random.PRNGKey(1)
    params = MD.init_model(cfg, key)
    B, T = 2, 12
    batch = _batch_for(cfg, key, B, T + 1)
    toks = batch["tokens"]
    full, _ = MD.forward(cfg, params, toks, mode="train",
                         frames=batch.get("frames"),
                         patches=batch.get("patches"))
    npatch = cfg.n_patches if cfg.family == "vlm" else 0
    lg, cache, _ = MD.prefill(cfg, params, toks[:, :T], max_len=npatch + T + 4,
                              frames=batch.get("frames"),
                              patches=batch.get("patches"))
    pos = jnp.full((B,), T + npatch, jnp.int32)
    lg2, _ = MD.decode_step(cfg, params, toks[:, T:T + 1], pos, cache)
    ref = full[:, T + npatch - 0 - 1 + 1] if False else full[:, npatch + T]
    np.testing.assert_allclose(np.asarray(lg2), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_sliding_window_equals_full_when_window_large():
    cfg = reduced("granite_3_2b")
    cfgw = cfg.replace(sliding_window=64)   # window > T
    key = jax.random.PRNGKey(2)
    params = MD.init_model(cfg, key)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    a, _ = MD.forward(cfg, params, toks, mode="train")
    b, _ = MD.forward(cfgw, params, toks, mode="train")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_blocked_attn_impl_matches_naive():
    cfg = reduced("llama2_13b")
    key = jax.random.PRNGKey(3)
    params = MD.init_model(cfg, key)
    toks = jax.random.randint(key, (2, 48), 0, cfg.vocab_size)
    a, _ = MD.forward(cfg, params, toks, mode="train")
    b, _ = MD.forward(cfg.replace(attn_impl="blocked"), params, toks,
                      mode="train")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                               atol=2e-4)


def test_moe_dispatch_conservation():
    """With capacity ample and top_k=1, each token's output equals the pure
    per-expert MLP output for its routed expert."""
    cfg = ModelConfig(family="moe", n_layers=1, d_model=16, n_heads=2,
                      n_kv_heads=2, d_ff=32, vocab_size=64, n_experts=4,
                      top_k=1, moe_d_ff=32, capacity_factor=8.0,
                      dtype="float32")
    key = jax.random.PRNGKey(4)
    p = MOE.init_moe(cfg, key)
    x = jax.random.normal(key, (2, 8, 16))
    y, aux = MOE.apply_moe(cfg, p, x)
    assert float(aux["moe_drop_frac"]) == 0.0
    x2d = x.reshape(-1, 16)
    w, idx, _ = MOE.route(cfg, p, x2d)
    from repro.models.layers import activation
    for t in range(x2d.shape[0]):
        e = int(idx[t, 0])
        h = activation("silu", x2d[t] @ p["w_gate"][e]) * (x2d[t] @ p["w_up"][e])
        ref = h @ p["w_down"][e]
        np.testing.assert_allclose(np.asarray(y.reshape(-1, 16)[t]),
                                   np.asarray(ref), rtol=1e-4, atol=1e-4)


def test_moe_capacity_drops_overflow():
    cfg = ModelConfig(family="moe", n_layers=1, d_model=8, n_heads=1,
                      n_kv_heads=1, d_ff=16, vocab_size=64, n_experts=2,
                      top_k=1, moe_d_ff=16, capacity_factor=0.25,
                      dtype="float32")
    p = MOE.init_moe(cfg, jax.random.PRNGKey(5))
    x = jax.random.normal(jax.random.PRNGKey(6), (4, 16, 8))
    _, aux = MOE.apply_moe(cfg, p, x)
    assert float(aux["moe_drop_frac"]) > 0.0


def test_mtp_loss_present_for_deepseek():
    cfg = reduced("deepseek_v3_671b")
    params = MD.init_model(cfg, jax.random.PRNGKey(7))
    batch = _batch_for(cfg, jax.random.PRNGKey(8))
    loss, metrics = MD.loss_fn(cfg, params, batch)
    assert "mtp_ce" in metrics and np.isfinite(float(metrics["mtp_ce"]))
    assert "moe_aux_loss" in metrics


def test_int8_kv_cache_decode_close_to_fp():
    cfg = reduced("llama2_13b")
    cfg8 = cfg.replace(kv_cache_dtype="int8")
    key = jax.random.PRNGKey(9)
    params = MD.init_model(cfg, key)
    toks = jax.random.randint(key, (2, 12), 0, cfg.vocab_size)
    _, c1, _ = MD.prefill(cfg, params, toks, max_len=16)
    _, c8, _ = MD.prefill(cfg8, params, toks, max_len=16)
    pos = jnp.full((2,), 12, jnp.int32)
    nxt = jax.random.randint(key, (2, 1), 0, cfg.vocab_size)
    l1, _ = MD.decode_step(cfg, params, nxt, pos, c1)
    l8, _ = MD.decode_step(cfg8, params, nxt, pos, c8)
    # int8 KV is approximate: logits rank order mostly preserved
    a1 = np.argsort(np.asarray(l1[0]))[-5:]
    a8 = np.argsort(np.asarray(l8[0]))[-5:]
    assert len(set(a1) & set(a8)) >= 3
