"""Chunked prefill (continuous batching) correctness: streaming a prompt
into the fused decode scan chunk by chunk must be token-identical to the
whole-prompt prefill path, on dense and paged caches, fp32 and int8 KV,
at K=1 and K>1 — including chunk boundaries that straddle page boundaries.
Also covers the admission-model plumbing the chunk task feeds (per-chunk
page growth, eviction mid-prefill, bucketed entry-point tables)."""
import jax
import numpy as np
import pytest

from repro.configs import reduced
from repro.core import A100_40GB, CarbonIntensityProvider, EnergyModel
from repro.models import model as MD
from repro.serving import (ByteTokenizer, CarbonAwareScheduler,
                           InferenceEngine, SamplingParams, ServeRequest,
                           SproutGateway)


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced("granite_3_2b").replace(vocab_size=512)
    params = MD.init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


BG_PROMPT = "background request keeps its lane decoding"
ARRIVAL = "newcomer arrives with a much longer prompt that spans chunks"


def _interleaved(cfg, params, *, prefill_chunk, decode_block, paged=False,
                 kv_int8=False, page_size=16, arrival_mnt=10):
    """One background request decoding, then an arrival admitted against
    it. Returns (engine, {rid: token_ids})."""
    eng = InferenceEngine(cfg, params, n_slots=2, max_len=64,
                          decode_block=decode_block, paged=paged,
                          page_size=page_size, kv_int8=kv_int8,
                          prefill_chunk=prefill_chunk)
    tok = ByteTokenizer()
    eng.submit(tok.encode(BG_PROMPT), max_new_tokens=30)
    eng.step()                      # background lane is now live
    eng.submit(tok.encode(ARRIVAL), max_new_tokens=arrival_mnt)
    eng.run_to_completion()
    return eng, {f.rid: tuple(f.token_ids) for f in eng.finished}


@pytest.mark.parametrize("decode_block", [1, 8])
@pytest.mark.parametrize("paged,kv_int8", [(False, False), (True, False),
                                           (True, True)])
def test_chunked_matches_whole_prompt(small_model, decode_block, paged,
                                      kv_int8):
    """Greedy tokens — for the arrival AND the background lane it
    interleaves with — are bit-identical to the whole-prompt world."""
    cfg, params = small_model
    _, whole = _interleaved(cfg, params, prefill_chunk=0,
                            decode_block=decode_block, paged=paged,
                            kv_int8=kv_int8)
    eng, chunked = _interleaved(cfg, params, prefill_chunk=8,
                                decode_block=decode_block, paged=paged,
                                kv_int8=kv_int8)
    assert eng.chunk_steps > 0      # the chunk path actually ran
    assert whole == chunked


def test_chunk_straddles_page_boundary(small_model):
    """A chunk size that does not divide the page size forces chunk
    writes to span two pages mid-chunk; tokens must not change."""
    cfg, params = small_model
    # chunk=12 against page_size=16: the second chunk covers positions
    # [12, 24) and crosses the page boundary at 16
    _, whole = _interleaved(cfg, params, prefill_chunk=0, decode_block=8,
                            paged=True, page_size=16)
    eng, chunked = _interleaved(cfg, params, prefill_chunk=12,
                                decode_block=8, paged=True, page_size=16)
    assert eng.chunk_steps > 0
    assert whole == chunked


def test_chunked_pages_grow_per_chunk(small_model):
    """Paged chunk admission maps pages as chunks land, not the whole
    prompt at insert: the growth counter must see chunk-driven mapping
    and the allocator ledger must stay exact after completion."""
    cfg, params = small_model
    eng, _ = _interleaved(cfg, params, prefill_chunk=8, decode_block=8,
                          paged=True, page_size=16)
    assert eng.pages_grown_chunked > 0
    assert eng.pages.pages_in_use() == 0        # everything released
    assert eng._committed == 0


def test_chunked_first_token_before_background_finishes(small_model):
    """Admission proceeds while the lane keeps decoding: the arrival's
    first token must land before the background request completes."""
    cfg, params = small_model
    eng = InferenceEngine(cfg, params, n_slots=2, max_len=64,
                          decode_block=8, prefill_chunk=8)
    tok = ByteTokenizer()
    bg = eng.submit(tok.encode(BG_PROMPT), max_new_tokens=40)
    eng.step()
    arr = eng.submit(tok.encode(ARRIVAL), max_new_tokens=4)
    while not any(f.rid == arr for f in eng.finished):
        eng.step()
    done = {f.rid for f in eng.finished}
    assert arr in done and bg not in done


def test_chunked_sampled_arrival_reproducible(small_model):
    """A sampled request admitted through the chunk path draws its first
    token in-scan; the stream must still be seed-reproducible."""
    cfg, params = small_model
    outs = []
    for _ in range(2):
        eng = InferenceEngine(cfg, params, n_slots=2, max_len=64, seed=7,
                              decode_block=8, prefill_chunk=8)
        tok = ByteTokenizer()
        eng.submit(tok.encode(BG_PROMPT), max_new_tokens=20)
        eng.step()
        eng.submit(tok.encode(ARRIVAL), max_new_tokens=8,
                   sampling=SamplingParams(temperature=1.0, top_k=50))
        fin = eng.run_to_completion()
        outs.append(tuple(tuple(f.token_ids) for f in fin))
    assert outs[0] == outs[1]


def test_evict_mid_chunk_releases_everything(small_model):
    """Evicting the request that owns the active chunk task must clear
    the task and release its pages and admission reservation."""
    cfg, params = small_model
    eng = InferenceEngine(cfg, params, n_slots=2, max_len=64,
                          decode_block=1, paged=True, page_size=16,
                          prefill_chunk=8)
    tok = ByteTokenizer()
    eng.submit(tok.encode(BG_PROMPT), max_new_tokens=30)
    eng.step()
    arr = eng.submit(tok.encode(ARRIVAL), max_new_tokens=10)
    eng.step()                      # admits the arrival as a chunk task
    assert eng._task is not None
    st = eng.evict(arr)
    assert st is not None and st.rid == arr
    assert eng._task is None
    eng.run_to_completion()         # background still completes cleanly
    assert eng.pages.pages_in_use() == 0
    assert eng._committed == 0


def test_bucketed_entry_points_cover_occupancy(small_model):
    """Partial occupancy compiles bucketed programs; full occupancy runs
    the identity program — both recorded in the entry-point table."""
    cfg, params = small_model
    eng = InferenceEngine(cfg, params, n_slots=4, max_len=64,
                          decode_block=8)
    tok = ByteTokenizer()
    eng.submit(tok.encode("solo request"), max_new_tokens=12)
    eng.run_to_completion()
    assert any(n.startswith("decode_bs1_") for n in eng.entry_points)
    for i in range(4):
        eng.submit(tok.encode(f"req {i}"), max_new_tokens=12)
    eng.run_to_completion()
    assert any(n.startswith("decode_bs4_") for n in eng.entry_points)


def test_admission_models_chunked_overlap(small_model):
    """The gateway's predicted-completion estimate credits chunked pools
    with half a wave of prefill/decode overlap — only when queued."""
    cfg, params = small_model

    def mk_pool(region, prefill_chunk):
        prov = CarbonIntensityProvider(region, "jun")
        prov.trace = np.asarray([100.0])
        eng = InferenceEngine(cfg, params, n_slots=2, max_len=128,
                              eos_id=-1, prefill_chunk=prefill_chunk)
        return prov, CarbonAwareScheduler([eng])

    gw = SproutGateway([mk_pool("CA", 8), mk_pool("TX", 0)],
                       energy=EnergyModel(A100_40GB))
    assert gw.pools[0].chunked_fraction() == 1.0
    assert gw.pools[1].chunked_fraction() == 0.0
    for lvl in range(gw.n_levels):
        gw.latency_profiles.update(lvl, 0.0, 0.1)
    # idle pools: no queue, no credit — identical estimates
    assert gw.predicted_completion_s(gw.pools[0]) == pytest.approx(
        gw.predicted_completion_s(gw.pools[1]))
    for pool in gw.pools:
        for i in range(4):
            pool.scheduler.submit(ServeRequest(0, f"q{i}",
                                               max_new_tokens=8))
    # 4 queued on 2 slots = 2 extra waves; the chunked pool sheds half a
    # wave of slot-epoch alignment wait
    assert gw.predicted_completion_s(gw.pools[1]) == pytest.approx(0.3)
    assert gw.predicted_completion_s(gw.pools[0]) == pytest.approx(0.25)


def test_dispatch_prefers_chunked_on_load_tie(small_model):
    """Equal load: the scheduler routes to the engine whose prefill
    interleaves (shorter TTFT there), not the slot-epoch one."""
    cfg, params = small_model
    plain = InferenceEngine(cfg, params, n_slots=2, max_len=64)
    chunked = InferenceEngine(cfg, params, n_slots=2, max_len=64,
                              prefill_chunk=8)
    sched = CarbonAwareScheduler([plain, chunked])
    sched.submit(ServeRequest(0, "tie-break", max_new_tokens=4))
    sched._dispatch()
    assert chunked.load() == 1 and plain.load() == 0


def test_bucketing_preserves_solo_stream(small_model):
    """A request decoded in a bs=1 bucket (3 slots empty) produces the
    same greedy tokens as the same request at full fixed-batch width."""
    cfg, params = small_model
    tok = ByteTokenizer()
    outs = []
    for n_slots in (1, 4):
        eng = InferenceEngine(cfg, params, n_slots=n_slots, max_len=64,
                              decode_block=8)
        eng.submit(tok.encode("the solitary prompt"), max_new_tokens=16)
        outs.append(tuple(eng.run_to_completion()[0].token_ids))
    assert outs[0] == outs[1]
