"""Closed control loop: SproutGateway wiring the LP optimizer into real
engines — plan installation tracks grid intensity, telemetry feedback
converges to engine-derived energies, green routing respects load caps."""
import jax
import numpy as np
import pytest

from repro.configs import reduced
from repro.core import (A100_40GB, LLAMA2_13B, PUE, CarbonIntensityProvider,
                        DirectiveSet, EnergyModel)
from repro.core.policies import SproutPolicy
from repro.models import model as MD
from repro.serving import (ByteTokenizer, CarbonAwareScheduler,
                           InferenceEngine, ServeRequest, SproutGateway)
from repro.serving.gateway import serve_request_from


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced("granite_3_2b").replace(vocab_size=512)
    params = MD.init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _provider(trace):
    prov = CarbonIntensityProvider("CA", "jun")
    prov.trace = np.asarray(trace, float)
    return prov


def _policy(prov, **kw):
    return SproutPolicy(k0_min=prov.k_min, k0_max=prov.k_max, xi=0.25,
                        k1=A100_40GB.embodied_gco2 / A100_40GB.lifetime_s,
                        explore=0.0, **kw)


def _engine(cfg, params, **kw):
    # eos_id=-1: budget-bound decoding on the tiny random model, so
    # generated-token telemetry equals the per-level budgets exactly
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 128)
    return InferenceEngine(cfg, params, eos_id=-1, **kw)


def test_gateway_mix_tracks_grid_intensity(small_model):
    """Dirty hour -> the installed mix shifts mass onto higher (cheaper)
    directive levels; green hour -> the Eq. 3 floor pins it back to pure
    L0. Both the installed x AND the realized served levels must move."""
    cfg, params = small_model
    prov = _provider([CarbonIntensityProvider("CA").k_max,
                      CarbonIntensityProvider("CA").k_min])
    gw = SproutGateway([(prov, CarbonAwareScheduler([_engine(cfg, params)]))],
                       policy=_policy(prov), energy=EnergyModel(A100_40GB),
                       q=np.array([0.50, 0.33, 0.17]), load_cap=64, seed=3)
    # pre-seed profiles past the policy's warmup so hour 0 already solves
    gw.profiles.e[:] = [4e-6, 2e-6, 1e-6]
    gw.profiles.p[:] = [0.2, 0.1, 0.05]
    gw.profiles.counts[:] = 5

    def hour(t):
        reqs = [ServeRequest(0, f"q{t}-{i}", max_new_tokens=12,
                             max_new_by_level=[12, 6, 3]) for i in range(10)]
        return gw.run_hour(t, reqs)

    dirty = hour(0.0)
    green = hour(1.0)
    x_dirty, x_green = dirty["x"]["CA"], green["x"]["CA"]
    # dirty grid: quality floor relaxed -> real mass off L0
    assert x_dirty[1:].sum() > 0.2
    # green grid: q_lb == q0 and only L0 meets it -> pure L0
    assert x_green[0] > 0.99
    assert x_dirty[0] < x_green[0] - 0.2
    # the plan reached the engines: served levels follow the installed mix
    assert dirty["level_mix"][1:].sum() > 0
    assert green["level_mix"][0] == pytest.approx(1.0)
    # quality floor honored by the dirty-hour plan (Eq. 3/5)
    plan = gw.stats.plans[0]
    assert plan.expected_quality >= plan.q_lb - 1e-9


def test_gateway_profiles_converge_to_engine_energy(small_model):
    """The feedback edge: LevelProfiles must converge to the energies the
    ENGINE actually produced — computed independently here from the exact
    directive-rendered prompt lengths and the per-level token budgets."""
    cfg, params = small_model
    prov = _provider([300.0])
    gw = SproutGateway([(prov, CarbonAwareScheduler([_engine(cfg, params)]))],
                       policy=_policy(prov),   # fresh profiles => warmup
                       energy=EnergyModel(A100_40GB), load_cap=64, seed=0)
    budgets = [12, 6, 3]
    prompt = "telemetry check"
    for t in range(3):
        reqs = [ServeRequest(0, prompt, max_new_tokens=budgets[0],
                             max_new_by_level=budgets) for _ in range(8)]
        gw.run_hour(float(t), reqs)
    assert gw.stats.requests == 24
    tok, ds, em = ByteTokenizer(), DirectiveSet(), EnergyModel(A100_40GB)
    seen = set()
    for lvl in range(3):
        if gw.profiles.counts[lvl] == 0:
            continue
        seen.add(lvl)
        plen = len(tok.encode(ds.apply(prompt, lvl), bos=True))
        want = em.request_energy_kwh(LLAMA2_13B, plen, budgets[lvl]) * PUE
        assert gw.profiles.e[lvl] == pytest.approx(want, rel=1e-6), \
            f"level {lvl}"
    assert len(seen) >= 2   # warmup's uniform mix exercised several levels
    # telemetry records match the profile feed
    for rec in gw.stats.telemetry:
        assert rec.gen_tokens == budgets[rec.level]


def test_gateway_routes_green_under_load_cap(small_model):
    """Requests go to the greenest pool until its in-flight load hits the
    cap, then spill to dirtier pools, then fall back to least-loaded."""
    cfg, params = small_model
    dirty = CarbonIntensityProvider("TX", "jun")
    dirty.trace = np.array([400.0])
    green = _provider([50.0])        # CA
    gw = SproutGateway(
        [(dirty, CarbonAwareScheduler([_engine(cfg, params)])),
         (green, CarbonAwareScheduler([_engine(cfg, params)]))],
        policy=None, energy=EnergyModel(A100_40GB), load_cap=3)
    gw.tick(0.0)
    keys = [gw.submit(ServeRequest(0, f"r{i}", max_new_tokens=4))[1]
            for i in range(8)]
    # first three fill the green CA pool, next three spill to dirty TX,
    # the rest balance by load
    assert keys[:3] == ["CA"] * 3
    assert keys[3:6] == ["TX"] * 3
    assert gw.pools[1].routed >= 4
    gw.drain()
    assert gw.stats.requests == 8
    assert gw.stats.rejected == 0
    # policy=None is the L0-only baseline: nothing leaves level 0
    assert gw.stats.level_counts[0] == 8


def test_gateway_accounts_carbon_at_pool_intensity(small_model):
    """Eq. 1 accounting uses the serving pool's intensity at finish time."""
    cfg, params = small_model
    prov = _provider([250.0])
    gw = SproutGateway([(prov, CarbonAwareScheduler([_engine(cfg, params)]))],
                       policy=None, energy=EnergyModel(A100_40GB))
    gw.run_hour(0.0, [ServeRequest(0, "one", max_new_tokens=6)])
    rec = gw.stats.telemetry[0]
    assert rec.k0 == 250.0
    em = EnergyModel(A100_40GB)
    kwh, secs = em.measure(LLAMA2_13B, rec.prompt_tokens, rec.gen_tokens)
    assert rec.energy_kwh == pytest.approx(kwh * PUE, rel=1e-9)
    want = 250.0 * kwh * PUE + (A100_40GB.embodied_gco2
                                / A100_40GB.lifetime_s) * secs
    assert rec.carbon_g == pytest.approx(want, rel=1e-9)
    assert gw.stats.carbon_g == pytest.approx(want, rel=1e-9)


def _four_level_directives():
    from repro.core.directives import Directive
    return DirectiveSet((Directive(0, "L0", ""),
                         Directive(1, "L1", "Be brief."),
                         Directive(2, "L2", "Be very brief."),
                         Directive(3, "L3", "Answer in one word.")))


def test_gateway_dead_pool_rejects_instead_of_stalling(small_model):
    """A pool whose whole fleet is gone must not strand requests or spin
    drain(); its backlog is parked as rejected, and routing prefers pools
    that still have live engines."""
    cfg, params = small_model
    dead = CarbonIntensityProvider("TX", "jun")
    dead.trace = np.array([50.0])                 # greener, but no fleet
    live = _provider([400.0])
    gw = SproutGateway(
        [(dead, CarbonAwareScheduler([])),
         (live, CarbonAwareScheduler([_engine(cfg, params)]))],
        policy=None, energy=EnergyModel(A100_40GB), load_cap=4)
    gw.tick(0.0)
    keys = [gw.submit(ServeRequest(0, f"r{i}", max_new_tokens=4))[1]
            for i in range(3)]
    assert keys == ["CA"] * 3                     # dead TX pool skipped
    gw.drain()
    assert gw.stats.requests == 3 and gw.stats.rejected == 0
    # now the whole fleet dies with work queued: drain parks it rejected
    gw.pools[1].scheduler.fail_replica(0)
    gw.pools[0].scheduler.submit(ServeRequest(0, "stranded",
                                              max_new_tokens=4))
    gw.drain()
    assert gw.stats.rejected >= 1
    assert not any(p.load() for p in gw.pools)    # nothing left spinning


def test_gateway_run_hour_on_inflight_failover(small_model):
    """run_hour's mid-hour hook: fail a replica with work in flight; the
    hour still serves everything and the summary stays consistent."""
    cfg, params = small_model
    prov = _provider([300.0])
    sched = CarbonAwareScheduler([_engine(cfg, params),
                                  _engine(cfg, params)])
    gw = SproutGateway([(prov, sched)], policy=None,
                       energy=EnergyModel(A100_40GB), load_cap=64)

    def fail_first(g):
        assert g.pools[0].scheduler.fail_replica(0) >= 0

    s = gw.run_hour(0.0, [ServeRequest(0, f"f{i}", max_new_tokens=8)
                          for i in range(6)], on_inflight=fail_first)
    assert s["served"] == 6 and gw.stats.rejected == 0


def test_gateway_supports_non_default_level_counts():
    """n_levels != 3 end-to-end on the control plane: warmup mix, LP solve
    and installed x all carry the configured level count."""
    prov = _provider([400.0, 60.0])
    pol = SproutPolicy(k0_min=prov.k_min, k0_max=prov.k_max, xi=0.25,
                       k1=1e-3, explore=0.0, n_levels=4)
    gw = SproutGateway(
        [(prov, CarbonAwareScheduler([], _four_level_directives()))],
        policy=pol, n_levels=4, q=np.array([0.40, 0.30, 0.20, 0.10]))
    gw.tick(0.0)                                  # fresh profiles: warmup
    assert gw.pools[0].x.shape == (4,)
    np.testing.assert_allclose(gw.pools[0].x, 0.25)
    assert 0 <= gw.pools[0].scheduler.level_fn() < 4
    gw.profiles.e[:] = [4e-6, 3e-6, 2e-6, 1e-6]
    gw.profiles.p[:] = [0.2, 0.15, 0.1, 0.05]
    gw.profiles.counts[:] = 5
    gw.tick(1.0)                                  # real LP solve at N=4
    x = gw.pools[0].x
    assert x.shape == (4,) and x.sum() == pytest.approx(1.0)
    plan = gw.stats.plans[-1]
    assert plan.expected_quality >= plan.q_lb - 1e-9
    # a 3-level DirectiveSet cannot render a 4-level plan: rejected early
    with pytest.raises(ValueError, match="directive levels"):
        SproutGateway([(prov, CarbonAwareScheduler([]))], policy=pol,
                      n_levels=4)
    # a policy without a matching directive-level mix is rejected early
    # (the gateway installs policy.x as level_fn, never policy.assign)
    from repro.core.policies import BasePolicy
    with pytest.raises(ValueError, match="mix"):
        SproutGateway([(prov, CarbonAwareScheduler([]))],
                      policy=BasePolicy())


def test_serve_request_from_budgets_are_monotone():
    from repro.core.workload import Workload
    w = Workload(seed=5)
    for i in range(20):
        sr = serve_request_from(w.sample_request(i * 0.3), token_scale=8.0,
                                max_new=40)
        b = list(sr.max_new_by_level)
        assert b[0] >= b[1] >= b[2] >= 2      # L0 >= L1 >= L2 (directives)
        assert sr.max_new_tokens == b[0]
