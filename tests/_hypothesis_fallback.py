"""Deterministic stand-in for `hypothesis` when it is not installed.

The container this repo targets does not ship hypothesis and nothing may be
pip-installed, so conftest installs this shim into ``sys.modules`` before
test collection. It implements exactly the API surface the test suite uses
(``given``, ``settings``, ``strategies.{text,sampled_from,booleans,integers,
floats,lists}``) by running each property test over a fixed number of
pseudo-random examples seeded from the test name — deterministic across
runs, no shrinking, no database. If the real hypothesis is importable it is
always preferred (see conftest.py).
"""
from __future__ import annotations


import random
import sys
import types

_TEXT_POOL = (
    "abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789"
    " \t\n!\"#$%&'()*+,-./:;<=>?@[\\]^_`{|}~"
    "éüλπЖ中文🙂"
)


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def text(max_size: int = 20, **_kw):
    def draw(rng):
        n = rng.randint(0, max_size)
        return "".join(rng.choice(_TEXT_POOL) for _ in range(n))
    return _Strategy(draw)


def sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda rng: rng.choice(seq))


def booleans():
    return _Strategy(lambda rng: rng.random() < 0.5)


def integers(min_value: int = 0, max_value: int = 1 << 30):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float = 0.0, max_value: float = 1.0, **_kw):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10, **_kw):
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [elements.draw(rng) for _ in range(n)]
    return _Strategy(draw)


class settings:
    """Both the ``@settings(...)`` decorator and the profile registry."""

    _profiles: dict = {}
    _current: dict = {"max_examples": 25}

    def __init__(self, max_examples: int = None, deadline=None, **_kw):
        self.max_examples = max_examples

    def __call__(self, fn):
        if self.max_examples is not None:
            fn._shim_max_examples = self.max_examples
        return fn

    @classmethod
    def register_profile(cls, name: str, max_examples: int = 25, **kw):
        cls._profiles[name] = {"max_examples": max_examples}

    @classmethod
    def load_profile(cls, name: str):
        cls._current = dict(cls._profiles.get(name, cls._current))


def given(*strategies):
    def deco(fn):
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_shim_max_examples",
                        settings._current["max_examples"])
            rng = random.Random(f"shim:{fn.__module__}.{fn.__qualname__}")
            for _ in range(n):
                fn(*args, *[s.draw(rng) for s in strategies], **kwargs)
        # deliberately NOT functools.wraps: exposing the inner signature via
        # __wrapped__ would make pytest treat the strategy-supplied
        # parameters as fixtures. The wrapper takes no parameters itself.
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.hypothesis_shim = True
        return wrapper
    return deco


def assume(condition) -> bool:
    # no example rejection machinery; property tests here draw from ranges
    # that already satisfy their assumptions
    return bool(condition)


def install() -> None:
    """Register the shim as ``hypothesis`` / ``hypothesis.strategies``."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("text", "sampled_from", "booleans", "integers", "floats",
                 "lists"):
        setattr(st_mod, name, globals()[name])
    mod.strategies = st_mod
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod
