"""GPipe pipeline over a stage axis: matches sequential execution
(subprocess: needs >1 device)."""
import os
import subprocess
import sys

from repro.training.pipeline import bubble_fraction


def test_bubble_fraction():
    assert bubble_fraction(16, 4) == 3 / 19
    assert bubble_fraction(1, 4) == 0.75
    assert bubble_fraction(32, 2) < 0.05


_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import shard_map_compat as shard_map
from repro.training.pipeline import pipeline_apply

S, M, B, D = 4, 8, 2, 16
mesh = jax.make_mesh((S,), ("stage",))
key = jax.random.PRNGKey(0)
W = jax.random.normal(key, (S, D, D)) * 0.3
b = jax.random.normal(jax.random.PRNGKey(1), (S, D)) * 0.1
mbs = jax.random.normal(jax.random.PRNGKey(2), (M, B, D))

def stage_fn(params, x):
    w, bias = params
    return jnp.tanh(x @ w + bias)

@partial(shard_map, mesh=mesh, in_specs=(P("stage"), P(None)),
         out_specs=P(None), check_vma=False)
def piped(params, mbs):
    w, bias = params
    return pipeline_apply(stage_fn, (w[0], bias[0]), mbs, "stage")

got = piped((W, b), mbs)

# sequential reference
want = mbs
for s in range(S):
    want = jnp.tanh(want @ W[s] + b[s])
np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)
print("PIPELINE_OK")
"""


def test_pipeline_matches_sequential_subprocess():
    # the snippet goes through shard_map_compat (launch/mesh.py), which
    # maps the jax>=0.5 check_vma keyword onto 0.4.x check_rep — this was
    # an xfail from PR 4 to PR 9 (DESIGN.md §9). JAX_PLATFORMS stays
    # pinned to cpu: an unpinned jax probes for TPU hardware and spends
    # minutes in metadata-fetch retries on CPU-only containers, while the
    # forced host device count only applies to the CPU platform anyway.
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", _SNIPPET], env=env,
                       capture_output=True, text=True, timeout=420,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "PIPELINE_OK" in r.stdout, r.stdout + r.stderr
