"""Radix prefix cache (DESIGN.md §13): chain-hash match/adopt semantics,
refcount/COW lifecycle through release and eviction, prefix-aware
admission (a cached span reserves zero new pages), and the tentpole
property — prefix-cache-on vs off emits bit-identical tokens while
skipping the shared span's prefill entirely."""
import jax
import numpy as np
import pytest

from repro.configs import reduced
from repro.core import A100_40GB, CarbonIntensityProvider, EnergyModel
from repro.models import model as MD
from repro.serving import (ByteTokenizer, CarbonAwareScheduler,
                           InferenceEngine, SproutGateway)
from repro.serving.engine import FinishedRequest
from repro.serving.kv_cache import PageAllocator


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced("granite_3_2b").replace(vocab_size=512)
    params = MD.init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _alloc(**kw):
    kw.setdefault("n_pages", 8)
    kw.setdefault("page_size", 8)
    kw.setdefault("n_slots", 4)
    kw.setdefault("max_len", 64)
    kw.setdefault("prefix_cache", True)
    return PageAllocator(**kw)


# ======================================================================
# allocator: chain hashing, adopt, refcounts, COW, LRU retention
# ======================================================================

def test_match_adopt_shares_pages_without_allocating():
    al = _alloc()
    ids = list(range(20))                      # 2 full pages + 4 tail tokens
    al.ensure_capacity(0, 20)
    assert al.register_prefix(0, ids) == 2     # tail page never indexed
    in_use = al.pages_in_use()
    m, pids, newly = al.match_prefix(ids)
    assert m == 2 and pids == [0, 1] and newly == 0   # owner still holds
    al.adopt(1, pids)
    assert al.pages_in_use() == in_use         # zero new pages for the span
    assert al.block_table[1, :2].tolist() == al.block_table[0, :2].tolist()
    assert al.refcount[pids].tolist() == [2, 2]
    assert al.pinned == 0                      # owner's reservation pays


def test_chain_hash_means_equal_prefix_not_equal_page():
    """Page 2's key is chained on page 1's: an identical second page under
    a DIFFERENT first page must not match (content-hash alone would)."""
    al = _alloc()
    a = list(range(16))
    b = [99] * 8 + list(range(8, 16))          # same 2nd page, different 1st
    al.ensure_capacity(0, 16)
    al.register_prefix(0, a)
    assert al.match_prefix(a)[0] == 2
    assert al.match_prefix(b)[0] == 0
    assert al.match_prefix(a[:8] + [7] * 8)[0] == 1    # divergence in page 2
    assert al.match_prefix(a[:7])[0] == 0      # partial page never matches


def test_kv_salt_partitions_the_index():
    """fp and int8 pages hash apart: an int8 engine's chain keys must never
    satisfy an fp lookup (the page bytes mean different things)."""
    ids = list(range(8))
    fp, q8 = _alloc(kv_salt="float32"), _alloc(kv_salt="int8")
    assert fp._chain_hashes(ids) != q8._chain_hashes(ids)


def test_refcount_lifecycle_release_pin_cache_evict():
    al = _alloc(n_pages=4)
    ids = list(range(16))
    al.ensure_capacity(0, 16)
    al.register_prefix(0, ids)
    al.adopt(1, al.match_prefix(ids)[1])
    al.release(0)                              # owner gone, adopter remains
    assert al.refcount[:2].tolist() == [1, 1]
    assert al.pinned == 2 and al.cached_pages() == 0
    al.release(1)                              # last holder gone
    assert al.refcount[:2].tolist() == [0, 0]
    assert al.pinned == 0 and al.cached_pages() == 2   # retained, not freed
    assert al.pages_in_use() == 2
    m, pids, newly = al.match_prefix(ids)      # still a hit from cache
    assert m == 2 and newly == 2
    # allocation pressure reclaims cached pages LRU-first, index entries die
    al.ensure_capacity(2, 32)                  # needs all 4 pages
    assert al.cached_pages() == 0 and al.cache_evictions == 2
    assert al.match_prefix(ids)[0] == 0


def test_cow_on_shared_page_write():
    al = _alloc()
    ids = list(range(16))
    al.ensure_capacity(0, 16)
    al.register_prefix(0, ids)
    al.adopt(1, al.match_prefix(ids)[1])
    # slot 1 writes into its last shared page -> fresh page, remap, decref
    cow = al.prepare_append(1, 15)
    assert cow is not None
    src, dst = cow
    assert src == int(al.block_table[0, 1]) and dst not in (0, 1)
    assert int(al.block_table[1, 1]) == dst
    assert al.refcount[src] == 1 and al.refcount[dst] == 1
    assert al.cow_copies == 1
    # owner's write into its own indexed page needs no copy, but de-indexes
    assert al.prepare_append(0, 15) is None
    assert al.match_prefix(ids)[0] == 1        # page 2's key dropped


def test_invalidate_slot_drops_only_owned_pages():
    al = _alloc()
    ids = list(range(20))                      # 2 full pages + 4 tail tokens
    al.ensure_capacity(0, 20)
    al.register_prefix(0, ids)                 # pages 0, 1 indexed
    al.adopt(1, al.match_prefix(ids)[1])
    al.ensure_capacity(1, 20)                  # slot 1's own tail page
    assert al.invalidate_slot(1) == 0          # adopted pages not implicated
    assert al.match_prefix(ids)[0] == 2
    assert al.invalidate_slot(0) == 2          # owner's suspect pages drop
    assert al.match_prefix(ids)[0] == 0


# ======================================================================
# engine: hit admission, zero-new-page adoption, bit-identity
# ======================================================================

def _run(cfg, params, reqs, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("paged", True)
    kw.setdefault("page_size", 16)
    kw.setdefault("eos_id", -1)
    eng = InferenceEngine(cfg, params, **kw)
    tok = ByteTokenizer()
    for prompt, mnt in reqs:
        eng.submit(tok.encode(prompt), max_new_tokens=mnt)
    return eng, eng.run_to_completion()


SHARED = "system: answer briefly and cite sources. "   # 41 tokens, 2 pages
# the first two duplicates admit in ONE cold batch (the index registers at
# prefill completion, so simultaneous cold duplicates cannot share); every
# later duplicate is a hit
DUP_REQS = [(SHARED + "q1", 12), (SHARED + "second?", 12),
            (SHARED + "x", 8), ("unrelated prompt", 8), (SHARED + "y", 8)]


def test_prefix_on_vs_off_bit_identical_tokens(small_model):
    """The tentpole acceptance property: enabling the prefix cache must
    not change one emitted token on a duplicate-heavy trace."""
    cfg, params = small_model
    e0, f0 = _run(cfg, params, DUP_REQS)
    e1, f1 = _run(cfg, params, DUP_REQS, prefix_cache=True)
    assert {f.rid: f.token_ids for f in f0} == \
        {f.rid: f.token_ids for f in f1}
    # and it genuinely hit: the shared span's prefill was skipped for the
    # two duplicates admitted after the prefix was registered
    assert e1.prefill_tokens_cached >= 2 * 32
    assert e1.prefill_tokens_computed < e0.prefill_tokens_computed
    assert sum(f.cached_tokens for f in f1) == e1.prefill_tokens_cached
    assert all(f.cached_tokens == 0 for f in f0)
    # ledger clean at drain; cached pages retained for future traffic
    assert e1._committed == 0 and e1.pages.pinned == 0
    assert e1.pages.pages_in_use() == e1.pages.cached_pages() > 0


def test_full_prefix_hit_adopts_the_same_pages(small_model):
    """A hit maps the EXISTING pages into the new slot's block table —
    zero new pages for the shared span."""
    cfg, params = small_model
    tok = ByteTokenizer()
    eng = InferenceEngine(cfg, params, n_slots=2, max_len=64, paged=True,
                          page_size=16, eos_id=-1, prefix_cache=True)
    ids = tok.encode(SHARED)                   # 41 tokens -> pages 0,1 shared
    eng.submit(ids, max_new_tokens=4)
    eng.run_to_completion()
    shared_pages = sorted(eng.pages._cached)   # retained after release
    assert len(shared_pages) == 2
    eng.submit(ids + tok.encode("tail"), max_new_tokens=4)
    eng._try_prefill()                         # hit admission, no dispatch
    assert eng._task is not None
    slot = eng._task.slot
    assert eng.pages.block_table[slot, :2].tolist() == shared_pages
    assert eng._task.next == 32                # prefill starts past the span
    assert eng.slots[slot].cached_tokens == 32
    eng.run_to_completion()
    assert eng.pages.pages_adopted == 2 and eng._committed == 0


def test_page_aligned_full_cover_prompt_cows_and_stays_identical(small_model):
    """A fully cached page-aligned prompt still computes its last token
    (first-token logits), whose KV write lands inside the last shared page
    — the one genuine COW. Outputs stay identical to the cold run."""
    cfg, params = small_model
    tok = ByteTokenizer()
    prompt = "p" * 32                          # exactly 2 pages
    _, f0 = _run(cfg, params, [(prompt, 8)])
    e1 = InferenceEngine(cfg, params, n_slots=2, max_len=64, paged=True,
                         page_size=16, eos_id=-1, prefix_cache=True)
    # sequential so the second submission sees the first's registration
    e1.submit(tok.encode(prompt), max_new_tokens=8)
    e1.run_to_completion()
    e1.submit(tok.encode(prompt), max_new_tokens=8)
    f1 = e1.run_to_completion()
    assert [f.token_ids for f in f1] == [f0[0].token_ids] * 2
    assert e1.pages.cow_copies == 1
    assert f1[1].cached_tokens == 31           # 32 shared minus the recompute


def test_duplicate_admission_fits_where_worst_case_would_not(small_model):
    """Prefix-aware reservation: with a 5-page budget, two 32-token-prefix
    requests run CONCURRENTLY under the prefix cache (3 + 2 pages) where
    worst-case reservation (3 + 4) admits them only serially."""
    cfg, params = small_model
    tok = ByteTokenizer()
    a = tok.encode(SHARED[:32])                # 2 full pages
    b = a + tok.encode("extra suffix")         # shares both
    for on, want_peak in ((False, 1), (True, 2)):
        eng = InferenceEngine(cfg, params, n_slots=2, max_len=64, paged=True,
                              page_size=16, n_pages=5, eos_id=-1,
                              prefix_cache=on)
        eng.submit(a, max_new_tokens=16)       # cap 48 -> 3 pages
        eng.submit(b, max_new_tokens=12)       # cap 55 -> 4 pages, 2 cached
        fins = eng.run_to_completion()
        assert sorted(f.gen_tokens for f in fins) == [12, 16]
        assert eng.peak_concurrent == want_peak
        assert eng._committed == 0


def test_evict_and_drain_repay_exact_reservation(small_model):
    """Release sites decref and repay the admission-time charge — after a
    hit-admitted request is evicted mid-flight, the ledger and refcounts
    are exactly as before its admission."""
    cfg, params = small_model
    tok = ByteTokenizer()
    eng = InferenceEngine(cfg, params, n_slots=2, max_len=64, paged=True,
                          page_size=16, eos_id=-1, prefix_cache=True)
    eng.submit(tok.encode(SHARED), max_new_tokens=4)
    eng.run_to_completion()
    cached0 = eng.pages.cached_pages()
    rid = eng.submit(tok.encode(SHARED + "zz"), max_new_tokens=8)
    eng._try_prefill()                         # hit admission, no dispatch
    assert eng._task is not None and eng._committed > 0
    st = eng.evict(rid)
    assert st is not None and st.reserved_pages == 0
    assert eng._committed == 0 and eng.pages.pinned == 0
    assert eng.pages.cached_pages() == cached0
    assert np.all(eng.pages.refcount <= 1)
    # drained engine still serves the cache: resubmit hits again
    eng.submit(tok.encode(SHARED + "zz"), max_new_tokens=8)
    eng.run_to_completion()
    assert eng.prefill_tokens_cached >= 2 * 32
    assert eng._committed == 0


def test_gateway_eq1_credits_cached_prefill_tokens(small_model):
    """Eq. 1 accounting charges only the computed prompt span: identical
    finishes that differ in cached_tokens differ in measured kWh."""
    cfg, params = small_model
    prov = CarbonIntensityProvider("CA", "jun")
    eng = InferenceEngine(cfg, params, n_slots=2, max_len=64, eos_id=-1)
    gw = SproutGateway([(prov, CarbonAwareScheduler([eng]))],
                       energy=EnergyModel(A100_40GB))
    pool = gw.pools[0]
    fin = dict(rid=1, token_ids=[1] * 8, text="", prompt_tokens=64,
               gen_tokens=8, ttft_s=0.1, latency_s=0.2, directive_level=0,
               decode_s=0.05)
    gw._account(pool, FinishedRequest(**fin))
    gw._account(pool, FinishedRequest(**fin, cached_tokens=48))
    t0, t1 = gw.stats.telemetry[-2:]
    assert t0.cached_tokens == 0 and t1.cached_tokens == 48
    assert t1.energy_kwh < t0.energy_kwh
    assert t1.carbon_g < t0.carbon_g
