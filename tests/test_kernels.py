"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

pytest.importorskip("jax.experimental.pallas",
                    reason="Pallas unavailable in this jax build")
pytestmark = pytest.mark.pallas

from repro.kernels.flash_attention import flash_attention  # noqa: E402
from repro.kernels.paged_attention import paged_attention
from repro.kernels.rmsnorm import fused_rmsnorm
from repro.kernels import ref
from repro.kernels import ops
from repro.models.attention import quantize_kv


def _qkv(key, B, H, KVH, T, D, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, T, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, KVH, T, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, KVH, T, D)).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("B,H,KVH,T,D,causal,window", [
    (2, 4, 2, 256, 64, True, 0),
    (1, 2, 2, 128, 32, False, 0),
    (1, 4, 1, 256, 64, True, 96),
    (2, 8, 8, 128, 128, True, 0),
])
def test_flash_kernel_sweep(B, H, KVH, T, D, causal, window, dtype, tol):
    q, k, v = _qkv(jax.random.PRNGKey(0), B, H, KVH, T, D, dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@settings(max_examples=8, deadline=None)
@given(st.sampled_from([64, 128, 192]), st.sampled_from([32, 64]),
       st.sampled_from([1, 2, 4]), st.booleans())
def test_flash_kernel_property(T, D, group, causal):
    KVH = 2
    H = KVH * group
    q, k, v = _qkv(jax.random.PRNGKey(T + D), 1, H, KVH, T, D, jnp.float32)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("B,H,KVH,D,P,page,maxp", [
    (3, 8, 2, 64, 16, 32, 4),
    (2, 4, 4, 128, 8, 64, 2),
    (1, 16, 2, 64, 32, 16, 8),
])
def test_paged_kernel_sweep(B, H, KVH, D, P, page, maxp):
    key = jax.random.PRNGKey(B * 100 + H)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, D))
    kp = jax.random.normal(ks[1], (P, page, KVH, D))
    vp = jax.random.normal(ks[2], (P, page, KVH, D))
    rng = np.random.default_rng(0)
    bt = rng.permutation(P)[: B * maxp].reshape(B, maxp).astype(np.int32)
    lengths = rng.integers(1, page * maxp, B).astype(np.int32)
    out = paged_attention(q, kp, vp, jnp.asarray(bt), jnp.asarray(lengths),
                          interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, jnp.asarray(bt),
                                   jnp.asarray(lengths))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paged_kernel_int8():
    key = jax.random.PRNGKey(11)
    B, H, KVH, D, P, page, maxp = 2, 8, 2, 64, 8, 32, 3
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, D))
    kp = jax.random.normal(ks[1], (P, page, KVH, D))
    vp = jax.random.normal(ks[2], (P, page, KVH, D))
    kq, ksc = quantize_kv(kp.reshape(P * page, 1, KVH, D))
    vq, vsc = quantize_kv(vp.reshape(P * page, 1, KVH, D))
    kq = kq.reshape(P, page, KVH, D).astype(jnp.float32)
    vq = vq.reshape(P, page, KVH, D).astype(jnp.float32)
    ksc = ksc.reshape(P, page, KVH)
    vsc = vsc.reshape(P, page, KVH)
    bt = jnp.asarray([[0, 1, 2], [3, 4, 5]], jnp.int32)
    lengths = jnp.asarray([70, 96], jnp.int32)
    out = paged_attention(q, kq, vq, bt, lengths, ksc, vsc, interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=0.05, atol=0.05)  # int8 quant noise


@pytest.mark.parametrize("N,d,block,res", [(512, 128, 128, True),
                                           (256, 256, 64, False),
                                           (128, 64, 128, True)])
def test_rmsnorm_kernel_sweep(N, d, block, res):
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (N, d))
    r = jax.random.normal(ks[1], (N, d)) if res else None
    s = jax.random.normal(ks[2], (d,))
    y, ro = fused_rmsnorm(x, s, r, block_rows=block, interpret=True)
    wy, wro = ref.fused_rmsnorm_ref(x, s, r)
    np.testing.assert_allclose(np.asarray(y), np.asarray(wy), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(ro), np.asarray(wro), rtol=1e-6,
                               atol=1e-6)


def test_ops_wrappers_dispatch():
    key = jax.random.PRNGKey(6)
    q, k, v = _qkv(key, 1, 4, 2, 128, 32, jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ops.flash_attention(q, k, v)),
        np.asarray(ref.flash_attention_ref(q, k, v)), rtol=2e-5, atol=2e-5)
    x = jax.random.normal(key, (100, 32))   # ragged rows -> ref fallback
    s = jnp.ones((32,))
    y, _ = ops.fused_rmsnorm(x, s)
    wy, _ = ref.fused_rmsnorm_ref(x, s)
    np.testing.assert_allclose(np.asarray(y), np.asarray(wy), rtol=1e-5,
                               atol=1e-5)
