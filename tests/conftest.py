import os
import sys

# Tests run on the single real CPU device. The 512-device override belongs
# ONLY to the dry-run (src/repro/launch/dryrun.py) — never set it here.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

try:
    from hypothesis import settings
except ModuleNotFoundError:
    # the target container has no hypothesis and installing packages is not
    # allowed; fall back to a deterministic shim with the same API surface
    sys.path.insert(0, os.path.dirname(__file__))
    from _hypothesis_fallback import install

    install()
    from hypothesis import settings

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")
