import os

# Tests run on the single real CPU device. The 512-device override belongs
# ONLY to the dry-run (src/repro/launch/dryrun.py) — never set it here.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

from hypothesis import settings

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")
