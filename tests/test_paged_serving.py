"""Paged KV serving: allocator invariants, paged kernel vs the
``PagedKVCache.gather()`` oracle, int8 parity, page-budget admission, and
dense-vs-paged fused-loop equivalence (the tentpole property: switching
the engine's KV layout must not change a single emitted token)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced
from repro.core.energy import LLAMA2_13B, A100_40GB, EnergyModel
from repro.models import model as MD
from repro.models.attention import quantize_kv
from repro.serving import ByteTokenizer, InferenceEngine
from repro.serving.kv_cache import PageAllocator, PagedKVCache

pallas = pytest.importorskip("jax.experimental.pallas",
                             reason="Pallas unavailable in this jax build")
from repro.kernels.paged_attention import paged_attention  # noqa: E402


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced("granite_3_2b").replace(vocab_size=512)
    params = MD.init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


# ======================================================================
# allocator
# ======================================================================

def test_allocator_exhaustion_release_reuse_roundtrip():
    al = PageAllocator(n_pages=4, page_size=8, n_slots=3, max_len=64)
    al.ensure_capacity(0, 17)                  # 3 pages
    assert al.pages_in_use() == 3
    al.ensure_capacity(1, 8)                   # 1 page -> full
    with pytest.raises(MemoryError, match="exhausted"):
        al.ensure_capacity(2, 1)
    # failed allocation must not leak partial state
    assert al.pages_in_use() == 4
    al.release(0)
    assert al.pages_in_use() == 1
    al.ensure_capacity(2, 9)                   # reuse freed pages
    assert al.pages_in_use() == 3
    # per-slot cap beats pool exhaustion in the error taxonomy
    with pytest.raises(MemoryError, match="max_len"):
        al.ensure_capacity(2, 65)


def test_allocator_deterministic_lowest_id_reuse():
    """Release order must not leak into reuse order: allocation is always
    the lowest-numbered free page, a pure function of alloc/release
    history (the old list-ordered free list depended on interleaving)."""
    al = PageAllocator(n_pages=8, page_size=8, n_slots=4, max_len=64)
    for slot, tokens in ((0, 16), (1, 16), (2, 16)):   # pages 0-5 in order
        al.ensure_capacity(slot, tokens)
    assert al.block_table[0, :2].tolist() == [0, 1]
    al.release(1)                                      # frees 2, 3
    al.release(0)                                      # frees 0, 1
    al.ensure_capacity(3, 32)                          # 4 pages
    assert al.block_table[3, :4].tolist() == [0, 1, 2, 3]


def test_allocator_incremental_counts_and_fragmentation():
    al = PageAllocator(n_pages=8, page_size=8, n_slots=2, max_len=64)
    al.ensure_capacity(0, 12)                  # 2 pages for 12 tokens
    al.lengths[0] = 12
    assert al.pages_in_use() == 2
    assert al.live_tokens() == 12
    assert al.fragmentation() == pytest.approx(1 - 12 / 16)
    rep = al.report()
    assert rep["pages_in_use"] == 2 and rep["occupancy"] == 0.25
    al.release(0)
    assert al.fragmentation() == 0.0 and al.live_tokens() == 0


def test_paged_cache_coalesced_append_matches_gather():
    """Multi-token appends land exactly like token-at-a-time appends and
    cross page boundaries correctly (per-page block writes)."""
    ps, nkv, dh = 8, 2, 4
    key = jax.random.PRNGKey(0)
    k = jax.random.normal(key, (21, nkv, dh))
    v = k * 0.5
    ref = PagedKVCache(n_pages=6, page_size=ps, n_kv=nkv, head_dim=dh,
                       n_slots=1, max_len=48)
    for t in range(21):
        ref.append(0, k[t], v[t])              # one token at a time
    run = PagedKVCache(n_pages=6, page_size=ps, n_kv=nkv, head_dim=dh,
                       n_slots=1, max_len=48)
    run.append(0, k[:5], v[:5])                # runs straddling pages
    run.append(0, k[5:19], v[5:19])
    run.append(0, k[19:], v[19:])
    for a, b in zip(ref.gather(0), run.gather(0)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))
    np.testing.assert_allclose(np.asarray(run.gather(0)[0]), np.asarray(k),
                               rtol=1e-6)


# ======================================================================
# kernel vs gather() oracle
# ======================================================================

def _oracle_from_gather(pc: PagedKVCache, q):
    """Attention computed from the materialized per-slot K/V — the
    independent oracle the kernel must match."""
    outs = []
    for b in range(q.shape[0]):
        kk, vv = pc.gather(b)
        kk = np.asarray(kk, np.float32)        # (L, KVH, D)
        vv = np.asarray(vv, np.float32)
        B, H, D = q.shape
        KVH = kk.shape[1]
        g = H // KVH
        qh = np.asarray(q[b], np.float32).reshape(KVH, g, D)
        s = np.einsum("kgd,skd->kgs", qh, kk) / math.sqrt(D)
        p = np.exp(s - s.max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        outs.append(np.einsum("kgs,skd->kgd", p, vv).reshape(H, D))
    return np.stack(outs)


@pytest.mark.pallas
@pytest.mark.parametrize("lengths", [
    (15, 16, 17),     # straddle below / exactly on / above a page boundary
    (1, 40, 33),      # near-empty slot + multi-page slots
])
def test_paged_kernel_matches_gather_oracle(lengths):
    ps, nkv, dh, H = 16, 2, 32, 4
    B = len(lengths)
    pc = PagedKVCache(n_pages=12, page_size=ps, n_kv=nkv, head_dim=dh,
                      n_slots=B, max_len=64)
    key = jax.random.PRNGKey(7)
    for b, L in enumerate(lengths):
        kb = jax.random.normal(jax.random.fold_in(key, b), (L, nkv, dh))
        pc.write_prompt(b, kb, kb * 0.25 + 1.0)
    q = jax.random.normal(jax.random.fold_in(key, 99), (B, H, dh))
    bt, ln = pc.device_tables()
    out = paged_attention(q, pc.k, pc.v, bt, ln, interpret=True)
    want = _oracle_from_gather(pc, q)
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-5, atol=2e-5)


@pytest.mark.pallas
def test_paged_kernel_predicated_empty_pages_no_dma():
    """Unmapped table entries (-1) past a slot's length must not affect
    the output — those grid steps are predicated off entirely."""
    ps, nkv, dh, H = 16, 1, 32, 2
    pc = PagedKVCache(n_pages=8, page_size=ps, n_kv=nkv, head_dim=dh,
                      n_slots=2, max_len=128)        # max_pages=8 > needed
    key = jax.random.PRNGKey(3)
    pc.write_prompt(0, jax.random.normal(key, (5, nkv, dh)),
                    jax.random.normal(key, (5, nkv, dh)))
    assert (pc.block_table[0] >= 0).sum() == 1       # 7 unmapped entries
    q = jax.random.normal(jax.random.fold_in(key, 1), (2, H, dh))
    bt, ln = pc.device_tables()
    out = paged_attention(q, pc.k, pc.v, bt, ln, interpret=True)
    want = _oracle_from_gather(pc, q[:1])
    np.testing.assert_allclose(np.asarray(out)[:1], want, rtol=2e-5,
                               atol=2e-5)
    # slot 1 holds nothing: all pages predicated off -> exactly zero
    np.testing.assert_array_equal(np.asarray(out)[1], 0.0)


@pytest.mark.pallas
def test_paged_kernel_int8_parity_with_fp_oracle():
    ps, nkv, dh, H, B = 16, 2, 32, 4, 2
    key = jax.random.PRNGKey(5)
    pc = PagedKVCache(n_pages=8, page_size=ps, n_kv=nkv, head_dim=dh,
                      n_slots=B, max_len=64)
    for b, L in enumerate((23, 48)):
        kb = jax.random.normal(jax.random.fold_in(key, b), (L, nkv, dh))
        pc.write_prompt(b, kb, kb * 0.5)
    kq, ks = quantize_kv(pc.k.reshape(-1, 1, nkv, dh))
    vq, vs = quantize_kv(pc.v.reshape(-1, 1, nkv, dh))
    q = jax.random.normal(jax.random.fold_in(key, 9), (B, H, dh))
    bt, ln = pc.device_tables()
    out = paged_attention(
        q, kq.reshape(pc.k.shape).astype(jnp.float32),
        vq.reshape(pc.v.shape).astype(jnp.float32), bt, ln,
        ks.reshape(*pc.k.shape[:2], nkv), vs.reshape(*pc.v.shape[:2], nkv),
        interpret=True)
    want = _oracle_from_gather(pc, q)
    np.testing.assert_allclose(np.asarray(out), want, rtol=0.05, atol=0.05)


# ======================================================================
# fused-loop equivalence: dense vs paged
# ======================================================================

REQS = [("alpha prompt", 20), ("b", 3), ("c c c", 3), ("dddd", 11),
        ("e", 7)]


def _run_engine(cfg, params, reqs, **kw):
    eng = InferenceEngine(cfg, params, n_slots=2, max_len=64, **kw)
    tok = ByteTokenizer()
    for prompt, mnt in reqs:
        eng.submit(tok.encode(prompt), max_new_tokens=mnt)
    return eng, eng.run_to_completion()


@pytest.mark.parametrize("decode_block", [1, 8])
def test_fused_loop_dense_vs_paged_identical(small_model, decode_block):
    """Switching the KV layout must not change one emitted token, the
    finish order, or the token accounting — at K=1 and K>1."""
    cfg, params = small_model
    ed, fd = _run_engine(cfg, params, REQS, decode_block=decode_block)
    ep, fp = _run_engine(cfg, params, REQS, decode_block=decode_block,
                         paged=True, page_size=16)
    assert [f.rid for f in fd] == [f.rid for f in fp]
    for a, b in zip(fd, fp):
        assert a.token_ids == b.token_ids
        assert (a.prompt_tokens, a.gen_tokens) == (b.prompt_tokens,
                                                   b.gen_tokens)
    # one device_get per block in BOTH layouts: the block-table push is
    # host->device only, so the sync count cannot differ
    assert ed.decode_syncs == ep.decode_syncs
    # everything released at the end: memory followed live tokens down
    assert ep.pages.pages_in_use() == 0
    assert ep._committed == 0


def test_fused_loop_dense_vs_paged_int8(small_model):
    cfg, params = small_model
    _, fd = _run_engine(cfg, params, REQS, decode_block=8, kv_int8=True)
    _, fp = _run_engine(cfg, params, REQS, decode_block=8, kv_int8=True,
                        paged=True, page_size=16)
    for a, b in zip(fd, fp):
        assert a.token_ids == b.token_ids


@pytest.mark.pallas
def test_fused_loop_pallas_interpret_matches_ref(small_model):
    """The engine driving the real kernel (interpret mode) emits the same
    tokens as the XLA reference path."""
    cfg, params = small_model
    reqs = REQS[:3]
    _, fx = _run_engine(cfg, params, reqs, decode_block=4, paged=True,
                        page_size=16, paged_impl="xla")
    _, fk = _run_engine(cfg, params, reqs, decode_block=4, paged=True,
                        page_size=16, paged_impl="pallas_interpret")
    for a, b in zip(fx, fk):
        assert a.token_ids == b.token_ids


# ======================================================================
# page-budget admission + telemetry
# ======================================================================

def test_page_budget_gates_admission_not_completion(small_model):
    """With pages for ~2 requests but 4 free slots, concurrency tracks the
    page budget; every request still completes, FIFO."""
    cfg, params = small_model
    tok = ByteTokenizer()
    eng2 = InferenceEngine(cfg, params, n_slots=4, max_len=64, paged=True,
                           page_size=16, n_pages=4, eos_id=-1)
    # prompt 3 + 20 new = 23 tokens -> 2-page reservation each; the 4-page
    # budget admits exactly two at a time
    rids = [eng2.submit(tok.encode("pp"), max_new_tokens=20)
            for _ in range(4)]
    eng2.run_to_completion()
    assert sorted(f.rid for f in eng2.finished) == sorted(rids)
    assert all(f.gen_tokens == 20 for f in eng2.finished)
    # the engine-tracked high-water mark (sampled at maximal residency,
    # before same-step finishes free slots): budget-gated, not slot-gated
    assert eng2.peak_concurrent == 2
    assert eng2.pages.pages_in_use() == 0


def test_unservable_page_budget_rejected_at_submit(small_model):
    cfg, params = small_model
    eng = InferenceEngine(cfg, params, n_slots=2, max_len=64, paged=True,
                          page_size=16, n_pages=2)
    tok = ByteTokenizer()
    with pytest.raises(ValueError, match="page budget"):
        eng.submit(tok.encode("x" * 40), max_new_tokens=20)  # needs 4 pages
    eng.submit(tok.encode("ok"), max_new_tokens=8)           # 1 page: fine
    assert len(eng.run_to_completion()) == 1


def test_kv_memory_scales_with_live_tokens(small_model):
    """The acceptance property: measured pages_in_use x page_bytes tracks
    live tokens, while the dense layout charges n_slots x max_len always."""
    cfg, params = small_model
    tok = ByteTokenizer()
    eng = InferenceEngine(cfg, params, n_slots=4, max_len=64, paged=True,
                          page_size=16, eos_id=-1)
    dense = InferenceEngine(cfg, params, n_slots=4, max_len=64)
    assert eng.kv_stats()["kv_bytes_in_use"] == 0
    assert dense.kv_stats()["kv_bytes_in_use"] == \
        dense.kv_stats()["kv_bytes_capacity"]
    eng.submit(tok.encode("hello"), max_new_tokens=40)
    eng.step()                       # one decode block: still mid-flight
    s1 = eng.kv_stats()
    assert 0 < s1["kv_bytes_in_use"] < s1["kv_bytes_capacity"]
    assert s1["pages_in_use"] == eng.pages.pages_needed(
        int(eng.positions[0]) + 1)  # prompt + in-flight appends, 1 slot
    eng.run_to_completion()
    assert eng.kv_stats()["kv_bytes_in_use"] == 0


def test_drain_slots_releases_pages(small_model):
    cfg, params = small_model
    eng = InferenceEngine(cfg, params, n_slots=2, max_len=64, paged=True,
                          page_size=16, eos_id=-1)
    tok = ByteTokenizer()
    for i in range(2):
        eng.submit(tok.encode(f"req {i}"), max_new_tokens=20)
    eng.step()
    assert eng.pages.pages_in_use() > 0
    drained = eng.drain_slots()
    assert len(drained) == 2
    assert eng.pages.pages_in_use() == 0 and eng._committed == 0


def test_int8_profile_halves_modeled_decode_kv_bytes():
    """engine flag -> EnergyModel roofline: the int8 profile's modeled
    decode KV bytes/token are ~2x lower, and that flows into measure()."""
    em = EnergyModel(A100_40GB)
    m8 = LLAMA2_13B.with_int8_kv()
    ratio = (em.decode_kv_bytes_per_token(LLAMA2_13B, 512)
             / em.decode_kv_bytes_per_token(m8, 512))
    assert 1.8 < ratio < 2.1
    kwh, _ = em.measure(LLAMA2_13B, 128, 64)
    kwh8, _ = em.measure(m8, 128, 64)
    assert kwh8 < kwh
