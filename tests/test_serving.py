"""Serving stack: engine, continuous batching, failover, paged cache,
tokenizer round-trips, sampler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.configs import reduced
from repro.core.directives import DirectiveSet
from repro.models import model as MD
from repro.serving import (ByteTokenizer, CarbonAwareScheduler,
                           InferenceEngine, SamplingParams, ServeRequest)
from repro.serving.kv_cache import PagedKVCache
from repro.serving.sampler import sample_logits, sample_logits_batched


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced("granite_3_2b").replace(vocab_size=512)
    params = MD.init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_serves_all_requests(small_model):
    cfg, params = small_model
    eng = InferenceEngine(cfg, params, n_slots=3, max_len=64)
    tok = ByteTokenizer()
    rids = [eng.submit(tok.encode(f"hi {i}"), max_new_tokens=8)
            for i in range(7)]
    fin = eng.run_to_completion()
    assert sorted(f.rid for f in fin) == sorted(rids)
    for f in fin:
        assert 1 <= f.gen_tokens <= 8
        assert f.ttft_s >= 0 and f.latency_s >= f.ttft_s


def test_engine_continuous_batching_overlaps(small_model):
    cfg, params = small_model
    eng = InferenceEngine(cfg, params, n_slots=2, max_len=64)
    tok = ByteTokenizer()
    eng.submit(tok.encode("a"), max_new_tokens=20)
    eng.submit(tok.encode("b"), max_new_tokens=3)
    eng.submit(tok.encode("c"), max_new_tokens=3)
    fin = eng.run_to_completion()
    assert len(fin) == 3   # short requests slot in while the long one runs


def test_engine_deterministic_greedy(small_model):
    cfg, params = small_model
    tok = ByteTokenizer()
    outs = []
    for _ in range(2):
        eng = InferenceEngine(cfg, params, n_slots=1, max_len=64)
        eng.submit(tok.encode("determinism test"), max_new_tokens=10)
        outs.append(tuple(eng.run_to_completion()[0].token_ids))
    assert outs[0] == outs[1]


def test_scheduler_failover_preserves_requests(small_model):
    cfg, params = small_model
    e1 = InferenceEngine(cfg, params, n_slots=2, max_len=64)
    e2 = InferenceEngine(cfg, params, n_slots=2, max_len=64)
    sched = CarbonAwareScheduler([e1, e2], DirectiveSet(), level_fn=lambda: 1)
    for i in range(6):
        # budget outlasts one fused decode block so work is still in
        # flight on the replica when it fails
        sched.submit(ServeRequest(0, f"q{i}", max_new_tokens=40))
    sched.step()
    requeued = sched.fail_replica(0)
    assert requeued >= 1
    fin = sched.run()
    assert len({f.rid for f in fin}) >= 6    # nothing lost
    assert all(f.directive_level == 1 for f in fin)


def test_scheduler_failover_does_not_rewrap_prompt(small_model):
    """A requeued request's prompt is already directive-rendered; dispatch
    must not nest it in another ChatML wrapper with a fresh directive."""
    cfg, params = small_model
    tok = ByteTokenizer()

    def baseline():
        eng = InferenceEngine(cfg, params, n_slots=1, max_len=64)
        s = CarbonAwareScheduler([eng], DirectiveSet(), level_fn=lambda: 2)
        s.submit(ServeRequest(0, "hello", max_new_tokens=40))
        return s.run()[0]

    ref = baseline()
    e1 = InferenceEngine(cfg, params, n_slots=1, max_len=64)
    e2 = InferenceEngine(cfg, params, n_slots=1, max_len=64)
    sched = CarbonAwareScheduler([e1, e2], DirectiveSet(), level_fn=lambda: 2)
    sched.submit(ServeRequest(0, "hello", max_new_tokens=40))
    sched.step()                       # prefills on e1, still in flight
    assert sched.fail_replica(0) == 1
    fin = sched.run()[0]
    assert fin.prompt_tokens == ref.prompt_tokens   # no nested re-wrap
    assert fin.directive_level == ref.directive_level == 2


def test_serve_request_sampling_default_not_shared():
    """Regression: a class-level ``SamplingParams()`` default was one
    shared instance across every request."""
    a, b = ServeRequest(0, "a"), ServeRequest(0, "b")
    assert a.sampling is not b.sampling


def test_scheduler_failover_resubmits_token_ids_verbatim(small_model):
    """Regression: failover used to decode() the prompt ids and re-encode
    them — a lossy round trip. The requeued request must carry the ORIGINAL
    token ids and dispatch must submit them unchanged."""
    cfg, params = small_model
    tok = ByteTokenizer()
    # ids that do NOT survive a decode/encode round trip (interior BOS
    # renders as nothing)
    ids = [ByteTokenizer.BOS, 104, 105, ByteTokenizer.BOS, 106]
    assert tok.encode(tok.decode(ids), bos=True) != ids
    e1 = InferenceEngine(cfg, params, n_slots=1, max_len=64)
    e2 = InferenceEngine(cfg, params, n_slots=1, max_len=64)
    sched = CarbonAwareScheduler([e1, e2], DirectiveSet(), level_fn=lambda: 1)
    sched.submit(ServeRequest(0, "raw-ids", max_new_tokens=40,
                              prompt_token_ids=ids, directive_level=1))
    sched.step()                       # prefills on e1, still in flight
    assert e1.slots[0] is not None and e1.slots[0].prompt_ids == ids
    assert sched.fail_replica(0) == 1
    assert sched.pending[0].prompt_token_ids == ids
    sched.step()                       # redispatches onto e2
    assert e2.slots[0] is not None and e2.slots[0].prompt_ids == ids
    fin = sched.run()
    assert len(fin) == 1
    assert fin[0].prompt_tokens == len(ids)
    assert fin[0].directive_level == 1


def test_scheduler_per_level_token_budgets(small_model):
    """max_new_by_level: the drawn directive level selects the generation
    budget at dispatch time (the serving-side effect of a brevity
    directive on a model that cannot follow instructions)."""
    cfg, params = small_model
    budgets = [12, 6, 3]
    for lvl in range(3):
        eng = InferenceEngine(cfg, params, n_slots=1, max_len=64, eos_id=-1)
        sched = CarbonAwareScheduler([eng], DirectiveSet(),
                                     level_fn=lambda lvl=lvl: lvl)
        sched.submit(ServeRequest(0, "budget", max_new_by_level=budgets))
        fin = sched.run()
        assert fin[0].directive_level == lvl
        assert fin[0].gen_tokens == budgets[lvl]


def test_engine_attributes_decode_seconds_per_request(small_model):
    """Per-request decode-only telemetry: warm decode blocks charge each
    live slot per executed step; totals reconcile with the engine-level
    decode clock."""
    cfg, params = small_model
    eng = InferenceEngine(cfg, params, n_slots=2, max_len=64, eos_id=-1,
                          decode_block=4)
    tok = ByteTokenizer()
    for i in range(4):
        eng.submit(tok.encode(f"warm {i}"), max_new_tokens=8)
    eng.run_to_completion()            # warm: compiles charge 0.0
    eng.finished = []
    for i in range(4):
        eng.submit(tok.encode(f"timed {i}"), max_new_tokens=8)
    wall = 0.0
    while eng.queue or any(s is not None for s in eng.slots):
        eng.step()
        wall += eng.last_decode_s      # decode-only clock, this dispatch
    fin = eng.finished
    assert len(fin) == 4
    assert all(f.decode_s > 0 for f in fin)
    # partitioned attribution: per-request decode seconds sum to the
    # device's decode wall time (dead tail steps included)
    assert sum(f.decode_s for f in fin) == pytest.approx(wall, rel=1e-6)
    # requests co-occupied every block in equal shares
    assert max(f.decode_s for f in fin) < 10 * min(f.decode_s for f in fin)


def test_scheduler_rejects_unservable_without_losing_others(small_model):
    """A request whose budget no engine can hold is parked in .rejected
    with the reason; the rest of the batch is unaffected."""
    cfg, params = small_model
    eng = InferenceEngine(cfg, params, n_slots=2, max_len=64)
    sched = CarbonAwareScheduler([eng], DirectiveSet())
    sched.submit(ServeRequest(0, "fine", max_new_tokens=6))
    sched.submit(ServeRequest(0, "impossible", max_new_tokens=64))
    sched.submit(ServeRequest(0, "also fine", max_new_tokens=6))
    fin = sched.run()
    assert len(fin) == 2
    assert len(sched.rejected) == 1
    req, reason = sched.rejected[0]
    assert req.max_new_tokens == 64 and "max_new_tokens" in reason


def test_scheduler_elastic_scale_up(small_model):
    cfg, params = small_model
    e1 = InferenceEngine(cfg, params, n_slots=1, max_len=64)
    sched = CarbonAwareScheduler([e1], DirectiveSet())
    for i in range(4):
        sched.submit(ServeRequest(0, f"q{i}", max_new_tokens=4))
    sched.step()
    sched.add_replica(InferenceEngine(cfg, params, n_slots=2, max_len=64))
    fin = sched.run()
    assert len(fin) == 4


def test_paged_cache_alloc_free_cycle():
    pc = PagedKVCache(n_pages=6, page_size=8, n_kv=1, head_dim=4,
                      n_slots=3, max_len=32)
    k = jax.random.normal(jax.random.PRNGKey(0), (20, 1, 4))
    pc.write_prompt(0, k, k)
    assert pc.pages_in_use() == 3
    pc.write_prompt(1, k[:8], k[:8])
    assert pc.pages_in_use() == 4
    gk, _ = pc.gather(0)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(k), rtol=1e-6)
    pc.release(0)
    assert pc.pages_in_use() == 1
    with pytest.raises(MemoryError):
        big = jax.random.normal(jax.random.PRNGKey(1), (33, 1, 4))
        pc.write_prompt(2, big, big)   # > max_len pages available? exhaust
    pc.release(1)


@given(st.text(max_size=60))
def test_tokenizer_roundtrip(s):
    tok = ByteTokenizer()
    assert tok.decode(tok.encode(s)) == s


def test_tokenizer_specials():
    tok = ByteTokenizer()
    ids = tok.encode("<|user|>hi<|end|>")
    assert ids[0] == ByteTokenizer.USR and ids[-1] == ByteTokenizer.END
    assert tok.decode(ids) == "<|user|>hi<|end|>"


def test_sampler_modes():
    key = jax.random.PRNGKey(0)
    logits = jnp.asarray([[0.0, 5.0, 1.0, -2.0]] * 4)
    greedy = sample_logits(logits, key, SamplingParams())
    assert (np.asarray(greedy) == 1).all()
    topk = sample_logits(jnp.tile(logits, (64, 1))[:64], key,
                         SamplingParams(temperature=1.0, top_k=2))
    assert set(np.asarray(topk)) <= {1, 2}
    topp = sample_logits(jnp.tile(logits, (64, 1))[:64], key,
                         SamplingParams(temperature=1.0, top_p=0.6))
    assert set(np.asarray(topp)) <= {1}


def test_sampler_greedy_deterministic():
    logits = jax.random.normal(jax.random.PRNGKey(3), (8, 128))
    outs = [sample_logits(logits, jax.random.PRNGKey(k), SamplingParams())
            for k in range(4)]
    for o in outs[1:]:   # greedy ignores the key entirely
        np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(o))
    np.testing.assert_array_equal(np.asarray(outs[0]),
                                  np.asarray(jnp.argmax(logits, -1)))


def test_sampler_top_k_masks_exactly_k():
    V, k = 64, 5
    logits = jax.random.normal(jax.random.PRNGKey(7), (16, V))
    # draw many samples: only the k largest logits of each row may appear
    draws = np.asarray(jnp.stack([
        sample_logits(logits, jax.random.PRNGKey(s),
                      SamplingParams(temperature=1.0, top_k=k))
        for s in range(200)]))
    top = np.argsort(np.asarray(logits), axis=-1)[:, -k:]
    for row in range(logits.shape[0]):
        seen = set(draws[:, row].tolist())
        assert seen <= set(top[row].tolist())
    # an un-masked token CAN appear given enough draws (k-th largest allowed)
    flat_top_counts = sum(len(set(draws[:, r])) for r in range(16))
    assert flat_top_counts > 16    # more than just the argmax survives


def test_sampler_top_p_smallest_nucleus():
    # construct a row whose nucleus is known exactly
    probs = np.array([0.55, 0.25, 0.12, 0.05, 0.03])
    logits = jnp.asarray(np.log(probs)[None, :].repeat(64, 0))
    # p=0.5: the single largest token already covers it
    d1 = sample_logits(logits, jax.random.PRNGKey(0),
                       SamplingParams(temperature=1.0, top_p=0.5))
    assert set(np.asarray(d1)) == {0}
    # p=0.7: {0.55, 0.25} is the smallest set with mass >= 0.7
    d2 = np.concatenate([np.asarray(sample_logits(
        logits, jax.random.PRNGKey(s),
        SamplingParams(temperature=1.0, top_p=0.7))) for s in range(50)])
    assert set(d2.tolist()) <= {0, 1}
    assert 1 in set(d2.tolist())   # the boundary token stays in the nucleus
    # p=0: degenerate nucleus collapses to the single top token
    d3 = sample_logits(logits, jax.random.PRNGKey(1),
                       SamplingParams(temperature=1.0, top_p=0.0))
    assert set(np.asarray(d3).tolist()) == {0}


def test_sampler_batched_matches_per_slot_loop():
    """The fused per-slot-params path must be token-for-token identical to
    sampling each slot on its own with the slot-folded key (the discipline
    the pre-fusion engine loop used)."""
    key = jax.random.PRNGKey(11)
    B, V = 6, 96
    logits = jax.random.normal(jax.random.PRNGKey(5), (B, V)) * 3.0
    params = [SamplingParams(),                                   # greedy
              SamplingParams(temperature=0.7),
              SamplingParams(temperature=1.3, top_k=10),
              SamplingParams(temperature=0.9, top_p=0.8),
              SamplingParams(temperature=1.1, top_k=7, top_p=0.9),
              SamplingParams()]                                   # greedy
    batched = np.asarray(sample_logits_batched(
        logits, key,
        jnp.asarray([p.temperature for p in params], jnp.float32),
        jnp.asarray([p.top_k for p in params], jnp.int32),
        jnp.asarray([p.top_p for p in params], jnp.float32)))
    for i, p in enumerate(params):
        ref = int(sample_logits(logits[i:i + 1],
                                jax.random.fold_in(key, i), p)[0])
        assert batched[i] == ref, f"slot {i} ({p}) diverged"


def test_sampler_batched_mixed_greedy_and_sampled():
    logits = jnp.asarray([[0.0, 9.0, 1.0, -2.0]] * 4)
    out = np.asarray(sample_logits_batched(
        logits, jax.random.PRNGKey(0),
        jnp.asarray([0.0, 1.0, 0.0, 1.0]),
        jnp.asarray([0, 2, 0, 0], jnp.int32),
        jnp.asarray([1.0, 1.0, 1.0, 0.6])))
    assert out[0] == 1 and out[2] == 1          # greedy rows
    assert out[1] in (1, 2) and out[3] == 1     # top-k=2 / top-p=0.6 rows
