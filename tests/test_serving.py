"""Serving stack: engine, continuous batching, failover, paged cache,
tokenizer round-trips, sampler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.configs import reduced
from repro.core.directives import DirectiveSet
from repro.models import model as MD
from repro.serving import (ByteTokenizer, CarbonAwareScheduler,
                           InferenceEngine, SamplingParams, ServeRequest)
from repro.serving.kv_cache import PagedKVCache
from repro.serving.sampler import sample_logits


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced("granite_3_2b").replace(vocab_size=512)
    params = MD.init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_serves_all_requests(small_model):
    cfg, params = small_model
    eng = InferenceEngine(cfg, params, n_slots=3, max_len=64)
    tok = ByteTokenizer()
    rids = [eng.submit(tok.encode(f"hi {i}"), max_new_tokens=8)
            for i in range(7)]
    fin = eng.run_to_completion()
    assert sorted(f.rid for f in fin) == sorted(rids)
    for f in fin:
        assert 1 <= f.gen_tokens <= 8
        assert f.ttft_s >= 0 and f.latency_s >= f.ttft_s


def test_engine_continuous_batching_overlaps(small_model):
    cfg, params = small_model
    eng = InferenceEngine(cfg, params, n_slots=2, max_len=64)
    tok = ByteTokenizer()
    eng.submit(tok.encode("a"), max_new_tokens=20)
    eng.submit(tok.encode("b"), max_new_tokens=3)
    eng.submit(tok.encode("c"), max_new_tokens=3)
    fin = eng.run_to_completion()
    assert len(fin) == 3   # short requests slot in while the long one runs


def test_engine_deterministic_greedy(small_model):
    cfg, params = small_model
    tok = ByteTokenizer()
    outs = []
    for _ in range(2):
        eng = InferenceEngine(cfg, params, n_slots=1, max_len=64)
        eng.submit(tok.encode("determinism test"), max_new_tokens=10)
        outs.append(tuple(eng.run_to_completion()[0].token_ids))
    assert outs[0] == outs[1]


def test_scheduler_failover_preserves_requests(small_model):
    cfg, params = small_model
    e1 = InferenceEngine(cfg, params, n_slots=2, max_len=64)
    e2 = InferenceEngine(cfg, params, n_slots=2, max_len=64)
    sched = CarbonAwareScheduler([e1, e2], DirectiveSet(), level_fn=lambda: 1)
    for i in range(6):
        sched.submit(ServeRequest(0, f"q{i}", max_new_tokens=6))
    for _ in range(3):
        sched.step()
    requeued = sched.fail_replica(0)
    assert requeued >= 1
    fin = sched.run()
    assert len({f.rid for f in fin}) >= 6    # nothing lost
    assert all(f.directive_level == 1 for f in fin)


def test_scheduler_elastic_scale_up(small_model):
    cfg, params = small_model
    e1 = InferenceEngine(cfg, params, n_slots=1, max_len=64)
    sched = CarbonAwareScheduler([e1], DirectiveSet())
    for i in range(4):
        sched.submit(ServeRequest(0, f"q{i}", max_new_tokens=4))
    sched.step()
    sched.add_replica(InferenceEngine(cfg, params, n_slots=2, max_len=64))
    fin = sched.run()
    assert len(fin) == 4


def test_paged_cache_alloc_free_cycle():
    pc = PagedKVCache(n_pages=6, page_size=8, n_kv=1, head_dim=4,
                      n_slots=3, max_len=32)
    k = jax.random.normal(jax.random.PRNGKey(0), (20, 1, 4))
    pc.write_prompt(0, k, k)
    assert pc.pages_in_use() == 3
    pc.write_prompt(1, k[:8], k[:8])
    assert pc.pages_in_use() == 4
    gk, _ = pc.gather(0)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(k), rtol=1e-6)
    pc.release(0)
    assert pc.pages_in_use() == 1
    with pytest.raises(MemoryError):
        big = jax.random.normal(jax.random.PRNGKey(1), (33, 1, 4))
        pc.write_prompt(2, big, big)   # > max_len pages available? exhaust
    pc.release(1)


@given(st.text(max_size=60))
def test_tokenizer_roundtrip(s):
    tok = ByteTokenizer()
    assert tok.decode(tok.encode(s)) == s


def test_tokenizer_specials():
    tok = ByteTokenizer()
    ids = tok.encode("<|user|>hi<|end|>")
    assert ids[0] == ByteTokenizer.USR and ids[-1] == ByteTokenizer.END
    assert tok.decode(ids) == "<|user|>hi<|end|>"


def test_sampler_modes():
    key = jax.random.PRNGKey(0)
    logits = jnp.asarray([[0.0, 5.0, 1.0, -2.0]] * 4)
    greedy = sample_logits(logits, key, SamplingParams())
    assert (np.asarray(greedy) == 1).all()
    topk = sample_logits(jnp.tile(logits, (64, 1))[:64], key,
                         SamplingParams(temperature=1.0, top_k=2))
    assert set(np.asarray(topk)) <= {1, 2}
    topp = sample_logits(jnp.tile(logits, (64, 1))[:64], key,
                         SamplingParams(temperature=1.0, top_p=0.6))
    assert set(np.asarray(topp)) <= {1}
