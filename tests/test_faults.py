"""Chaos hardening (DESIGN.md §12): deterministic fault injection and the
degraded-mode control plane. Unit layers first (injector, watchdog, grid
client, LP validation, health machine, brownout clamp), then the paired
end-to-end scenario: a fault-free control run and a chaos run sharing the
same wiring must finish with zero stranded work, bit-identical retried
greedy outputs, bounded retries, and a conserved carbon ledger."""
import json
import math
import time
import types

import jax
import numpy as np
import pytest

from repro.configs import reduced
from repro.core import CarbonIntensityProvider, GridSignalClient
from repro.core.carbon import WatchdogProvider
from repro.core.lp import solve_directive_lp
from repro.models import model as MD
from repro.serving import (CarbonAwareScheduler, FaultInjector, FaultPlan,
                           FaultSpec, InferenceEngine, ServeRequest,
                           SproutGateway, no_faults)
import repro.serving.chaos as C


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced("granite_3_2b").replace(vocab_size=512)
    params = MD.init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


# ======================================================================
# FaultInjector: seed-deterministic scripting
# ======================================================================

def test_injector_scripted_occurrences():
    inj = FaultInjector(FaultPlan([
        FaultSpec("lp.fail", "TX", occurrences=(1, 3))]))
    fired = [inj.fire("lp.fail", "TX") for _ in range(5)]
    assert fired == [False, True, False, True, False]
    assert inj.fired("lp.fail") == 2
    # an unrelated target has its own counter: never fires
    assert not any(inj.fire("lp.fail", "CA") for _ in range(5))


def test_injector_wildcard_co_advance():
    """Concrete-target consults advance the wildcard counter too, so "the
    3rd opportunity anywhere" is scriptable across targets."""
    inj = FaultInjector(FaultPlan([
        FaultSpec("replica.crash", "*", occurrences=(2,))]))
    assert not inj.fire("replica.crash", "TX/0")   # any-counter 0
    assert not inj.fire("replica.crash", "CA/1")   # any-counter 1
    assert inj.fire("replica.crash", "TX/1")       # any-counter 2 -> fires


def test_injector_disarmed_consults_do_not_count():
    """Occurrence indices are relative to ARMING: a warmup phase of any
    length must not shift the script."""
    inj = FaultInjector(FaultPlan([
        FaultSpec("carbon.nan", "TX", occurrences=(0,))]))
    inj.armed = False
    assert not any(inj.fire("carbon.nan", "TX") for _ in range(7))
    assert inj.counts == {} and inj.events == []
    inj.armed = True
    assert inj.fire("carbon.nan", "TX")            # first ARMED opportunity


def test_injector_prob_mode_is_seed_deterministic():
    plan = FaultPlan([FaultSpec("decode.nonfinite", "*", occurrences=(),
                                prob=0.5)])
    ia, ib = FaultInjector(plan, seed=9), FaultInjector(plan, seed=9)
    a = [ia.fire("decode.nonfinite", str(r)) for r in range(20)]
    b = [ib.fire("decode.nonfinite", str(r)) for r in range(20)]
    assert a == b and any(a) and not all(a)
    # a different seed draws a different stream
    ic = FaultInjector(plan, seed=10)
    assert [ic.fire("decode.nonfinite", str(r)) for r in range(20)] != a


def test_injector_validation_and_default():
    with pytest.raises(ValueError):
        FaultSpec("not.a.point")
    with pytest.raises(ValueError):
        FaultSpec("lp.fail", prob=1.5)
    clean = no_faults()
    assert clean.armed
    assert not any(clean.fire(p) for p in ("lp.fail", "replica.crash"))


# ======================================================================
# WatchdogProvider: validated carbon feed with graceful degradation
# ======================================================================

def _watchdog(plan=None, **kw):
    inner = CarbonIntensityProvider("TX", "jun")
    inj = FaultInjector(plan) if plan is not None else None
    return WatchdogProvider(inner, fault_injector=inj, **kw), inner


def test_watchdog_nan_payload_serves_last_good():
    wd, inner = _watchdog(FaultPlan([
        FaultSpec("carbon.nan", "TX", occurrences=(1,))]), max_stale_h=3.0)
    v0 = wd.intensity(0.0)                 # clean fetch -> last good
    assert v0 == pytest.approx(inner.intensity(0.0))
    v1 = wd.intensity(1.0)                 # NaN payload -> rejected
    assert v1 == v0 and math.isfinite(v1)
    assert wd.faults["nan"] == 1
    assert not wd.degraded                 # last good is only 1h old


def test_watchdog_staleness_ages_into_degraded():
    wd, _ = _watchdog(FaultPlan([
        FaultSpec("carbon.stale", "TX", occurrences=(1, 2))]),
        max_stale_h=1.5)
    v0 = wd.intensity(0.0)
    assert wd.intensity(1.0) == v0 and not wd.degraded     # age 1.0 <= 1.5
    assert wd.intensity(2.0) == v0 and wd.degraded         # age 2.0 > 1.5
    assert wd.faults["stale"] == 2
    # the feed recovers -> fresh sample clears degraded
    v = wd.intensity(3.0)
    assert math.isfinite(v) and not wd.degraded


def test_watchdog_exception_then_climatology():
    """With no good sample at all, the fallback is the region climatology
    (trace mean) and the provider reports itself degraded."""
    wd, inner = _watchdog(FaultPlan([
        FaultSpec("carbon.exception", "TX", occurrences=(0,))]))
    v = wd.intensity(0.0)
    assert v == pytest.approx(float(np.mean(inner.trace)))
    assert wd.degraded and wd.faults["exception"] == 1


def test_watchdog_forecast_falls_back_to_persistence():
    wd, inner = _watchdog(FaultPlan([
        FaultSpec("carbon.exception", "TX", occurrences=(1,))]))
    v0 = wd.intensity(0.0)                 # clean fetch -> last good
    f = wd.forecast(0.0, 4.0)              # feed raises -> persistence
    assert f.shape == (4,) and np.allclose(f, v0) and not wd.degraded
    good = wd.forecast(1.0, 4.0)           # feed recovers -> real forecast
    assert good.shape == (4,) and np.isfinite(good).all()


# ======================================================================
# GridSignalClient: live-feed client with stubbed transport (no network)
# ======================================================================

def test_grid_client_parses_latest_and_forecast():
    def transport(url, headers, timeout_s):
        assert headers == {"auth-token": "tok"}
        if "latest" in url:
            return json.dumps({"carbonIntensity": 123.5})
        return json.dumps({"forecast": [
            {"carbonIntensity": 100.0}, {"carbonIntensity": 110.0}]})
    cli = GridSignalClient("TX", token="tok", transport=transport,
                           sleep=lambda s: None)
    assert cli.intensity(0.0) == 123.5
    f = cli.forecast(0.0, 4.0)
    # short API horizon persists its last value out to the request
    assert f.tolist() == [100.0, 110.0, 110.0, 110.0]
    assert cli.fetches == 2 and cli.fallbacks == 0 and cli.retries_used == 0


def test_grid_client_bounded_retries_then_trace_fallback():
    calls, sleeps = [], []

    def bad_transport(url, headers, timeout_s):
        calls.append(url)
        raise ConnectionError("injected outage")

    cli = GridSignalClient("TX", token="tok", transport=bad_transport,
                           max_retries=3, backoff_base_s=0.5,
                           backoff_cap_s=1.0, sleep=sleeps.append)
    ref = CarbonIntensityProvider("TX", "jun")
    assert cli.intensity(0.0) == pytest.approx(ref.intensity(0.0))
    assert len(calls) == 4                 # 1 + max_retries, then stop
    assert sleeps == [0.5, 1.0, 1.0]       # capped exponential backoff
    assert cli.retries_used == 3 and cli.fallbacks == 1 and cli.fetches == 0


def test_grid_client_tokenless_is_ci_safe():
    """No token -> no transport is ever built: immediate trace fallback,
    zero sleeps, zero network."""
    cli = GridSignalClient("CA", token="")
    ref = CarbonIntensityProvider("CA", "jun")
    assert cli.intensity(5.0) == pytest.approx(ref.intensity(5.0))
    assert np.allclose(cli.forecast(0.0, 3.0), ref.forecast(0.0, 3.0))
    assert cli.retries_used == 0 and cli.fallbacks == 2


def test_grid_client_rejects_garbage_payloads():
    cli = GridSignalClient("TX", token="t", sleep=lambda s: None,
                           transport=lambda u, h, t:
                           json.dumps({"carbonIntensity": float("nan")}))
    ref = CarbonIntensityProvider("TX", "jun")
    assert cli.intensity(0.0) == pytest.approx(ref.intensity(0.0))
    assert cli.fallbacks == 1
    with pytest.raises(ValueError):
        GridSignalClient("TX", provider="enron")


# ======================================================================
# LP input validation (the plan-hold trigger)
# ======================================================================

def test_lp_rejects_non_finite_inputs():
    e, p, q = [3e-6, 2e-6, 1e-6], [0.2, 0.1, 0.05], [1.0, 0.8, 0.6]
    kw = dict(k1=1e-6, k0_min=100.0, k0_max=500.0, xi=0.25)
    with pytest.raises(ValueError):
        solve_directive_lp(e, p, q, k0=float("nan"), **kw)
    with pytest.raises(ValueError):
        solve_directive_lp([3e-6, float("inf"), 1e-6], p, q, k0=300.0, **kw)
    sol = solve_directive_lp(e, p, q, k0=300.0, **kw)   # finite inputs solve
    assert np.isfinite(sol.x).all()


# ======================================================================
# Brownout clamp: shed toward cheap levels, never through the floor
# ======================================================================

def test_brownout_clamp_respects_quality_floor():
    ns = types.SimpleNamespace(n_levels=3)
    q = np.array([1.0, 0.8, 0.6])
    x = np.array([1.0, 0.0, 0.0])
    out = SproutGateway._brownout_clamp(ns, x, q, 0.7)
    assert float(q @ out) == pytest.approx(0.7)    # clamped exactly to floor
    assert out[2] == pytest.approx(0.75) and abs(out.sum() - 1.0) < 1e-12
    # floor at or below the cheapest level -> pure cheap
    assert np.allclose(SproutGateway._brownout_clamp(ns, x, q, 0.5),
                       [0.0, 0.0, 1.0])
    # mix already at/below the floor -> untouched (clamp never raises q)
    x_cheap = np.array([0.0, 0.0, 1.0])
    assert np.allclose(SproutGateway._brownout_clamp(ns, x_cheap, q, 0.7),
                       x_cheap)


# ======================================================================
# Replica health machine: healthy -> suspect -> dead -> probation
# ======================================================================

def _engine(cfg, params, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 64)
    return InferenceEngine(cfg, params, eos_id=-1, **kw)


def test_health_machine_probation_cycle(small_model):
    cfg, params = small_model
    eng = _engine(cfg, params)
    sched = CarbonAwareScheduler([eng], probation_steps=2, clean_window=3)
    sched._record_fault(0)
    assert sched.health[0].state == "suspect"
    sched._record_fault(0)                         # threshold=2 -> benched
    h = sched.health[0]
    assert h.state == "dead" and h.probations == 1
    assert sched.engines[0] is None and h.engine is eng
    assert sched.has_recoverable_replica()
    sched.step()                                   # cooldown not elapsed
    assert sched.engines[0] is None
    sched.step()                                   # elapsed -> re-admitted
    assert sched.engines[0] is eng
    assert h.state == "suspect"
    assert h.faults == sched.fault_threshold - 1   # one strike from re-bench
    for _ in range(3):                             # clean window -> healthy
        sched.step()
    assert h.state == "healthy" and h.faults == 0 and h.probations == 0


def test_fail_replica_deprecated_permanent(small_model):
    cfg, params = small_model
    eng = _engine(cfg, params)
    sched = CarbonAwareScheduler([eng], probation_steps=1)
    with pytest.warns(DeprecationWarning):
        sched.fail_replica(0)
    h = sched.health[0]
    assert h.state == "dead" and h.permanent and h.engine is None
    assert not sched.has_recoverable_replica()
    for _ in range(4):                             # probation never refills
        sched.step()
    assert sched.engines[0] is None
    sched.add_replica(_engine(cfg, params))        # elastic scale-up may
    assert sched.engines[0] is not None            # reuse the dead slot


def test_retry_backoff_defers_dispatch(small_model):
    cfg, params = small_model
    eng = _engine(cfg, params)
    sched = CarbonAwareScheduler([eng])
    rid = sched.submit(ServeRequest(0, "hold me back", max_new_tokens=4))
    sched._backoff[rid] = sched.steps + 100
    sched._dispatch()
    assert [r.rid for r in sched.pending] == [rid]  # sat out
    assert eng.load() == 0
    del sched._backoff[rid]
    sched._dispatch()
    assert not sched.pending and eng.load() == 1


def test_retry_budget_exhaustion_rejects(small_model):
    """A lane poisoned on every block exhausts its retry budget and parks
    in ``rejected`` with a reason — never a crash loop."""
    cfg, params = small_model
    plan = FaultPlan([FaultSpec("decode.nonfinite", "*", occurrences=(),
                                prob=1.0)])
    eng = _engine(cfg, params, decode_block=4)
    sched = CarbonAwareScheduler([eng], fault_injector=FaultInjector(plan),
                                 retry_budget=1, backoff_base_steps=1,
                                 probation_steps=1, clean_window=4)
    sched.submit(ServeRequest(0, "doomed request", max_new_tokens=16))
    for _ in range(60):
        sched.step()
        if sched.rejected:
            break
    assert len(sched.rejected) == 1
    req, reason = sched.rejected[0]
    assert "retry budget exhausted (1)" in reason
    assert "decode.nonfinite" in reason
    assert req.retries == 2 and not sched.finished and not sched.pending


# ======================================================================
# Mid-chunk-prefill replica failure (chunked + paged admission)
# ======================================================================

def test_replica_failure_mid_chunk_prefill(small_model):
    """A replica dying while a chunk task is mid-prefill must release the
    lane's KV pages AND its admission reservation, and requeue the request
    with its identity (deadline_at, t_submit, verbatim ids) intact."""
    cfg, params = small_model

    def fresh():
        return _engine(cfg, params, decode_block=4, paged=True, page_size=8,
                       prefill_chunk=8)

    eng = fresh()
    sched = CarbonAwareScheduler([eng], probation_steps=2,
                                 backoff_base_steps=1)
    sched.submit(ServeRequest(0, "background lane", max_new_tokens=24))
    sched.step()                       # background live -> chunked admission
    long_prompt = "a long arrival prompt that spans several prefill chunks"
    deadline = time.monotonic() + 3600.0
    rid_b = sched.submit(ServeRequest(0, long_prompt, max_new_tokens=6,
                                      deadline_at=deadline))
    t_submit = next(r.t_submit for r in sched.pending if r.rid == rid_b)
    sched.step()
    task = eng._task
    assert task is not None and task.next < task.plen   # genuinely mid-chunk
    assert eng.pages.pages_in_use() > 0 and eng._committed > 0
    # the ids dispatch actually submitted (directive-rendered): requeue
    # must carry these verbatim, not a lossy re-render
    orig_ids = list(next(s for s in eng.slots
                         if s is not None and s.rid == rid_b).prompt_ids)

    sched._bench(0, fault_reason="replica.crash")       # replica dies

    # the lane's pages and its admission reservation are both released,
    # and the half-fed chunk task dies with its slot
    assert eng.pages.pages_in_use() == 0
    assert eng._committed == 0
    assert eng._task is None
    assert all(s is None for s in eng.slots)
    req = next(r for r in sched.pending if r.rid == rid_b)
    assert req.retries == 1 and req.last_fault == "replica.crash"
    assert req.deadline_at == deadline and req.t_submit == t_submit
    assert req.prompt_token_ids == orig_ids
    assert len(sched.fault_events) == 2        # both in-flight lanes charged

    # probation re-admits the replica and the retried prefill restarts
    # from the verbatim ids: greedy tokens match an undisturbed run
    fins = {f.rid: f for f in sched.run(max_steps=200)}
    assert set(fins) == {1, rid_b} and fins[rid_b].retries == 1
    ref = fresh()
    ref.submit(orig_ids, max_new_tokens=6)
    ref.run_to_completion()
    assert fins[rid_b].token_ids == ref.finished[0].token_ids


# ======================================================================
# End-to-end chaos scenario (paired control vs fault run)
# ======================================================================

def test_chaos_scenario_invariants(small_model):
    cfg, params = small_model
    out = C.run_chaos(cfg, params)
    checks, chaos = out["checks"], out["chaos"]
    for name, ok in checks.items():            # named asserts: readable CI
        assert ok, f"chaos invariant failed: {name}"
    assert out["ok"]
    # every scripted class actually landed, through the genuine mechanisms
    assert {e[0] for e in chaos["injected"]} == set(C.POINTS)
    assert chaos["faults"] >= 3
    assert chaos["plan_holds"] >= 1
    assert chaos["shed"] >= 1
    assert len(out["digest"]) == 64
