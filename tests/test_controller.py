"""End-to-end SPROUT simulation: the paper's headline behaviors."""
import numpy as np
import pytest

from repro.core import SproutSimulation, summarize
from repro.core.directives import DirectiveSet


@pytest.fixture(scope="module")
def week_sim():
    sim = SproutSimulation(region="CA", season="jun", hours=24 * 7, seed=0,
                           requests_per_hour_cap=80,
                           schemes=["BASE", "CO2_OPT", "MODEL_OPT",
                                    "SPROUT_STA", "SPROUT", "SPROUT_TASK",
                                    "ORACLE"])
    stats = sim.run()
    return sim, stats, summarize(stats)


def test_sprout_saves_carbon_with_quality(week_sim):
    _, stats, s = week_sim
    assert s["SPROUT"]["carbon_savings_pct"] > 25.0
    assert s["SPROUT"]["normalized_preference_pct"] > 90.0


def test_co2_opt_sacrifices_quality(week_sim):
    _, _, s = week_sim
    assert s["CO2_OPT"]["carbon_savings_pct"] > s["SPROUT"]["carbon_savings_pct"]
    assert s["CO2_OPT"]["normalized_preference_pct"] < 80.0


def test_model_opt_saves_less_than_sprout(week_sim):
    _, _, s = week_sim
    assert s["MODEL_OPT"]["carbon_savings_pct"] < \
        s["SPROUT"]["carbon_savings_pct"]


def test_static_below_dynamic(week_sim):
    """Over short horizons a lucky static config can edge out dynamic on raw
    savings (the paper's Fig. 10 comparison is month-long); the robust claim
    is that STA cannot dominate BOTH axes."""
    _, _, s = week_sim
    sta, dyn = s["SPROUT_STA"], s["SPROUT"]
    assert not (sta["carbon_savings_pct"] > dyn["carbon_savings_pct"] + 1 and
                sta["normalized_preference_pct"] >
                dyn["normalized_preference_pct"] + 1)


def test_oracle_upper_bounds_savings(week_sim):
    _, _, s = week_sim
    assert s["ORACLE"]["carbon_savings_pct"] >= \
        s["SPROUT"]["carbon_savings_pct"] - 1.0
    assert s["ORACLE"]["normalized_preference_pct"] > 88.0


def test_task_conditioned_beats_paper_sprout(week_sim):
    """Beyond-paper extension dominates the paper policy."""
    _, _, s = week_sim
    assert s["SPROUT_TASK"]["carbon_savings_pct"] > \
        s["SPROUT"]["carbon_savings_pct"] - 1.0
    assert s["SPROUT_TASK"]["normalized_preference_pct"] > 90.0


def test_evaluator_overhead_small(week_sim):
    _, _, s = week_sim
    assert s["SPROUT"]["eval_overhead_pct"] < 1.5   # paper: "well below 1%"


def test_directive_mix_adapts(week_sim):
    sim, stats, _ = week_sim
    mixes = np.stack(stats["SPROUT"].hourly_mix)
    # after warmup the mix is not constant (adaptive, Fig. 12)
    assert mixes[24:].std(axis=0).max() > 0.02


def test_directives_render_as_system_prompt():
    ds = DirectiveSet()
    txt = ds.apply("What is 2+2?", 1)
    assert txt.startswith("<|system|>")
    assert "brief" in txt
    assert txt.endswith("<|assistant|>")
    # existing system prompt is preserved after the directive (Fig. 7)
    txt2 = ds.apply("Q", 2, system_prompt="You are a helpful bot.")
    assert txt2.index("brief") < txt2.index("helpful")
    # L0 adds nothing
    assert ds.apply("Q", 0) == "<|user|>Q<|end|><|assistant|>"
