"""Gateway-side SLOs (DESIGN.md §10): per-tenant quality floors, latency
targets, predicted-completion admission routing, migration SLO pricing,
and the capacity-drain protocol.

The per-tenant LP tests are pure control-plane (no engines). The serving
tests use the tiny reduced model; latency profiles are SEEDED where a
test needs deterministic predicted-completion numbers, so nothing here
depends on wall-clock speed.
"""
import math

import jax
import numpy as np
import pytest

from repro.configs import reduced
from repro.core import (BATCH, DEFAULT_TENANTS, PREMIUM,
                        A100_40GB, CarbonIntensityProvider, EnergyModel,
                        TenantSpec, solve_tenant_lps)
from repro.models import model as MD
from repro.serving import (CarbonAwareScheduler, InferenceEngine,
                           MigrationPlanner, ServeRequest, SproutGateway)


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced("granite_3_2b").replace(vocab_size=512)
    params = MD.init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _provider(trace, region="CA"):
    prov = CarbonIntensityProvider(region, "jun")
    prov.trace = np.asarray(trace, float)
    return prov


def _engine(cfg, params, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 128)
    return InferenceEngine(cfg, params, eos_id=-1, **kw)


def _two_pool_gateway(cfg, params, trace_a, trace_b, **kw):
    pa = _provider(trace_a, "CA")
    pb = _provider(trace_b, "TX")
    kw.setdefault("energy", EnergyModel(A100_40GB))
    return SproutGateway(
        [(pa, CarbonAwareScheduler([_engine(cfg, params)])),
         (pb, CarbonAwareScheduler([_engine(cfg, params)]))], **kw)


def _seed_latency(gw, per_level_s):
    """Install measured per-level decode seconds so predicted-completion
    numbers are deterministic (no real telemetry needed)."""
    for lvl in range(gw.n_levels):
        gw.latency_profiles.update(lvl, 0.0, per_level_s)


# ---------------------------------------------------------------------------
# per-tenant LP solves (core/lp.py)
# ---------------------------------------------------------------------------

E = [1.74e-5, 8.3e-6, 3.8e-6]
P = [0.32, 0.15, 0.06]
Q = np.array([0.45, 0.39, 0.16])


def test_premium_floor_holds_on_dirty_grid():
    """Eq. 3 relaxes the floor as the grid dirties; the premium class's
    absolute floor does not budge, while batch chases carbon."""
    sols = solve_tenant_lps(E, P, DEFAULT_TENANTS, Q, k0=494.0, k1=1e-3,
                            k0_min=55.0, k0_max=494.0)
    q0 = float(Q[0])
    assert sols["premium"].q_lb == pytest.approx(0.97 * q0)
    assert sols["premium"].expected_quality >= 0.97 * q0 - 1e-9
    # looser classes pay less carbon than the premium floor allows
    assert sols["batch"].expected_carbon <= sols["standard"].expected_carbon
    assert sols["standard"].expected_carbon <= sols["premium"].expected_carbon
    assert sols["batch"].q_lb < sols["premium"].q_lb


def test_tenant_lps_are_independent_of_each_other():
    """Dropping one class never changes another's solution (per-tenant
    floors, not one aggregate constraint)."""
    all_three = solve_tenant_lps(E, P, DEFAULT_TENANTS, Q, k0=300.0,
                                 k1=1e-3, k0_min=55.0, k0_max=494.0)
    just_premium = solve_tenant_lps(E, P, [PREMIUM], Q, k0=300.0, k1=1e-3,
                                    k0_min=55.0, k0_max=494.0)
    np.testing.assert_allclose(all_three["premium"].x,
                               just_premium["premium"].x)


def test_task_weighted_quality_vector():
    """A tenant with per-task q vectors solves over the task-weighted mix;
    shifting the live task mix toward the brief-friendly task moves its
    directive mass down-level."""
    q_by_task = {"gsm8k": [0.70, 0.20, 0.10],       # brevity hurts
                 "triviaqa": [0.10, 0.40, 0.50]}    # brevity preferred
    t = TenantSpec("t", xi=0.3, q_by_task=q_by_task)
    q_reasoning = t.effective_q(Q, {"gsm8k": 9.0, "triviaqa": 1.0})
    q_lookup = t.effective_q(Q, {"gsm8k": 1.0, "triviaqa": 9.0})
    np.testing.assert_allclose(
        q_reasoning, 0.9 * np.array(q_by_task["gsm8k"])
        + 0.1 * np.array(q_by_task["triviaqa"]))
    # unknown weights degrade to uniform over the tenant's tasks
    np.testing.assert_allclose(
        t.effective_q(Q, None),
        np.mean([q_by_task["gsm8k"], q_by_task["triviaqa"]], axis=0))
    sol_r = solve_tenant_lps(E, P, [t], Q, k0=300.0, k1=1e-3, k0_min=55.0,
                             k0_max=494.0,
                             task_weights={"gsm8k": 9, "triviaqa": 1})["t"]
    sol_l = solve_tenant_lps(E, P, [t], Q, k0=300.0, k1=1e-3, k0_min=55.0,
                             k0_max=494.0,
                             task_weights={"gsm8k": 1, "triviaqa": 9})["t"]
    assert float(q_lookup @ sol_l.x) >= sol_l.q_lb - 1e-9
    # lookup-heavy mix pushes mass off L0 relative to reasoning-heavy
    assert sol_l.x[0] <= sol_r.x[0] + 1e-9
    assert sol_l.expected_carbon <= sol_r.expected_carbon + 1e-12


def test_deadline_for_targets():
    assert PREMIUM.deadline_for(32) == pytest.approx(0.5 + 0.05 * 32)
    assert math.isinf(BATCH.deadline_for(32))
    assert TenantSpec("x", ttft_s=1.0).deadline_for(10) == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# gateway: stamping, composite level_fn, predicted-completion routing
# ---------------------------------------------------------------------------

def test_gateway_stamps_tenant_priority_and_deadline(small_model):
    cfg, params = small_model
    gw = _two_pool_gateway(cfg, params, [100.0], [400.0],
                           tenants=DEFAULT_TENANTS, load_cap=64)
    prem = ServeRequest(0, "p", max_new_tokens=10, tenant="premium")
    bat = ServeRequest(0, "b", max_new_tokens=10, tenant="batch")
    untagged = ServeRequest(0, "u", max_new_tokens=10)
    for r in (prem, bat, untagged):
        gw.submit(r)
    assert prem.priority == 0 and bat.priority == 2
    assert prem.deadline_s == pytest.approx(PREMIUM.deadline_for(10))
    assert math.isinf(bat.deadline_s)
    # untagged traffic is mapped onto the default (standard) class
    assert untagged.tenant == "standard" and untagged.priority == 1
    # scheduler.submit turned the relative deadline into an absolute one
    assert not math.isinf(prem.deadline_at) and prem.t_submit > 0


def test_composite_level_fn_draws_from_tenant_mix(small_model):
    cfg, params = small_model
    gw = _two_pool_gateway(cfg, params, [100.0], [400.0],
                           tenants=DEFAULT_TENANTS, load_cap=64)
    pool = gw.pools[0]
    pool.x_by_tenant = {"premium": np.array([1.0, 0.0, 0.0]),
                        "standard": np.array([0.0, 1.0, 0.0]),
                        "batch": np.array([0.0, 0.0, 1.0])}
    sched = pool.scheduler
    assert getattr(sched.level_fn, "per_request", False)
    draw = sched._draw_level
    assert draw(ServeRequest(0, "p", tenant="premium")) == 0
    assert draw(ServeRequest(0, "s", tenant="standard")) == 1
    assert draw(ServeRequest(0, "b", tenant="batch")) == 2
    # unknown tenant -> default class mix, not a crash
    assert draw(ServeRequest(0, "u", tenant="nope")) == 1


def test_routing_dirty_but_fast_wins_near_deadline(small_model):
    """The SLO half of admission: predicted completion is PRIORITY-AWARE
    (a premium request waits behind the premium queue, not the batch
    backlog), and a green pool whose relevant queue would bust the
    deadline loses to a dirty idle pool."""
    cfg, params = small_model
    gw = _two_pool_gateway(cfg, params, [80.0], [400.0],
                           tenants=DEFAULT_TENANTS, load_cap=64)
    _seed_latency(gw, 0.1)        # 0.1 s per request, all levels
    # green pool backlog: 6 batch fillers + 2 premium fillers, 2 slots
    for i in range(6):
        gw.pools[0].scheduler.submit(
            ServeRequest(0, f"fill b{i}", max_new_tokens=8,
                         tenant="batch", priority=2))
    for i in range(2):
        gw.pools[0].scheduler.submit(
            ServeRequest(0, f"fill p{i}", max_new_tokens=8,
                         tenant="premium", priority=0))
    # premium waits behind 2 premiums -> 2 waves; batch behind all 8 -> 5
    assert gw.predicted_completion_s(
        gw.pools[0], tenant="premium") == pytest.approx(0.2)
    assert gw.predicted_completion_s(
        gw.pools[0], tenant="batch") == pytest.approx(0.5)
    assert gw.predicted_completion_s(
        gw.pools[1], tenant="premium") == pytest.approx(0.1)
    # priority dispatch keeps the green pool viable for this deadline
    _, key = gw.submit(ServeRequest(0, "urgent-ish", max_new_tokens=8,
                                    tenant="premium", deadline_s=0.3))
    assert key == "CA"
    # tighter deadline: even the premium queue busts it -> dirty-but-fast
    _, key = gw.submit(ServeRequest(0, "urgent", max_new_tokens=8,
                                    tenant="premium", deadline_s=0.15))
    assert key == "TX"
    _, key = gw.submit(ServeRequest(0, "batchy", max_new_tokens=8,
                                    tenant="batch"))
    assert key == "CA"            # no deadline: pure greenness
    # impossible deadline: nobody fits -> fastest pool, not an error
    _, key = gw.submit(ServeRequest(0, "now", max_new_tokens=8,
                                    tenant="premium", deadline_s=1e-6))
    assert key == "TX"
    # once work is dispatched INTO engine queues (FIFO — priority cannot
    # jump there), it counts for every class: the filtered estimate is
    # honest, never optimistic
    gw.pools[0].scheduler._dispatch()
    full = gw.pools[0].load()
    assert gw.pools[0].load(0) == full
    assert gw.predicted_completion_s(
        gw.pools[0], tenant="premium") == pytest.approx(
            0.1 * (1 + full / 2))


def test_priority_dispatch_order(small_model):
    """Premium work never queues behind batch on the same fleet: dispatch
    is priority-ordered (stable within a class)."""
    cfg, params = small_model
    sched = CarbonAwareScheduler([_engine(cfg, params, n_slots=1)])
    r_batch = ServeRequest(0, "b", max_new_tokens=4, priority=2)
    r_std = ServeRequest(0, "s", max_new_tokens=4, priority=1)
    r_prem = ServeRequest(0, "p", max_new_tokens=4, priority=0)
    for r in (r_batch, r_std, r_prem):
        sched.submit(r)
    sched._dispatch()
    eng = sched.engines[0]
    assert [st.rid for st in eng.queue] == [r_prem.rid, r_std.rid,
                                            r_batch.rid]
    assert [st.priority for st in eng.queue] == [0, 1, 2]


def test_slo_attainment_accounting(small_model):
    """Deadline attainment lands in the per-tenant ledgers: a generous
    deadline is met, an impossible one is recorded as missed (the request
    still serves — deadlines steer, they never abort)."""
    cfg, params = small_model
    gw = _two_pool_gateway(cfg, params, [100.0], [400.0],
                           tenants=DEFAULT_TENANTS, load_cap=64)
    gw.run_hour(0.0, [ServeRequest(0, "ok", max_new_tokens=6,
                                   tenant="premium", deadline_s=60.0),
                      ServeRequest(0, "late", max_new_tokens=6,
                                   tenant="batch", deadline_s=1e-9)])
    st = gw.stats
    assert st.requests == 2 and st.rejected == 0
    assert st.tenant_requests == {"premium": 1, "batch": 1}
    assert st.slo_attainment("premium") == 1.0
    assert st.slo_attainment("batch") == 0.0
    assert st.slo_attainment() == pytest.approx(0.5)
    by_tenant = {t.tenant: t for t in st.telemetry}
    assert by_tenant["premium"].slo_met
    assert not by_tenant["batch"].slo_met
    # measured decode seconds flowed into the latency profiles
    assert gw.latency_profiles.counts.sum() == 2


# ---------------------------------------------------------------------------
# migration prices SLO risk
# ---------------------------------------------------------------------------

def test_near_deadline_request_never_migrates(small_model):
    """A decoding request within its migration-redo time of its deadline
    stays put across an intensity crossover; the same request without a
    deadline moves."""
    cfg, params = small_model

    def run(deadline_s):
        gw = _two_pool_gateway(cfg, params, [100.0, 450.0], [450.0, 80.0],
                               migration=MigrationPlanner(slo_margin=2.0),
                               load_cap=64)
        _seed_latency(gw, 0.5)    # redo estimate: 0.5 s at an idle pool
        gw.submit(ServeRequest(0, "r", max_new_tokens=30,
                               deadline_s=deadline_s))
        gw.step()                 # prefill + first decode block
        gw.tick(1.0)              # crossover: CA dirty, TX green
        return gw

    assert run(math.inf).stats.migrated == 1
    # slack (~0.6 s) < slo_margin * redo (2 * 0.5 s): the move is unsafe
    gw = run(0.6)
    assert gw.stats.migrated == 0
    gw.drain()                    # still finishes at the source
    assert gw.stats.requests == 1
    assert gw.stats.telemetry[0].pool == "CA"


# ---------------------------------------------------------------------------
# capacity drain
# ---------------------------------------------------------------------------

def test_drain_pool_empties_with_zero_stranded(small_model):
    """The maintenance protocol: backlog leaves over the verbatim requeue
    path, admission stops routing to the pool, and nothing is stranded or
    rejected."""
    cfg, params = small_model
    gw = _two_pool_gateway(cfg, params, [100.0], [400.0],
                           migration=None, load_cap=64)
    reqs = [ServeRequest(0, f"r {i}", max_new_tokens=8) for i in range(6)]
    for r in reqs:
        _, key = gw.submit(r)
        assert key == "CA"        # green pool takes everything
    gw.step()                     # some decoding, some queued
    served_before = gw.stats.requests   # finished pre-drain, in CA — fine
    moved = gw.drain_pool("CA", deadline=1.0)
    assert moved > 0 and "CA" in gw.draining
    assert gw.pools[0].load() == 0, "drained pool still holds work"
    # admission now avoids the draining pool
    extra = ServeRequest(0, "post-drain", max_new_tokens=8)
    _, key = gw.submit(extra)
    assert key == "TX"
    gw.drain()
    st = gw.stats
    assert st.requests == 7 and st.rejected == 0
    assert all(m.trigger == "drain" for m in st.migrations)
    # everything that finished after the drain began finished elsewhere
    assert {t.pool for t in st.telemetry[served_before:]} == {"TX"}
    # maintenance over: the pool takes traffic again
    gw.undrain_pool("CA")
    _, key = gw.submit(ServeRequest(0, "back", max_new_tokens=8))
    assert key == "CA"
    with pytest.raises(KeyError):
        gw.drain_pool("??")


def test_drain_keeps_near_deadline_decoding_in_place(small_model):
    """Drain is SLO-aware too: a decoding request that cannot be redone
    in time finishes where it is (the pool serves until the maintenance
    deadline), instead of being moved into a miss."""
    cfg, params = small_model
    gw = _two_pool_gateway(cfg, params, [100.0], [400.0],
                           migration=None, load_cap=64)
    _seed_latency(gw, 0.5)
    gw.submit(ServeRequest(0, "urgent", max_new_tokens=30, deadline_s=0.6))
    gw.step()                     # decoding now
    moved = gw.drain_pool("CA")
    assert moved == 0
    gw.drain()
    assert gw.stats.requests == 1 and gw.stats.rejected == 0
    assert gw.stats.telemetry[0].pool == "CA"


# ---------------------------------------------------------------------------
# evict racing a same-tick finish (satellite)
# ---------------------------------------------------------------------------

def test_evict_race_with_finished_request_single_accounting(small_model):
    """A request that completes in the decode block during which the
    planner selected it for migration: the evict comes back None and the
    planner must walk away — one finish OR one migration, never both, and
    the carbon ledger takes exactly the finish."""
    cfg, params = small_model
    gw = _two_pool_gateway(cfg, params, [100.0, 450.0], [450.0, 80.0],
                           migration=MigrationPlanner(), load_cap=64)
    rid, key = gw.submit(ServeRequest(0, "fast finish", max_new_tokens=6))
    assert key == "CA"
    gw.drain()                    # the request finishes this tick
    assert gw.stats.requests == 1
    carbon_after_finish = gw.stats.carbon_g
    # stale planner view: the candidate list still names the finished rid
    # as decoding work (enumeration happened before the block completed)
    from repro.serving.gateway import _Candidate
    stale = _Candidate(rid, "decoding", 0, 6, 3, prompt_len=5)
    src_sched = gw.pools[0].scheduler
    gw.migration._candidates = (
        lambda sched: [stale] if sched is src_sched else [])
    gw.tick(1.0)                  # crossover: the planner WANTS to move it
    st = gw.stats
    assert st.migrated == 0 and st.migrations == []
    assert st.requests == 1, "finish must be accounted exactly once"
    assert st.carbon_g == carbon_after_finish, \
        "no wasted-work charge for a request that was never evicted"
    assert len([t for t in st.telemetry if t.rid == rid]) == 1
    # rid bookkeeping: the rid is gone from every queue in the source pool
    assert src_sched.evict(rid) is None


# ---------------------------------------------------------------------------
# SPROUT_KERNEL_IMPL resolution (satellite: kernels-interpret CI job)
# ---------------------------------------------------------------------------

def test_kernel_impl_env_override(monkeypatch):
    from repro.kernels import ops
    monkeypatch.delenv("SPROUT_KERNEL_IMPL", raising=False)
    assert ops.resolve_impl("auto") == (
        "pallas" if jax.default_backend() == "tpu" else "xla")
    monkeypatch.setenv("SPROUT_KERNEL_IMPL", "pallas_interpret")
    assert ops.resolve_impl("auto") == "pallas_interpret"
    # explicit always beats the env override
    assert ops.resolve_impl("xla") == "xla"
    monkeypatch.setenv("SPROUT_KERNEL_IMPL", "bogus")
    with pytest.raises(ValueError):
        ops.resolve_impl("auto")
