"""Sharding rules (divisibility over all archs), HLO cost parser, roofline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.launch import hlo_cost as HC
from repro.launch import roofline as RL
from repro.launch import sharding as SH
from repro.launch.mesh import abstract_mesh
from repro.launch.shapes import SHAPES, SHAPE_BY_NAME, input_specs, skip_reason
from repro.models import model as MD


def _mesh(multi=False):
    # abstract_mesh: the jax-version compat constructor (launch/mesh.py) --
    # these 26 cases were xfail'd from PR 4 to PR 9 because jax 0.4.x
    # cannot construct AbstractMesh from (axis_sizes, axis_names) directly
    if multi:
        return abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    return abstract_mesh((16, 16), ("data", "model"))


def _axis_size(mesh, axis):
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


@pytest.mark.parametrize("arch", ASSIGNED)
@pytest.mark.parametrize("multi", [False, True])
def test_param_shardings_divide(arch, multi):
    mesh = _mesh(multi)
    cfg = get_config(arch).padded_for_tp(mesh.shape["model"])
    shapes = jax.eval_shape(lambda: MD.init_model(cfg, jax.random.PRNGKey(0)))
    shards = SH.param_shardings(cfg, mesh, shapes)
    n_sharded = 0
    for (path, leaf), sh in zip(jax.tree_util.tree_leaves_with_path(shapes),
                                jax.tree_util.tree_leaves(shards)):
        spec = sh.spec
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 8):
            assert dim % _axis_size(mesh, ax) == 0, \
                f"{jax.tree_util.keystr(path)}: {leaf.shape} vs {spec}"
            if ax is not None:
                n_sharded += 1
    assert n_sharded > 0   # something actually sharded


@pytest.mark.parametrize("arch", ["granite_3_2b", "deepseek_v3_671b",
                                  "hymba_1_5b", "xlstm_1_3b", "whisper_base"])
def test_cache_shardings_divide(arch):
    mesh = _mesh()
    cfg = get_config(arch).padded_for_tp(16)
    cell = SHAPE_BY_NAME["decode_32k"]
    cache = jax.eval_shape(lambda: MD.init_cache(cfg, cell.global_batch, 1024))
    shards = SH.cache_shardings(cfg, mesh, cache)
    for leaf, sh in zip(jax.tree_util.tree_leaves(cache),
                        jax.tree_util.tree_leaves(shards)):
        for dim, ax in zip(leaf.shape, tuple(sh.spec) + (None,) * 8):
            assert dim % _axis_size(mesh, ax) == 0


def test_big_param_fraction_sharded():
    """>= 99% of parameter BYTES must be sharded across >= 16 ways."""
    mesh = _mesh()
    cfg = get_config("command_r_plus_104b").padded_for_tp(16)
    shapes = jax.eval_shape(lambda: MD.init_model(cfg, jax.random.PRNGKey(0)))
    shards = SH.param_shardings(cfg, mesh, shapes)
    tot = shard16 = 0
    for leaf, sh in zip(jax.tree_util.tree_leaves(shapes),
                        jax.tree_util.tree_leaves(shards)):
        b = np.prod(leaf.shape) * 2
        ways = 1
        for ax in sh.spec:
            ways *= _axis_size(mesh, ax)
        tot += b
        if ways >= 16:
            shard16 += b
    assert shard16 / tot > 0.99


def test_skip_matrix():
    assert skip_reason("granite_3_2b", SHAPE_BY_NAME["long_500k"])
    assert skip_reason("hymba_1_5b", SHAPE_BY_NAME["long_500k"]) is None
    assert skip_reason("xlstm_1_3b", SHAPE_BY_NAME["long_500k"]) is None
    assert skip_reason("whisper_base", SHAPE_BY_NAME["decode_32k"]) is None
    n_cells = len(ASSIGNED) * len(SHAPES)
    n_skipped = sum(1 for a in ASSIGNED for s in SHAPES if skip_reason(a, s))
    assert n_cells == 40 and n_skipped == 8


def test_input_specs_cover_all_runnable_cells():
    for arch in ASSIGNED:
        for cell in SHAPES:
            if skip_reason(arch, cell):
                continue
            cfg = get_config(arch).padded_for_tp(16)
            specs = input_specs(cfg, cell)
            assert specs, (arch, cell.name)


# ----------------------------------------------------------------------
# HLO cost parser
# ----------------------------------------------------------------------

def test_hlo_cost_counts_scan_trips():
    def body(x, w):
        return jnp.tanh(x @ w), None
    w = jnp.zeros((8, 128, 128), jnp.float32)
    x = jnp.ones((4, 128), jnp.float32)

    def f(x, w):
        y, _ = jax.lax.scan(body, x, w)
        return jnp.sum(y)

    text = jax.jit(f).lower(x, w).compile().as_text()
    r = HC.analyze(text)
    want = 8 * 2 * 4 * 128 * 128
    assert want * 0.95 <= r.flops <= want * 1.3
    assert any(m >= 8 for m in r.loop_info.values())


def test_hlo_cost_inplace_dus_not_inflated():
    def f(buf, xs):
        def body(b, i):
            return jax.lax.dynamic_update_slice_in_dim(
                b, xs[i][None], i * 4, 0), None
        out, _ = jax.lax.scan(body, buf, jnp.arange(8))
        return out

    buf = jnp.zeros((32, 1024), jnp.float32)
    xs = jnp.ones((8, 1024), jnp.float32)
    text = jax.jit(f).lower(buf, xs).compile().as_text()
    r = HC.analyze(text)
    # in-place updates: traffic ~ slices (8 x 4KB x few), NOT 8 x 128KB
    assert r.bytes < 8 * buf.nbytes * 0.5


def test_roofline_report_fields():
    rep = RL.RooflineReport(
        arch="a", shape="train_4k", mesh="single", chips=256,
        flops_per_dev=1e12, bytes_per_dev=1e11, wire_bytes_per_dev=1e10,
        compute_s=1e12 / RL.PEAK_FLOPS, memory_s=1e11 / RL.HBM_BW,
        collective_s=1e10 / RL.ICI_BW, model_flops_total=2e14,
        collectives={"all-reduce": 3})
    assert rep.dominant == "collective"
    assert 0 < rep.useful_ratio < 1
    assert 0 < rep.roofline_fraction <= 1


def test_model_flops_moe_uses_active_params():
    dense = RL.model_flops(get_config("granite_3_2b"),
                           SHAPE_BY_NAME["train_4k"])
    total, active = RL.model_param_counts(get_config("deepseek_v3_671b"))
    assert active < 0.15 * total      # 671B total, 37B-ish active
    moe = RL.model_flops(get_config("deepseek_v3_671b"),
                         SHAPE_BY_NAME["train_4k"])
    assert moe < 6 * total * 256 * 4096 * 0.2
    assert dense > 0


def test_collective_parse_ring_model():
    text = """
ENTRY %main (p: f32[16,1024]) -> f32[16,1024] {
  %p = f32[16,1024]{1,0} parameter(0)
  ROOT %all-reduce.1 = f32[16,1024]{1,0} all-reduce(%p), replica_groups={{0,1,2,3}}, to_apply=%add
}
"""
    st = RL.parse_collectives(text)
    assert st.counts == {"all-reduce": 1}
    want = 2 * (3 / 4) * 16 * 1024 * 4
    assert st.wire_bytes == pytest.approx(want)
