"""Forecast-aware re-planning + cross-region migration (DESIGN.md §8).

Covers the new control-plane layer end to end: the provider forecast
interface, the forecast-weighted LP re-plan, the MigrationPlanner's
decision rule (hysteresis band, cooldown, redo economics), and the
mechanics the planner rides on — ``engine.evict`` releasing slots and KV
pages, and the verbatim-token requeue path preserving generated output
across a migration.
"""
import jax
import numpy as np
import pytest

from repro.configs import reduced
from repro.core import A100_40GB, CarbonIntensityProvider, EnergyModel
from repro.core.lp import forecast_weighted_intensity
from repro.core.policies import SproutPolicy
from repro.models import model as MD
from repro.serving import (ByteTokenizer, CarbonAwareScheduler,
                           InferenceEngine, MigrationPlanner, ServeRequest,
                           SproutGateway)


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced("granite_3_2b").replace(vocab_size=512)
    params = MD.init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _provider(trace):
    prov = CarbonIntensityProvider("CA", "jun")
    prov.trace = np.asarray(trace, float)
    return prov


def _engine(cfg, params, **kw):
    # eos_id=-1: budget-bound decoding on the tiny random model, so token
    # telemetry is deterministic and restart-identical under greedy sampling
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 128)
    return InferenceEngine(cfg, params, eos_id=-1, **kw)


# ---------------------------------------------------------------------------
# forecast interface + weighting
# ---------------------------------------------------------------------------

def test_forecast_matches_trace_window():
    prov = _provider([100.0, 200.0, 300.0, 400.0])
    np.testing.assert_array_equal(prov.forecast(0.0, 3), [100, 200, 300])
    # horizon covers the hour containing t, then wraps like intensity()
    np.testing.assert_array_equal(prov.forecast(2.7, 3), [300, 400, 100])
    # degenerate horizon degrades to the instantaneous signal
    assert prov.forecast(1.2, 0)[0] == prov.intensity(1.2)


def test_forecast_weighted_intensity():
    w = [100.0, 400.0, 400.0]
    assert forecast_weighted_intensity(w, decay=1.0) == pytest.approx(300.0)
    # geometric decay: the current hour dominates but the dirty hours pull
    eff = forecast_weighted_intensity(w, decay=0.5)
    assert 100.0 < eff < 300.0
    assert forecast_weighted_intensity(w, decay=1e-9) == pytest.approx(
        100.0, rel=1e-6)
    with pytest.raises(ValueError):
        forecast_weighted_intensity(w, decay=0.0)
    with pytest.raises(ValueError):
        forecast_weighted_intensity(w, decay=1.5)


def test_replan_shifts_mix_preemptively_on_dirty_forecast():
    """A green hour with a dirty window ahead: the instantaneous planner
    stays pure L0; the forecast-aware planner pre-emptively moves mass to
    cheaper levels (the whole point of solving over the window)."""
    def gateway(horizon):
        prov = _provider([50.0, 500.0, 500.0])   # hour 0 at k0_min
        # k bounds span the synthetic trace so Eq. 3 has room to relax
        pol = SproutPolicy(k0_min=50.0, k0_max=500.0, xi=0.25,
                           k1=A100_40GB.embodied_gco2 / A100_40GB.lifetime_s,
                           explore=0.0)
        gw = SproutGateway([(prov, CarbonAwareScheduler([]))], policy=pol,
                           q=np.array([0.50, 0.33, 0.17]),
                           forecast_horizon=horizon, forecast_decay=1.0)
        gw.profiles.e[:] = [4e-6, 2e-6, 1e-6]
        gw.profiles.p[:] = [0.2, 0.1, 0.05]
        gw.profiles.counts[:] = 5
        gw.tick(0.0)
        return gw

    instant = gateway(0.0)
    ahead = gateway(3.0)
    # planning intensity: instantaneous vs the window mean (decay=1)
    assert instant.stats.plans[-1].k0 == pytest.approx(50.0)
    assert ahead.stats.plans[-1].k0 == pytest.approx((50 + 500 + 500) / 3)
    assert ahead.stats.plans[-1].k0_now == pytest.approx(50.0)
    # green-now planner pins L0; dirty-window planner shifts pre-emptively
    assert instant.pools[0].x[0] > 0.99
    assert ahead.pools[0].x[1:].sum() > 0.2


# ---------------------------------------------------------------------------
# eviction mechanics
# ---------------------------------------------------------------------------

def test_evict_returns_every_page(small_model):
    cfg, params = small_model
    eng = _engine(cfg, params, paged=True, page_size=16, n_slots=2)
    before = eng.kv_stats()
    assert before["pages_in_use"] == 0 and before["committed_pages"] == 0
    tok = ByteTokenizer()
    rid = eng.submit(tok.encode("migrate me " * 4), max_new_tokens=24)
    eng.step()                       # prefilled into a slot, pages mapped
    assert eng.kv_stats()["pages_in_use"] > 0
    st = eng.evict(rid)
    assert st is not None and st.slot == -1
    after = eng.kv_stats()
    assert after["pages_in_use"] == before["pages_in_use"]
    assert after["committed_pages"] == before["committed_pages"]
    assert after["live_tokens"] == 0
    assert eng.evict(rid) is None    # already gone
    assert eng.evict(424242) is None


def test_evict_from_engine_queue(small_model):
    cfg, params = small_model
    eng = _engine(cfg, params)
    tok = ByteTokenizer()
    rid = eng.submit(tok.encode("queued"), max_new_tokens=4)
    assert len(eng.queue) == 1
    st = eng.evict(rid)
    assert st is not None and st.rid == rid and not eng.queue


def test_scheduler_evict_covers_pending_and_rejected(small_model):
    cfg, params = small_model
    sched = CarbonAwareScheduler([_engine(cfg, params)])
    rid = sched.submit(ServeRequest(0, "still pending", max_new_tokens=4))
    req = sched.evict(rid)
    assert req is not None and req.rid == rid and not sched.pending
    parked = ServeRequest(777, "parked", max_new_tokens=4)
    sched.rejected.append((parked, "no capacity"))
    assert sched.evict(777) is parked and not sched.rejected
    assert sched.evict(999999) is None


# ---------------------------------------------------------------------------
# the planner
# ---------------------------------------------------------------------------

def _two_pool_gateway(cfg, params, trace_a, trace_b, *, planner, **kw):
    pa, pb = _provider(trace_a), _provider(trace_b)
    pb.region = CarbonIntensityProvider("TX", "jun").region  # distinct key
    gw = SproutGateway(
        [(pa, CarbonAwareScheduler([_engine(cfg, params)])),
         (pb, CarbonAwareScheduler([_engine(cfg, params)]))],
        policy=None, energy=EnergyModel(A100_40GB), migration=planner,
        **kw)
    return gw


def test_migration_moves_queued_backlog_to_green_pool(small_model):
    """Intensity crossover with backlog in flight: work queued in the
    now-dirty pool migrates to the now-green one at the re-plan tick and
    finishes there."""
    cfg, params = small_model
    gw = _two_pool_gateway(cfg, params, [100.0, 450.0], [450.0, 80.0],
                           planner=MigrationPlanner(), load_cap=64)
    reqs = [ServeRequest(0, f"xover {i}", max_new_tokens=12)
            for i in range(8)]
    s0 = gw.run_hour(0.0, reqs, steps=1)   # partial service: backlog rides
    assert s0["routes"]["CA"] == 8         # hour 0: CA green, all go there
    assert s0["migrated"] == 0
    backlog = gw.pools[0].load()
    assert backlog > 0
    s1 = gw.run_hour(1.0, [])              # crossover: CA dirty, TX green
    assert s1["migrated"] > 0
    assert gw.stats.requests == 8 and gw.stats.rejected == 0
    for rec in gw.stats.migrations:
        assert rec.src == "CA" and rec.dst == "TX"
        assert rec.est_saving_g > 0
        assert rec.kind in ("pending", "rejected", "queued", "decoding")
    # migrated work really finished in TX: its pool served the tail
    assert gw.pools[1].scheduler.finished == []   # harvested by gateway
    assert gw.stats.telemetry[-1].pool == "TX"


def test_hysteresis_band_blocks_small_crossings(small_model):
    """Oscillation smaller than the hysteresis band: zero migrations."""
    cfg, params = small_model
    gw = _two_pool_gateway(
        cfg, params, [300.0, 260.0, 300.0, 260.0], [260.0, 300.0, 260.0,
                                                    300.0],
        planner=MigrationPlanner(hysteresis=0.2, cooldown_h=0.0),
        load_cap=64)
    gw.pools[0].scheduler.submit(ServeRequest(0, "parked", max_new_tokens=8))
    for t in range(4):
        gw.tick(float(t))                  # re-plan + migration pass only
    assert gw.stats.migrated == 0


def test_cooldown_bounds_ping_pong_on_large_oscillation(small_model):
    """When the swing exceeds the band, the per-request cooldown still
    bounds moves: one migration, then the request stays put even though
    the gap reverses every hour."""
    cfg, params = small_model
    gw = _two_pool_gateway(
        cfg, params, [400.0, 100.0, 400.0, 100.0], [100.0, 400.0, 100.0,
                                                    400.0],
        planner=MigrationPlanner(hysteresis=0.15, cooldown_h=10.0),
        load_cap=64)
    gw.pools[0].scheduler.submit(ServeRequest(0, "parked", max_new_tokens=8))
    for t in range(4):
        gw.tick(float(t))
    assert gw.stats.migrated == 1
    assert gw.stats.migrations[0].t == 0.0


def test_migration_respects_destination_load_cap(small_model):
    cfg, params = small_model
    gw = _two_pool_gateway(cfg, params, [450.0, 450.0], [450.0, 80.0],
                           planner=MigrationPlanner(), load_cap=2)
    for i in range(6):
        gw.pools[0].scheduler.submit(
            ServeRequest(0, f"capped {i}", max_new_tokens=8))
    gw.tick(1.0)
    # destination had 0 in flight and a cap of 2: at most 2 moved
    assert gw.stats.migrated == 2
    assert gw.pools[1].load() == 2


def test_pool_rid_spaces_are_disjoint(small_model):
    """Migration preserves rids across pools, so each pool's scheduler
    draws from a disjoint range — a migrated rid can never collide with a
    destination-native one (evict-by-rid pops exactly one request)."""
    cfg, params = small_model
    gw = _two_pool_gateway(cfg, params, [100.0], [200.0],
                           planner=MigrationPlanner())
    r0 = gw.pools[0].scheduler.submit(ServeRequest(0, "a", max_new_tokens=4))
    r1 = gw.pools[1].scheduler.submit(ServeRequest(0, "b", max_new_tokens=4))
    assert r0 != r1
    assert r1 == SproutGateway.RID_STRIDE + 1


def test_routing_uses_planning_intensity(small_model):
    """With a forecast horizon, admission routes by the same forecast-
    weighted signal the planner migrates against — an instantaneously
    green but forecast-dirty pool stops attracting work the next tick
    would immediately pull back out."""
    cfg, params = small_model
    gw = _two_pool_gateway(cfg, params, [80.0, 500.0, 500.0],
                           [200.0, 100.0, 100.0],
                           planner=None, forecast_horizon=3.0,
                           forecast_decay=1.0, load_cap=64)
    gw.tick(0.0)
    # instantaneous would pick CA (80 < 200); the window mean picks TX
    _, key = gw.submit(ServeRequest(0, "r", max_new_tokens=4))
    assert key == "TX"


def test_migration_skips_pools_that_cannot_serve(small_model):
    """Heterogeneous fleet: the green pool's engines cannot hold the
    request's budget, so the planner leaves it where it is instead of
    stranding it as rejected at the destination."""
    cfg, params = small_model
    pa, pb = _provider([100.0, 450.0]), _provider([450.0, 80.0])
    pb.region = CarbonIntensityProvider("TX", "jun").region
    gw = SproutGateway(
        [(pa, CarbonAwareScheduler([_engine(cfg, params)])),
         (pb, CarbonAwareScheduler([_engine(cfg, params, max_len=16)]))],
        policy=None, energy=EnergyModel(A100_40GB),
        migration=MigrationPlanner(), load_cap=64)
    gw.run_hour(0.0, [ServeRequest(0, f"big {i}", max_new_tokens=20)
                      for i in range(4)], steps=1)
    gw.run_hour(1.0, [])                  # crossover, but TX can't hold 20
    assert gw.stats.migrated == 0
    assert gw.stats.requests == 4 and gw.stats.rejected == 0
    assert all(rec.pool == "CA" for rec in gw.stats.telemetry)


def test_decoding_eviction_charges_wasted_work(small_model):
    """Evicting a decoding request discards its prefill + partial decode;
    that work is charged to the source pool at eviction time, so realized
    carbon never flatters migration with free restarts."""
    cfg, params = small_model
    gw = _two_pool_gateway(cfg, params, [100.0, 450.0], [450.0, 80.0],
                           planner=MigrationPlanner(), load_cap=64)
    rid, key = gw.submit(ServeRequest(0, "decode then move",
                                      max_new_tokens=30))
    assert key == "CA"
    gw.step()                             # prefill + first decode block
    assert gw.stats.requests == 0 and gw.stats.carbon_g == 0.0
    gw.tick(1.0)                          # crossover -> decoding eviction
    assert gw.stats.migrated == 1
    assert gw.stats.migrations[0].kind == "decoding"
    # wasted work charged with NO finished request
    assert gw.stats.requests == 0
    assert gw.stats.carbon_g > 0
    wasted = gw.stats.carbon_g
    gw.drain()
    assert gw.stats.requests == 1
    assert gw.stats.carbon_g > wasted     # finish adds the real serve cost


def test_migrated_request_resumes_identical_with_page_reservation(
        small_model):
    """A DECODING request is evicted mid-generation and migrated: the
    destination re-reserves exactly its worst-case pages, and the finished
    token ids match an undisturbed run bit-for-bit (verbatim prompt ids +
    greedy decoding => restart-identical output)."""
    cfg, params = small_model
    tok = ByteTokenizer()
    prompt = tok.encode("crossover request, long enough to span pages "
                        "and keep decoding", bos=True)
    max_new = 20

    # reference: the same request served start-to-finish on one engine
    ref = _engine(cfg, params, paged=True, page_size=16)
    ref.submit(list(prompt), max_new_tokens=max_new)
    ref_fin = ref.run_to_completion()[0]

    def paged_pool(trace_a, trace_b):
        pa, pb = _provider(trace_a), _provider(trace_b)
        pb.region = CarbonIntensityProvider("TX", "jun").region
        mk = lambda: _engine(cfg, params, paged=True, page_size=16)
        return SproutGateway(
            [(pa, CarbonAwareScheduler([mk()])),
             (pb, CarbonAwareScheduler([mk()]))],
            policy=None, energy=EnergyModel(A100_40GB),
            migration=MigrationPlanner(min_saving_g=0.0), load_cap=64)

    gw = paged_pool([100.0, 450.0], [450.0, 80.0])
    rid, key = gw.submit(ServeRequest(0, "ignored", max_new_tokens=max_new,
                                      prompt_token_ids=list(prompt),
                                      pre_rendered=True))
    assert key == "CA"
    gw.step()                              # prefill + first decode block
    src_eng = gw.pools[0].scheduler.engines[0]
    assert any(s is not None and s.rid == rid for s in src_eng.slots)
    gw.tick(1.0)                           # crossover -> evict + migrate
    assert gw.stats.migrated == 1
    assert gw.stats.migrations[0].kind == "decoding"
    # source engine released everything
    assert src_eng.kv_stats()["pages_in_use"] == 0
    assert src_eng.kv_stats()["committed_pages"] == 0
    # destination reserves exactly the request's worst-case pages
    dst_eng = gw.pools[1].scheduler.engines[0]
    gw.pools[1].scheduler.step()
    assert dst_eng._committed == dst_eng._pages_for(len(prompt), max_new)
    gw.drain()
    assert gw.stats.requests == 1
    fin = gw.stats.telemetry[0]
    assert fin.pool == "TX" and fin.rid == rid
    # same generation length as the undisturbed run (exact token identity
    # is pinned by test_migrated_tokens_bit_identical, which keeps the
    # FinishedRequest in hand)
    assert fin.gen_tokens == ref_fin.gen_tokens


def test_migrated_tokens_bit_identical(small_model):
    """Scheduler-level view of the same property, with the finished
    outputs in hand: evict a decoding request, resubmit it to a second
    pool's scheduler, and the finished token ids equal the undisturbed
    run's exactly."""
    cfg, params = small_model
    tok = ByteTokenizer()
    prompt = tok.encode("deterministic restart check", bos=True)
    ref = _engine(cfg, params, paged=True, page_size=16)
    ref.submit(list(prompt), max_new_tokens=16)
    want = ref.run_to_completion()[0].token_ids

    src = CarbonAwareScheduler([_engine(cfg, params, paged=True,
                                        page_size=16)])
    dst = CarbonAwareScheduler([_engine(cfg, params, paged=True,
                                        page_size=16)])
    rid = src.submit(ServeRequest(0, "x", max_new_tokens=16,
                                  prompt_token_ids=list(prompt),
                                  pre_rendered=True))
    src.step()                             # decoding began at the source
    req = src.evict(rid)
    assert req is not None
    assert req.prompt_token_ids == list(prompt)   # verbatim, not re-encoded
    dst.submit(req)
    fins = dst.run()
    assert len(fins) == 1 and fins[0].rid == rid
    assert fins[0].token_ids == want
