"""LP optimizer (Eq. 2–7): HiGHS vs exact fallback cross-check + invariants,
and the static-sweep baseline against the LP at several level counts."""
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.lp import quality_lower_bound, solve_directive_lp
from repro.core.policies import SproutStaticPolicy

K = dict(k0=300.0, k1=1e-3, k0_min=50.0, k0_max=500.0, xi=0.1)


def test_basic_solution_valid():
    e = [1.0, 0.5, 0.2]
    p = [1.0, 0.5, 0.2]
    q = [0.45, 0.35, 0.20]
    sol = solve_directive_lp(e, p, q, **K)
    assert sol.feasible
    assert abs(sol.x.sum() - 1) < 1e-9
    assert (sol.x >= -1e-12).all()
    assert sol.expected_quality >= sol.q_lb - 1e-9


def test_low_intensity_prefers_quality():
    e = [1.0, 0.5, 0.2]
    p = [1.0, 0.5, 0.2]
    q = [0.5, 0.3, 0.2]
    lo = solve_directive_lp(e, p, q, **dict(K, k0=50.0))
    hi = solve_directive_lp(e, p, q, **dict(K, k0=500.0))
    # at min intensity the constraint pins quality to q0 -> pure L0
    assert lo.x[0] > 0.99
    # at max intensity the floor relaxes by xi -> lower-ENERGY mix
    assert float(np.dot(e, hi.x)) <= float(np.dot(e, lo.x)) + 1e-12
    assert hi.x[0] < lo.x[0]


def test_quality_lower_bound_endpoints():
    assert quality_lower_bound(0.5, 50, 50, 500, 0.1) == pytest.approx(0.5)
    assert quality_lower_bound(0.5, 500, 50, 500, 0.1) == pytest.approx(0.45)
    # clamped outside historical range
    assert quality_lower_bound(0.5, 1000, 50, 500, 0.1) == pytest.approx(0.45)


def test_infeasible_falls_back_to_best_quality():
    # floor above max achievable quality: report infeasible, pick best level
    e = [1.0, 0.5, 0.2]
    p = e
    q = [0.2, 0.5, 0.3]  # q0 small but floor relative to q0 -> feasible;
    sol = solve_directive_lp(e, p, q, **K)
    assert sol.feasible  # L1 dominates: cheaper AND higher-preference


@given(st.lists(st.floats(0.05, 2.0), min_size=3, max_size=3),
       st.lists(st.floats(0.0, 1.0), min_size=3, max_size=3),
       st.floats(50.0, 500.0))
def test_highs_matches_exact_fallback(e, qraw, k0):
    q = np.asarray(qraw) + 1e-3
    q = q / q.sum()
    p = [x * 0.5 for x in e]
    s1 = solve_directive_lp(e, p, q, **dict(K, k0=k0), solver="highs")
    s2 = solve_directive_lp(e, p, q, **dict(K, k0=k0), solver="fallback")
    assert s1.feasible == s2.feasible
    if s1.feasible:
        assert s1.expected_carbon == pytest.approx(s2.expected_carbon,
                                                   rel=1e-6, abs=1e-9)
        assert s1.expected_quality >= s1.q_lb - 1e-7


@pytest.mark.parametrize("e,q", [
    # N=2
    ([1.0, 0.35], [0.62, 0.38]),
    # N=3 (the paper's default)
    ([1.0, 0.5, 0.2], [0.45, 0.39, 0.16]),
    # N=4
    ([1.0, 0.6, 0.35, 0.15], [0.40, 0.30, 0.20, 0.10]),
])
def test_static_sweep_matches_lp_any_level_count(e, q):
    """Regression: sweep() hardcoded a 3-level simplex walk. For every N it
    must land within grid resolution of the LP optimum of the same problem
    (k1=0 makes both objectives proportional to eᵀx)."""
    e, q = np.asarray(e, float), np.asarray(q, float)
    step = 0.02
    kw = dict(k0_min=50.0, k0_max=500.0, xi=0.1)
    pol = SproutStaticPolicy.sweep(e, q, k0_avg=300.0, step=step, **kw)
    assert pol.x.shape == e.shape
    assert pol.x.sum() == pytest.approx(1.0)
    q_lb = quality_lower_bound(q[0], 300.0, 50.0, 500.0, 0.1)
    assert float(q @ pol.x) >= q_lb - 1e-9          # feasible
    sol = solve_directive_lp(e, np.zeros_like(e), q, k0=300.0, k1=0.0, **kw)
    # optimal within the grid's resolution of the true LP vertex
    tol = 2 * step * (e.max() - e.min())
    assert float(e @ pol.x) <= float(e @ sol.x) + tol + 1e-9
    assert float(e @ pol.x) >= float(e @ sol.x) - 1e-9   # LP is the optimum
    # assignment draws from the same N-level simplex (regression: assign
    # hardcoded a 3-level choice)
    rng = np.random.default_rng(0)
    draws = {pol.assign(None, rng)[1] for _ in range(50)}
    assert draws <= set(range(len(e)))


@given(st.floats(50.0, 500.0), st.floats(50.0, 500.0))
def test_energy_mix_monotone_in_intensity(k0a, k0b):
    """Higher carbon intensity relaxes the quality floor (Eq. 3), so the
    chosen mix's ENERGY eᵀx is non-increasing in k0."""
    e = np.array([1.0, 0.5, 0.2])
    p = [1.0, 0.5, 0.2]
    q = [0.45, 0.35, 0.20]
    lo, hi = sorted((k0a, k0b))
    s_lo = solve_directive_lp(e, p, q, **dict(K, k0=lo, k1=0.0))
    s_hi = solve_directive_lp(e, p, q, **dict(K, k0=hi, k1=0.0))
    assert float(e @ s_hi.x) <= float(e @ s_lo.x) + 1e-9
