"""Opportunistic invoker (Eq. 8, Fig. 6) and the 500-sample evaluator."""
import math

import numpy as np
import pytest

from repro.core.invoker import EvaluationInvoker
from repro.core.quality import QualityEvaluator
from repro.core.workload import N_LEVELS, Workload


def test_urgency_decay_halves_after_24h():
    inv = EvaluationInvoker(beta=0.028, k_hist_max=500)
    inv.last_eval_t = 0.0
    assert inv.urgency_adjusted(24.0, 100.0) == pytest.approx(
        100.0 * math.exp(-0.028 * 24), rel=1e-6)
    assert inv.urgency_adjusted(24.0, 100.0) < 52.0


def test_grace_period_blocks_early_eval():
    inv = EvaluationInvoker(grace_hours=12, k_hist_max=500)
    inv.fire(0.0)
    # deep local minimum right after an evaluation: still blocked
    for t, k in [(1, 400), (2, 100), (3, 400), (4, 400)]:
        assert not inv.observe(float(t), float(k))


def test_local_minimum_below_threshold_fires():
    inv = EvaluationInvoker(grace_hours=2, threshold_frac=0.5, k_hist_max=500)
    fired = []
    trace = [400, 380, 300, 150, 220, 300]   # min at t=3 (150 < 250 thresh)
    for t, k in enumerate(trace):
        if inv.observe(float(t), float(k)):
            fired.append(t)
    assert fired == [4]   # detected causally one sample after the minimum


def test_high_intensity_eventually_fires_fig6b():
    """Even under persistently high carbon intensity, urgency decay forces
    an evaluation (paper Fig. 6b)."""
    inv = EvaluationInvoker(grace_hours=6, threshold_frac=0.5, k_hist_max=500)
    inv.fire(0.0)
    rng = np.random.default_rng(0)
    fired_at = None
    for t in range(1, 200):
        # persistently high (420-540) with realistic diurnal swing
        k = 480 + 60 * math.sin(2 * math.pi * t / 24.0) + rng.normal(0, 5)
        if inv.observe(float(t), float(k)):
            fired_at = t
            break
    assert fired_at is not None and fired_at < 72


def test_evaluator_recovers_true_preferences():
    w = Workload(seed=7)
    pool = [w.sample_request(i * 0.01) for i in range(3000)]
    ev = QualityEvaluator(sample_size=500, seed=3)
    rep = ev.evaluate(pool)
    # ground truth preference rates from the latent model
    truth = np.zeros(N_LEVELS)
    for r in pool:
        truth[r.preferred] += 1
    truth = truth / truth.sum()
    # 500 samples -> max margin of error 4.4% at 95% conf (paper §III-D)
    assert np.abs(rep.q - truth).max() < 0.06
    assert rep.n_samples == 500
    assert rep.judge_tokens_generated <= 3 * 500   # minimal-token replies
    assert rep.q_by_task and set(rep.q_by_task) <= {r.task for r in pool}


def test_evaluator_energy_accounting():
    w = Workload(seed=9)
    pool = [w.sample_request(i * 0.1) for i in range(600)]
    ev = QualityEvaluator(sample_size=100,
                          regen_energy_fn=lambda r, l: 1e-5)
    rep = ev.evaluate(pool)
    assert rep.eval_energy_kwh == pytest.approx(100 * 2000.0 / 3.6e6)
    assert rep.regen_energy_kwh == pytest.approx(100 * 3 * 1e-5)
