"""Tensor-parallel serving equivalence (DESIGN.md §14).

A tp-sharded engine must be a pure *placement* change: the fused decode
programs are unchanged SPMD, so tp=1 and tp>1 must emit token-identical
streams — greedy and seeded-sampled, dense and paged, fp32 and int8 KV —
and an evicted sharded request must requeue over the verbatim-token path
exactly like an unsharded one.

These tests need real multi-device placement, so they skip on the tier-1
single-device run and execute under scripts/multidevice.sh, which forces
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
import jax
import pytest

from repro.configs import reduced
from repro.models import model as MD
from repro.serving.engine import InferenceEngine
from repro.serving.sampler import SamplingParams as SP


def _needs(n):
    return pytest.mark.skipif(
        jax.device_count() < n,
        reason=f"needs {n} devices (scripts/multidevice.sh forces 8 host "
               f"devices; tier-1 runs single-device)")


PROMPTS = ["hello sharded world", "carbon aware decode", "ab"]
SAMPLED = SP(temperature=0.9, top_k=40, top_p=0.95)


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced("granite_3_2b").replace(vocab_size=512)
    params = MD.init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine(small_model, tp, *, paged=False, kv_int8=False):
    cfg, params = small_model
    return InferenceEngine(cfg, params, n_slots=4, max_len=64, eos_id=-1,
                           seed=7, decode_block=8, paged=paged,
                           page_size=16, kv_int8=kv_int8, tp_degree=tp)


def _decode_all(eng, *, sampling=None, max_new=10):
    for p in PROMPTS:
        eng.submit(eng.tok.encode(p), max_new_tokens=max_new,
                   sampling=sampling)
    eng.run_to_completion()
    return {f.rid: list(f.token_ids) for f in eng.finished}


@_needs(2)
@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
@pytest.mark.parametrize("kv_int8", [False, True], ids=["fp32", "int8"])
@pytest.mark.parametrize("sampling", [None, SAMPLED],
                         ids=["greedy", "sampled"])
def test_tp2_token_identical(small_model, paged, kv_int8, sampling):
    ref = _decode_all(
        _engine(small_model, 1, paged=paged, kv_int8=kv_int8),
        sampling=sampling)
    e2 = _engine(small_model, 2, paged=paged, kv_int8=kv_int8)
    got = _decode_all(e2, sampling=sampling)
    assert got == ref
    # sharded programs are minted under mesh-keyed names: a tp=2 bucket
    # can never collide with a tp=1 compilation of the same shape
    assert e2.entry_points and all(
        name.endswith("_tp2") for name in e2.entry_points)


@_needs(4)
@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
@pytest.mark.parametrize("sampling", [None, SAMPLED],
                         ids=["greedy", "sampled"])
def test_tp4_token_identical(small_model, paged, sampling):
    # tp=4 over 2 KV heads: the KV store's head axis does not divide, so
    # launch/sharding.py's _guard keeps it replicated while q-heads and
    # the MLP still shard 4 ways — tokens must be unchanged either way
    ref = _decode_all(_engine(small_model, 1, paged=paged),
                      sampling=sampling)
    got = _decode_all(_engine(small_model, 4, paged=paged),
                      sampling=sampling)
    assert got == ref


@_needs(2)
def test_tp_engine_reports_degree(small_model):
    eng = _engine(small_model, 2)
    assert eng.tp_degree == 2
    assert eng.shard_spec is not None
    assert eng.shard_spec.tp_degree == 2
    single = _engine(small_model, 1)
    assert single.tp_degree == 1 and single.shard_spec is None


@_needs(2)
@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_tp2_evict_requeues_verbatim(small_model, paged):
    """Evicting a mid-decode request from a sharded engine and
    resubmitting it regenerates the exact token stream (the migration
    contract: prompt ids are verbatim, redo restarts identically)."""
    ref = _decode_all(_engine(small_model, 1, paged=paged))

    eng = _engine(small_model, 2, paged=paged)
    rids = [eng.submit(eng.tok.encode(p), max_new_tokens=10)
            for p in PROMPTS]
    eng.step()                      # all live, partway through decode
    victim = rids[0]
    st = eng.evict(victim)
    assert st is not None and st.rid == victim
    assert st.prompt_ids == eng.tok.encode(PROMPTS[0])  # verbatim
    eng.run_to_completion()
    # requeue on the same sharded engine with the verbatim prompt
    eng.submit(st.prompt_ids, max_new_tokens=st.max_new_tokens,
               sampling=st.sampling, rid=st.rid)
    eng.run_to_completion()
    got = {f.rid: list(f.token_ids) for f in eng.finished}
    assert got == ref
