"""sproutlint + jaxpr audit (DESIGN.md §11).

Layer 1 fixtures are inline source snippets: for each rule a positive
(the finding fires), a ``# noqa``-suppressed, an allowlisted, and a clean
variant. Layer 2 tests run the f64/donation/scatter/inventory checks on
deliberately broken toy jitted programs — each check must demonstrably
fail on a fixture that violates it (ISSUE 7 acceptance criteria).
"""
from __future__ import annotations

import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import frozen_entry_points
from repro.analysis.findings import (Finding, apply_baseline, load_baseline,
                                     save_baseline)
from repro.analysis.jaxpr_audit import (Recorder, RecordingTable,
                                        check_donation, check_f64,
                                        check_inventory, check_scatter_oob,
                                        expects_donation, load_inventory,
                                        save_inventory)
from repro.analysis.sproutlint import lint_module

HOT = {"*"}


def _lint(src, hot=frozenset(), deterministic=True, allowlist=None):
    kept, allowed = lint_module("fix.py", textwrap.dedent(src), set(hot),
                                deterministic, allowlist)
    return kept, allowed


def _rules(findings):
    return [f.rule for f in findings]


# ---------------------------------------------------------------- SPL001
SYNC_SRC = """
    import jax
    def hot_fn(x):
        return jax.device_get(x)
"""


def test_spl001_positive_device_get():
    kept, _ = _lint(SYNC_SRC, hot=HOT)
    assert _rules(kept) == ["SPL001"]
    assert kept[0].scope == "hot_fn"


def test_spl001_cold_function_is_clean():
    kept, _ = _lint(SYNC_SRC)          # not reachable from a hot root
    assert kept == []


def test_spl001_noqa_suppresses():
    src = """
        import jax
        def hot_fn(x):
            return jax.device_get(x)  # noqa: SPL001
    """
    kept, _ = _lint(src, hot=HOT)
    assert kept == []


def test_spl001_allowlist_budget_is_a_count():
    two = """
        import jax
        def hot_fn(x):
            a = jax.device_get(x)
            b = jax.device_get(x)
            return a, b
    """
    allow = {("fix.py", "hot_fn", "SPL001"): 1}
    kept, allowed = _lint(two, hot=HOT, allowlist=allow)
    # budget of one: first sync sanctioned, second still fires
    assert len(allowed) == 1 and _rules(kept) == ["SPL001"]
    assert "exceeds allowlist budget" in kept[0].message


def test_spl001_item_and_float_jnp():
    src = """
        import jax.numpy as jnp
        def hot_fn(x):
            a = x.item()
            b = float(jnp.sum(x))
            return a, b
    """
    kept, _ = _lint(src, hot=HOT)
    assert _rules(kept) == ["SPL001", "SPL001"]


def test_spl001_float_of_host_value_clean():
    src = """
        def hot_fn(share):
            return float(share.sum())
    """
    kept, _ = _lint(src, hot=HOT)
    assert kept == []


# ---------------------------------------------------------------- SPL002
def test_spl002_read_after_donate():
    src = """
        import jax
        jf = jax.jit(lambda c, x: c, donate_argnums=(0,))
        def run(cache, x):
            out = jf(cache, x)
            return cache.sum()
    """
    kept, _ = _lint(src)
    assert _rules(kept) == ["SPL002"]
    assert "`cache`" in kept[0].message


def test_spl002_rebind_is_clean():
    src = """
        import jax
        jf = jax.jit(lambda c, x: c, donate_argnums=(0,))
        def run(cache, x):
            cache = jf(cache, x)
            return cache.sum()
    """
    kept, _ = _lint(src)
    assert kept == []


def test_spl002_noqa():
    src = """
        import jax
        jf = jax.jit(lambda c, x: c, donate_argnums=(0,))
        def run(cache, x):
            out = jf(cache, x)
            return cache.sum()  # noqa: SPL002
    """
    kept, _ = _lint(src)
    assert kept == []


def test_spl002_attribute_donor_and_target():
    src = """
        import jax
        class Eng:
            def __init__(self):
                self.insert = jax.jit(lambda c, s: c, donate_argnums=(0,))
            def ok(self, slots):
                self.cache = self.insert(self.cache, slots)
                return self.cache
            def bad(self, slots):
                out = self.insert(self.cache, slots)
                return self.cache
    """
    kept, _ = _lint(src)
    assert _rules(kept) == ["SPL002"]
    assert kept[0].scope == "Eng.bad"


# ---------------------------------------------------------------- SPL003
def test_spl003_bare_hash():
    kept, _ = _lint("seed = hash(('a', 1))\n")
    assert _rules(kept) == ["SPL003"]


def test_spl003_set_iteration_and_sorted_exemption():
    src = """
        def f(xs):
            lanes = set(xs)
            for i in lanes:
                print(i)
            return sorted(lanes)
    """
    kept, _ = _lint(src)
    assert _rules(kept) == ["SPL003"]
    src_ok = """
        import numpy as np
        def f(xs):
            lanes = set(xs)
            rows = np.sort(np.fromiter(lanes, np.int64))
            return [i for i in sorted(lanes)], rows
    """
    kept, _ = _lint(src_ok)
    assert kept == []


def test_spl003_wall_clock_only_in_deterministic_paths():
    src = """
        import time
        def f():
            return time.time()
    """
    kept, _ = _lint(src, deterministic=True)
    assert _rules(kept) == ["SPL003"]
    kept, _ = _lint(src, deterministic=False)
    assert kept == []


def test_spl003_stdlib_random():
    src = """
        import random
        def f():
            return random.random()
    """
    kept, _ = _lint(src, deterministic=True)
    assert _rules(kept) == ["SPL003"]


# ---------------------------------------------------------------- SPL004
def test_spl004_inline_jit():
    src = """
        import jax
        def f(x):
            return jax.jit(lambda v: v + 1)(x)
    """
    kept, _ = _lint(src)
    assert _rules(kept) == ["SPL004"]


def test_spl004_jit_in_loop():
    src = """
        import jax
        def f(fns):
            out = []
            for g in fns:
                out.append(jax.jit(g))
            return out
    """
    kept, _ = _lint(src)
    assert _rules(kept) == ["SPL004"]


def test_spl004_unbucketed_entry_point_key():
    src = """
        def f(self, rows, fn):
            self.entry_points[f"decode_bs{len(rows)}"] = fn
    """
    kept, _ = _lint(src)
    assert _rules(kept) == ["SPL004"]


def test_spl004_bucketed_key_is_clean():
    src = """
        import jax
        def f(self, bs, fn):
            jf = jax.jit(fn)
            self.entry_points[f"decode_bs{bs}"] = jf
            return self.entry_points.setdefault(f"decode_bs{bs}", jf)
    """
    kept, _ = _lint(src)
    assert kept == []


# ------------------------------------------------------- baseline format
def test_baseline_round_trip_and_staleness(tmp_path):
    f1 = Finding("SPL003", "a.py", "f", 3, "seed = hash(x)", "m")
    f2 = Finding("SPL001", "b.py", "g", 9, "jax.device_get(x)", "m")
    p = tmp_path / "baseline.json"
    save_baseline(p, [f1, f2])
    keys = load_baseline(p)
    assert len(keys) == 2
    # both findings still fire -> fully absorbed, nothing stale
    new, baselined, stale = apply_baseline([f1, f2], keys)
    assert new == [] and len(baselined) == 2 and stale == []
    # f2 got fixed but its entry remains -> STALE, must fail the lint
    new, baselined, stale = apply_baseline([f1], keys)
    assert new == [] and stale == [f2.key]
    # line-number churn does not invalidate an entry (keyed on snippet)
    moved = Finding("SPL003", "a.py", "f", 31, "seed = hash(x)", "m")
    new, baselined, stale = apply_baseline([moved, f2], keys)
    assert new == [] and stale == []


# ------------------------------------------------------------- jaxpr audit
def test_check_f64_fires_on_promotion():
    def f(x):
        return x * 2.0

    spec32 = jax.ShapeDtypeStruct((4,), jnp.float32)
    assert check_f64(jax.jit(f), (spec32,)) == []
    jax.config.update("jax_enable_x64", True)
    try:
        spec64 = jax.ShapeDtypeStruct((4,), jnp.float64)
        issues = check_f64(jax.jit(f), (spec64,))
    finally:
        jax.config.update("jax_enable_x64", False)
    assert issues and "float64" in issues[0]


def test_check_donation_aliasing():
    def f(c, x):
        return c + x

    donating = jax.jit(f, donate_argnums=(0,))
    plain = jax.jit(f)
    specs = (jax.ShapeDtypeStruct((8,), jnp.float32),
             jax.ShapeDtypeStruct((8,), jnp.float32))
    assert check_donation(donating, specs, expect_donation=True) == []
    assert check_donation(plain, specs, expect_donation=False) == []
    # a program that must donate but doesn't: aliasing missing -> issue
    issues = check_donation(plain, specs, expect_donation=True)
    assert issues and "copy" in issues[0]
    # and the dual: donation where the host still reads the input
    issues = check_donation(donating, specs, expect_donation=False)
    assert issues


def test_check_scatter_oob_semantics():
    idx = jax.ShapeDtypeStruct((3,), jnp.int32)
    val = jax.ShapeDtypeStruct((3,), jnp.float32)
    buf = jax.ShapeDtypeStruct((8,), jnp.float32)

    def drop(b, i, v):
        return b.at[i].set(v)               # default: OOB dropped

    def promised(b, i, v):
        return b.at[i].set(v, mode="promise_in_bounds")

    assert check_scatter_oob(jax.jit(drop), (buf, idx, val)) == []
    issues = check_scatter_oob(jax.jit(promised), (buf, idx, val))
    assert issues and "DROPPED" in issues[0]


def test_inventory_drift_detection(tmp_path):
    audited = {"dense_fp32": ["decode_bs4_k8_full", "insert"]}
    committed = {"dense_fp32": ["decode_bs4_k8_full", "insert"]}
    assert check_inventory(audited, committed) == []
    # missing inventory file is itself a failure
    assert check_inventory(audited, None)
    # a new compiled variant and a dead committed one both fire
    drifted = {"dense_fp32": ["decode_bs4_k8_full", "decode_bs2_k8_temp"]}
    issues = check_inventory(drifted, committed)
    checks = sorted((i.entry, i.check) for i in issues)
    assert checks == [("decode_bs2_k8_temp", "inventory"),
                      ("insert", "inventory")]
    # round-trip through the committed JSON format
    p = tmp_path / "inv.json"
    save_inventory(p, audited)
    assert load_inventory(p) == {k: sorted(v) for k, v in audited.items()}


def test_expected_donation_map():
    assert expects_donation("decode_bs4_k8_full")
    assert expects_donation("mixed_bs4_k4_c4_temp")
    assert expects_donation("insert") and expects_donation("paged_insert")
    assert not expects_donation("prefill_bs4_p16")


def test_recorder_captures_specs_before_donation():
    rec = Recorder()
    table = RecordingTable(rec)
    jf = jax.jit(lambda c: c * 2, donate_argnums=(0,))
    fn = table.setdefault("toy", jf)
    out = fn(jnp.ones((4,), jnp.float32))
    assert float(out[0]) == 2.0
    got_fn, specs = rec.programs["toy"]
    assert got_fn is jf
    assert specs[0] == jax.ShapeDtypeStruct((4,), jnp.float32)
    # specs survive even though the concrete arg buffer was donated:
    # retracing from them must work
    assert check_f64(got_fn, specs) == []
    # second dispatch does not re-record or double-wrap
    fn2 = table.setdefault("toy", jf)
    assert fn2 is fn and len(rec.programs) == 1


def test_frozen_entry_points_guard():
    class FakeEngine:
        entry_points = {"decode_bs4_k8_full": object()}

    eng = FakeEngine()
    with frozen_entry_points(eng):
        pass                                   # stable table: fine
    with pytest.raises(AssertionError, match="decode_bs2"):
        with frozen_entry_points(eng, "measured window"):
            eng.entry_points = dict(eng.entry_points,
                                    decode_bs2_k8_temp=object())
