"""Carbon traces (Table II calibration), Eq. 1 accounting, workload model."""
import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.carbon import (REGIONS, SEASONS, carbon_intensity_trace,
                               request_carbon)
from repro.core.energy import A100_40GB, LLAMA2_7B, LLAMA2_13B, EnergyModel
from repro.core.workload import N_LEVELS, TASKS, Workload


@pytest.mark.parametrize("region", list(REGIONS))
@pytest.mark.parametrize("season", SEASONS)
def test_trace_within_annual_bounds(region, season):
    r = REGIONS[region]
    tr = carbon_intensity_trace(region, season, hours=24 * 28)
    assert tr.min() >= r.ci_min - 1e-9
    assert tr.max() <= r.ci_max + 1e-9
    assert tr.std() > 0.02 * (r.ci_max - r.ci_min)  # actually varies


def test_trace_deterministic():
    a = carbon_intensity_trace("CA", "jun")
    b = carbon_intensity_trace("CA", "jun")
    np.testing.assert_array_equal(a, b)


def test_trace_pinned_values():
    """Regression for the salted-hash seeding bug: traces are seeded from a
    stable digest, so these exact values hold on every machine and under
    every PYTHONHASHSEED. If this fails, the seeding scheme changed and
    every downstream 'deterministic per (region, season)' claim broke."""
    ca = carbon_intensity_trace("CA", "jun")
    np.testing.assert_allclose(
        ca[:3], [153.649541732424, 148.20864970912868, 148.92312928014482],
        rtol=0, atol=1e-9)
    np.testing.assert_allclose(ca[100], 139.7275458948663, rtol=0, atol=1e-9)
    np.testing.assert_allclose(carbon_intensity_trace("TX", "feb")[0],
                               379.1893120650777, rtol=0, atol=1e-9)


def test_trace_identical_across_hash_seeds():
    """Bit-identical across fresh interpreters with different
    PYTHONHASHSEED (the old ``abs(hash((region, season)))`` seeding was
    salted per process)."""
    snippet = ("from repro.core.carbon import carbon_intensity_trace as t;"
               "print(t('CA', 'jun')[:4].tobytes().hex())")
    outs = []
    for seed in ("0", "12345"):
        env = dict(os.environ, PYTHONPATH="src", PYTHONHASHSEED=seed)
        r = subprocess.run([sys.executable, "-c", snippet], env=env,
                           capture_output=True, text=True, timeout=120,
                           cwd=os.path.dirname(os.path.dirname(__file__)))
        assert r.returncode == 0, r.stderr
        outs.append(r.stdout.strip())
    assert outs[0] == outs[1]
    want = carbon_intensity_trace("CA", "jun")[:4].tobytes().hex()
    assert outs[0] == want


def test_request_carbon_eq1():
    # C = CI * E * PUE + embodied/lifetime * t
    c = request_carbon(100.0, 2.0, 10.0, 150_000.0, 1.5e8, pue=1.2)
    assert c == pytest.approx(100 * 2 * 1.2 + 150_000 / 1.5e8 * 10)


def test_energy_model_paper_anchors():
    em = EnergyModel(A100_40GB)
    # Fig 2b: carbon/energy linear in generated tokens
    e100 = em.request_energy_kwh(LLAMA2_13B, 200, 100)
    e200 = em.request_energy_kwh(LLAMA2_13B, 200, 200)
    e400 = em.request_energy_kwh(LLAMA2_13B, 200, 400)
    d1, d2 = e200 - e100, (e400 - e200) / 2
    assert d2 == pytest.approx(d1, rel=0.25)  # near-linear slope
    # Fig 2a: 13B costs ~1.8x 7B per token
    r = em.request_energy_kwh(LLAMA2_13B, 100, 200) / \
        em.request_energy_kwh(LLAMA2_7B, 100, 200)
    assert 1.4 < r < 2.3


def test_workload_request_structure():
    w = Workload(seed=3)
    r = w.sample_request(5.0)
    assert r.task in TASKS
    assert len(r.gen_tokens) == N_LEVELS
    # directives shorten generation: L0 >= L1 >= L2
    assert r.gen_tokens[0] >= r.gen_tokens[1] >= r.gen_tokens[2]
    assert 0 <= r.preferred < N_LEVELS


def test_mixture_normalized_and_rps_positive():
    w = Workload(seed=0)
    for t in (0.0, 7.5, 13.0, 22.0):
        mix = w.mixture(t)
        assert sum(mix.values()) == pytest.approx(1.0)
        assert w.rps(t) > 0


def test_judge_head_to_head_consistency():
    w = Workload(seed=1)
    rng = np.random.default_rng(0)
    r = w.sample_request(0.0)
    wins = sum(r.judge_prefers(rng, r.preferred, (r.preferred + 1) % 3)
               for _ in range(300))
    assert wins > 250  # judge prefers the preferred level ~97% of the time


@given(st.integers(0, 10_000))
def test_judge_pick_is_valid_level(seed):
    w = Workload(seed=seed % 50)
    rng = np.random.default_rng(seed)
    r = w.sample_request(seed * 0.1)
    assert 0 <= r.judge_pick(rng) < N_LEVELS
    assert r.judge_pick(rng, [1, 2]) in (1, 2)
