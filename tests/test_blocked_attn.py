"""Flash-in-XLA attention: fwd/bwd vs naive, padding, windows (property)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models.blocked_attn import flash_sdpa, _pair_schedule


def naive(q, k, v, qp, kp, causal=True, window=0):
    d = q.shape[-1]
    s = jnp.einsum("btkgd,bskd->bkgts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    m = (kp[:, None, :] >= 0) & (qp[:, :, None] >= 0)
    if causal:
        m &= kp[:, None, :] <= qp[:, :, None]
    if window > 0:
        m &= kp[:, None, :] > qp[:, :, None] - window
    s = jnp.where(m[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    p = jnp.where(m[:, None, None], p, 0.0)
    return jnp.einsum("bkgts,bskd->btkgd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


@settings(max_examples=12, deadline=None)
@given(st.sampled_from([64, 96, 130]), st.sampled_from([16, 32]),
       st.sampled_from([0, 24]), st.booleans(), st.integers(0, 5))
def test_flash_matches_naive_fwd_bwd(T, bq, window, causal, seed):
    if window and not causal:
        window = 0
    B, KV, G, D = 2, 2, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, T, KV, G, D))
    k = jax.random.normal(ks[1], (B, T, KV, D))
    v = jax.random.normal(ks[2], (B, T, KV, D))
    qp = jnp.broadcast_to(jnp.arange(T), (B, T))
    qp = qp.at[0, -3:].set(-1)   # ragged row
    f = lambda q, k, v: flash_sdpa(q, k, v, qp, qp, causal=causal,
                                   window=window, block_q=bq, block_k=bq)
    g = lambda q, k, v: naive(q, k, v, qp, qp, causal, window)
    np.testing.assert_allclose(np.asarray(f(q, k, v)),
                               np.asarray(g(q, k, v)), rtol=2e-5, atol=2e-5)
    l1 = jax.grad(lambda *a: (f(*a) ** 2).sum(), (0, 1, 2))(q, k, v)
    l2 = jax.grad(lambda *a: (g(*a) ** 2).sum(), (0, 1, 2))(q, k, v)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


def test_causal_schedule_is_half():
    qis, kis, first = _pair_schedule(8, 8, True, 0, 64, 64)
    assert len(qis) == 8 * 9 // 2     # lower triangle only: T^2/2 flops
    assert all(k <= q for q, k in zip(qis, kis))
    assert first[0] and first.sum() == 8


def test_window_schedule_is_banded():
    qis, kis, _ = _pair_schedule(16, 16, True, 128, 64, 64)
    # window 128 / block 64 -> at most 3+1 live k-blocks per q block
    from collections import Counter
    per_q = Counter(qis.tolist())
    assert max(per_q.values()) <= 4
