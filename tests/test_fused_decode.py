"""Device-resident fused decode loop: multi-token stepping must be
observationally identical to single-token stepping, with one host sync per
block and no per-slot Python sampling fallback."""
import jax
import pytest

from repro.configs import reduced
from repro.models import model as MD
from repro.serving import (ByteTokenizer, InferenceEngine, SamplingParams)


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced("granite_3_2b").replace(vocab_size=512)
    params = MD.init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _run(cfg, params, decode_block, reqs, n_slots=2, max_len=64):
    eng = InferenceEngine(cfg, params, n_slots=n_slots, max_len=max_len,
                          decode_block=decode_block)
    tok = ByteTokenizer()
    for prompt, mnt in reqs:
        eng.submit(tok.encode(prompt), max_new_tokens=mnt)
    fin = eng.run_to_completion()
    return eng, fin


REQS = [("alpha prompt", 20), ("b", 3), ("c c c", 3), ("dddd", 11),
        ("e", 7)]


def test_multi_step_matches_single_step(small_model):
    """K=1 and K=8 produce identical token ids, finished order, and token
    accounting on a greedy workload."""
    cfg, params = small_model
    _, fin1 = _run(cfg, params, 1, REQS)
    _, fin8 = _run(cfg, params, 8, REQS)
    assert [f.rid for f in fin1] == [f.rid for f in fin8]
    for a, b in zip(fin1, fin8):
        assert a.token_ids == b.token_ids
        assert a.text == b.text
        assert a.prompt_tokens == b.prompt_tokens
        assert a.gen_tokens == b.gen_tokens


def test_latency_bookkeeping_sane_any_block_size(small_model):
    cfg, params = small_model
    max_budget = max(m for _, m in REQS)
    for K in (1, 4, 8):
        _, fin = _run(cfg, params, K, REQS)
        assert len(fin) == len(REQS)
        for f in fin:
            assert f.ttft_s >= 0
            assert f.latency_s >= f.ttft_s
            assert 1 <= f.gen_tokens <= max_budget


def test_one_sync_per_block(small_model):
    """Steady-state decode performs >= decode_block tokens per device_get."""
    cfg, params = small_model
    eng = InferenceEngine(cfg, params, n_slots=4, max_len=64, decode_block=8)
    tok = ByteTokenizer()
    for i in range(4):
        eng.submit(tok.encode(f"prompt {i}"), max_new_tokens=33)
    eng.run_to_completion()
    assert eng.decode_syncs > 0
    assert eng.decode_tokens / eng.decode_syncs >= 8


def test_mixed_sampled_and_greedy_one_batch(small_model):
    """Sampled and greedy requests decode in the same fused batch."""
    cfg, params = small_model
    eng = InferenceEngine(cfg, params, n_slots=2, max_len=64, decode_block=8)
    tok = ByteTokenizer()
    eng.submit(tok.encode("greedy req"), max_new_tokens=12)
    eng.submit(tok.encode("sampled req"), max_new_tokens=12,
               sampling=SamplingParams(temperature=1.0, top_k=50, top_p=0.9))
    fin = eng.run_to_completion()
    assert len(fin) == 2
    assert all(1 <= f.gen_tokens <= 12 for f in fin)
    # the greedy request must be unaffected by its sampled neighbour
    eng2 = InferenceEngine(cfg, params, n_slots=2, max_len=64, decode_block=8)
    eng2.submit(tok.encode("greedy req"), max_new_tokens=12)
    solo = eng2.run_to_completion()[0]
    paired = next(f for f in fin if f.rid == min(x.rid for x in fin))
    assert paired.token_ids == solo.token_ids


def test_rid_monotonic_no_collision(small_model):
    """Auto-assigned rids never repeat, even after requests finish."""
    cfg, params = small_model
    eng = InferenceEngine(cfg, params, n_slots=2, max_len=64)
    tok = ByteTokenizer()
    rids = [eng.submit(tok.encode(f"x{i}"), max_new_tokens=2)
            for i in range(3)]
    eng.run_to_completion()
    rids += [eng.submit(tok.encode(f"y{i}"), max_new_tokens=2)
             for i in range(3)]
    eng.run_to_completion()
    assert len(set(rids)) == len(rids) == 6
    assert sorted(f.rid for f in eng.finished) == sorted(rids)


def test_submit_rejects_impossible_budget(small_model):
    cfg, params = small_model
    eng = InferenceEngine(cfg, params, n_slots=1, max_len=32)
    tok = ByteTokenizer()
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(tok.encode("hello"), max_new_tokens=31)
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(tok.encode("hello"), max_new_tokens=100)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit([], max_new_tokens=4)
    # boundary: max_new_tokens + 1 == max_len - 1 leaves a 1-token prompt
    eng.submit(tok.encode("hello"), max_new_tokens=30)
    fin = eng.run_to_completion()
    assert fin and fin[0].prompt_tokens == 1


def test_long_prompt_truncated_not_empty(small_model):
    cfg, params = small_model
    eng = InferenceEngine(cfg, params, n_slots=1, max_len=48)
    tok = ByteTokenizer()
    eng.submit(tok.encode("z" * 200), max_new_tokens=8)
    fin = eng.run_to_completion()
    assert fin[0].prompt_tokens == 48 - 8 - 1
    assert 1 <= fin[0].gen_tokens <= 8


def test_sampled_run_reproducible_same_seed(small_model):
    cfg, params = small_model
    outs = []
    for _ in range(2):
        eng = InferenceEngine(cfg, params, n_slots=2, max_len=64, seed=9)
        tok = ByteTokenizer()
        eng.submit(tok.encode("stochastic"), max_new_tokens=10,
                   sampling=SamplingParams(temperature=1.0))
        outs.append(tuple(eng.run_to_completion()[0].token_ids))
    assert outs[0] == outs[1]
