"""Training substrate: convergence, schedules, optimizers, checkpointing,
distributed primitives (multi-device parts run in a subprocess so the
512-device flag never leaks into this process)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced
from repro.training import (AdamWConfig, SyntheticLM, checkpoint,
                            make_train_step, train_state_init, wsd_schedule)
from repro.training.optimizer import (adafactor_init, adafactor_update,
                                      cosine_schedule)


def test_loss_decreases():
    cfg = reduced("llama2_13b")
    st = train_state_init(cfg, jax.random.PRNGKey(0))
    src = SyntheticLM(cfg.vocab_size, seed=1)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=3e-3), microbatches=2))
    losses = []
    for i in range(20):
        b = {k: jnp.asarray(v) for k, v in src.batch(i, 8, 32).items()}
        st.params, st.opt, m = step(st.params, st.opt, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3
    assert np.isfinite(losses).all()


def test_microbatching_matches_full_batch():
    cfg = reduced("granite_3_2b")
    st = train_state_init(cfg, jax.random.PRNGKey(1))
    src = SyntheticLM(cfg.vocab_size, seed=2)
    b = {k: jnp.asarray(v) for k, v in src.batch(0, 8, 16).items()}
    s1 = make_train_step(cfg, AdamWConfig(lr=1e-3), microbatches=1)
    s2 = make_train_step(cfg, AdamWConfig(lr=1e-3), microbatches=4)
    p1, _, m1 = s1(st.params, st.opt, b)
    p2, _, m2 = s2(st.params, st.opt, b)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    for a, c in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32),
                                   rtol=2e-3, atol=2e-4)


def test_schedules():
    f = cosine_schedule(100, warmup=10)
    assert float(f(0)) == 0.0
    assert float(f(10)) == pytest.approx(1.0)
    assert float(f(100)) == pytest.approx(0.1, abs=1e-6)
    g = wsd_schedule(100, warmup=10, decay_frac=0.2)
    assert float(g(50)) == pytest.approx(1.0)       # stable plateau
    assert float(g(99)) < 0.15                      # decayed tail
    assert float(g(5)) == pytest.approx(0.5)        # warmup


def test_adafactor_trains_and_is_small():
    cfg = reduced("kimi_k2_1t_a32b")
    params = jax.tree.map(jnp.asarray,
                          __import__("repro.models.model", fromlist=["m"]
                                     ).init_model(cfg, jax.random.PRNGKey(2)))
    opt = adafactor_init(params)
    pbytes = sum(x.size * 4 for x in jax.tree.leaves(params))
    obytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(opt))
    assert obytes < 0.25 * pbytes          # factored states are small
    g = jax.tree.map(lambda p: jnp.ones_like(p, jnp.float32) * 0.01, params)
    p2, opt2, _ = adafactor_update(AdamWConfig(lr=1e-3), g, opt, params)
    delta = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert 0 < delta < 1.0


def test_checkpoint_roundtrip_and_reshard(tmp_path):
    cfg = reduced("granite_3_2b")
    st = train_state_init(cfg, jax.random.PRNGKey(3))
    d = str(tmp_path / "ckpt")
    checkpoint.save({"params": st.params}, d, step=7, n_shards=4)
    assert checkpoint.latest_step(d) == 7
    # restore with a different (elastic) shard count target
    restored = checkpoint.restore(d, {"params": st.params})
    for a, b in zip(jax.tree.leaves(st.params),
                    jax.tree.leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # atomic save: second save overwrites cleanly
    checkpoint.save({"params": st.params}, d, step=8, n_shards=2)
    assert checkpoint.latest_step(d) == 8


_DIST_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
from functools import partial
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import shard_map_compat as shard_map
from repro.training import distributed

mesh = jax.make_mesh((8,), ("data",))
g = {"a": jax.random.normal(jax.random.PRNGKey(1), (8, 64)),
     "b": jax.random.normal(jax.random.PRNGKey(2), (8, 33))}
exact = jax.tree.map(lambda x: jnp.broadcast_to(x.sum(0, keepdims=True), x.shape), g)

f1 = shard_map(lambda t: distributed.bucketed_psum(t, "data"),
               mesh=mesh, in_specs=P("data"), out_specs=P("data"), check_vma=False)
r1 = f1(g)
for k in g:
    np.testing.assert_allclose(np.asarray(r1[k]), np.asarray(exact[k]), rtol=1e-5, atol=1e-5)

f2 = shard_map(lambda t: distributed.compressed_psum(t, "data"),
               mesh=mesh, in_specs=P("data"), out_specs=P("data"), check_vma=False)
r2 = f2(g)
for k in g:
    rel = float(jnp.max(jnp.abs(r2[k]-exact[k]))) / float(jnp.max(jnp.abs(exact[k])))
    assert rel < 0.05, rel

def per_step(step):
    f3 = shard_map(lambda t: distributed.periodic_sync(t, "data", step, 4),
                   mesh=mesh, in_specs=P("data"), out_specs=P("data"), check_vma=False)
    return f3(g)
synced = per_step(8)     # 8 % 4 == 0 -> mean across axis
local = per_step(9)      # no sync
mean = jax.tree.map(lambda x: x.mean(0, keepdims=True), g)
np.testing.assert_allclose(np.asarray(synced["a"][0]), np.asarray(mean["a"][0]), rtol=1e-5, atol=1e-5)
np.testing.assert_allclose(np.asarray(local["a"]), np.asarray(g["a"]), rtol=1e-6)
print("DIST_OK")
"""


def test_distributed_primitives_subprocess():
    # the snippet goes through shard_map_compat (launch/mesh.py), which
    # maps the jax>=0.5 check_vma keyword onto 0.4.x check_rep — this was
    # an xfail from PR 4 to PR 9 (DESIGN.md §9). JAX_PLATFORMS must stay
    # pinned to cpu: an unpinned jax probes for TPU hardware and spends
    # minutes in metadata-fetch retries on CPU-only containers, while the
    # forced host device count only applies to the CPU platform anyway.
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", _DIST_SNIPPET], env=env,
                       capture_output=True, text=True, timeout=420,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "DIST_OK" in r.stdout, r.stdout + r.stderr
